"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Three terms, in seconds, for a step on the target TPU v5e pod:

    compute    = HLO_FLOPs_total   / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_total   / (chips × 819e9  B/s HBM)
    collective = collective_bytes  / (chips × 50e9   B/s ICI link)

``cost_analysis()`` on the SPMD-partitioned executable reports the
*per-device* program; we report totals (× num chips) and divide back per
the formulas above. Collective bytes are NOT in cost_analysis: we parse the
post-SPMD HLO text and sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (the per-device payload —
we deliberately do not model algorithm factors like ring 2(n-1)/n; the
relative comparisons that drive §Perf are unaffected).

MODEL_FLOPS (the "useful work" yardstick): 6·N·D for training, 2·N·D for
prefill, 2·N_active·B for one decode token; MoE archs use active params.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import jax
import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of dicts (per computation), newer
    returns the dict directly; normalize to the dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'bf16[8,128]{...}'-style shape (tuples: sum parts)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind from post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        # lines look like:  %x = bf16[...] all-reduce(bf16[...] %y), ...
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+([a-z\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[:-6]
        if op in _COLLECTIVES:
            out[op] += _shape_bytes(m.group(1))
            out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def count_params(params_shape: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_shape))


def count_active_params(cfg, params_shape: Any) -> int:
    """MoE-aware: expert weights count at top_k/n_experts utilization."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    total = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        ps = jax.tree_util.keystr(path)
        if cfg.moe and "moe" in ps and any(
                w in ps for w in ("w_in", "w_out", "w_gate")):
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return total


def model_flops(cfg, params_shape: Any, kind: str, tokens: int) -> float:
    n_active = count_active_params(cfg, params_shape)
    # embedding lookups are gathers, not FLOPs: subtract the embed table
    embed = cfg.vocab * cfg.d_model
    n_mm = max(n_active - embed, 1)
    if kind == "train":
        return 6.0 * n_mm * tokens
    return 2.0 * n_mm * tokens          # prefill / decode forward


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_total: float
    bytes_total: float
    coll_bytes_per_chip: float
    coll_count: int
    model_flops: float
    mem_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_total / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_total / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops_total, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based fraction of peak at the step's critical time."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / max(t, 1e-30)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            compiled, cfg, params_shape, kind: str, tokens: int,
            hlo_text: Optional[str] = None) -> Roofline:
    cost = cost_analysis_dict(compiled)
    # cost_analysis is per-device on the partitioned module
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_total=flops_dev * chips, bytes_total=bytes_dev * chips,
        coll_bytes_per_chip=float(coll["total"]), coll_count=coll["count"],
        model_flops=model_flops(cfg, params_shape, kind, tokens),
        mem_per_device=mem)
