"""PSO × LM integration: the paper's optimizer tunes the training
hyperparameters of an assigned-architecture LM (smoke scale on CPU).

Each particle is (log10 lr, warmup fraction, weight decay); fitness is the
negative loss of a short probe run on the synthetic pipeline. This is the
black-box tuner from DESIGN.md §3 — at pod scale each probe is itself a
distributed job and the swarm logic is unchanged.

    PYTHONPATH=src python examples/tune_lm_hparams.py --arch stablelm-3b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import PSOTuner, SearchDim
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import zoo


def make_probe(arch: str, probe_steps: int = 8, batch: int = 4,
               seq: int = 64):
    cfg = get_arch(arch).smoke()
    params0 = zoo.init_params(cfg, jax.random.key(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=7))
    batches = [
        {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        for i in range(probe_steps)
    ]

    def probe(hp) -> float:
        step, opt_init = make_train_step(
            cfg, base_lr=hp["lr"],
            warmup=max(1, int(hp["warmup_frac"] * probe_steps)),
            total_steps=probe_steps)
        jstep = jax.jit(step)
        params, opt = params0, opt_init(params0)
        loss = None
        for b in batches:
            params, opt, m = jstep(params, opt, b)
            loss = float(m["loss"])
            if not jnp.isfinite(loss):
                return -1e9               # diverged: worst fitness
        return -loss                      # maximize −loss

    return probe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--particles", type=int, default=6)
    ap.add_argument("--iters", type=int, default=4)
    args = ap.parse_args()

    dims = [
        SearchDim("lr", 1e-5, 1e-2, log=True),
        SearchDim("warmup_frac", 0.05, 0.5),
        SearchDim("wd", 0.0, 0.1),
    ]
    tuner = PSOTuner(dims, particles=args.particles, seed=0)
    probe = make_probe(args.arch)
    result = tuner.run(probe, iters=args.iters,
                       callback=lambda it, t: print(
                           f"iter {it}: best probe loss "
                           f"{-t.gbest_fit:.4f}"))
    print(f"\nbest hyperparameters after {result.evaluations} probes:")
    for k, v in result.best_params.items():
        print(f"  {k} = {v:.5g}")
    print(f"best probe loss = {-result.best_fitness:.4f}")


if __name__ == "__main__":
    main()
