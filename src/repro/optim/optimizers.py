"""Optimizers (hand-rolled, pytree-generic, sharding-transparent).

Adam: fp32 m/v states. Adafactor: factored second moment (row/col fp32
vectors) + bf16 momentum — the memory-viable choice for the ≥100B assigned
archs (arctic-480b, qwen1.5-110b, llava-next-34b): states shrink from
8 bytes/param to ~2 bytes/param (DESIGN.md §6).

States mirror the param tree structure, so pjit shards them exactly like
the parameters without extra annotations.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


# ---------------------------------------------------------------------------
# SGD (momentum)
# ---------------------------------------------------------------------------

def sgd_init(params: Params) -> OptState:
    mom = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), mom)


def sgd_update(params, grads, state: OptState, lr, *, momentum=0.9,
               weight_decay=0.0):
    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + g
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    out = jax.tree.map(upd, params, grads, state.inner)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(state.step + 1, new_m)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def adam_init(params: Params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(jnp.zeros((), jnp.int32),
                    {"m": jax.tree.map(zeros, params),
                     "v": jax.tree.map(zeros, params)})


def adam_update(params, grads, state: OptState, lr, *, b1=0.9, b2=0.95,
                eps=1e-8, weight_decay=0.0):
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    triples = jax.tree.map(upd, params, grads, state.inner["m"],
                           state.inner["v"])
    is3 = lambda t: isinstance(t, tuple)
    new_p = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
    return new_p, OptState(step, {"m": new_m, "v": new_v})


# ---------------------------------------------------------------------------
# Adafactor (factored 2nd moment, bf16 momentum)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor_init(params: Params) -> OptState:
    def state_for(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    "m": jnp.zeros_like(p, dtype=jnp.bfloat16)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32),
                "m": jnp.zeros_like(p, dtype=jnp.bfloat16)}

    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(state_for, params))


def adafactor_update(params, grads, state: OptState, lr, *, b2=0.999,
                     b1=0.9, eps=1e-30, clip=1.0, weight_decay=0.0):
    step = state.step + 1

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in s:
            vr = b2 * s["vr"] + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * s["vc"] + (1 - b2) * g2.mean(axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None],
                              eps))
            u = g / jnp.maximum(denom, eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = b2 * s["v"] + (1 - b2) * g2
            u = g / (jnp.sqrt(v) + 1e-8)
            new_s = {"v": v}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        m = b1 * s["m"].astype(jnp.float32) + (1 - b1) * u
        if weight_decay:
            m = m + weight_decay * p.astype(jnp.float32)
        new_s["m"] = m.astype(jnp.bfloat16)
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), new_s

    isleaf = lambda t: isinstance(t, dict) and ("v" in t or "vr" in t)
    pairs = jax.tree.map(upd, params, grads, state.inner, is_leaf=None)
    is2 = lambda t: isinstance(t, tuple)
    new_p = jax.tree.map(lambda t: t[0], pairs, is_leaf=is2)
    new_s = jax.tree.map(lambda t: t[1], pairs, is_leaf=is2)
    return new_p, OptState(step, new_s)


def get_optimizer(name: str) -> Tuple[Callable, Callable]:
    return {"adam": (adam_init, adam_update),
            "adafactor": (adafactor_init, adafactor_update),
            "sgd": (sgd_init, sgd_update)}[name]
