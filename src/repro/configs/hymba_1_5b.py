"""hymba-1.5b — hybrid: every layer runs attention and mamba(SSD) heads in
parallel and fuses their outputs; sliding-window attention except 3 global
layers; 128 learned meta tokens prepended. [arXiv:2411.13676; hf]

Sub-quadratic (SWA + SSM) ⇒ runs the long_500k cell.
"""
from .base import ArchConfig, register

HYMBA_1_5B = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    hybrid_ssm=True, ssm_state=16, ssm_heads=25, ssm_expand=2,
    swa_window=1024, global_attn_layers=(0, 16, 31),
    meta_tokens=128,
    source="arXiv:2411.13676",
))
