"""Diff two BENCH_pso.json artifacts (benchmarks/run.py output).

Matches records by ``name``, reports the per-record ``us_per_call`` delta,
and exits nonzero when any shared record regressed beyond ``--threshold``
(fractional; 0.3 = 30% slower). Records with ``us_per_call == 0`` are
quality-only (e.g. the async_sweep jnp leg) and are compared on their
derived values informationally, never gated.

    python benchmarks/compare.py OLD.json NEW.json [--threshold 0.3]
        [--warn-only] [--top 20] [--gate async_sweep/,table3/]
        [--gate-threshold 0.15]

``--warn-only`` prints the same report but always exits 0 for the
non-gated records — the CI trend step runs in this mode against the
committed baseline, since cross-machine absolute deltas are noisy.

``--gate`` names record prefixes that HARD-FAIL (exit 2) when they
regress beyond ``--gate-threshold``, even under ``--warn-only`` — the
promoted gate for the paper-critical records (async_sweep, table3) and,
as refreshed-baseline cycles confirmed their noise floors, the
custom_objective, islands_ring, mixed_traffic, autotune and constrained
records (see .github/workflows/ci.yml for the armed list). The
gate only arms when the two artifacts are comparable: same ``smoke`` mode
and same ``host`` (recorded in the meta); otherwise it downgrades to a
warning, because a threshold this tight is only meaningful for
same-runner A/Bs. CI keeps it armed by auto-refreshing the committed
baseline from the same job on main (see .github/workflows/ci.yml), so
after one merge the baseline tracks the CI runner.

Records matching ``WARN_ONLY_PREFIXES`` (currently the ``telemetry/``
overhead suite and the ``portfolio/`` update-rule suite) are
reported but can never fail the run, gated or not — see the constant
below for the promotion path.
"""
from __future__ import annotations

import argparse
import json
import sys

#: Record-name prefixes that are reported but never fail the run — not
#: even under ``--gate``. The ``telemetry/`` records are fresh (this PR)
#: and time the counter plumbing's overhead-when-disabled — CI asserts
#: the derived overhead ratio directly, so the wall-clock record has no
#: baseline-refresh history yet; the ``portfolio/`` per-rule us/iter is
#: in the same position. Until they have a few cycles of noise-floor
#: history they stay warn-only. Promote by removing the prefix here and
#: adding it to the CI gate list (the path ``autotune/``,
#: ``constrained/`` and now ``serving/`` took — all armed in
#: .github/workflows/ci.yml).
WARN_ONLY_PREFIXES = ("telemetry/", "portfolio/")


def load(path):
    with open(path) as f:
        doc = json.load(f)
    recs = {r["name"]: r for r in doc.get("benchmarks", [])}
    return doc.get("meta", {}), recs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", help="baseline BENCH_pso.json")
    ap.add_argument("new", help="candidate BENCH_pso.json")
    ap.add_argument("--threshold", type=float, default=0.3,
                    help="max tolerated fractional us/call regression")
    ap.add_argument("--warn-only", action="store_true",
                    help="report but always exit 0")
    ap.add_argument("--top", type=int, default=20,
                    help="show at most this many rows (worst first)")
    ap.add_argument("--gate", default="",
                    help="comma-separated record-name prefixes that hard-"
                         "fail on regression beyond --gate-threshold, even "
                         "under --warn-only")
    ap.add_argument("--gate-threshold", type=float, default=0.15,
                    help="max tolerated fractional regression for --gate "
                         "records")
    args = ap.parse_args()

    old_meta, old = load(args.old)
    new_meta, new = load(args.new)
    for side, meta in (("old", old_meta), ("new", new_meta)):
        print(f"# {side}: backend={meta.get('backend')} "
              f"jax={meta.get('jax_version')} smoke={meta.get('smoke')} "
              f"interpret={meta.get('pallas_interpret')}")
    if old_meta.get("smoke") != new_meta.get("smoke"):
        print("# note: smoke flags differ — deltas are indicative only")

    shared = sorted(set(old) & set(new))
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    rows = []
    for name in shared:
        a, b = old[name]["us_per_call"], new[name]["us_per_call"]
        if a <= 0 or b <= 0:
            continue                      # quality-only record
        rows.append((b / a - 1.0, name, a, b))
    rows.sort(reverse=True)

    print(f"\n{'delta':>8s}  {'old us':>12s}  {'new us':>12s}  name")
    for delta, name, a, b in rows[:args.top]:
        flag = " <-- REGRESSION" if delta > args.threshold else ""
        print(f"{100 * delta:+7.1f}%  {a:12.3f}  {b:12.3f}  {name}{flag}")
    if len(rows) > args.top:
        print(f"... ({len(rows) - args.top} more)")
    if added:
        print(f"# {len(added)} new records: {', '.join(added[:6])}"
              + (" ..." if len(added) > 6 else ""))
    if removed:
        print(f"# {len(removed)} removed records: {', '.join(removed[:6])}"
              + (" ..." if len(removed) > 6 else ""))

    rc = 0
    warn_only = [r for r in rows
                 if any(r[1].startswith(p) for p in WARN_ONLY_PREFIXES)]
    rows = [r for r in rows if r not in warn_only]
    if warn_only:
        bad = [r for r in warn_only if r[0] > args.threshold]
        print(f"# {len(warn_only)} warn-only records "
              f"({', '.join(WARN_ONLY_PREFIXES)}): "
              f"{len(bad)} beyond threshold, never gated")
    worst = [r for r in rows if r[0] > args.threshold]
    if worst:
        print(f"\n{len(worst)}/{len(rows)} records regressed more than "
              f"{100 * args.threshold:.0f}%")
        if not args.warn_only:
            rc = 1
        else:
            print("(warn-only mode: not failing on these)")
    else:
        print(f"\nno record regressed more than "
              f"{100 * args.threshold:.0f}% ({len(rows)} compared)")

    prefixes = [p for p in args.gate.split(",") if p]
    if prefixes:
        gated = [r for r in rows
                 if any(r[1].startswith(p) for p in prefixes)]
        failed = [r for r in gated if r[0] > args.gate_threshold]
        comparable = (old_meta.get("smoke") == new_meta.get("smoke")
                      and old_meta.get("host") == new_meta.get("host")
                      and old_meta.get("host") is not None)
        print(f"# gate: {len(gated)} records under {prefixes}, "
              f"{len(failed)} beyond {100 * args.gate_threshold:.0f}%")
        if failed:
            for delta, name, a, b in failed:
                print(f"# GATED REGRESSION {100 * delta:+.1f}%  {name}")
            if comparable:
                return 2
            print("# (gate disarmed: artifacts differ in smoke mode or "
                  "host — not a same-runner A/B)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
