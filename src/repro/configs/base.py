"""Architecture config system: one frozen dataclass describes every assigned
architecture; a registry maps ``--arch <id>`` to its exact config and a
smoke-reduced variant for CPU tests.

Input-shape cells (assigned set): train_4k / prefill_32k / decode_32k /
long_500k. ``decode_*``/``long_*`` lower ``serve_step`` (1 new token against
a KV/recurrent cache of ``seq_len``); the others lower ``train_step`` /
``prefill``. long_500k is defined only for sub-quadratic archs
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    act: str = "silu"                       # mlp activation
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False            # arctic: dense FFN in parallel
    dense_residual_ff: int = 0              # width of the parallel dense FFN
    moe_group_tokens: int = 4096            # dispatch group size
    moe_expert_sharding: str = "tp"         # tp (baseline) | ep (§Perf)
    # --- MLA (minicpm3) ---
    mla: bool = False
    q_rank: int = 768
    kv_rank: int = 256
    rope_head_dim: int = 32
    nope_head_dim: int = 64
    v_head_dim: int = 64
    # --- hybrid (hymba): parallel attention + mamba heads ---
    hybrid_ssm: bool = False
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128                    # GLA/SSD chunk length (§Perf)
    swa_window: int = 0                     # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()  # layers with full attn
    meta_tokens: int = 0
    # --- xLSTM ---
    xlstm: bool = False
    slstm_group: int = 0                    # 1 sLSTM per `slstm_group` blocks
    # --- enc-dec (whisper) ---
    encdec: bool = False
    enc_layers: int = 0
    # --- vlm (llava) ---
    vision_prefix: int = 0                  # precomputed patch embeds (stub)
    # --- execution knobs (perf-tunable, see EXPERIMENTS.md §Perf) ---
    remat: str = "full"                     # nothing | dots | full
    loss_chunk: int = 2048                  # vocab-xent sequence chunking
    attn_q_block: int = 1024
    attn_kv_block: int = 1024
    flash_custom_vjp: bool = False    # hand-written flash backward (§Perf)
    row_parallel_out: bool = False    # Megatron row-parallel wo/w_out (§Perf)
    pad_vocab: bool = False           # pad V to 128 for vocab-TP (§Perf)
    swa_window_decode: bool = False   # SWA decode reads window only (§Perf)
    optimizer: str = "adam"                 # adam | adafactor (huge archs)
    param_dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.xlstm

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell."""
        return self.xlstm or (self.hybrid_ssm and self.swa_window > 0)

    def supports(self, shape: str) -> bool:
        cell = SHAPES[shape]
        if cell.name == "long_500k":
            return self.subquadratic
        return True

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers // 16 or 2)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads >= 4 else self.n_kv_heads,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32,
            q_rank=64, kv_rank=32, rope_head_dim=8, nope_head_dim=16,
            v_head_dim=16,
            n_experts=min(self.n_experts, 4) if self.moe else 0,
            dense_residual_ff=128 if self.dense_residual else 0,
            moe_group_tokens=64,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            swa_window=min(self.swa_window, 32) if self.swa_window else 0,
            global_attn_layers=(0,) if self.global_attn_layers else (),
            meta_tokens=min(self.meta_tokens, 8),
            enc_layers=2 if self.encdec else 0,
            slstm_group=min(self.slstm_group, 2) if self.slstm_group else 0,
            vision_prefix=16 if self.vision_prefix else 0,
            loss_chunk=64, attn_q_block=64, attn_kv_block=64,
            param_dtype="float32",
        )


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # Import all config modules exactly once (they call register()).
    from . import (arctic_480b, hymba_1_5b, llava_next_34b,  # noqa: F401
                   minicpm3_4b, phi35_moe, qwen15_110b, qwen2_7b,
                   stablelm_3b, whisper_small, xlstm_350m)
