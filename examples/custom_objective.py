"""A user-defined objective, end to end.

cuPSO hard-codes six benchmark landscapes; real workloads bring their own
(the Low-Complexity-PSO line of work exists precisely for time-critical,
application-specific objectives). ``repro.Problem`` makes an objective a
first-class value:

* ``fn``: any pure-jnp function ``pos[..., D] -> value[...]`` — it runs
  unchanged in the jnp step variants AND inside the fused/async/batched
  Pallas kernels, where ``repro.kernels.pso_step.dmajor_adapter`` lowers it
  into the masked d-major tile layout automatically (array constants the
  objective closes over are hoisted into kernel operands for you).
* per-dimension bounds: ``lo``/``hi`` scalars or length-D tuples.
* ``sense``: "min" or "max" — the engine canonicalizes internally and
  reports results back in YOUR sense.

    PYTHONPATH=src python examples/custom_objective.py
"""
import jax.numpy as jnp
import numpy as np

import repro
from repro import Method, Problem

# Minimize a weighted, shifted quadratic bowl over a per-dimension box:
#   f(x) = sum_i w_i (x_i - c_i)^2 ,  x in [-5,5] x [-10,10] x [-2,2].
# The optimum is x = c = (1, -2, 0.5) with f = 0.
W = jnp.asarray([1.0, 4.0, 0.25])
C = jnp.asarray([1.0, -2.0, 0.5])


def weighted_bowl(x):
    return jnp.sum(W * (x - C) ** 2, axis=-1)


problem = Problem(
    name="weighted_bowl",
    fn=weighted_bowl,
    lo=(-5.0, -10.0, -2.0),        # per-dimension boxes pin dim=3
    hi=(5.0, 10.0, 2.0),
    sense="min",                   # minimize; results come back minimized
)


def main():
    # jnp backend, queue variant (dim defaults to the bounds' length).
    res = repro.solve(problem, particles=512, iters=400, seed=0,
                      variant="queue")
    print(f"jnp queue      : f={res.best_fit:.6f} at {res.best_pos}")

    # The same problem inside the fused Pallas queue-lock kernel (interpret
    # mode off-TPU) — no hand-written kernel form needed.
    res_k = repro.solve(problem, particles=512, iters=100, seed=0,
                       method=Method(variant="queue_lock", backend="kernel"))
    print(f"pallas fused   : f={res_k.best_fit:.6f} at {res_k.best_pos}")

    # And the asynchronous queue-lock (block-resident, relaxed consistency).
    res_a = repro.solve(problem, particles=512, iters=100, seed=0,
                       method=Method(variant="async", backend="kernel",
                                     sync_every=10))
    print(f"pallas async   : f={res_a.best_fit:.6f} at {res_a.best_pos}")

    assert res.best_fit < 0.1, "should sit near the optimum f=0"
    assert np.all(res.best_pos >= np.array([-5.0, -10.0, -2.0]) - 1e-5)
    assert np.all(res.best_pos <= np.array([5.0, 10.0, 2.0]) + 1e-5)

    # Registering makes it addressable by name (configs, serving requests):
    repro.register_problem(problem)
    res2 = repro.solve("weighted_bowl", particles=256, iters=200)
    print(f"by name        : f={res2.best_fit:.6f}")
    print(f"registered     : {', '.join(repro.list_problems())}")


if __name__ == "__main__":
    main()
