"""jax version compatibility shims shared by the Pallas kernel modules."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
_cp = getattr(pltpu, "CompilerParams",
              getattr(pltpu, "TPUCompilerParams", None))
if _cp is None:  # pragma: no cover - depends on installed jax
    def _cp(*args, **kwargs):
        raise ImportError(
            "this jax version exposes neither pallas.tpu.CompilerParams nor "
            "TPUCompilerParams; the Pallas kernels need one of them")

CompilerParams = _cp
