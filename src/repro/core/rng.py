"""Counter-based stateless RNG shared by the jnp library code, the Pallas
kernel bodies, and the kernel reference oracles.

The paper uses cuRAND's ``curand_uniform_double`` (§5.4) because a stateful
hand-rolled RNG is not thread-safe on GPU. The TPU-native adaptation is a
*counter-based* generator: a 32-bit mixing hash of ``(seed, iteration,
stream, element index)``. It is stateless (no RNG state to carry, checkpoint
or shard), identical inside and outside Pallas (the body is plain jnp ops on
uint32, which lower in both contexts), and reproducible across any device
layout — resharding a swarm never changes its trajectory.

The mixer is two rounds of the murmur3/splitmix finalizer over a Weyl-summed
counter. It passes the birthday/equidistribution sanity checks in
``tests/test_rng.py``; it is not cryptographic and does not need to be.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy scalars (NOT jnp arrays): Pallas kernel bodies may not close over
# array constants, and numpy scalars fold into the kernel at trace time.
_U32 = np.uint32

# Weyl constants (odd, high-entropy) for combining counter components.
_W0 = _U32(0x9E3779B9)  # golden-ratio
_W1 = _U32(0x85EBCA6B)
_W2 = _U32(0xC2B2AE35)
_W3 = _U32(0x27D4EB2F)


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 finalizer (uint32 in, uint32 out)."""
    x = x ^ (x >> 16)
    x = x * _W1
    x = x ^ (x >> 13)
    x = x * _W2
    x = x ^ (x >> 16)
    return x


def hash_u32(seed, iteration, stream, index) -> jnp.ndarray:
    """uint32 hash of the 4-component counter. All args broadcastable uint32/int32."""
    seed = jnp.asarray(seed).astype(_U32)
    iteration = jnp.asarray(iteration).astype(_U32)
    stream = jnp.asarray(stream).astype(_U32)
    index = jnp.asarray(index).astype(_U32)
    h = seed * _W0 + iteration * _W1 + stream * _W2 + index * _W3
    h = _mix(h)
    # Second round decorrelates consecutive indices fully.
    h = _mix(h ^ (index * _W0 + iteration * _W2))
    return h


def uniform(seed, iteration, stream, index, dtype=jnp.float32) -> jnp.ndarray:
    """Uniform in [0, 1) with 24 bits of mantissa entropy."""
    bits = hash_u32(seed, iteration, stream, index)
    # python-float scale: folds at trace time, keeps dtype via weak promotion
    return (bits >> 8).astype(dtype) * (1.0 / (1 << 24))


def uniform_grid(seed, iteration, stream, n, d, dtype=jnp.float32) -> jnp.ndarray:
    """Uniform [n, d] grid keyed by flat element index — the common PSO shape."""
    idx = jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
    return uniform(seed, iteration, stream, idx, dtype=dtype)
