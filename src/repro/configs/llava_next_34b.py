"""llava-next-34b — VLM: decoder-LM backbone; anyres vision tiling is a
STUB: input_specs() provides 576 precomputed patch embeddings that are
prepended to the token embeddings. [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""
from .base import ArchConfig, register

LLAVA_NEXT_34B = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000,
    vision_prefix=576,
    rope_theta=5e6,
    optimizer="adafactor",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
