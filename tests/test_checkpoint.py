"""Checkpoint/restart: atomicity, resume, pruning, crash simulation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import PSOConfig, init_swarm, run


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step_count": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 3, tree)
    assert ckpt.latest_step(d) == 3
    out = ckpt.restore(d, 3, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_prune(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree)
    assert ckpt.latest_step(d) == 5
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 5
    assert ckpt.restore_latest(d, tree)[0] == 5
    with pytest.raises(FileNotFoundError):
        ckpt.restore(d, 1, tree)


def test_incomplete_checkpoint_ignored(tmp_path):
    """A dir without manifest (simulated crash mid-write) is not 'latest'."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000009"))  # torn write, no manifest
    assert ckpt.latest_step(d) == 1


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    bad = dict(_tree(), w=jnp.zeros((2, 2)))
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(d, 1, bad)


def test_pso_crash_restart_bit_exact(tmp_path):
    """Run 30 iters; 'crash'; resume from step-10 checkpoint and re-run —
    trajectory must be bit-exact vs uninterrupted (counter RNG contract)."""
    d = str(tmp_path)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness="rastrigin").resolved()
    s = init_swarm(cfg, 3)
    s10 = run(cfg, s, 10, "queue")
    ckpt.save(d, 10, s10)
    full = run(cfg, s10, 20, "queue")          # uninterrupted continuation
    # --- crash happens here; new process restores:
    step, restored = ckpt.restore_latest(d, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s10))
    assert step == 10
    from repro.core.pso import SwarmState
    restored = SwarmState(*restored) if not isinstance(
        restored, SwarmState) else restored
    resumed = run(cfg, restored, 20, "queue")
    np.testing.assert_array_equal(np.asarray(full.pos),
                                  np.asarray(resumed.pos))
    assert float(full.gbest_fit) == float(resumed.gbest_fit)


def test_step_runner_retry_and_resume(tmp_path):
    """StepRunner recovers from a transient failure via its checkpoint."""
    from repro.runtime import RunnerConfig, StepRunner
    calls = {"n": 0}

    def flaky_step(state, step):
        calls["n"] += 1
        if calls["n"] == 7:                       # one transient device loss
            raise RuntimeError("simulated device failure")
        return jax.tree.map(lambda x: x + 1, state)

    runner = StepRunner(RunnerConfig(str(tmp_path), ckpt_interval=2,
                                     backoff_s=0.0), flaky_step)
    out = runner.run({"x": jnp.zeros(())}, 0, 10)
    assert float(out["x"]) == 10.0                # all 10 steps applied
    assert ckpt.latest_step(str(tmp_path)) == 10
