"""Behaviour tests for the core PSO variants (paper Alg. 1 / §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PSOConfig, SerialSwarm, init_swarm, run, solve,
                        step_queue, step_queue_lock, step_reduction)
from repro.core.pso import STEP_FNS

CUBIC_1D_MAX = 900000.0  # f(100) for Eq. 3, the boundary max on [-100, 100]


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock"])
def test_variants_converge_cubic_1d(variant):
    s = solve(PSOConfig(dim=1, particle_cnt=256), seed=0, iters=200,
              variant=variant)
    assert float(s.gbest_fit) == pytest.approx(CUBIC_1D_MAX, rel=1e-6)


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock"])
def test_variants_converge_sphere_5d(variant):
    cfg = PSOConfig(dim=5, particle_cnt=512, fitness="sphere", w=0.7)
    s = solve(cfg, seed=1, iters=400, variant=variant)
    assert float(s.gbest_fit) > -1e-2          # optimum is 0
    np.testing.assert_allclose(np.asarray(s.gbest_pos), 0.0, atol=0.2)


def test_queue_equals_reduction_trajectory():
    """§4.1: the queue algorithm is an *optimization*, not an approximation —
    gbest trajectories must be identical to the reduction baseline."""
    cfg = PSOConfig(dim=7, particle_cnt=128, fitness="rastrigin").resolved()
    s_q = init_swarm(cfg, 3)
    s_r = init_swarm(cfg, 3)
    # 20 eager (unjitted) steps: enough to cross several gbest publications
    for _ in range(20):
        s_q = step_queue(cfg, s_q)
        s_r = step_reduction(cfg, s_r)
        assert float(s_q.gbest_fit) == float(s_r.gbest_fit)
    np.testing.assert_allclose(np.asarray(s_q.pos), np.asarray(s_r.pos),
                               rtol=1e-6, atol=1e-6)


def test_queue_lock_equals_queue_trajectory():
    cfg = PSOConfig(dim=4, particle_cnt=256, fitness="ackley").resolved()
    s_q = init_swarm(cfg, 5)
    s_l = init_swarm(cfg, 5)
    for _ in range(12):
        s_q = step_queue(cfg, s_q)
        s_l = step_queue_lock(cfg, s_l)
    np.testing.assert_allclose(float(s_q.gbest_fit), float(s_l.gbest_fit),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_q.pos), np.asarray(s_l.pos),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock"])
def test_gbest_monotone_and_bounds(variant):
    cfg = PSOConfig(dim=12, particle_cnt=64, fitness="griewank").resolved()
    s = init_swarm(cfg, 11)
    step = STEP_FNS[variant]
    prev = float(s.gbest_fit)
    for _ in range(15):
        s = step(cfg, s)
        g = float(s.gbest_fit)
        assert g >= prev                       # gbest never regresses
        prev = g
        pos = np.asarray(s.pos)
        vel = np.asarray(s.vel)
        assert pos.min() >= cfg.min_pos - 1e-6
        assert pos.max() <= cfg.max_pos + 1e-6
        assert np.abs(vel).max() <= cfg.max_v + 1e-6
        # pbest dominates current fitness
        assert np.all(np.asarray(s.pbest_fit) >= np.asarray(s.fit) - 1e-5)
        # gbest dominates all pbests
        assert g >= np.asarray(s.pbest_fit).max() - 1e-4 * abs(g)


def test_serial_spso_matches_sync_on_single_particle():
    """With one particle, sequential vs synchronous semantics coincide."""
    cfg = PSOConfig(dim=2, particle_cnt=1, fitness="sphere").resolved()
    ser = SerialSwarm(cfg, seed=9)
    par = init_swarm(cfg, 9)
    np.testing.assert_allclose(ser.pos, np.asarray(par.pos), rtol=1e-6)
    for _ in range(20):
        ser.step()
        par = step_reduction(cfg, par)
    np.testing.assert_allclose(ser.pos, np.asarray(par.pos),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ser.gbest_fit, float(par.gbest_fit),
                               rtol=1e-4)


def test_serial_spso_gbest_dominates():
    cfg = PSOConfig(dim=3, particle_cnt=8, fitness="rastrigin")
    ser = SerialSwarm(cfg, seed=2)
    f0 = ser.gbest_fit
    ser.run(25)
    assert ser.gbest_fit >= f0
    assert ser.gbest_fit >= ser.pbest_fit.max() - 1e-6


def test_run_fori_loop_equals_python_loop():
    cfg = PSOConfig(dim=6, particle_cnt=128, fitness="cubic").resolved()
    s_loop = init_swarm(cfg, 4)
    for _ in range(8):
        s_loop = step_queue(cfg, s_loop)
    s_run = run(cfg, init_swarm(cfg, 4), 8, "queue")
    np.testing.assert_allclose(np.asarray(s_loop.pos), np.asarray(s_run.pos),
                               rtol=1e-5, atol=1e-5)
    assert int(s_run.iteration) == 8


def test_float64_path():
    """Paper uses double precision; the library supports it on CPU."""
    jax.config.update("jax_enable_x64", True)
    try:
        cfg = PSOConfig(dim=1, particle_cnt=64, dtype="float64")
        s = solve(cfg, seed=0, iters=100, variant="queue")
        assert s.pos.dtype == jnp.float64
        assert float(s.gbest_fit) == pytest.approx(CUBIC_1D_MAX, rel=1e-9)
    finally:
        jax.config.update("jax_enable_x64", False)
