"""Flush-batching front end for PSO solves: collect a queue generation,
group by compile key, dispatch padded batches.

This is the simpler of the repo's two serving front ends. ``SolveServer``
collects submitted requests until ``flush()``, groups them by their
*compilation key*, pads each group to a bucketed batch size (one
compiled program per (key, bucket), not per request count), and routes
every group through a single ``solve_many`` — or through the batched
fused Pallas kernels for the ``queue_lock``/``async`` variants with
``backend="kernel"``. It is the right tool for OFFLINE batches: all
requests known up front, throughput over latency, no arrivals mid-solve.

For STREAMING traffic — staggered arrivals, mixed iteration budgets,
tail-latency targets — use the continuous-batching scheduler built on
top of this module's request/result types:
``repro.serving.ContinuousScheduler`` keeps persistent batched async
lanes running and admits new requests at chunk boundaries instead of
waiting for a whole flush to return (architecture, admission invariants
and the restart story: docs/serving.md). The two front ends share
``SolveRequest``/``SolveResult``/``ServingMetrics``, and
``benchmarks/loadgen.py`` races them on the same trace.

Grouping here is two-tier. Requests whose problem is one of the
registered built-ins (``hetero_fid``) coalesce into a single
HETEROGENEOUS batch keyed only on the shape of the solve — ``(dim,
particle_cnt, iters, variant, dtype, sync_every)`` — with each row's
objective and box bounds dispatched by ``lax.switch`` inside one
compiled program, so a mixed sphere/rastrigin/ackley trace rides one
dispatch. Row results lean on ``gbest_fit``/``gbest_pos``, the validated
bit-exactness surface of the heterogeneous engines.
``coalesce_registry=False`` restores the legacy content-hash-only keys.
Custom ``Problem``s keep the second tier: their grouping key hashes the
problem's CONTENT (``Problem.cache_key``), never its name or identity,
so distinct objectives never share a batch and re-submitted identical
ones still do. Constrained problems ride the same machinery, and
``SolveResult.feasible``/``violation`` report Deb-rule feasibility.

Failure isolation: a group whose solve raises no longer poisons the
whole flush — the other groups return normally and the offending
tickets resolve to error results (``SolveResult.error`` set,
``SolveResult.ok`` False; see ``flush``).

    PYTHONPATH=src python -m repro.launch.serve --requests 24 --iters 200

Padding rows reuse the group's first seed and are dropped before results
are returned; they cost compute but never correctness. ``ServeStats``
reports how much padding each flush paid, and an attached
``repro.serving.ServingMetrics`` additionally records per-request
queue/solve latency spans and dispatch counters for the JSON snapshot.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import ASYNC_SYNC_EVERY, PSOConfig
from repro.core.multi_swarm import (hetero_fid, init_batch, problem_rows,
                                    solve_many)
from repro.core.problem import Problem, resolve_problem

# Minimum bucket restored to 4: the S=4 row-bit-identity anomaly (XLA:CPU
# loop-body fusion FMA-contracts the velocity chain 1 ulp differently for a
# few tiny batch shapes — root-caused at S=4/dim=3/n=64) is pinned at the
# engine level: ``repro.core.multi_swarm.run_many`` runs sub-8 batches on
# the smallest VALIDATED program shape with dead rows
# (MIN_VALIDATED_SWARMS), so a bucket-4 dispatch is row-bit-identical to
# the standalone solve again (tests/test_multi_swarm.py regression test).
_MIN_BUCKET = 4
BUCKETS = (_MIN_BUCKET, 8, 16, 32, 64, 128)

# Hetero batch keys carry this marker in the content-hash slot: every
# registry built-in at the same solve shape lands in ONE group. The batch's
# PSOConfig is pinned to a canonical fitness so every mix that shares a
# group key also shares a compiled program (cfg.fitness only keys the jit
# cache for heterogeneous batches — the rows carry the real objectives).
_HETERO = "__hetero__"
_HETERO_CANONICAL_FITNESS = "cubic"


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One independent PSO solve.

    ``sync_every`` is the ``variant="async"`` publication interval. It only
    enters the compile key for async requests — the synchronous variants
    ignore it, and keying on it would split otherwise-identical requests
    into separate batches and duplicate compiled programs.
    """

    dim: int = 1
    particle_cnt: int = 1024
    fitness: Union[str, Problem] = "cubic"
    seed: int = 0
    iters: int = 1000
    variant: str = "queue"
    dtype: str = "float32"
    sync_every: int = ASYNC_SYNC_EVERY
    rule: str = "pso"          # update rule (repro.core.update_rules)
    topology: str = "gbest"    # async lbest topology (repro.core.topology)

    def _topology_key(self) -> str:
        """The topology only exists on the async variant's block-local
        machinery — keying sync requests on it would split identical
        programs (mirrors the ``sync_every`` rationale above)."""
        return self.topology if self.variant == "async" else "gbest"

    @property
    def batch_key(self) -> Tuple:
        """Everything that forces a distinct compiled program. The problem
        enters by CONTENT hash (see module docstring), resolving registered
        names through the registry so a string and its Problem batch
        together."""
        return (self.dim, self.particle_cnt,
                resolve_problem(self.fitness).cache_key(), self.iters,
                self.variant, self.dtype,
                self.sync_every if self.variant == "async" else 0,
                self.rule, self._topology_key())

    @property
    def hetero_eligible(self) -> bool:
        """True when the problem is a registered built-in: the request can
        ride a shared heterogeneous batch with other built-ins."""
        return hetero_fid(self.fitness) is not None

    def group_key(self, coalesce_registry: bool = True) -> Tuple:
        """The server's grouping key: hetero marker for built-ins (all
        built-ins at one solve shape coalesce), content hash otherwise."""
        if coalesce_registry and self.hetero_eligible:
            return (self.dim, self.particle_cnt, _HETERO, self.iters,
                    self.variant, self.dtype,
                    self.sync_every if self.variant == "async" else 0,
                    self.rule, self._topology_key())
        return self.batch_key

    def config(self) -> PSOConfig:
        return PSOConfig(dim=self.dim, particle_cnt=self.particle_cnt,
                         fitness=self.fitness, dtype=self.dtype,
                         update_rule=self.rule,
                         topology=self._topology_key())


@dataclasses.dataclass
class SolveResult:
    request: SolveRequest
    gbest_fit: float         # canonical (maximized) fitness
    gbest_pos: np.ndarray
    batch_size: int          # padded batch the request rode in
    error: Optional[BaseException] = None  # set when the solve raised
    history: Optional[object] = None  # repro.History: gbest-vs-iteration
    # series sampled at the lane's chunk boundaries (continuous scheduler
    # with record_history=True; None elsewhere)

    @property
    def ok(self) -> bool:
        """False when this request's group failed: ``error`` holds the
        exception and the ``gbest_*`` fields are meaningless."""
        return self.error is None

    @property
    def objective(self) -> float:
        """The objective value in the problem's OWN sense (a sense="min"
        problem reports the minimized value)."""
        if not self.ok:
            raise RuntimeError(
                f"request failed: {self.error!r}") from self.error
        return float(resolve_problem(self.request.fitness)
                     .user_value(self.gbest_fit))

    @property
    def violation(self) -> float:
        """Aggregate constraint violation at ``gbest_pos`` (0.0 for
        unconstrained problems) — the Deb-rule input, mirrored from
        ``repro.Result.violation`` so serving responses carry the same
        feasibility report as the facade."""
        return resolve_problem(self.request.fitness).violation_at(
            self.gbest_pos)

    @property
    def feasible(self) -> bool:
        return self.violation <= 0.0


def request_error(r: SolveRequest) -> Optional[Exception]:
    """Per-request admission validation: the rejection (or None).

    Returned, not raised, so an unknown variant/rule/topology resolves to
    its OWN error result (``SolveResult.error`` set, ``ok`` False) at
    flush time instead of poisoning the whole group it would have been
    batched into — the group-level isolation in ``flush`` only catches
    solves that raise, and a bad name would otherwise raise while
    *grouping* (``group_key`` resolves the problem) or compile-key every
    valid request in the group into the failure."""
    from repro.core.pso import VARIANTS
    from repro.core.update_rules import TOPOLOGIES, resolve_rule
    if r.variant not in VARIANTS:
        return ValueError(
            f"unknown variant {r.variant!r}; one of {VARIANTS}")
    try:
        resolve_rule(r.rule)
    except ValueError as e:
        return e
    if r.topology not in TOPOLOGIES:
        return ValueError(
            f"unknown topology {r.topology!r}; one of {TOPOLOGIES}")
    try:
        resolve_problem(r.fitness)
    except (KeyError, ValueError, TypeError) as e:
        return e
    return None


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    dispatches: int = 0      # batched device programs launched
    padded_rows: int = 0     # wasted swarm slots from bucket padding
    hetero_dispatches: int = 0  # of which: heterogeneous (mixed-problem)
    failed: int = 0          # requests whose group's solve raised

    @property
    def batch_fill(self) -> float:
        """Mean real (non-padding) rows per dispatch — the coalescing
        payoff metric: higher means fewer, fuller device programs."""
        return self.requests / self.dispatches if self.dispatches else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_fill"] = self.batch_fill
        return d


def bucket_size(k: int, max_batch: int = BUCKETS[-1],
                buckets: Tuple[int, ...] = BUCKETS) -> int:
    """Smallest bucket >= k (capped): bounds the jit cache per batch_key.

    ``buckets`` defaults to the static ladder; an autotuning server passes
    a model-tuned ladder instead (``repro.core.autotune.bucket_ladder``)."""
    for b in buckets:
        if b >= min(k, max_batch):
            return min(b, max_batch)
    return max_batch


class SolveServer:
    """Collects solve requests and dispatches them as padded batches.

    ``backend="jnp"`` runs every variant through the vmapped ``solve_many``;
    ``backend="kernel"`` routes ``queue_lock`` requests through the batched
    fused Pallas kernel (interpret mode off-TPU) and everything else through
    the jnp path. ``coalesce_registry`` (default on) merges every registered
    built-in problem at the same solve shape into one heterogeneous batch
    (``lax.switch`` row dispatch); off, grouping falls back to the legacy
    per-problem content-hash keys.

    ``autotune=True`` consults the roofline autotuner
    (``repro.core.autotune``, model-only: no timed micro-runs on the
    serving path, but previously measured cache entries win): async
    requests' ``sync_every`` is rewritten to the tuned value for their
    shape BEFORE grouping — the tuned interval is part of the batch
    compile key, so every request at one shape shares one tuned compiled
    program — and the bucket ladder is re-derived per grouping shape from
    the cost model (buckets past the point of diminishing per-row returns
    are dropped, shrinking the jit-cache footprint).
    """

    def __init__(self, max_batch: int = 64, backend: str = "jnp",
                 interpret: bool = True, block_n: Optional[int] = None,
                 coalesce_registry: bool = True, autotune: bool = False,
                 metrics=None):
        if backend not in ("jnp", "kernel"):
            raise ValueError(f"unknown backend {backend!r}")
        if max_batch < BUCKETS[0]:
            raise ValueError(
                f"max_batch={max_batch} < minimum bucket {BUCKETS[0]}")
        self.max_batch = max_batch
        self.backend = backend
        self.interpret = interpret
        self.block_n = block_n
        self.coalesce_registry = coalesce_registry
        self.autotune = autotune
        self.stats = ServeStats()
        self.metrics = metrics   # optional repro.serving.ServingMetrics
        self._pending: List[Tuple[int, SolveRequest, float]] = []
        self._ticket = 0
        self._ladders: Dict[Tuple, Tuple[int, ...]] = {}

    def _tuned_request(self, r: SolveRequest) -> SolveRequest:
        """Rewrite an async request's publication interval to the tuned
        value for its shape (no-op for sync variants / autotune off)."""
        if not self.autotune or r.variant != "async":
            return r
        from repro.core.autotune import tuned_sync_every
        k = tuned_sync_every(r.fitness, r.dim, r.particle_cnt, r.iters,
                             r.dtype)
        return dataclasses.replace(r, sync_every=k)

    def _buckets_for(self, r0: SolveRequest) -> Tuple[int, ...]:
        """The bucket ladder for one grouping shape: static by default,
        model-tuned (and memoized per shape) when autotuning."""
        if not self.autotune:
            return BUCKETS
        key = (r0.dim, r0.particle_cnt, r0.iters, r0.variant, r0.dtype)
        if key not in self._ladders:
            from repro.core.autotune import bucket_ladder
            self._ladders[key] = bucket_ladder(
                r0.fitness, r0.dim, r0.particle_cnt, r0.iters,
                max_batch=self.max_batch, variant=r0.variant,
                dtype=r0.dtype, min_bucket=_MIN_BUCKET)
        return self._ladders[key]

    def submit(self, req: SolveRequest) -> int:
        """Enqueue a request; returns a ticket resolved by ``flush()``."""
        t = self._ticket
        self._ticket += 1
        self._pending.append((t, req, time.perf_counter()))
        if self.metrics is not None:
            self.metrics.inc("submitted")
        return t

    def _solve_group(self, reqs: List[SolveRequest]) -> List[SolveResult]:
        """One compilation group -> one (or a few, if > max_batch) dispatches."""
        out: List[SolveResult] = []
        hetero = (self.coalesce_registry
                  and all(r.hetero_eligible for r in reqs))
        for lo in range(0, len(reqs), self.max_batch):
            chunk = reqs[lo:lo + self.max_batch]
            k = len(chunk)
            padded = bucket_size(k, self.max_batch,
                                 self._buckets_for(chunk[0]))
            seeds = np.array([r.seed for r in chunk]
                             + [chunk[0].seed] * (padded - k), dtype=np.int64)
            r0 = chunk[0]
            if hetero:
                # Padding rows replicate the first request's problem too, so
                # they stay as dead weight with well-defined bounds.
                probs = ([r.fitness for r in chunk]
                         + [r0.fitness] * (padded - k))
                cfg = PSOConfig(dim=r0.dim, particle_cnt=r0.particle_cnt,
                                fitness=_HETERO_CANONICAL_FITNESS,
                                dtype=r0.dtype, update_rule=r0.rule,
                                topology=r0._topology_key())
                batch = self._dispatch_hetero(cfg, seeds, probs, r0)
            else:
                cfg = r0.config()
                batch = self._dispatch_uniform(cfg, seeds, r0)
            gf = np.asarray(batch.gbest_fit)
            gp = np.asarray(batch.gbest_pos)
            self.stats.dispatches += 1
            self.stats.hetero_dispatches += int(hetero)
            self.stats.padded_rows += padded - k
            if self.metrics is not None:
                self.metrics.inc("dispatches")
                self.metrics.inc("lane_slots", padded)
                self.metrics.inc("lane_active_slots", k)
            out.extend(SolveResult(request=r, gbest_fit=float(gf[i]),
                                   gbest_pos=gp[i], batch_size=padded)
                       for i, r in enumerate(chunk))
        return out

    def _dispatch_uniform(self, cfg: PSOConfig, seeds: np.ndarray,
                          r0: SolveRequest):
        """Legacy single-problem dispatch (content-hash-keyed groups)."""
        if self.backend == "kernel" and r0.variant == "queue_lock":
            from repro.kernels.ops import run_queue_lock_fused_batch
            return run_queue_lock_fused_batch(
                cfg, init_batch(cfg, seeds), iters=r0.iters,
                block_n=self.block_n, interpret=self.interpret)
        if self.backend == "kernel" and r0.variant == "async":
            from repro.kernels.ops import run_queue_lock_fused_async_batch
            return run_queue_lock_fused_async_batch(
                cfg, init_batch(cfg, seeds), iters=r0.iters,
                sync_every=r0.sync_every,
                block_n=self.block_n, interpret=self.interpret)
        return solve_many(cfg, seeds, iters=r0.iters, variant=r0.variant,
                          sync_every=r0.sync_every)

    def _dispatch_hetero(self, cfg: PSOConfig, seeds: np.ndarray,
                         probs: List[Union[str, Problem]], r0: SolveRequest):
        """Mixed-problem dispatch: per-row objective/bounds descriptors +
        ``lax.switch`` dispatch, one compiled program for the whole mix."""
        if self.backend == "kernel" and r0.variant in ("queue_lock", "async"):
            rows, table = problem_rows(probs, cfg.dim, cfg.dtype)
            rcfg = cfg.resolved()
            batch = init_batch(rcfg, seeds, rows=rows, table=table)
            if r0.variant == "queue_lock":
                from repro.kernels.ops import run_queue_lock_fused_batch
                return run_queue_lock_fused_batch(
                    rcfg, batch, iters=r0.iters, block_n=self.block_n,
                    interpret=self.interpret, fids=rows.fid, table=table)
            from repro.kernels.ops import run_queue_lock_fused_async_batch
            return run_queue_lock_fused_async_batch(
                rcfg, batch, iters=r0.iters, sync_every=r0.sync_every,
                block_n=self.block_n, interpret=self.interpret,
                fids=rows.fid, table=table)
        return solve_many(cfg, seeds, iters=r0.iters, variant=r0.variant,
                          sync_every=r0.sync_every, problems=probs)

    def flush(self) -> Dict[int, SolveResult]:
        """Dispatch all pending requests; returns {ticket: result}.

        Failures are isolated per GROUP (the dispatch unit): if one
        group's solve raises, its tickets resolve to error results
        (``SolveResult.error`` set, ``ok`` False) and every other group
        returns normally — a poisoned custom objective cannot take down
        unrelated requests sharing the flush.
        """
        groups: Dict[Tuple, List[Tuple[int, SolveRequest, float]]] = \
            defaultdict(list)
        results: Dict[int, SolveResult] = {}
        for t, r, ts in self._pending:
            err = request_error(r)
            if err is not None:
                # reject at admission: the bad request gets its own error
                # result and never joins (or poisons) a dispatch group
                self.stats.failed += 1
                if self.metrics is not None:
                    self.metrics.inc("failed")
                results[t] = SolveResult(
                    request=r, gbest_fit=float("nan"),
                    gbest_pos=np.full((r.dim,), np.nan),
                    batch_size=0, error=err)
                continue
            r = self._tuned_request(r)   # tuned sync_every enters group_key
            groups[r.group_key(self.coalesce_registry)].append((t, r, ts))
        self._pending.clear()
        for _, members in sorted(groups.items(), key=lambda kv: repr(kv[0])):
            tickets = [t for t, _, _ in members]
            t0 = time.perf_counter()
            try:
                solved = self._solve_group([r for _, r, _ in members])
            except Exception as e:
                self.stats.failed += len(members)
                if self.metrics is not None:
                    self.metrics.inc("failed", len(members))
                results.update(
                    (t, SolveResult(request=r, gbest_fit=float("nan"),
                                    gbest_pos=np.full((r.dim,), np.nan),
                                    batch_size=0, error=e))
                    for t, r, _ in members)
                continue
            results.update(zip(tickets, solved))
            self.stats.requests += len(members)
            if self.metrics is not None:
                now = time.perf_counter()
                self.metrics.inc("completed", len(members))
                self.metrics.observe("dispatch_us", (now - t0) * 1e6)
                for _, _, ts in members:
                    self.metrics.observe("e2e_us", (now - ts) * 1e6)
        return results

    def solve_all(self, requests: Sequence[SolveRequest]) -> List[SolveResult]:
        """Convenience: submit + flush, results in request order."""
        tickets = [self.submit(r) for r in requests]
        resolved = self.flush()
        return [resolved[t] for t in tickets]

    def snapshot(self) -> dict:
        """ServeStats (+ the attached metrics sink, if any) as JSON-able
        dict — the flush-server half of the serving observability story."""
        doc = {"stats": self.stats.as_dict()}
        if self.metrics is not None:
            doc["metrics"] = self.metrics.snapshot()
        return doc

    def prometheus(self, *, prefix: str = "repro") -> str:
        """This server's serving state as a Prometheus text exposition
        (``repro.telemetry.prometheus_text``). With a metrics sink
        attached, renders its spans and counters; without one, renders the
        ServeStats counters and batch fill."""
        if self.metrics is not None:
            return self.metrics.prometheus(prefix=prefix)
        from repro.telemetry import prometheus_text
        counters = {k: v for k, v in self.stats.as_dict().items()
                    if k != "batch_fill"}
        return prometheus_text(
            {"counters": counters, "batch_fill": self.stats.batch_fill,
             "spans": {}}, prefix=prefix)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--iters", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "kernel"])
    ap.add_argument("--variant", default="auto",
                    choices=["auto", "reduction", "queue", "queue_lock",
                             "async"])
    ap.add_argument("--sync-every", type=int, default=ASYNC_SYNC_EVERY,
                    help="async variant publication interval")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="legacy per-problem content-hash grouping")
    ap.add_argument("--autotune", action="store_true",
                    help="roofline-tuned sync_every + bucket ladder")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text exposition of the "
                         "serving metrics here after the flush")
    args = ap.parse_args()
    # A mixed workload: four built-in objectives over two solve shapes. With
    # registry coalescing each shape is ONE heterogeneous dispatch; with
    # --no-coalesce each (shape, problem) pair compiles and runs alone.
    if args.variant == "auto":
        variant = "queue_lock" if args.backend == "kernel" else "queue"
    else:
        variant = args.variant
    mix = [("cubic", 1, 256), ("sphere", 1, 256),
           ("rastrigin", 10, 128), ("ackley", 10, 128)]
    reqs = [SolveRequest(dim=d, particle_cnt=n, fitness=f, seed=i,
                         iters=args.iters, variant=variant,
                         sync_every=args.sync_every)
            for i, (f, d, n) in ((i, mix[i % len(mix)])
                                 for i in range(args.requests))]
    metrics = None
    if args.metrics_out:
        from repro.serving import ServingMetrics
        metrics = ServingMetrics()
    srv = SolveServer(max_batch=args.max_batch, backend=args.backend,
                      coalesce_registry=not args.no_coalesce,
                      autotune=args.autotune, metrics=metrics)
    t0 = time.time()
    results = srv.solve_all(reqs)
    dt = time.time() - t0
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(srv.prometheus())
        print(f"metrics -> {args.metrics_out}")
    for r in results[:4]:
        print(f"req({r.request.fitness}, dim={r.request.dim}, "
              f"seed={r.request.seed}) gbest_fit={r.gbest_fit:.6g} "
              f"(batch={r.batch_size})")
    s = srv.stats
    print(f"{s.requests} requests in {s.dispatches} dispatches "
          f"({s.hetero_dispatches} heterogeneous, {s.padded_rows} padded "
          f"rows, fill={s.batch_fill:.1f}), wall={dt:.3f}s "
          f"({s.requests / dt:.1f} solves/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
