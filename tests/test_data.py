"""Data pipeline: determinism, shard-exactness, restart replay."""
import numpy as np

from repro.data import DataConfig, MemmapCorpus, SyntheticLM, write_corpus


def test_synthetic_deterministic_replay():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=5)
    a = SyntheticLM(cfg).batch(13)
    b = SyntheticLM(cfg).batch(13)            # fresh instance, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = SyntheticLM(cfg).batch(14)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=1)
    whole = SyntheticLM(cfg).batch(3)
    parts = [
        SyntheticLM(DataConfig(vocab=1000, seq_len=16, global_batch=8,
                               seed=1, num_shards=2, shard_id=i)).batch(3)
        for i in range(2)
    ]
    merged = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(whole["tokens"], merged)


def test_elastic_reshard_same_examples():
    """4 shards and 2 shards must produce the same global example set."""
    def allb(n):
        return np.concatenate([
            SyntheticLM(DataConfig(vocab=500, seq_len=8, global_batch=8,
                                   seed=2, num_shards=n, shard_id=i)
                        ).batch(0)["tokens"]
            for i in range(n)])
    np.testing.assert_array_equal(allb(2), allb(4))


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    rng = np.random.default_rng(0)
    write_corpus(path, rng.integers(0, 1000, size=10000))
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=3)
    c = MemmapCorpus(path, cfg)
    a = c.batch(5)
    b = MemmapCorpus(path, cfg).batch(5)      # restart-exact
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 1000
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
