"""GLA Pallas kernel (interpret) vs the pure-jnp chunked engine, swept over
shapes/chunks/dtypes — including the exact mLSTM (v-augmented) and SSD
gate patterns used by the models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gla import gla_forward
from repro.models.ssm import gla_chunked

SWEEP = [
    # (B, S, H, N, P, chunk) — one representative stays in tier-1, the
    # rest of the interpret-mode sweep rides behind --runslow
    (2, 64, 2, 16, 32, 16),
    pytest.param((1, 128, 4, 16, 16, 32), marks=pytest.mark.slow),
    pytest.param((2, 96, 1, 8, 24, 32),   # S not multiple of chunk (pad)
                 marks=pytest.mark.slow),
    pytest.param((1, 256, 2, 32, 8, 128), marks=pytest.mark.slow),
]


def _inputs(case, seed=0, decay_scale=0.1):
    b, s, h, n, p, chunk = case
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (b, s, h, n), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (b, s, h, n), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, p), jnp.float32)
    # realistic gates: log_decay <= 0 (forget), log_inc bounded
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, s, h))) * decay_scale
    li = jnp.clip(jax.random.normal(ks[4], (b, s, h)) * 0.3, -2, 2)
    return q, k, v, ld, li


@pytest.mark.parametrize("case", SWEEP)
def test_kernel_matches_engine(case):
    q, k, v, ld, li = _inputs(case)
    chunk = case[-1]
    want, _ = gla_chunked(q, k, v, ld, li, chunk=chunk)
    got = gla_forward(q, k, v, ld, li, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_kernel_chunk_invariance():
    """Different chunk sizes must give the same function values."""
    case = (1, 128, 2, 16, 16, 32)
    q, k, v, ld, li = _inputs(case, seed=3)
    a = gla_forward(q, k, v, ld, li, chunk=32)
    b = gla_forward(q, k, v, ld, li, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_kernel_state_carry_across_chunks():
    """Strong-decay vs no-decay distinguishes true state carrying."""
    case = (1, 64, 1, 8, 8, 16)
    q, k, v, ld, li = _inputs(case, seed=5)
    # zero decay (ld = 0 keeps all history): later outputs differ strongly
    y_keep = gla_forward(q, k, v, jnp.zeros_like(ld), li, chunk=16)
    y_forget = gla_forward(q, k, v, jnp.full_like(ld, -50.0), li, chunk=16)
    want_keep, _ = gla_chunked(q, k, v, jnp.zeros_like(ld), li, chunk=16)
    np.testing.assert_allclose(np.asarray(y_keep), np.asarray(want_keep),
                               rtol=2e-4, atol=2e-4)
    # with total forgetting, chunks are independent — outputs must differ
    assert not np.allclose(np.asarray(y_keep)[:, -16:],
                           np.asarray(y_forget)[:, -16:], atol=1e-3)


@pytest.mark.slow
def test_kernel_mlstm_pattern():
    """mLSTM's v-augmentation (ones column as the normalizer)."""
    b, s, h, n = 1, 64, 2, 16
    q, k, v, ld, li = _inputs((b, s, h, n, n, 16), seed=7)
    v_aug = jnp.concatenate([v, jnp.ones((b, s, h, 1), v.dtype)], -1)
    want, _ = gla_chunked(q, k, v_aug, ld, li, chunk=16)
    got = gla_forward(q, k, v_aug, ld, li, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
