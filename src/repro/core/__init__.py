"""cuPSO core: the paper's contribution as a composable JAX module."""
from .blocking import LANE, pick_block_n
from .fitness import (BUILTIN_PROBLEMS, FITNESS_FNS, FITNESS_IDS,
                      DEFAULT_BOUNDS)
from .constraints import (Constraint, ConstraintSet, constrain_problem,
                          constraint_from_spec, constraint_set_from_cli,
                          project_simplex, simplex_constraints)
from .problem import (Problem, get_problem, list_problems, register_problem,
                      resolve_problem)
from .pso import (ASYNC_SYNC_EVERY, PSOConfig, SwarmState, STEP_FNS,
                  VARIANTS, flush_async_locals, init_async_locals,
                  init_swarm, publish_async_locals, run, run_async,
                  run_with_history, solve, step_async, step_queue,
                  step_queue_lock, step_reduction)
from .multi_swarm import (MIN_VALIDATED_SWARMS, SwarmBatch, batch_row,
                          best_of_batch, init_batch, run_many, solve_many,
                          stack_states)
from .serial import SerialSwarm, run_serial_fast
from .topology import block_neighbor_best, grid_dims
from .tuner import (PSO_COEFF_DIMS, PSOTuner, SearchDim, TunerResult,
                    make_solve_many_fitness)
from .update_rules import (TOPOLOGIES, UPDATE_RULES, UpdateRule,
                           resolve_rule, rule_names)

__all__ = [
    "FITNESS_FNS", "FITNESS_IDS", "DEFAULT_BOUNDS", "BUILTIN_PROBLEMS",
    "Problem", "register_problem", "get_problem", "list_problems",
    "resolve_problem", "LANE", "pick_block_n",
    "Constraint", "ConstraintSet", "constrain_problem",
    "constraint_from_spec", "constraint_set_from_cli", "project_simplex",
    "simplex_constraints",
    "PSOConfig", "SwarmState", "STEP_FNS", "VARIANTS", "ASYNC_SYNC_EVERY",
    "init_swarm", "run", "solve", "run_async", "run_with_history",
    "step_async",
    "init_async_locals", "publish_async_locals", "flush_async_locals",
    "step_queue", "step_queue_lock", "step_reduction",
    "SwarmBatch", "init_batch", "batch_row", "stack_states", "run_many",
    "solve_many", "best_of_batch", "MIN_VALIDATED_SWARMS",
    "SerialSwarm", "run_serial_fast",
    "block_neighbor_best", "grid_dims",
    "UpdateRule", "UPDATE_RULES", "TOPOLOGIES", "resolve_rule",
    "rule_names",
    "PSOTuner", "SearchDim", "TunerResult", "PSO_COEFF_DIMS",
    "make_solve_many_fitness",
]
