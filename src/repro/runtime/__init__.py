from .fault_tolerance import RunnerConfig, StepRunner, \
    suggest_checkpoint_interval

__all__ = ["RunnerConfig", "StepRunner", "suggest_checkpoint_interval"]
