"""Serving subsystem: continuous batching, AOT compile cache, metrics.

Three layers over the flush server in ``repro.launch.serve``:

* ``scheduler.ContinuousScheduler`` — persistent batched async lanes with
  chunk-boundary admission (the streaming front end).
* ``compile_cache.CompileCache`` — ``jax.export``-backed persistent AOT
  programs, so a restarted replica serves its first request with zero
  re-traces.
* ``metrics.ServingMetrics`` — queue/compile/solve latency spans
  (p50/p99), batch-fill and preemption counters, JSON snapshots.

See docs/serving.md for the architecture and the admission invariants.
"""
from .compile_cache import CompileCache
from .metrics import LatencyStat, ServingMetrics
from .scheduler import ContinuousScheduler

__all__ = ["CompileCache", "ContinuousScheduler", "LatencyStat",
           "ServingMetrics"]
