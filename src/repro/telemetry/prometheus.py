"""Prometheus text-exposition renderer for solver metrics.

Renders a ``ServingMetrics.snapshot()`` dict (and optionally kernel
counters) in the Prometheus text format (version 0.0.4): ``# HELP`` /
``# TYPE`` preambles, counters suffixed ``_total``, latency spans as
summaries with ``quantile`` labels plus ``_sum``/``_count``. Pure string
assembly over the snapshot — no client library, no registry, so a
``/metrics`` endpoint (or the CLI's ``--metrics-out``) is one call.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str) -> str:
    n = _NAME_RE.sub("_", name)
    if not n or n[0].isdigit():
        n = "_" + n
    return n


def prometheus_text(snapshot: Dict[str, Any], *, prefix: str = "repro",
                    kernel_counters: Optional[Dict[str, int]] = None
                    ) -> str:
    """Render a metrics snapshot as a Prometheus exposition document.

    ``snapshot`` is ``ServingMetrics.snapshot()`` (``uptime_s`` /
    ``counters`` / ``batch_fill`` / ``spans``); extra keys (the
    scheduler's ``lanes`` list etc.) are ignored. ``kernel_counters``
    optionally adds the in-kernel contention counts
    (``repro.telemetry.KernelCounters.as_dict()``) as
    ``<prefix>_kernel_<name>_total``.
    """
    out: List[str] = []

    def emit(name: str, kind: str, help_: str, samples) -> None:
        out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {kind}")
        for labels, value in samples:
            lab = ("{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"
                   if labels else "")
            out.append(f"{name}{lab} {value:g}")

    if "uptime_s" in snapshot:
        emit(f"{prefix}_uptime_seconds", "gauge",
             "Seconds since the metrics sink was created.",
             [((), float(snapshot["uptime_s"]))])
    for cname in sorted(snapshot.get("counters", {})):
        emit(f"{prefix}_{_metric_name(cname)}_total", "counter",
             f"Monotonic count of {cname} events.",
             [((), float(snapshot["counters"][cname]))])
    if snapshot.get("batch_fill") is not None:
        emit(f"{prefix}_batch_fill", "gauge",
             "Mean fraction of lane slots running real rows.",
             [((), float(snapshot["batch_fill"]))])
    spans = snapshot.get("spans", {})
    if spans:
        name = f"{prefix}_span_latency_microseconds"
        samples = []
        for sname in sorted(spans):
            s = spans[sname]
            lab = ("span", _metric_name(sname))
            samples.append(((lab, ("quantile", "0.5")), float(s["p50_us"])))
            samples.append(((lab, ("quantile", "0.99")), float(s["p99_us"])))
        emit(name, "summary",
             "Host-side span latencies (reservoir-sampled).", samples)
        for sname in sorted(spans):
            s = spans[sname]
            lab = f'{{span="{_metric_name(sname)}"}}'
            out.append(f"{name}_sum{lab} "
                       f"{float(s['mean_us']) * s['count']:g}")
            out.append(f"{name}_count{lab} {s['count']:g}")
    for cname in sorted(kernel_counters or {}):
        emit(f"{prefix}_kernel_{_metric_name(cname)}_total", "counter",
             f"In-kernel {cname} events (see docs/observability.md).",
             [((), float(kernel_counters[cname]))])
    return "\n".join(out) + "\n"
