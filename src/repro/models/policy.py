"""Activation-sharding policy: with_sharding_constraint hooks that keep
intermediates in the Megatron-style TP layout so XLA reshards *weights*
(small, per layer) rather than *activations* (huge, per matmul).

Without these constraints XLA resolves the params-(data,model) ×
activations-(batch) layout conflict by all-gathering activations around
every projection — measured at 14 GB/chip/layer on stablelm-3b train_4k
(EXPERIMENTS.md §Perf iteration 1). With them, the only activation
collectives left are the two canonical TP all-reduces per layer.

The policy is set (module-global, read at trace time) by the launcher /
dry-run before lowering; unset, every hook is the identity, so tests and
single-device runs are unaffected. Constraints are divisibility-guarded:
an axis is applied only when the dim divides the mesh extent, so archs
with awkward head counts (qwen2: 28H, hymba: 25H) degrade gracefully.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: dict = {"mesh": None, "dp": None, "tp": None}


def set_policy(mesh: Optional[Mesh], dp=None, tp: Optional[str] = None):
    _POLICY.update(mesh=mesh, dp=dp, tp=tp)


@contextlib.contextmanager
def activation_policy(mesh: Mesh, dp, tp: str):
    prev = dict(_POLICY)
    set_policy(mesh, dp, tp)
    try:
        yield
    finally:
        _POLICY.update(prev)


def _axes_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def constrain(x, layout: Tuple[Optional[str], ...]):
    """layout entries: "dp" (batch axes), "tp" (model axis), or None.
    Identity when no policy is active or dims don't divide."""
    mesh = _POLICY["mesh"]
    if mesh is None or x.ndim != len(layout):
        return x
    spec = []
    for dim, tag in zip(x.shape, layout):
        ax = {"dp": _POLICY["dp"], "tp": _POLICY["tp"], None: None}[tag]
        if ax is not None and dim % _axes_size(mesh, ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
