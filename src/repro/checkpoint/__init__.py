from .checkpointer import latest_step, prune, restore, restore_latest, save

__all__ = ["save", "restore", "restore_latest", "latest_step", "prune"]
