"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step and one decode step on CPU; asserts shapes + no NaNs.

Tier-1 keeps one representative dense arch (stablelm-3b); the full
LM-substrate sweep (every registered arch) runs behind ``--runslow``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import zoo

FAST_ARCH = "stablelm-3b"
ARCHS = [a if a == FAST_ARCH else pytest.param(a, marks=pytest.mark.slow)
         for a in list_archs()]
SMOKE_B, SMOKE_S = 2, 64


def _smoke_setup(name):
    cfg = get_arch(name).smoke()
    params = zoo.init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(name):
    cfg, params = _smoke_setup(name)
    batch = zoo.make_batch(cfg, "train_4k", SMOKE_B, SMOKE_S,
                           jax.random.key(1))
    loss = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    # a random-init model on a vocab-V uniform target: loss ≈ log(V)
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab) + 5


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_grads(name):
    cfg, params = _smoke_setup(name)
    batch = zoo.make_batch(cfg, "train_4k", SMOKE_B, SMOKE_S,
                           jax.random.key(2))
    grads = jax.jit(jax.grad(lambda p: zoo.loss_fn(cfg, p, batch)))(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
               for g in flat), f"{name}: non-finite grads"
    # at least the embedding must receive gradient signal
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert gsum > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name):
    cfg, params = _smoke_setup(name)
    cache = zoo.init_cache(cfg, SMOKE_B, SMOKE_S)
    token = jnp.zeros((SMOKE_B, 1), jnp.int32)
    step = jax.jit(lambda p, c, n, t: zoo.decode_fn(cfg, p, c, n, t))
    logits, cache = step(params, cache, jnp.asarray(3, jnp.int32), token)
    assert logits.shape == (SMOKE_B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    # second step with the updated cache must also be finite
    logits2, _ = step(params, cache, jnp.asarray(4, jnp.int32), token)
    assert np.all(np.isfinite(np.asarray(logits2)))
    assert not np.allclose(np.asarray(logits), np.asarray(logits2))


def test_registry_complete():
    assert len(list_archs()) == 10
    for name in list_archs():
        cfg = get_arch(name)
        assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
