"""Particle-block sizing, shared by the Pallas kernels and the jnp async
fallback (ROADMAP: previously duplicated between ``kernels/ops.py`` and
``core/pso.py._default_async_blocks``; unified here).

``LANE`` is the TPU vector lane width: kernel block sizes want to be a
multiple of it so a block fills whole [8, 128] tiles. The jnp fallback has
no tile constraint and calls with ``lane=1`` (largest divisor wins,
alignment ignored) — which preserves its pre-unification block choices
bit-for-bit.
"""
from __future__ import annotations

LANE = 128


def pick_block_n(n: int, target: int = 512, lane: int = LANE) -> int:
    """Largest divisor of ``n`` that is <= ``target``, preferring
    ``lane``-aligned ones.

    One descending pass: the first ``lane``-aligned (multiple-of-``lane``)
    divisor wins outright; otherwise the first (i.e. largest) divisor of any
    kind is the fallback. With ``lane=1`` every divisor is "aligned", so the
    largest divisor <= target wins unconditionally. A prime ``n`` larger
    than ``target`` has no divisor <= target except 1.
    """
    best = 1
    for bn in range(min(n, target), 0, -1):
        if n % bn == 0:
            if bn % lane == 0:
                return bn
            if best == 1:
                best = bn
    return best


def default_block_count(n: int, target: int = 512) -> int:
    """Block COUNT for the jnp async fallback: the largest block size <=
    ``target`` that divides ``n``, alignment-free (``lane=1``)."""
    return n // pick_block_n(n, target, lane=1)
