"""Load generator: continuous batching vs flush batching on a mixed trace.

The serving claim under test (docs/serving.md): a stream with staggered
arrivals and MIXED iteration budgets fragments the flush server — its
group keys include ``iters``, so a wave of async requests at one solve
shape but four different budgets splits into four padded groups, each
bucket-padded up to ``MIN_VALIDATED_SWARMS`` rows and each running its
full budget on mostly-dead rows. The continuous scheduler's lane keys
DROP ``iters`` (accounting is per row), so the same trace rides one full
persistent lane and completed rows hand their slot to the next arrival
at a chunk boundary.

Both legs run the identical trace with the identical wave structure (a
wave of arrivals, then one scheduling opportunity: ``flush()`` vs
``step()``), share the ``ServingMetrics`` instrumentation, and are
measured in steady state: the first pass over the trace is warmup (it
pays the compiles; recorded as ``first_pass_s``), the second pass is the
reported one. Per-request results from the two legs are cross-checked
for bitwise agreement — both front ends sit on the row-bit-exact batched
engine, so any disagreement is a bug, not noise.

Reported per leg: wall us per request (the primary ``us_per_call``),
steady-state requests/s, e2e latency p50/p99, and batch fill
(real rows per dispatched slot). ``benchmarks/run.py`` wraps this as the
``serving/`` record family; standalone:

    PYTHONPATH=src python benchmarks/loadgen.py --smoke [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List, Optional

NAMES = ("cubic", "sphere", "rastrigin", "ackley", "griewank", "rosenbrock")


def make_trace(n_requests: int, dim: int = 6, particles: int = 64,
               sync_every: int = 8,
               iters_choices=(16, 32, 48, 64)) -> List:
    """A deterministic mixed trace: round-robin over the six built-ins
    crossed with the iteration budgets (coprime cycle lengths, so every
    (objective, budget) pair occurs). All-async, all one solve shape —
    the regime where lane sharing pays and flush grouping fragments."""
    from repro.launch.serve import SolveRequest
    return [SolveRequest(dim=dim, particle_cnt=particles,
                         fitness=NAMES[k % len(NAMES)], seed=k,
                         iters=iters_choices[k % len(iters_choices)],
                         variant="async", sync_every=sync_every)
            for k in range(n_requests)]


def _leg_summary(n: int, elapsed_s: float, metrics) -> dict:
    lat = metrics.span("e2e_us")
    return {"requests": n,
            "elapsed_s": elapsed_s,
            "requests_per_s": n / elapsed_s,
            "us_per_request": 1e6 * elapsed_s / n,
            "p50_us": lat.p50_us, "p99_us": lat.p99_us,
            "batch_fill": metrics.batch_fill,
            "dispatches": int(metrics.get("dispatches"))}


def run_continuous(trace, wave: int = 8, lane_width: int = 8,
                   compile_cache=None) -> dict:
    """One pass of the trace through ``ContinuousScheduler``: submit a
    wave, take one scheduling step, repeat; drain the tail."""
    from repro.serving import ContinuousScheduler, ServingMetrics
    m = ServingMetrics()
    sched = ContinuousScheduler(lane_width=lane_width,
                                compile_cache=compile_cache, metrics=m)
    t0 = time.perf_counter()
    tickets = []
    for lo in range(0, len(trace), wave):
        tickets.extend(sched.submit(r) for r in trace[lo:lo + wave])
        sched.step()
    resolved = sched.drain()
    elapsed = time.perf_counter() - t0
    out = _leg_summary(len(trace), elapsed, m)
    out["results"] = [resolved[t] for t in tickets]
    out["snapshot"] = sched.snapshot()
    return out


def run_flush(trace, wave: int = 8, coalesce_registry: bool = True) -> dict:
    """One pass of the trace through the flush server: submit a wave,
    ``flush()``, repeat — the same arrival structure as the continuous
    leg, but every wave blocks until its whole (fragmented) batch set
    returns."""
    from repro.launch.serve import SolveServer
    from repro.serving import ServingMetrics
    m = ServingMetrics()
    srv = SolveServer(coalesce_registry=coalesce_registry, metrics=m)
    t0 = time.perf_counter()
    tickets, resolved = [], {}
    for lo in range(0, len(trace), wave):
        tickets.extend(srv.submit(r) for r in trace[lo:lo + wave])
        resolved.update(srv.flush())
    elapsed = time.perf_counter() - t0
    out = _leg_summary(len(trace), elapsed, m)
    out["results"] = [resolved[t] for t in tickets]
    out["snapshot"] = srv.snapshot()
    return out


def _strip(leg: dict) -> dict:
    return {k: v for k, v in leg.items() if k not in ("results", "snapshot")}


def run_loadgen(smoke: bool = False, wave: int = 8, lane_width: int = 8,
                compile_cache=None, trace: Optional[list] = None) -> dict:
    """Race the two front ends on the same mixed trace (steady state).

    Pass 1 of each leg pays the compiles (warmup; both legs' programs are
    jit-cached in-process afterwards), pass 2 is reported. Returns the
    two steady-state leg summaries plus the cross-check and speedup.
    """
    if trace is None:
        n = 48 if smoke else 96
        iters_choices = (8, 16, 24, 32) if smoke else (16, 32, 48, 64)
        trace = make_trace(n, iters_choices=iters_choices)
    if compile_cache is None and os.environ.get("REPRO_COMPILE_CACHE"):
        # CI sets the env var so the lane programs' AOT blobs ship as an
        # artifact; XLA-cache redirection is left to the serving replica
        # (benchmarks elsewhere in the process keep their own compiles).
        from repro.serving import CompileCache
        compile_cache = CompileCache()
        compile_cache.prewarm()
    warm_c = run_continuous(trace, wave, lane_width, compile_cache)
    cont = run_continuous(trace, wave, lane_width, compile_cache)
    warm_f = run_flush(trace, wave)
    flush = run_flush(trace, wave)
    agree = all(
        rc.gbest_fit == rf.gbest_fit
        and (rc.gbest_pos == rf.gbest_pos).all()
        for rc, rf in zip(cont["results"], flush["results"]))
    return {"n_requests": len(trace),
            "wave": wave,
            "continuous": _strip(cont),
            "flush": _strip(flush),
            "continuous_first_pass_s": warm_c["elapsed_s"],
            "flush_first_pass_s": warm_f["elapsed_s"],
            "speedup_vs_flush": (cont["requests_per_s"]
                                 / flush["requests_per_s"]),
            "gbest_agree": bool(agree),
            "continuous_snapshot": cont["snapshot"],
            "flush_snapshot": flush["snapshot"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (24 requests, short budgets)")
    ap.add_argument("--wave", type=int, default=8,
                    help="arrivals per scheduling opportunity")
    ap.add_argument("--lane-width", type=int, default=8)
    ap.add_argument("--compile-cache", default=None,
                    help="directory for the persistent AOT compile cache")
    ap.add_argument("--json", default="",
                    help="write the full report here ('' disables)")
    args = ap.parse_args()
    cc = None
    if args.compile_cache:
        from repro.serving import CompileCache
        cc = CompileCache(args.compile_cache)
        cc.enable_xla_cache()
        cc.prewarm()
    rep = run_loadgen(smoke=args.smoke, wave=args.wave,
                      lane_width=args.lane_width, compile_cache=cc)
    for leg in ("continuous", "flush"):
        s = rep[leg]
        print(f"{leg:>10s}: {s['requests_per_s']:8.2f} req/s  "
              f"p50={s['p50_us']:.0f}us p99={s['p99_us']:.0f}us  "
              f"fill={s['batch_fill']:.2f}  dispatches={s['dispatches']}")
    print(f"continuous vs flush: {rep['speedup_vs_flush']:.2f}x req/s, "
          f"results bitwise agree: {rep['gbest_agree']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2, default=str)
            f.write("\n")
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
