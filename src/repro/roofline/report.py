"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
reports/dryrun.json.

    PYTHONPATH=src python -m repro.roofline.report reports/dryrun.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def dryrun_table(results: Dict) -> str:
    rows = ["| cell | mesh | status | HLO flops/dev | bytes/dev | "
            "coll GB/chip | mem/dev (arg+tmp) GB | compile s |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        arch_shape = "|".join(key.split("|")[:2])
        mesh = key.split("|")[2]
        if r.get("status") == "skip":
            rows.append(f"| {arch_shape} | {mesh} | skip | | | | | |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {arch_shape} | {mesh} | **FAIL** | | | | | |")
            continue
        chips = r.get("chips", 256)
        mem = r.get("mem_argument_gb", 0) + r.get("mem_temp_gb", 0)
        rows.append(
            f"| {arch_shape} | {mesh} | ok "
            f"| {r['flops_total']/chips:.2e} "
            f"| {r['bytes_total']/chips:.2e} "
            f"| {r['coll_bytes_per_chip']/1e9:.2f} "
            f"| {mem:.1f} "
            f"| {r.get('t_compile_s', 0):.0f} |")
    return "\n".join(rows)


def roofline_table(results: Dict) -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        if r.get("status") != "ok" or not key.endswith("16x16") \
                or "2x16x16" in key or "pieces" not in r:
            continue
        arch, shape, _ = key.split("|")
        rows.append(
            f"| {arch} | {shape} | {fmt_s(r['t_compute'])} "
            f"| {fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} "
            f"| **{r['bottleneck']}** | {r['model_flops']:.2e} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summary(results: Dict) -> str:
    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    skip = sum(1 for v in results.values() if v.get("status") == "skip")
    fail = sum(1 for v in results.values() if v.get("status") == "fail")
    return f"{ok} compiled ok, {skip} defined-skips, {fail} failures"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("## Summary\n")
    print(summary(results) + "\n")
    print("## Dry-run table\n")
    print(dryrun_table(results) + "\n")
    print("## Roofline table (single-pod 16x16)\n")
    print(roofline_table(results))


if __name__ == "__main__":
    main()
