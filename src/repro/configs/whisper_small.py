"""whisper-small — enc-dec backbone; conv audio frontend is a STUB: the
encoder consumes precomputed frame embeddings from input_specs()
(DESIGN.md §5). [arXiv:2212.04356; unverified]

Full attention everywhere ⇒ long_500k skipped. Decode runs (it has a
decoder with self- and cross-attention caches).
"""
from .base import ArchConfig, register

WHISPER_SMALL = register(ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    encdec=True, enc_layers=12,
    act="gelu",
    source="arXiv:2212.04356",
))
