import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-cell perf hillclimbing (EXPERIMENTS.md §Perf).

Re-runs ONE cell's piecewise roofline with ArchConfig overrides and prints
the before/after of all three terms vs the baseline in reports/dryrun.json.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch arctic-480b --shape train_4k \
        --set moe_expert_sharding=ep --set flash_custom_vjp=True \
        --tag ep_vjp --out reports/hillclimb.json
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get_arch  # noqa: E402
from repro.launch.mesh import data_axes, make_production_mesh  # noqa: E402
from repro.models.policy import activation_policy  # noqa: E402
from repro.roofline import analysis as ra  # noqa: E402
from repro.roofline.piecewise import analyze_cell_piecewise  # noqa: E402


def parse_val(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def run(arch: str, shape: str, overrides: dict, full: bool = False):
    cfg = get_arch(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh()
    chips = 256
    cell = SHAPES[shape]
    mem_temp_gb = None
    if full:
        # whole-graph compile: memory_analysis captures buffer reuse and
        # fusion, i.e. the true per-device residency (the bytes-accessed
        # piecewise proxy is fusion-naive on the CPU backend).
        import repro.launch.dryrun as dr
        with activation_policy(mesh, data_axes(mesh), "model"):
            import unittest.mock as um
            with um.patch("repro.launch.dryrun.get_arch",
                          lambda name: cfg):
                res = dr._run_cell_inner(cfg, arch, shape, False, mesh,
                                         verbose=False)
        mem_temp_gb = res["mem_temp_gb"]
    with activation_policy(mesh, data_axes(mesh), "model"):
        pw = analyze_cell_piecewise(cfg, shape, mesh)
    from repro.models import zoo
    params_shape = zoo.abstract_params(cfg)
    kind = cell.kind if cell.kind != "prefill" else "prefill"
    tokens = (cell.global_batch if cell.kind == "decode"
              else cell.seq_len * cell.global_batch)
    mf = ra.model_flops(cfg, params_shape, cell.kind, tokens)
    t_c = pw["flops_dev"] / ra.PEAK_FLOPS
    t_m = pw["bytes_dev"] / ra.HBM_BW
    t_x = pw["coll_bytes_dev"] / ra.ICI_BW
    crit = max(t_c, t_m, t_x)
    return {
        "arch": arch, "shape": shape, "overrides": overrides,
        "flops_dev": pw["flops_dev"], "bytes_dev": pw["bytes_dev"],
        "coll_bytes_dev": pw["coll_bytes_dev"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": max((("compute", t_c), ("memory", t_m),
                           ("collective", t_x)), key=lambda kv: kv[1])[0],
        "model_flops": mf,
        "useful_ratio": mf / max(pw["flops_dev"] * chips, 1.0),
        "roofline_fraction": (mf / (chips * ra.PEAK_FLOPS)) / max(crit,
                                                                  1e-30),
        "mem_temp_gb": mem_temp_gb,
        "pieces": pw["pieces"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    metavar="FIELD=VALUE")
    ap.add_argument("--tag", default="exp")
    ap.add_argument("--out", default="reports/hillclimb.json")
    ap.add_argument("--baseline", default="reports/dryrun.json")
    ap.add_argument("--full", action="store_true",
                    help="also whole-graph compile for memory_analysis")
    args = ap.parse_args()

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    res = run(args.arch, args.shape, overrides, full=args.full)

    # compare vs baseline
    base = {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            b = json.load(f)
        base = b.get(f"{args.arch}|{args.shape}|16x16", {})
    print(f"\n=== {args.arch} | {args.shape} | {args.tag} ===")
    hdr = f"{'term':13s} {'baseline':>12s} {'this':>12s} {'delta':>8s}"
    print(hdr)
    for term in ("t_compute", "t_memory", "t_collective",
                 "roofline_fraction", "useful_ratio"):
        b0 = base.get(term)
        v = res[term]
        if b0:
            print(f"{term:13s} {b0:12.4f} {v:12.4f} {v/b0-1:+8.1%}")
        else:
            print(f"{term:13s} {'—':>12s} {v:12.4f}")
    print(f"bottleneck: {base.get('bottleneck', '—')} -> {res['bottleneck']}")
    if res.get("mem_temp_gb") is not None:
        print(f"mem_temp_gb: {base.get('mem_temp_gb', float('nan')):.1f}"
              f" -> {res['mem_temp_gb']:.1f}")

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    results[f"{args.arch}|{args.shape}|{args.tag}"] = res
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
