"""Inner-scan unroll switch for piecewise roofline analysis.

``compiled.cost_analysis()`` counts lax.scan bodies once; the piecewise
analyzer (repro.roofline.piecewise) therefore lowers single pieces with
inner loops UNROLLED so each piece's cost is exact. Production lowering
keeps scans (small HLO, fast compile). Flip via ``unrolled()`` context.
"""
from __future__ import annotations

import contextlib

import jax

_STATE = {"unroll": False}


def is_unrolled() -> bool:
    return _STATE["unroll"]


@contextlib.contextmanager
def unrolled(on: bool = True):
    prev = _STATE["unroll"]
    _STATE["unroll"] = on
    try:
        yield
    finally:
        _STATE["unroll"] = prev


def maybe_scan(body, carry, xs, length=None):
    """lax.scan, or an equivalent python loop when unroll mode is on.
    xs: pytree of stacked arrays (or None with ``length``)."""
    if not _STATE["unroll"]:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(int(n)):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jax.numpy.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
