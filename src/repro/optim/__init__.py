from .optimizers import (OptState, adafactor_init, adafactor_update,
                         adam_init, adam_update, get_optimizer, sgd_init,
                         sgd_update)
from .schedules import cosine_schedule, linear_warmup
from .pso_optimizer import PSOOptimizer

__all__ = ["OptState", "adam_init", "adam_update", "adafactor_init",
           "adafactor_update", "sgd_init", "sgd_update", "get_optimizer",
           "cosine_schedule", "linear_warmup", "PSOOptimizer"]
