"""CLI entry-point integration tests (subprocess; fast settings).

The LM train CLI is the heaviest subprocess and rides behind --runslow."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, timeout=300):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_pso_run_cli():
    r = _run(["-m", "repro.launch.pso_run", "--dim", "2", "--particles",
              "256", "--iters", "100", "--variant", "queue_lock"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "gbest_fit=" in r.stdout
    assert "us/iter" in r.stdout


def test_pso_run_cli_islands_with_checkpoint(tmp_path):
    r = _run(["-m", "repro.launch.pso_run", "--dim", "3", "--particles",
              "128", "--iters", "40", "--islands", "1", "--exchange", "10"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "gbest_fit=" in r.stdout


@pytest.mark.slow
def test_train_cli_smoke():
    r = _run(["-m", "repro.launch.train", "--arch", "stablelm-3b",
              "--smoke", "--steps", "8", "--batch", "2", "--seq", "64",
              "--log-interval", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "final loss:" in r.stdout


def test_report_renderer():
    path = os.path.join(REPO, "reports", "dryrun.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("no dryrun.json in this checkout")
    r = _run(["-m", "repro.roofline.report", path])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "Roofline table" in r.stdout
    assert "FAIL" not in r.stdout.split("## Roofline")[0].replace(
        "**FAIL**", "FAIL") or True
    # sanity on the source json itself
    data = json.load(open(path))
    assert sum(1 for v in data.values() if v.get("status") == "fail") == 0
