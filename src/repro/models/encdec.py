"""Encoder-decoder backbone (whisper-small). The conv audio frontend is a
STUB per the assignment: the encoder consumes precomputed frame embeddings
[B, S_enc, d] from input_specs(). Decoder: causal self-attention +
cross-attention to the encoder output; decode keeps a self KV cache and a
precomputed cross KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from .layers import chunked_xent, dense_init, embed_init, init_mlp, mlp, \
    rmsnorm, rmsnorm_init
from .transformer import _remat

Params = Dict[str, Any]


def _init_enc_layer(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(d, dt),
            "attn": attn.init_gqa(k1, d, cfg.n_heads, cfg.n_kv_heads, hd,
                                  False, dt),
            "ln2": rmsnorm_init(d, dt),
            "mlp": init_mlp(k2, d, cfg.d_ff, cfg.act, dt)}


def _init_dec_layer(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(d, dt),
            "self_attn": attn.init_gqa(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                       hd, False, dt),
            "ln_x": rmsnorm_init(d, dt),
            "cross_attn": attn.init_gqa(k2, d, cfg.n_heads, cfg.n_kv_heads,
                                        hd, False, dt),
            "ln2": rmsnorm_init(d, dt),
            "mlp": init_mlp(k3, d, cfg.d_ff, cfg.act, dt)}


def init_params(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": embed_init(kt, cfg.vocab, cfg.d_model, dt),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(cfg, k))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(cfg, k))(dec_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
        "unembed": dense_init(kh, cfg.d_model, cfg.vocab, dt),
    }


def _kw(cfg: ArchConfig):
    return dict(h=cfg.n_heads, kh=cfg.n_kv_heads, hd=cfg.resolved_head_dim,
                theta=cfg.rope_theta, q_block=cfg.attn_q_block,
                kv_block=cfg.attn_kv_block)


def encode(cfg: ArchConfig, params: Params, frames):
    """frames: [B, S_enc, d] (stub embeddings). Bidirectional encoder."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = frames.astype(jnp.dtype(cfg.param_dtype))

    def body(lp, x):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.gqa_project(lp["attn"], h, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.resolved_head_dim)
        from .layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        a = attn.flash_attention(q, k, v, causal=False,
                                 q_block=cfg.attn_q_block,
                                 kv_block=cfg.attn_kv_block)
        a = a.reshape(b, s, -1) @ lp["attn"]["wo"]
        x = x + a
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2, cfg.act)

    rb = _remat(body, cfg.remat)

    def step(x, lp):
        return rb(lp, x), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_attend(cfg, lp, x, enc_kv):
    """x: [B, St, d]; enc_kv: (k, v) [B, Se, K, hd]."""
    b, st, _ = x.shape
    h = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
    q = (h @ lp["cross_attn"]["wq"]).reshape(
        b, st, cfg.n_heads, cfg.resolved_head_dim)
    out = attn.flash_attention(q, enc_kv[0], enc_kv[1], causal=False,
                               q_block=cfg.attn_q_block,
                               kv_block=cfg.attn_kv_block)
    return out.reshape(b, st, -1) @ lp["cross_attn"]["wo"]


def _enc_kv(cfg, lp, enc_out):
    b, se, _ = enc_out.shape
    k = (enc_out @ lp["cross_attn"]["wk"]).reshape(
        b, se, cfg.n_kv_heads, cfg.resolved_head_dim)
    v = (enc_out @ lp["cross_attn"]["wv"]).reshape(
        b, se, cfg.n_kv_heads, cfg.resolved_head_dim)
    return k, v


def decode_train(cfg: ArchConfig, params: Params, tokens, enc_out):
    """Teacher-forced decoder forward. Returns final hidden [B, St, d]."""
    b, st = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(st)[None], (b, st))

    def body(lp, x):
        a = attn.gqa_forward(lp["self_attn"],
                             rmsnorm(lp["ln1"], x, cfg.norm_eps),
                             positions, **_kw(cfg))
        x = x + a
        kv = _enc_kv(cfg, lp, enc_out)
        x = x + _cross_attend(cfg, lp, x, kv)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h2, cfg.act)

    rb = _remat(body, cfg.remat)
    x, _ = jax.lax.scan(lambda x, lp: (rb(lp, x), None), x,
                        params["dec_layers"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def loss_fn(cfg: ArchConfig, params: Params, batch):
    enc = encode(cfg, params, batch["frames"])
    h = decode_train(cfg, params, batch["tokens"], enc)
    return chunked_xent(h, params["unembed"], batch["labels"],
                        cfg.loss_chunk, pad_vocab=cfg.pad_vocab)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int):
    dt = jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dt),
        "xk": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd), dt),
        "xv": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd), dt),
    }


def decode_step(cfg: ArchConfig, params: Params, cache, cache_len, token):
    """One decoder token; cross KV already lives in the cache."""
    x = jnp.take(params["embed"], token, axis=0)      # [B, 1, d]
    kw = _kw(cfg)
    kw.pop("q_block"), kw.pop("kv_block")

    def body(x, lc):
        lp, cl = lc
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a, new_kv = attn.gqa_decode(lp["self_attn"], h,
                                    {"k": cl["k"], "v": cl["v"]},
                                    cache_len, **kw)
        x = x + a
        hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        b = x.shape[0]
        q = (hx @ lp["cross_attn"]["wq"]).reshape(
            b, 1, cfg.n_heads, cfg.resolved_head_dim)
        xa = attn.decode_attention(q, cl["xk"], cl["xv"],
                                   cl["xk"].shape[1])
        x = x + xa.reshape(b, 1, -1) @ lp["cross_attn"]["wo"]
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h2, cfg.act)
        return x, dict(cl, k=new_kv["k"], v=new_kv["v"])

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, new_cache
