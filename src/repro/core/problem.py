"""First-class optimization problems: user-defined objectives as data.

The paper treats the fitness as a pluggable device function evaluated inside
the update kernel (cuPSO §5.1); a registry of six benchmark names can never
enumerate the time-critical, application-specific objectives real workloads
bring (Low-Complexity PSO, arXiv 1401.0546). ``Problem`` makes an objective a
frozen, hashable value that travels through every layer — configs (it is a
valid jit static argument), the jnp step variants, the fused/async/batched
Pallas kernels (via the generic d-major adapter in ``repro.kernels.pso_step``
or a hand-tuned ``kernel_fn``), the serving front end (content-hashed compile
keys), the tuner and the distributed runner.

Conventions
-----------
* ``fn`` is pure jnp, maps ``pos[..., D] -> fit[...]``, and must be safe
  under jit/vmap/shard_map (no Python side effects, shapes static).
* The engine always MAXIMIZES. ``sense="max"`` (default) uses ``fn`` as-is;
  ``sense="min"`` canonicalizes internally (``max_fn`` negates), and
  user-facing results convert back via ``user_value``. The six built-ins in
  ``repro.core.fitness`` bake their negation into ``fn`` itself (legacy
  convention) and therefore register with ``sense="max"``.
* ``lo``/``hi`` bounds are a scalar (every dimension shares the box, the
  seed behavior) or a length-D tuple (per-dimension boxes). Tuples keep the
  Problem hashable; arrays/lists are normalized in ``__post_init__``.
* ``constraints`` optionally attaches a ``repro.core.constraints.
  ConstraintSet`` (inequality/equality feasibility with penalty, projection
  or repair handling) — see that module for the Deb rule and which mode
  composes with which backend. Constrained problems never take the
  hand-tuned kernel fast paths (``kernel_fn`` is mutually exclusive with
  ``constraints``); they lower through the generic d-major adapter.
* ``kernel_fn``, when given, is a hand-tuned d-major form
  ``(pos [Dpad, bn], dmask, d_real) -> fit [1, bn]`` in CANONICAL (max)
  convention with padded sublanes masked/ignored — the same contract as
  ``repro.kernels.pso_step._fitness_dmajor``. Without it, custom objectives
  are lowered automatically by ``repro.kernels.pso_step.dmajor_adapter``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import types
from typing import Callable, Dict, Optional, Tuple, Union

Bound = Union[float, Tuple[float, ...]]


def _norm_bound(v) -> Bound:
    """Normalize a bound to a hashable float or tuple-of-floats."""
    if isinstance(v, (int, float)):
        return float(v)
    try:
        return tuple(float(x) for x in v)
    except TypeError:
        raise TypeError(f"bound must be a scalar or a sequence, got {v!r}")


def broadcast_bounds(lo: Bound, hi: Bound) -> Tuple[Bound, Bound]:
    """Make a (lo, hi) pair rank-consistent: if exactly one side is
    per-dimension, broadcast the scalar side to match."""
    if isinstance(lo, tuple) and not isinstance(hi, tuple):
        hi = (float(hi),) * len(lo)
    elif isinstance(hi, tuple) and not isinstance(lo, tuple):
        lo = (float(lo),) * len(hi)
    return lo, hi


# --- content hashing helpers (cache_key) ----------------------------------
# repr() is NOT a faithful serialization: numpy/jax truncate array reprs at
# ~1000 elements and 8 significant digits, so two behaviourally different
# objectives could collide — and the serving layer would then silently solve
# one request against the other's landscape. Hash raw array bytes and
# recurse into nested functions/code objects instead.

def _hash_value(h, v, depth: int = 0) -> None:
    import numpy as np
    if depth > 6:
        h.update(b"<deep>")
        return
    if v is None or isinstance(v, (str, bytes, int, float, bool, complex)):
        h.update(repr(v).encode())
    elif isinstance(v, (tuple, list)):
        h.update(b"(")
        for x in v:
            _hash_value(h, x, depth + 1)
        h.update(b")")
    elif isinstance(v, types.CodeType):
        _hash_code(h, v, depth + 1)
    elif callable(v):
        _hash_fn(h, v, depth + 1)
    else:
        try:
            arr = np.asarray(v)
            if arr.dtype != object:
                h.update(str(arr.dtype).encode())
                h.update(repr(arr.shape).encode())
                h.update(arr.tobytes())
                return
        except Exception:
            pass
        h.update(repr(v).encode())


def _hash_code(h, code: types.CodeType, depth: int) -> None:
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    _hash_value(h, code.co_consts, depth)      # may nest code objects


def _hash_fn(h, fn, depth: int = 0) -> None:
    if isinstance(fn, functools.partial):
        _hash_fn(h, fn.func, depth)
        _hash_value(h, fn.args, depth)
        _hash_value(h, tuple(sorted(fn.keywords.items())), depth)
        return
    code = getattr(fn, "__code__", None)
    if code is None:
        h.update(repr(fn).encode())
        return
    _hash_code(h, code, depth)
    _hash_value(h, getattr(fn, "__defaults__", None), depth)
    try:
        cells = tuple(c.cell_contents for c in (fn.__closure__ or ()))
    except ValueError:                          # unfilled cell
        h.update(b"<cell>")
        return
    _hash_value(h, cells, depth)


@dataclasses.dataclass(frozen=True)
class Problem:
    """A named objective with bounds and sense — hashable, jit-static.

    ``lo``/``hi`` may be scalars or length-D tuples (per-dimension boxes);
    a ``bounds=(lo, hi)`` pair may be passed instead of the two fields.
    Equality/hash follow dataclass semantics (``fn`` by identity), which is
    what jit caching needs; the serving layer uses the *content* hash
    ``cache_key()`` so two distinct objectives never share a compile key
    even if they collide on ``name``.
    """

    name: str
    fn: Callable
    lo: Bound = -100.0
    hi: Bound = 100.0
    sense: str = "max"
    kernel_fn: Optional[Callable] = None
    constraints: Optional[object] = None   # repro.core.constraints.ConstraintSet
    bounds: dataclasses.InitVar[Optional[Tuple[Bound, Bound]]] = None

    def __post_init__(self, bounds):
        if bounds is not None:
            lo, hi = bounds
        else:
            lo, hi = self.lo, self.hi
        lo, hi = broadcast_bounds(_norm_bound(lo), _norm_bound(hi))
        if isinstance(lo, tuple):
            if len(lo) != len(hi):
                raise ValueError(
                    f"lo/hi lengths differ: {len(lo)} vs {len(hi)}")
            # lo == hi on a dimension is legal: the coordinate is frozen
            # (zero span, zero velocity budget) — see tests/test_bounds.py.
            bad = not all(l <= h for l, h in zip(lo, hi))
        else:
            bad = not lo <= hi
        if bad:
            raise ValueError(f"need lo <= hi elementwise, got {lo} / {hi}")
        if self.sense not in ("min", "max"):
            raise ValueError(f"sense must be 'min' or 'max', got {self.sense!r}")
        if not (isinstance(self.name, str) and self.name):
            raise ValueError("Problem.name must be a non-empty string")
        if not callable(self.fn):
            raise TypeError("Problem.fn must be callable")
        if self.constraints is not None:
            from .constraints import ConstraintSet
            if not isinstance(self.constraints, ConstraintSet):
                raise TypeError(
                    f"constraints must be a repro.core.constraints."
                    f"ConstraintSet, got {self.constraints!r}")
            if self.kernel_fn is not None:
                raise ValueError(
                    "kernel_fn and constraints are mutually exclusive: a "
                    "hand-tuned kernel form cannot apply the penalty/"
                    "projection (drop kernel_fn; the adapter lowers the "
                    "constrained objective automatically)")
        object.__setattr__(self, "lo", lo)
        object.__setattr__(self, "hi", hi)

    # -- canonical (maximization) view -------------------------------------
    @property
    def max_fn(self) -> Callable:
        """``fn`` in the engine's canonical maximization convention —
        including the penalty term for a ``mode="penalty"`` constraint set
        (``max_fn(x) = sense-canonical fn(x) - weight * violation(x)``), so
        penalized fitness rides every engine/kernel path exactly like any
        other custom objective.

        The wrapper is cached on the instance (not in a global cache, which
        would pin every one-off serving objective — and its closed-over
        arrays — in memory forever), so repeated accesses return the same
        object and jit tracing stays stable.
        """
        cset = self.constraints
        penalized = cset is not None and cset.mode == "penalty"
        if self.sense == "max" and not penalized:
            return self.fn
        cached = self.__dict__.get("_max_fn")
        if cached is None:
            fn = self.fn
            neg = self.sense == "min"
            if penalized:
                viol = cset.violation_fn()
                weight = cset.weight

                def wrapped(pos):
                    f = fn(pos)
                    if neg:
                        f = -f
                    return f - weight * viol(pos)

                wrapped.__name__ = (
                    f"penalized_{getattr(fn, '__name__', 'fn')}")
            else:
                def wrapped(pos):
                    return -fn(pos)

                wrapped.__name__ = f"neg_{getattr(fn, '__name__', 'fn')}"
            object.__setattr__(self, "_max_fn", wrapped)
            cached = wrapped
        return cached

    def user_value(self, canonical_fit):
        """Map a canonical (maximized) fitness back to the user's sense.

        For penalty-constrained problems the canonical fitness carries the
        penalty term; at a feasible point (violation 0) the mapped value is
        exactly the user objective."""
        return -canonical_fit if self.sense == "min" else canonical_fit

    # -- constraints --------------------------------------------------------
    @property
    def constrained(self) -> bool:
        return self.constraints is not None

    @property
    def projection_fn(self) -> Optional[Callable]:
        """The feasibility projection ``pos[..., D] -> pos`` (applied after
        the box clip), or None for every mode but "projection"."""
        cset = self.constraints
        if cset is not None and cset.mode == "projection":
            return cset.projection
        return None

    @property
    def violation_fn(self) -> Optional[Callable]:
        """Aggregate violation ``pos[..., D] -> viol[...]``, or None when
        unconstrained."""
        cset = self.constraints
        return None if cset is None else cset.violation_fn()

    def violation_at(self, pos) -> float:
        """Host-side violation of one position vector (0.0 if
        unconstrained)."""
        vf = self.violation_fn
        return 0.0 if vf is None else float(vf(pos))

    def with_penalty_weight(self, weight: float) -> "Problem":
        """This problem at a different penalty weight (the ramp schedule's
        per-segment step; see ``repro.core.constraints``)."""
        if self.constraints is None or self.constraints.mode != "penalty":
            raise ValueError("with_penalty_weight needs a penalty-mode "
                             "constraint set")
        return dataclasses.replace(
            self, constraints=self.constraints.with_weight(weight))

    @property
    def ndim(self) -> Optional[int]:
        """Dimensionality pinned by per-dimension bounds (None if scalar)."""
        return len(self.lo) if isinstance(self.lo, tuple) else None

    # -- content identity ---------------------------------------------------
    def cache_key(self) -> str:
        """Content hash for serving/compile-cache keys.

        Hashes the objective's *code* (bytecode, consts — raw array bytes,
        never truncated reprs — closure values, defaults, nested
        functions), bounds and sense — not the Python object identity — so
        two requests carrying behaviourally different objectives under the
        same ``name`` can never be batched into one compiled program, while
        re-constructed but identical Problems still share one. Memoized on
        the (frozen) instance: the serving layer recomputes batch keys per
        flush, and hashing a large closed-over array every time would sit
        on the request hot path.
        """
        cached = self.__dict__.get("_cache_key")
        if cached is None:
            h = hashlib.sha1()
            _hash_value(h, (self.name, self.sense, self.lo, self.hi))
            for fn in (self.fn, self.kernel_fn):
                _hash_value(h, fn)
            if self.constraints is not None:
                # mode/weights/constraint code all change the compiled
                # program — two differently-constrained objectives must
                # never share a serving batch.
                _hash_value(h, self.constraints._content())
            cached = h.hexdigest()[:16]
            object.__setattr__(self, "_cache_key", cached)
        return cached


# --------------------------------------------------------------------------
# Registry: the legacy string path ("cubic", ...) resolves through here.
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, Problem] = {}


def register_problem(problem: Union[Problem, str], fn: Callable = None, *,
                     overwrite: bool = False, **kwargs) -> Problem:
    """Register a Problem under its name.

    Two forms::

        register_problem(Problem(name="mine", fn=f, lo=-1.0, hi=1.0))
        register_problem("mine", f, lo=-1.0, hi=1.0, sense="min")

    Re-registering an identical Problem is a no-op; a *different* Problem
    under an existing name raises unless ``overwrite=True`` (silent
    replacement would re-route every config already holding the string).
    """
    if isinstance(problem, str):
        problem = Problem(name=problem, fn=fn, **kwargs)
    elif fn is not None or kwargs:
        raise TypeError("pass either a Problem or (name, fn, **fields)")
    old = _REGISTRY.get(problem.name)
    if old is not None and old != problem and not overwrite:
        raise ValueError(
            f"problem {problem.name!r} already registered with different "
            f"content; pass overwrite=True to replace it")
    _REGISTRY[problem.name] = problem
    return problem


def get_problem(name: str) -> Problem:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown problem {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY)) or '<none>'}") from None


def list_problems() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_problem(obj: Union[str, Problem, Callable]) -> Problem:
    """str -> registry lookup; Problem -> itself; bare callable -> an
    anonymous max-sense Problem with the default [-100, 100] box."""
    if isinstance(obj, Problem):
        return obj
    if isinstance(obj, str):
        return get_problem(obj)
    if callable(obj):
        return Problem(name=getattr(obj, "__name__", "anonymous"), fn=obj)
    raise TypeError(f"cannot resolve {obj!r} to a Problem")
