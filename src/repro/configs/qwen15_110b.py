"""qwen1.5-110b — largest dense arch; GQA kv=8, QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ArchConfig, register

QWEN15_110B = register(ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
    optimizer="adafactor",
    source="hf:Qwen/Qwen1.5-0.5B",
))
