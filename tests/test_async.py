"""Async queue-lock validation: the paper's enhanced variant across all
four layers — Pallas kernel vs bit-exact oracle, sync_every=1 / single-block
identity with the synchronous fused kernel, batched row identity, the jnp
fallback's staleness bound and convergence quality, and the serving path.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PSOConfig, batch_row, init_async_locals, init_batch,
                        init_swarm, publish_async_locals, run, run_async,
                        solve, solve_many, step_async)
from repro.kernels import ops, ref

SEEDS = [0, 1, 7, 42, 99, 123, 100000, 2 ** 31 - 5]


def _oracle_kwargs(cfg, dim):
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = dim
    return kw


# --------------------------------------------------------------------------
# Kernel: the sync fused kernel is a special case of the async one.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sync_every", [1, 4])
def test_async_kernel_single_block_bit_identical_to_fused(sync_every):
    """With one particle block the block-local best IS the global best, so
    the async kernel — through an entirely different grid (block-major,
    chunked, fori-loop body, local-best carry) — must reproduce the
    synchronous fused kernel bit-for-bit for EVERY sync_every. This is the
    acceptance identity: run_queue_lock_fused_async(sync_every=1) ==
    run_queue_lock_fused."""
    cfg = PSOConfig(dim=3, particle_cnt=128, fitness="cubic")
    s = init_swarm(cfg, 7)
    a = ops.run_queue_lock_fused_async(cfg, s, iters=8,
                                       sync_every=sync_every, block_n=128)
    f = ops.run_queue_lock_fused(cfg, s, iters=8, block_n=128)
    for name in ("pos", "vel", "pbest_pos", "pbest_fit",
                 "gbest_pos", "gbest_fit"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(f, name)),
                                      err_msg=name)
    assert int(a.iteration) == int(f.iteration) == 8


ASYNC_SWEEP = [
    # (dim, n, block_n, iters, sync_every) — multi-block relaxed schedules,
    # including a remainder split (10 % 4) and the paper's 120D regime.
    (1, 128, 64, 8, 2),
    (2, 256, 64, 10, 4),
    (7, 256, 128, 8, 8),
    (120, 256, 128, 6, 3),
    pytest.param(33, 384, 128, 9, 4, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("dim,n,bn,iters,k", ASYNC_SWEEP)
def test_async_kernel_vs_oracle(dim, n, bn, iters, k):
    """Multi-block async kernel vs the eager oracle that mirrors the
    block-major publication order bit-exactly."""
    cfg = PSOConfig(dim=dim, particle_cnt=n, fitness="cubic").resolved()
    s = init_swarm(cfg, 42)
    out = ops.run_queue_lock_fused_async(cfg, s, iters=iters, sync_every=k,
                                         block_n=bn)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s, dim)
    kw = _oracle_kwargs(cfg, dim)
    fitness_name = kw.pop("fitness")
    o = ref.run_fused_async_oracle(
        int(s.seed), int(s.iteration), pos, vel, pbp, pbf, gp,
        float(gf[0]), iters, bn, k, fitness=fitness_name, **kw)
    # atol: the kernel's compiled fori-loop chunk body may FMA-contract one
    # ulp differently from the oracle's eager per-iteration loop; chaotic
    # dynamics amplify it (~1e-5 -> ~1e-3 over these spans on [-100, 100])
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, dim)),
                               np.asarray(o[0]), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o[3])[0], rtol=1e-4, atol=0.5)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out.gbest_pos),
                               np.asarray(o[4])[:dim, 0],
                               rtol=1e-4, atol=1e-4)


def test_async_kernel_iteration_counter_chains():
    """Two async calls of k iters == one call of 2k iters (RNG continuity)
    in the single-block regime where the schedule is call-split invariant."""
    cfg = PSOConfig(dim=9, particle_cnt=128, fitness="sphere")
    s = init_swarm(cfg, 13)
    a = ops.run_queue_lock_fused_async(cfg, s, iters=4, sync_every=2,
                                       block_n=128)
    a = ops.run_queue_lock_fused_async(cfg, a, iters=4, sync_every=2,
                                       block_n=128)
    b = ops.run_queue_lock_fused_async(cfg, s, iters=8, sync_every=2,
                                       block_n=128)
    np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos),
                               rtol=1e-5, atol=1e-5)
    assert int(a.iteration) == int(b.iteration) == 8


def test_async_batch_rows_bit_identical_to_single():
    """Batched async kernel row s == standalone async kernel (exact)."""
    cfg = PSOConfig(dim=7, particle_cnt=256, fitness="cubic")
    b = init_batch(cfg, SEEDS[:4])
    out = ops.run_queue_lock_fused_async_batch(cfg, b, iters=10,
                                               sync_every=4, block_n=64)
    for s in range(4):
        single = ops.run_queue_lock_fused_async(
            cfg, batch_row(b, s), iters=10, sync_every=4, block_n=64)
        np.testing.assert_array_equal(np.asarray(out.pos[s]),
                                      np.asarray(single.pos))
        np.testing.assert_array_equal(np.asarray(out.gbest_fit)[s],
                                      np.asarray(single.gbest_fit))
        np.testing.assert_array_equal(np.asarray(out.gbest_pos[s]),
                                      np.asarray(single.gbest_pos))
        np.testing.assert_array_equal(np.asarray(out.pbest_fit[s]),
                                      np.asarray(single.pbest_fit))


# --------------------------------------------------------------------------
# Library fallback: relaxed-consistency semantics.
# --------------------------------------------------------------------------

def test_async_staleness_bound():
    """The consistency contract: every block's local best is never below
    the shared gbest of the last sync point (staleness <= sync_every), and
    at each sync point the shared gbest equals the true swarm-wide best."""
    cfg = PSOConfig(dim=4, particle_cnt=128, fitness="rastrigin").resolved()
    k, nb = 4, 4
    s = init_swarm(cfg, 5)
    local = init_async_locals(s, nb)
    last_sync_gbest = float(s.gbest_fit)
    for t in range(1, 3 * k + 1):
        s, local = step_async(cfg, s, local)
        lbf = np.asarray(local[1])
        # between syncs: no block has forgotten the last synced best
        assert np.all(lbf >= last_sync_gbest - 0.0)
        # shared gbest is untouched (stale) between syncs
        if t % k:
            assert float(s.gbest_fit) == last_sync_gbest
        else:
            s, local = publish_async_locals(s, local)
            # sync point: shared best == true best over everything seen
            true_best = max(float(np.max(np.asarray(s.pbest_fit))),
                            last_sync_gbest)
            assert float(s.gbest_fit) == true_best
            # pull: every block now sees the fresh shared best
            np.testing.assert_array_equal(
                np.asarray(local[1]),
                np.full(nb, float(s.gbest_fit), np.float32))
            last_sync_gbest = float(s.gbest_fit)


def test_run_async_final_flush():
    """run_async always ends on a sync: gbest_fit == max(pbest_fit), for
    multiple-of-sync_every and remainder iteration counts alike."""
    cfg = PSOConfig(dim=2, particle_cnt=256, fitness="cubic")
    s = init_swarm(cfg, 3)
    for iters in (8, 11):                  # 11 = 2 chunks of 4 + rem 3
        out = run_async(cfg, s, iters, sync_every=4, n_blocks=4)
        assert float(out.gbest_fit) == float(jnp.max(out.pbest_fit))
        assert int(out.iteration) == iters


@pytest.mark.parametrize("fitness,dim,tol", [
    ("cubic", 1, 0.01),        # fraction of the optimum's magnitude
    ("sphere", 3, 0.02),
    ("rastrigin", 3, 0.02),
])
def test_async_convergence_quality_vs_sync(fitness, dim, tol):
    """Relaxed consistency must not cost convergence: async final gbest
    within a small tolerance of synchronous queue_lock (both near-optimal).
    Tolerance is relative to the optimum magnitude / search-span scale."""
    cfg = PSOConfig(dim=dim, particle_cnt=256, fitness=fitness,
                    w=0.7).resolved()
    s = init_swarm(cfg, 0)
    sync = run(cfg, s, 200, "queue_lock")
    a = run_async(cfg, s, 200, sync_every=16, n_blocks=4)
    scale = max(abs(float(sync.gbest_fit)), 1.0)
    gap = float(sync.gbest_fit) - float(a.gbest_fit)
    assert gap <= tol * scale, (float(a.gbest_fit), float(sync.gbest_fit))


def test_solve_many_async_rows_bit_identical_to_solve():
    """variant="async" through the batched engine: vmapped run_async row s
    is bit-identical to the standalone solve (the engine's contract)."""
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="rastrigin")
    b = solve_many(cfg, SEEDS, iters=25, variant="async")
    for i, sd in enumerate(SEEDS):
        s = solve(cfg, seed=sd, iters=25, variant="async")
        assert np.asarray(b.gbest_fit)[i] == np.asarray(s.gbest_fit)
        np.testing.assert_array_equal(np.asarray(b.pos[i]),
                                      np.asarray(s.pos))
        np.testing.assert_array_equal(np.asarray(b.pbest_fit[i]),
                                      np.asarray(s.pbest_fit))
    assert int(b.iteration[0]) == 25


def test_run_variant_async_dispatch():
    """run()/solve() accept variant="async" and actually relax: sync_every
    changes the trajectory (different consistency => different dynamics).
    particle_cnt=1024 so the default block picker yields > 1 block — with a
    single block the async schedule degenerates to the synchronous one and
    sync_every would be a no-op."""
    cfg = PSOConfig(dim=2, particle_cnt=1024, fitness="rastrigin")
    s = init_swarm(cfg, 1)
    a1 = run(cfg, s, 12, "async", sync_every=1)
    a8 = run(cfg, s, 12, "async", sync_every=8)
    assert a1.pos.shape == a8.pos.shape
    assert not np.array_equal(np.asarray(a1.pos), np.asarray(a8.pos))


# --------------------------------------------------------------------------
# Serving surface.
# --------------------------------------------------------------------------

def test_solve_server_async_variant_both_backends():
    from repro.launch.serve import SolveRequest, SolveServer
    reqs = [SolveRequest(dim=2, particle_cnt=128, fitness="cubic", seed=i,
                         iters=8, variant="async", sync_every=4)
            for i in range(3)]
    # jnp backend == solve_many(variant="async") == standalone run_async
    jnp_srv = SolveServer(max_batch=8, backend="jnp")
    for r in jnp_srv.solve_all(reqs):
        cfg = r.request.config().resolved()
        direct = run_async(cfg, init_swarm(cfg, r.request.seed), 8,
                           sync_every=4)
        assert r.gbest_fit == float(direct.gbest_fit)
    # kernel backend routes through the batched async pallas_call
    k_srv = SolveServer(max_batch=8, backend="kernel", block_n=64)
    for r in k_srv.solve_all(reqs):
        cfg = r.request.config().resolved()
        direct = ops.run_queue_lock_fused_async(
            cfg, init_swarm(cfg, r.request.seed), iters=8, sync_every=4,
            block_n=64)
        assert r.gbest_fit == float(direct.gbest_fit)


def test_sync_every_is_part_of_compile_key_for_async_only():
    from repro.launch.serve import SolveRequest
    a = SolveRequest(variant="async", sync_every=4)
    b = SolveRequest(variant="async", sync_every=16)
    assert a.batch_key != b.batch_key
    # sync variants ignore sync_every — keying on it would split
    # otherwise-identical requests into separate batches
    c = SolveRequest(variant="queue_lock", sync_every=4)
    d = SolveRequest(variant="queue_lock", sync_every=16)
    assert c.batch_key == d.batch_key


def test_async_kernel_externalizes_and_resumes_local_bests():
    """The async kernel wrappers surface the block-local best buffers in
    SwarmState.lbest_* and resume from them, so a chunked kernel run keeps
    the staleness window across calls (checkpoint/resume parity with the
    jnp path). In the single-block regime this must stay bit-identical to
    one long call (the call-split-invariant schedule)."""
    cfg = PSOConfig(dim=5, particle_cnt=128, fitness="cubic")
    s = init_swarm(cfg, 11)
    a = ops.run_queue_lock_fused_async(cfg, s, iters=4, sync_every=2,
                                       block_n=128)
    assert a.lbest_fit is not None and a.lbest_fit.shape == (1,)
    assert a.lbest_pos.shape == (1, 5)
    b = ops.run_queue_lock_fused_async(cfg, a, iters=4, sync_every=2,
                                       block_n=128)
    one = ops.run_queue_lock_fused_async(cfg, s, iters=8, sync_every=2,
                                         block_n=128)
    for name in ("pos", "vel", "pbest_fit", "gbest_pos", "gbest_fit",
                 "lbest_pos", "lbest_fit"):
        np.testing.assert_array_equal(np.asarray(getattr(b, name)),
                                      np.asarray(getattr(one, name)),
                                      err_msg=name)
    # multi-block: the buffers match what the eager oracle tracks
    cfg2 = PSOConfig(dim=2, particle_cnt=256, fitness="cubic").resolved()
    s2 = init_swarm(cfg2, 42)
    out = ops.run_queue_lock_fused_async(cfg2, s2, iters=8, sync_every=4,
                                         block_n=64)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s2, 2)
    kw = _oracle_kwargs(cfg2, 2)
    fitness = kw.pop("fitness")
    o = ref.run_fused_async_oracle(
        int(s2.seed), int(s2.iteration), pos, vel, pbp, pbf, gp,
        float(gf[0]), 8, 64, 4, fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(out.lbest_fit),
                               np.asarray(o[7]), rtol=1e-4, atol=1e-3)


def test_async_kernel_degenerate_inputs_clamp_like_jnp():
    """sync_every <= 0 / > iters and iters == 0 must not crash the kernel
    wrapper (clamped exactly like run_async)."""
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="cubic")
    s = init_swarm(cfg, 0)
    zero = ops.run_queue_lock_fused_async(cfg, s, iters=0, sync_every=0)
    assert int(zero.iteration) == 0
    np.testing.assert_array_equal(np.asarray(zero.pos), np.asarray(s.pos))
    a = ops.run_queue_lock_fused_async(cfg, s, iters=4, sync_every=0,
                                       block_n=128)
    b = ops.run_queue_lock_fused_async(cfg, s, iters=4, sync_every=1,
                                       block_n=128)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    big = ops.run_queue_lock_fused_async(cfg, s, iters=4, sync_every=99,
                                         block_n=128)
    np.testing.assert_array_equal(
        np.asarray(big.pos),
        np.asarray(ops.run_queue_lock_fused_async(cfg, s, iters=4,
                                                  sync_every=4,
                                                  block_n=128).pos))
