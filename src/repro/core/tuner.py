"""PSO as a black-box (hyperparameter) tuner — the first-class integration of
the paper's technique with the LM training substrate (DESIGN.md §3).

A particle is a point in a box-constrained search space (e.g. log-lr, warmup
fraction, weight decay). Fitness is any callable ``params -> score`` (higher
is better), typically "−validation loss after a short probe run" produced by
``repro.launch.train.make_probe_fitness``. The swarm logic reuses the exact
step variants from ``repro.core.pso``; evaluations are batched over the
population so the underlying train substrate can vmap/pmap them when cheap,
or loop when each evaluation is itself a distributed job.

Batched evaluation: ``PSOTuner.run`` accepts ``batch_fitness`` — one call
scoring the whole population — instead of a per-candidate callable. The
first-class producer is ``make_solve_many_fitness``: when the quantity being
tuned is PSO's own hyper-parameters ``(w, c1, c2)``, the entire population x
probe-seed grid is evaluated as ONE ``repro.core.multi_swarm.solve_many``
device program (per-swarm coeffs ride the same vmap as per-swarm seeds),
instead of population x seeds separate solves.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pso import PSOConfig

Array = np.ndarray


@dataclasses.dataclass(frozen=True)
class SearchDim:
    """One tunable hyperparameter."""
    name: str
    low: float
    high: float
    log: bool = False     # search in log10 space

    def to_user(self, unit: Array) -> Array:
        """unit in [0,1] -> user-space value."""
        if self.log:
            lo, hi = np.log10(self.low), np.log10(self.high)
            return 10.0 ** (lo + unit * (hi - lo))
        return self.low + unit * (self.high - self.low)


@dataclasses.dataclass
class TunerResult:
    best_params: Dict[str, float]
    best_fitness: float
    history: List[Tuple[int, float]]          # (iteration, gbest_fit)
    evaluations: int


class PSOTuner:
    """Synchronous-population PSO over a hyperparameter box.

    Runs the swarm dynamics in unit space [0,1]^D (numpy: population sizes
    here are tens, not millions — device execution buys nothing and keeps the
    expensive fitness evaluations, which ARE device jobs, the only hot path).
    Matches paper Alg. 1 with synchronous gbest and the queue-style
    "skip aggregation when nothing improved" predicate.
    """

    def __init__(self, dims: Sequence[SearchDim], particles: int = 16,
                 w: float = 0.7, c1: float = 1.5, c2: float = 1.5,
                 seed: int = 0):
        self.dims = list(dims)
        self.n = particles
        self.w, self.c1, self.c2 = w, c1, c2
        self.rng = np.random.default_rng(seed)
        d = len(self.dims)
        self.pos = self.rng.uniform(size=(particles, d))
        self.vel = self.rng.uniform(-0.25, 0.25, size=(particles, d))
        self.pbest_pos = self.pos.copy()
        self.pbest_fit = np.full(particles, -np.inf)
        self.gbest_pos = self.pos[0].copy()
        self.gbest_fit = -np.inf
        self.evaluations = 0

    def _decode(self, unit_row: Array) -> Dict[str, float]:
        return {d.name: float(d.to_user(unit_row[i]))
                for i, d in enumerate(self.dims)}

    def ask(self) -> List[Dict[str, float]]:
        """Current population in user space (for external batch evaluation)."""
        return [self._decode(self.pos[i]) for i in range(self.n)]

    def tell(self, fits: Sequence[float]) -> None:
        """Report fitness for the population returned by the last ask()."""
        fits = np.asarray(fits, dtype=np.float64)
        self.evaluations += len(fits)
        improved = fits > self.pbest_fit
        self.pbest_fit = np.where(improved, fits, self.pbest_fit)
        self.pbest_pos = np.where(improved[:, None], self.pos, self.pbest_pos)
        if np.any(fits > self.gbest_fit):          # queue predicate
            b = int(np.argmax(fits))
            self.gbest_fit = float(fits[b])
            self.gbest_pos = self.pos[b].copy()
        # Advance the swarm.
        d = len(self.dims)
        r1 = self.rng.uniform(size=(self.n, d))
        r2 = self.rng.uniform(size=(self.n, d))
        self.vel = (self.w * self.vel
                    + self.c1 * r1 * (self.pbest_pos - self.pos)
                    + self.c2 * r2 * (self.gbest_pos[None] - self.pos))
        np.clip(self.vel, -0.5, 0.5, out=self.vel)
        self.pos = np.clip(self.pos + self.vel, 0.0, 1.0)

    def run(self, fitness: Optional[Callable[[Dict[str, float]], float]] = None,
            iters: int = 10,
            callback: Optional[Callable[[int, "PSOTuner"], None]] = None,
            *, batch_fitness: Optional[
                Callable[[List[Dict[str, float]]], Sequence[float]]] = None
            ) -> TunerResult:
        """Optimize; exactly one of ``fitness`` / ``batch_fitness`` is given.

        ``batch_fitness(population) -> scores`` evaluates the whole
        population at once (e.g. ``make_solve_many_fitness``: one batched
        device program per tuner iteration instead of N solves).
        """
        if (fitness is None) == (batch_fitness is None):
            raise ValueError("pass exactly one of fitness / batch_fitness")
        history: List[Tuple[int, float]] = []
        for it in range(iters):
            pop = self.ask()
            if batch_fitness is not None:
                fits = list(batch_fitness(pop))
            else:
                fits = [fitness(p) for p in pop]
            self.tell(fits)
            history.append((it, self.gbest_fit))
            if callback:
                callback(it, self)
        return TunerResult(best_params=self._decode(self.gbest_pos),
                           best_fitness=self.gbest_fit,
                           history=history, evaluations=self.evaluations)


PSO_COEFF_DIMS = (
    SearchDim("w", 0.3, 1.0),
    SearchDim("c1", 0.5, 2.5),
    SearchDim("c2", 0.5, 2.5),
)


def make_solve_many_fitness(cfg: PSOConfig, seeds: Sequence[int],
                            iters: int = 100, variant: str = "queue",
                            sync_every: Optional[int] = None):
    """Batch-fitness scoring PSO coefficient candidates via ONE batched solve.

    Each candidate ``{"w": ..., "c1": ..., "c2": ...}`` (missing keys fall
    back to ``cfg``) is scored as the mean final ``gbest_fit`` over the probe
    ``seeds``. The full population x seeds grid runs as a single
    ``solve_many`` call with per-swarm coeffs — P*K swarms, one dispatch.

    ``cfg.fitness`` may be a registered name or a first-class
    ``repro.core.problem.Problem`` — tuning PSO coefficients *for a user
    objective* is just ``make_solve_many_fitness(PSOConfig(fitness=prob),
    ...)``; scores stay in the engine's canonical maximization convention
    (a sense="min" problem's scores are its negated objective, which orders
    candidates correctly). Constrained problems
    (``repro.core.constraints``) thread through the same way: penalty-mode
    scores are the penalized canonical fitness (infeasible candidates rank
    below feasible ones by construction), projection/repair modes score
    the feasible-set optimum directly — so tuning PSO coefficients FOR a
    constrained workload needs no tuner changes
    (tests/test_constraints.py). ``sync_every`` forwards to the ``async``
    variant's publication interval.
    """
    from .multi_swarm import solve_many
    from .pso import ASYNC_SYNC_EVERY

    if sync_every is None:
        sync_every = ASYNC_SYNC_EVERY
    cfg = cfg.resolved()
    seeds = np.asarray(seeds, dtype=np.int64)
    k = len(seeds)

    def batch_fitness(population: List[Dict[str, float]]) -> np.ndarray:
        p = len(population)
        all_seeds = np.tile(seeds, p)
        w = np.repeat([c.get("w", cfg.w) for c in population], k)
        c1 = np.repeat([c.get("c1", cfg.c1) for c in population], k)
        c2 = np.repeat([c.get("c2", cfg.c2) for c in population], k)
        batch = solve_many(cfg, all_seeds, iters=iters, variant=variant,
                           sync_every=sync_every,
                           coeffs=(w.astype(np.float32),
                                   c1.astype(np.float32),
                                   c2.astype(np.float32)))
        return np.asarray(batch.gbest_fit).reshape(p, k).mean(axis=1)

    return batch_fitness
