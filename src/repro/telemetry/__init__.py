"""In-program solver telemetry (DESIGN: the observability layer).

The paper's contention story — queue-lock publication vs reduction memory
traffic — is invisible from outside a fused kernel: the host sees one
dispatch, not the per-iteration gbest races it resolved. This package
makes every engine report what it actually did, at three levels:

1. **Kernel counters** (``counters``): the fused Pallas kernels optionally
   emit per-swarm int32 event counts (queue-best updates, gbest
   publications, per-block pbest improvements) accumulated in SMEM across
   the whole grid. Off by default — the counter code is Python-gated at
   trace time, so a telemetry-off program is byte-identical to the
   pre-telemetry jaxpr and every bit-exactness pin stands untouched.
   Validated against the eager oracles in ``repro.kernels.ref``
   (tests/test_telemetry.py).

2. **Convergence traces**: ``Method(record_history=True)`` now covers all
   engines — jnp single-swarm (per-iteration), the kernel backend
   (chunk-boundary gbest readbacks), ``solve_many`` + heterogeneous
   batches (per-row series), and the continuous scheduler's lanes
   (per-row samples at every dispatched chunk). See
   ``repro.api`` / ``repro.serving.scheduler``.

3. **Exporters** (``trace``, ``prometheus``): a Chrome/Perfetto
   ``trace.json`` writer for serving spans, lane dispatches and solve
   chunks (load the file in https://ui.perfetto.dev), and a Prometheus
   text-exposition renderer for ``ServingMetrics.snapshot()`` plus kernel
   counters. Reachable from ``repro.solve_stream`` (``trace=`` /
   ``trace_path=``), ``SolveServer`` (``.prometheus()``), and the
   ``pso_run`` CLI (``--telemetry`` / ``--trace-out`` /
   ``--metrics-out``).

docs/observability.md documents the counter semantics and trace schema.
"""
from .counters import (COUNTER_NAMES, SLOTS_PER_SWARM, KernelCounters,
                       zero_counts)
from .prometheus import prometheus_text
from .trace import TraceWriter, profiler_session

__all__ = [
    "COUNTER_NAMES",
    "SLOTS_PER_SWARM",
    "KernelCounters",
    "zero_counts",
    "prometheus_text",
    "TraceWriter",
    "profiler_session",
]
