from .base import SHAPES, ArchConfig, ShapeCell, get_arch, list_archs

__all__ = ["SHAPES", "ArchConfig", "ShapeCell", "get_arch", "list_archs"]
