"""Data pipeline: deterministic synthetic token streams (for benchmarks,
dry-runs and tests) and a memmap-backed tokenized corpus reader — both
shard-aware and restart-exact.

Determinism contract: batch(step, host) depends only on (seed, step,
global example index), via the same counter RNG the PSO core uses. A job
restarted from a checkpoint at step k regenerates exactly the batches
k+1, k+2, ... regardless of host count — the data side of elastic
fault-tolerance (tests/test_data.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng as crng


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # sharding over hosts
    num_shards: int = 1
    shard_id: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class SyntheticLM:
    """Markov-ish synthetic tokens: next token correlated with current so a
    model can actually learn (loss decreases in examples/train_lm.py)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.local_batch, cfg.seq_len
        ex0 = step * cfg.global_batch + cfg.shard_id * b
        idx = (np.arange(b * (s + 1), dtype=np.uint32).reshape(b, s + 1)
               + np.uint32(ex0 * (s + 1)))
        u = np.asarray(crng.uniform(cfg.seed, 0, 7, jnp.asarray(idx)))
        base = (u * cfg.vocab).astype(np.int32) % cfg.vocab
        # correlate: token[t+1] = (token[t] + small drift) mod V  (80%)
        drift = (u * 17).astype(np.int32) % 7
        toks = base.copy()
        for t in range(1, s + 1):
            keep = u[:, t] < 0.8
            toks[:, t] = np.where(keep, (toks[:, t - 1] + drift[:, t]) % cfg.vocab,
                                  base[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapCorpus:
    """Flat .bin of int32 tokens; random-access windows, shard-aware,
    restart-exact (window choice keyed by (seed, step, example))."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len
        if self.n_windows <= 0:
            raise ValueError(f"corpus at {path} shorter than seq_len")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s = cfg.local_batch, cfg.seq_len
        ex0 = step * cfg.global_batch + cfg.shard_id * b
        idx = np.arange(b, dtype=np.uint32) + np.uint32(ex0)
        u = np.asarray(crng.uniform(cfg.seed, 1, 11, jnp.asarray(idx)))
        starts = (u * self.n_windows).astype(np.int64) * cfg.seq_len
        toks = np.stack([self.data[st:st + s + 1] for st in starts])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def write_corpus(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, dtype=np.int32).tofile(path)
