"""Fault-tolerance runtime: supervised step loops with checkpoint/restart,
retry-with-backoff around device failures, heartbeats, and straggler notes.

What can be exercised in this container: crash-and-restore (simulated by
killing the loop mid-run and resuming from the atomic checkpoint —
tests/test_checkpoint.py), deterministic data replay, elastic resharding.
What is designed-for but needs real fleet plumbing (documented here so the
launcher carries the hooks): coordinator failover, preemption signals
(SIGTERM → checkpoint-now), and slice-level hot-spares.

Straggler mitigation strategy per workload:
  * PSO (this paper): island mode — the only barrier is the gbest exchange
    every K iterations; a straggling shard delays an 8-byte collective, not
    each step, and K can be raised online (queue-lock insight at scale).
  * LM training: synchronous data-parallel steps are barrier-per-step by
    nature; the mitigations wired here are (a) deterministic batch replay
    so a restarted worker rejoins at the exact step, (b) checkpoint cadence
    tuned to MTBF via `suggest_checkpoint_interval`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, Optional

from repro import checkpoint as ckpt


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_interval: int = 100         # steps between checkpoints
    keep: int = 3
    max_retries: int = 3
    backoff_s: float = 1.0
    heartbeat_interval: int = 10     # steps between heartbeat callbacks


def suggest_checkpoint_interval(step_time_s: float, mtbf_hours: float,
                                write_time_s: float) -> int:
    """Young/Daly optimum: sqrt(2 * write * MTBF), in steps."""
    mtbf_s = mtbf_hours * 3600.0
    interval_s = math.sqrt(2.0 * write_time_s * mtbf_s)
    return max(1, int(interval_s / max(step_time_s, 1e-9)))


class StepRunner:
    """Supervised training/optimization loop.

    ``step_fn(state, step) -> state`` must be a pure update (jitted).
    ``save_tree``/``load_tree`` convert between the runtime state and the
    checkpointable pytree (e.g. host-gather for swarm state).
    """

    def __init__(self, cfg: RunnerConfig, step_fn: Callable,
                 save_tree: Callable = lambda s: s,
                 load_tree: Callable = lambda tree, tmpl: tree,
                 heartbeat: Optional[Callable[[int, Any], None]] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.save_tree = save_tree
        self.load_tree = load_tree
        self.heartbeat = heartbeat
        self.retries = 0

    def resume_or(self, init_state: Any):
        """Restore the latest checkpoint if one exists, else init."""
        step, tree = ckpt.restore_latest(self.cfg.ckpt_dir,
                                         self.save_tree(init_state))
        if step is None:
            return 0, init_state
        return step, self.load_tree(tree, init_state)

    def run(self, state: Any, start_step: int, num_steps: int) -> Any:
        step = start_step
        while step < start_step + num_steps:
            try:
                state = self.step_fn(state, step)
                step += 1
                self.retries = 0
            except Exception:                     # device loss, OOM, ...
                self.retries += 1
                if self.retries > self.cfg.max_retries:
                    # final checkpoint attempt, then surface the failure
                    ckpt.save(self.cfg.ckpt_dir, step,
                              self.save_tree(state))
                    raise
                time.sleep(self.cfg.backoff_s * 2 ** (self.retries - 1))
                # restart from the last durable state
                step, state = self.resume_or(state)
                continue
            if step % self.cfg.ckpt_interval == 0:
                ckpt.save(self.cfg.ckpt_dir, step, self.save_tree(state))
                ckpt.prune(self.cfg.ckpt_dir, self.cfg.keep)
            if self.heartbeat and step % self.cfg.heartbeat_interval == 0:
                self.heartbeat(step, state)
        ckpt.save(self.cfg.ckpt_dir, step, self.save_tree(state))
        return state
