"""Optimizers + PSO-as-optimizer + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (PSOOptimizer, adafactor_init, adafactor_update,
                         adam_init, adam_update, cosine_schedule,
                         get_optimizer, sgd_init, sgd_update)

OPTS = [("adam", adam_init, adam_update),
        ("adafactor", adafactor_init, adafactor_update),
        ("sgd", sgd_init, sgd_update)]


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.0]),
            "b": {"c": jnp.asarray([[0.5, -0.5], [1.0, -1.0]])}}


@pytest.mark.parametrize("name,init,update", OPTS)
def test_optimizers_minimize_quadratic(name, init, update):
    params = _quadratic_params()
    state = init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

    lr = {"adam": 0.05, "adafactor": 0.05, "sgd": 0.05}[name]
    l0 = float(loss(params))
    for _ in range(120):
        grads = jax.grad(loss)(params)
        params, state = update(params, grads, state, lr)
    assert float(loss(params)) < 0.05 * l0, name
    assert int(state.step) == 120


@pytest.mark.parametrize("name,init,update", OPTS)
def test_dtype_and_shape_preserved(name, init, update):
    params = {"a": jnp.ones((8, 16), jnp.bfloat16),
              "v": jnp.ones((5,), jnp.float32)}
    grads = jax.tree.map(lambda p: jnp.full_like(p, 0.01), params)
    state = init(params)
    new_p, _ = update(params, grads, state, 1e-3)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: a.shape == b.shape and a.dtype == b.dtype,
        new_p, params))


def test_adafactor_memory_factored():
    """Factored 2nd moment must be O(rows+cols), not O(rows*cols)."""
    p = {"big": jnp.zeros((1024, 512), jnp.bfloat16)}
    st = adafactor_init(p)
    inner = st.inner["big"]
    assert inner["vr"].shape == (1024,)
    assert inner["vc"].shape == (512,)
    assert inner["m"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.asarray(0), 1e-3, 10, 100)
    assert float(s) == 0.0
    mid = cosine_schedule(jnp.asarray(10), 1e-3, 10, 100)
    assert float(mid) == pytest.approx(1e-3, rel=1e-5)
    end = cosine_schedule(jnp.asarray(100), 1e-3, 10, 100)
    assert float(end) == pytest.approx(1e-4, rel=1e-3)


def test_pso_optimizer_gradient_free_regression():
    key = jax.random.key(0)
    X = jax.random.normal(key, (128, 4))
    w_true = jnp.asarray([0.4, -0.2, 0.1, 0.3])
    y = X @ w_true
    opt = PSOOptimizer({"w": jnp.zeros((4,))}, particles=128, span=1.0,
                       seed=0)
    loss = lambda p: jnp.mean((X @ p["w"] - y) ** 2)
    best = None
    for _ in range(150):
        best = opt.step(loss)
    assert best < 1e-2
    np.testing.assert_allclose(np.asarray(opt.best_params["w"]),
                               np.asarray(w_true), atol=0.1)


def test_get_optimizer_registry():
    for name in ("adam", "adafactor", "sgd"):
        init, update = get_optimizer(name)
        assert callable(init) and callable(update)
