"""Jitted public wrappers around the Pallas cuPSO kernels.

Handles layout packing ([N, D] particle-major library layout ↔ [Dpad, N]
D-major kernel layout), block-size selection, the queue algorithm's tiny
cross-block second stage, and SwarmState plumbing so kernels are drop-in
replacements for the ``repro.core.pso`` step functions.

``interpret`` defaults to True: this container is CPU-only and the kernels
TARGET TPU; on a real TPU pass interpret=False (the pallas_calls carry
TPU-valid BlockSpecs, dtypes and memory spaces).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.multi_swarm import SwarmBatch
from repro.core.pso import PSOConfig, SwarmState
from .pso_step import (fused_batch_call, fused_call, pad_dim,
                       queue_step_call, LANE)


def pick_block_n(n: int, target: int = 512) -> int:
    """Largest divisor of n that is ≤ target and lane-aligned if possible."""
    best = n
    for bn in range(min(n, target), 0, -1):
        if n % bn == 0:
            if bn % LANE == 0:
                return bn
            best = min(best, bn) if best == n else best
    for bn in range(min(n, target), 0, -1):  # fall back: any divisor
        if n % bn == 0:
            return bn
    return n


def pack_dmajor(pos, d: int):
    """[N, D] -> [Dpad, N] (zero-padded sublanes)."""
    n = pos.shape[0]
    dpad = pad_dim(d)
    out = jnp.zeros((dpad, n), pos.dtype)
    return out.at[:d, :].set(pos.T)


def unpack_dmajor(arr, d: int):
    """[Dpad, N] -> [N, D]."""
    return arr[:d, :].T


def _cfg_kwargs(cfg: PSOConfig):
    cfg = cfg.resolved()
    return dict(w=cfg.w, c1=cfg.c1, c2=cfg.c2, min_pos=cfg.min_pos,
                max_pos=cfg.max_pos, max_v=cfg.max_v, fitness=cfg.fitness)


def state_to_kernel(s: SwarmState, d: int):
    """SwarmState -> packed kernel operands."""
    scal = jnp.stack([s.seed.astype(jnp.int32),
                      s.iteration.astype(jnp.int32)])
    return (scal,
            pack_dmajor(s.pos, d), pack_dmajor(s.vel, d),
            pack_dmajor(s.pbest_pos, d), s.pbest_fit[None, :],
            pack_dmajor(s.gbest_pos[None, :], d), s.gbest_fit[None])


def kernel_to_state(s: SwarmState, d: int, pos, vel, pbp, pbf, gp, gf,
                    iters: int) -> SwarmState:
    return s._replace(
        pos=unpack_dmajor(pos, d), vel=unpack_dmajor(vel, d),
        fit=pbf[0],  # NOTE: kernels do not retain raw fit; pbest_fit ≥ fit
        pbest_pos=unpack_dmajor(pbp, d), pbest_fit=pbf[0],
        gbest_pos=gp[:d, 0], gbest_fit=gf[0],
        iteration=s.iteration + iters)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block_n", "interpret"))
def queue_step(cfg: PSOConfig, s: SwarmState, block_n: Optional[int] = None,
               interpret: bool = True) -> SwarmState:
    """One PSO iteration via the queue kernel + jnp cross-block epilogue.

    Semantics match ``repro.core.pso.step_queue`` (stale-gbest comparison).
    """
    cfg = cfg.resolved()
    n, d = s.pos.shape
    bn = block_n or pick_block_n(n)
    scal, pos, vel, pbp, pbf, gp, gf = state_to_kernel(s, d)
    call = queue_step_call(n, d, bn, s.pos.dtype, interpret=interpret,
                           **_cfg_kwargs(cfg))
    pos, vel, pbp, pbf, aux_fit, aux_idx = call(
        scal, gp, gf, pos, vel, pbp, pbf)
    # --- 2nd kernel (paper Fig. 1), shrunk to an O(nblocks) jnp epilogue.
    wb = jnp.argmax(aux_fit)
    cand_fit = aux_fit[wb]
    take = cand_fit > s.gbest_fit
    cand_pos = jax.lax.dynamic_index_in_dim(  # §5.3: gather pos by index once
        pos, aux_idx[wb], axis=1, keepdims=True)
    gp = jnp.where(take, cand_pos, gp)
    gf = jnp.where(take, cand_fit[None], gf)
    return kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, 1)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "block_n", "interpret"))
def run_queue_lock_fused(cfg: PSOConfig, s: SwarmState, iters: int,
                         block_n: Optional[int] = None,
                         interpret: bool = True) -> SwarmState:
    """``iters`` iterations in ONE pallas_call (fused queue-lock, §4.2+).

    On TPU this is the roofline-relevant path: state stays resident, the
    global best is published in-kernel under sequential-grid serialization,
    and there are zero kernel launches or HBM round-trips per iteration.
    """
    cfg = cfg.resolved()
    n, d = s.pos.shape
    bn = block_n or pick_block_n(n)
    scal, pos, vel, pbp, pbf, gp, gf = state_to_kernel(s, d)
    call = fused_call(n, d, iters, bn, s.pos.dtype, interpret=interpret,
                      **_cfg_kwargs(cfg))
    pos, vel, pbp, pbf, gp, gf = call(scal, pos, vel, pbp, pbf, gp, gf)
    return kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, iters)


def pack_dmajor_batch(x, d: int):
    """[S, N, D] -> [Dpad, S*N] (swarm s owns columns [s*N, (s+1)*N))."""
    s_cnt, n, _ = x.shape
    return pack_dmajor(x.reshape(s_cnt * n, d), d)


def unpack_dmajor_batch(arr, s_cnt: int, d: int):
    """[Dpad, S*N] -> [S, N, D]."""
    n = arr.shape[1] // s_cnt
    return unpack_dmajor(arr, d).reshape(s_cnt, n, d)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "block_n", "interpret"))
def run_queue_lock_fused_batch(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                               block_n: Optional[int] = None,
                               interpret: bool = True) -> SwarmBatch:
    """S independent swarms x ``iters`` iterations in ONE pallas_call.

    The multi-swarm analogue of ``run_queue_lock_fused``: per-swarm gbest
    buffers and per-swarm ``(seed, iteration)`` RNG counters ride a third
    (swarm-major) grid dimension, so row ``s`` of the batch is bit-identical
    to ``run_queue_lock_fused`` on ``batch_row(batch, s)`` with the same
    ``block_n`` — asserted in tests/test_multi_swarm.py. On TPU this is the
    serving hot path: a whole request batch advances with zero host
    round-trips and one kernel launch.
    """
    cfg = cfg.resolved()
    s_cnt, n, d = batch.pos.shape
    bn = block_n or pick_block_n(n)
    seeds = batch.seed.astype(jnp.int32)
    its = batch.iteration.astype(jnp.int32)
    pos = pack_dmajor_batch(batch.pos, d)
    vel = pack_dmajor_batch(batch.vel, d)
    pbp = pack_dmajor_batch(batch.pbest_pos, d)
    pbf = batch.pbest_fit.reshape(1, s_cnt * n)
    gp = jnp.zeros((pad_dim(d), s_cnt), batch.pos.dtype).at[:d].set(
        batch.gbest_pos.T)
    gf = batch.gbest_fit
    call = fused_batch_call(s_cnt, n, d, iters, bn, batch.pos.dtype,
                            interpret=interpret, **_cfg_kwargs(cfg))
    pos, vel, pbp, pbf, gp, gf = call(seeds, its, pos, vel, pbp, pbf, gp, gf)
    pbf = pbf.reshape(s_cnt, n)
    return batch._replace(
        pos=unpack_dmajor_batch(pos, s_cnt, d),
        vel=unpack_dmajor_batch(vel, s_cnt, d),
        fit=pbf,  # kernels do not retain raw fit; pbest_fit >= fit
        pbest_pos=unpack_dmajor_batch(pbp, s_cnt, d), pbest_fit=pbf,
        gbest_pos=gp[:d].T, gbest_fit=gf,
        iteration=batch.iteration + iters)


def make_fused_local_step(iters_per_call: int = 1, block_n=None,
                          interpret: bool = True):
    """Adapter: fused kernel as a ``local_step_fn`` for distributed swarms."""
    def step(cfg: PSOConfig, s: SwarmState) -> SwarmState:
        return run_queue_lock_fused(cfg, s, iters_per_call,
                                    block_n=block_n, interpret=interpret)
    return step
