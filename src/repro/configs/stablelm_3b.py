"""stablelm-3b — dense MHA. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from .base import ArchConfig, register

STABLELM_3B = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304,
    source="hf:stabilityai/stablelm-2-1_6b",
))
