"""Problem API: first-class objectives, the d-major adapter, the unified
solve facade, and the legacy string path's bit-compatibility with the seed.

Layers covered: core/problem.py (registry, bounds, sense), core/pso.py
(PSOConfig widening, per-dimension bounds), kernels/pso_step.py
(dmajor_adapter + const hoisting + hand-tuned fast paths), kernels/ref.py
(oracle parity for custom objectives), repro.api (solve/solve_many/Method/
Result), launch/serve.py (content-hashed compile keys), core/tuner.py and
core/distributed.py (Problems thread through), core/blocking.py (unified
block sizing).
"""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import Method, Problem
from repro.core import PSOConfig, get_problem, init_swarm, solve
from repro.core.blocking import pick_block_n
from repro.core.fitness import DEFAULT_BOUNDS, FITNESS_FNS, FITNESS_IDS
from repro.core.pso import _default_async_blocks
from repro.core.problem import register_problem, resolve_problem
from repro.kernels import ops, ref
from repro.kernels.pso_step import (KERNEL_FITNESS, _fitness_dmajor,
                                    dmajor_adapter, is_converted,
                                    kernel_fitness, pad_dim)


def _digest(state) -> str:
    h = hashlib.sha1()
    for a in (state.pos, state.vel, state.pbest_fit, state.gbest_pos,
              state.gbest_fit):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()[:16]


def _wbowl():
    w = jnp.asarray([1.0, 4.0, 0.25])
    c = jnp.asarray([1.0, -2.0, 0.5])

    def weighted_bowl(x):
        return jnp.sum(w * (x - c) ** 2, axis=-1)

    return Problem(name="weighted_bowl", fn=weighted_bowl,
                   lo=(-5.0, -10.0, -2.0), hi=(5.0, 10.0, 2.0), sense="min")


# --------------------------------------------------------------------------
# Registry + Problem semantics
# --------------------------------------------------------------------------

def test_builtins_registered():
    assert set(FITNESS_FNS) <= set(repro.list_problems())
    for name, fn in FITNESS_FNS.items():
        p = get_problem(name)
        assert p.fn is fn                      # the SAME function object
        assert p.sense == "max"
        assert (p.lo, p.hi) == DEFAULT_BOUNDS[name]
    # stable kernel-side ids (order = declaration order)
    assert FITNESS_IDS == {n: i for i, n in enumerate(
        ["cubic", "sphere", "rosenbrock", "griewank", "rastrigin", "ackley"])}


def test_register_and_resolve():
    p = register_problem("t_reg_prob", lambda x: -jnp.sum(x * x, axis=-1),
                         lo=-1.0, hi=1.0)
    assert get_problem("t_reg_prob") is p
    assert resolve_problem("t_reg_prob") is p
    assert resolve_problem(p) is p
    register_problem(p)                        # identical re-register: ok
    with pytest.raises(ValueError, match="different content"):
        register_problem("t_reg_prob", lambda x: jnp.sum(x, axis=-1))
    register_problem("t_reg_prob", lambda x: jnp.sum(x, axis=-1),
                     overwrite=True)
    assert get_problem("t_reg_prob") is not p


def test_problem_validation():
    fn = lambda x: jnp.sum(x, axis=-1)
    with pytest.raises(ValueError, match="sense"):
        Problem(name="x", fn=fn, sense="down")
    with pytest.raises(ValueError, match="lo <= hi"):
        Problem(name="x", fn=fn, lo=1.0, hi=-1.0)
    with pytest.raises(ValueError, match="lo <= hi"):
        Problem(name="x", fn=fn, lo=(0.0, 2.0), hi=(1.0, 1.0))
    # lo == hi is legal: the coordinate is frozen (tests/test_bounds.py)
    Problem(name="x", fn=fn, lo=(0.0, 0.5), hi=(1.0, 0.5))
    with pytest.raises(ValueError, match="lengths differ"):
        Problem(name="x", fn=fn, lo=(0.0, 0.0), hi=(1.0, 1.0, 1.0))
    # arrays normalize to tuples (hashable); scalar broadcasts against [D]
    p = Problem(name="x", fn=fn, lo=np.array([-1.0, -2.0]), hi=3)
    assert p.lo == (-1.0, -2.0) and p.hi == (3.0, 3.0)
    assert p.ndim == 2
    hash(p)                                    # jit-static requirement


def test_sense_canonicalization():
    fn = lambda x: jnp.sum(x * x, axis=-1)
    pmin = Problem(name="x", fn=fn, sense="min")
    pmax = Problem(name="x", fn=fn, sense="max")
    x = jnp.asarray([[1.0, 2.0]])
    assert float(pmin.max_fn(x)[0]) == -5.0    # canonical = negated
    assert float(pmax.max_fn(x)[0]) == 5.0
    assert pmax.max_fn is fn                   # max sense: untouched object
    assert pmin.max_fn is pmin.max_fn          # stable wrapper identity
    assert pmin.user_value(-3.0) == 3.0


def test_cache_key_is_content_based():
    f1 = lambda x: jnp.sum(x * x, axis=-1)
    f2 = lambda x: jnp.sum(x * x * x, axis=-1)
    a = Problem(name="same", fn=f1)
    b = Problem(name="same", fn=f2)            # same name, different code
    c = Problem(name="same", fn=f1)
    assert a.cache_key() != b.cache_key()
    assert a.cache_key() == c.cache_key()
    assert a.cache_key() != Problem(name="same", fn=f1, lo=-1.0,
                                    hi=1.0).cache_key()
    # closure values count as content
    def make(k):
        return Problem(name="same", fn=lambda x: k * jnp.sum(x, axis=-1))
    assert make(2.0).cache_key() != make(3.0).cache_key()


# --------------------------------------------------------------------------
# Legacy string path: bit-identical to the seed
# --------------------------------------------------------------------------

# SHA1 digests of (pos, vel, pbest_fit, gbest_pos, gbest_fit) captured from
# the SEED tree (commit 4b5c2fe, pre-Problem-API) on XLA:CPU/f32. The string
# path must keep resolving through the new registry to these exact bits.
SEED_DIGESTS = [
    ("cubic", 2, 64, 50, "queue_lock", "649cc0206e00b1bf"),
    ("cubic", 1, 128, 40, "queue", "53b5412a0a919c50"),
    ("rastrigin", 3, 64, 30, "reduction", "d3f5e2947555481c"),
    ("sphere", 5, 64, 25, "async", "0f2a4ff94b78904d"),
    ("griewank", 4, 64, 20, "queue", "3c02a38e175968c6"),
    ("ackley", 3, 64, 20, "queue_lock", "df71b03492f319b4"),
    ("rosenbrock", 2, 64, 20, "reduction", "7e614c844a9061ef"),
]


@pytest.mark.parametrize("name,dim,n,iters,variant,want", SEED_DIGESTS)
def test_legacy_string_path_bit_identical_to_seed(name, dim, n, iters,
                                                  variant, want):
    s = solve(PSOConfig(dim=dim, particle_cnt=n, fitness=name), seed=3,
              iters=iters, variant=variant)
    assert _digest(s) == want
    # and the Problem-object spelling of the same built-in matches exactly
    s2 = solve(PSOConfig(dim=dim, particle_cnt=n, fitness=get_problem(name)),
               seed=3, iters=iters, variant=variant)
    assert _digest(s2) == want


def test_legacy_kernel_path_bit_identical_to_seed():
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="cubic").resolved()
    s0 = init_swarm(cfg, 5)
    k = ops.run_queue_lock_fused(cfg, s0, iters=12, block_n=64)
    assert _digest(k) == "e738dfc1df826106"
    a = ops.run_queue_lock_fused_async(cfg, s0, iters=12, sync_every=4,
                                       block_n=64)
    assert _digest(a) == "919036ad04111333"


def test_resolved_bounds_match_seed_defaults():
    for name, (lo, hi) in DEFAULT_BOUNDS.items():
        cfg = PSOConfig(fitness=name).resolved()
        assert cfg.min_pos == lo and cfg.max_pos == hi
        assert cfg.max_v == 0.5 * (hi - lo)
        assert cfg.fitness_fn is FITNESS_FNS[name]


# --------------------------------------------------------------------------
# Unified block sizing (ROADMAP satellite)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 33, 96, 100, 128, 384, 640, 1009, 1024,
                               1042, 131072])
def test_default_async_blocks_shares_pick_block_n(n):
    # the jnp fallback = lane-free pick: largest divisor <= target
    nb = _default_async_blocks(n)
    assert nb == n // pick_block_n(n, lane=1)
    assert n % nb == 0
    # seed semantics: the block SIZE is the largest divisor <= 512
    bn = n // nb
    assert all(n % d for d in range(bn + 1, min(n, 512) + 1))


def test_pick_block_n_lane_preference_still_wins():
    assert pick_block_n(640) == 128            # lane-aligned beats larger 320
    assert pick_block_n(640, lane=1) == 320    # lane-free: largest divisor


# --------------------------------------------------------------------------
# d-major adapter: parity with the hand-tuned forms
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fitness", list(KERNEL_FITNESS))
@pytest.mark.parametrize("d,n", [(1, 128), (3, 64), (7, 96), (13, 128),
                                 (120, 64)])
def test_adapter_parity_with_hand_tuned(fitness, d, n):
    """dmajor_adapter(library fn) must agree with _fitness_dmajor on the
    same masked tile, across odd/prime dims and particle counts."""
    rng = np.random.default_rng(d * 1000 + n)
    pos = rng.uniform(-5, 5, size=(n, d)).astype(np.float32)
    packed = ops.pack_dmajor(jnp.asarray(pos), d)
    dmask = jnp.asarray((np.arange(pad_dim(d)) < d)[:, None]
                        & np.ones((1, n), bool))
    hand = np.asarray(_fitness_dmajor(fitness, packed, dmask, d))[0]
    lifted = dmajor_adapter(FITNESS_FNS[fitness])
    got = np.asarray(lifted(packed, dmask, d))[0]
    np.testing.assert_allclose(got, hand, rtol=2e-5, atol=2e-4)


def test_kernel_fitness_routing():
    # strings and built-in Problems take the hand-tuned fast path
    assert not is_converted("cubic")
    assert not is_converted(get_problem("cubic"))
    # custom Problems are adapter-lowered
    assert is_converted(_wbowl())
    # a user kernel_fn is used verbatim
    marker = lambda pos, dmask, d: -jnp.sum(pos, axis=0, keepdims=True)
    p = Problem(name="k", fn=lambda x: -jnp.sum(x, axis=-1), kernel_fn=marker)
    assert kernel_fitness(p) is marker
    assert is_converted(p)
    with pytest.raises(TypeError):
        kernel_fitness(123)


# --------------------------------------------------------------------------
# Custom objective end-to-end: jnp fallback + Pallas kernels vs oracle
# --------------------------------------------------------------------------

def _oracle_inputs(cfg, seed):
    s0 = init_swarm(cfg, seed)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s0, cfg.dim)
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = cfg.dim
    fitness = kw.pop("fitness")
    return s0, (pos, vel, pbp, pbf, gp, float(gf[0])), fitness, kw


def test_custom_fused_kernel_vs_oracle():
    prob = _wbowl()
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=prob).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=32)
    o = ref.run_fused_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                             8, 32, fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 3)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o[3])[0], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-6)


@pytest.mark.parametrize("iters,sync_every,block_n", [(8, 4, 32), (10, 4, 32),
                                                      (7, 7, 64)])
def test_custom_async_kernel_vs_oracle(iters, sync_every, block_n):
    prob = _wbowl()
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=prob).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused_async(cfg, s0, iters=iters,
                                         sync_every=sync_every,
                                         block_n=block_n)
    o = ref.run_fused_async_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp,
                                   gf, iters, block_n, sync_every,
                                   fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 3)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-6)


def test_custom_async_single_block_equals_fused_bitwise():
    """Kernel-to-kernel invariant (exact): with one block the async kernel
    IS the fused kernel, for custom objectives too."""
    prob = _wbowl()
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=prob).resolved()
    s0 = init_swarm(cfg, 1)
    f = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=64)
    for se in (1, 2, 4, 8):
        a = ops.run_queue_lock_fused_async(cfg, s0, iters=8, sync_every=se,
                                           block_n=64)
        assert np.array_equal(np.asarray(f.pos), np.asarray(a.pos))
        assert float(f.gbest_fit) == float(a.gbest_fit)


def test_custom_problem_solves_and_respects_bounds():
    prob = _wbowl()
    res = repro.solve(prob, particles=256, iters=300, seed=0, variant="queue")
    assert res.config.dim == 3                 # dim pinned by [D] bounds
    assert res.best_fit < 0.5                  # near the optimum f=0
    lo = np.array([-5.0, -10.0, -2.0])
    hi = np.array([5.0, 10.0, 2.0])
    pos = np.asarray(res.state.pos)
    assert np.all(pos >= lo - 1e-5) and np.all(pos <= hi + 1e-5)
    # per-dimension velocity clamp: |v_i| <= 0.5 * (hi_i - lo_i)
    vel = np.abs(np.asarray(res.state.vel))
    assert np.all(vel <= 0.5 * (hi - lo) * (1 + 1e-6))
    # user sense: reported value is the minimized objective
    assert res.best_fit == -res.gbest_fit


def test_custom_problem_jnp_vs_kernel_agree():
    prob = _wbowl()
    kw = dict(particles=64, iters=64, seed=2)
    rj = repro.solve(prob, variant="queue_lock", backend="jnp", **kw)
    rk = repro.solve(prob, method=Method(variant="queue_lock",
                                         backend="kernel"), **kw)
    ra = repro.solve(prob, method=Method(variant="async", backend="kernel",
                                         sync_every=8), **kw)
    # independent implementations on the same landscape: same neighborhood
    assert abs(rj.best_fit - rk.best_fit) < 0.5
    assert abs(rk.best_fit - ra.best_fit) < 0.5
    for r in (rj, rk, ra):
        assert np.isfinite(r.best_fit)


# --------------------------------------------------------------------------
# Facade
# --------------------------------------------------------------------------

def test_method_validation():
    with pytest.raises(ValueError, match="unknown variant"):
        Method(variant="warp")
    with pytest.raises(ValueError, match="unknown backend"):
        Method(backend="gpu")
    with pytest.raises(ValueError, match="kernel"):
        Method(variant="queue", backend="kernel")
    assert Method(variant="queue").resolve_backend() == "jnp"
    assert Method(variant="queue_lock",
                  backend="kernel").resolve_backend() == "kernel"
    assert Method().resolve_interpret() is (jax.default_backend() != "tpu")


def test_solve_rejects_method_plus_loose_kwargs():
    with pytest.raises(ValueError, match="not both"):
        repro.solve("cubic", particles=64, iters=5,
                    method=Method(variant="queue"), variant="queue_lock")


def test_facade_matches_core_solve():
    cfg = PSOConfig(dim=2, particle_cnt=64, fitness="cubic")
    want = solve(cfg, seed=7, iters=40, variant="queue_lock")
    got = repro.solve("cubic", dim=2, particles=64, iters=40, seed=7,
                      variant="queue_lock")
    assert _digest(got.state) == _digest(want)


def test_facade_solve_many_row_identity():
    rs = repro.solve_many("cubic", [0, 1, 2, 3], dim=2, particles=64,
                          iters=30, variant="queue")
    r1 = repro.solve("cubic", dim=2, particles=64, iters=30, seed=2,
                     variant="queue")
    assert _digest(rs[2].state) == _digest(r1.state)
    assert repro.best(rs).gbest_fit == max(r.gbest_fit for r in rs)


def test_facade_solve_many_kernel_backend():
    prob = _wbowl()
    rs = repro.solve_many(prob, [0, 1], particles=64, iters=16,
                          method=Method(variant="queue_lock",
                                        backend="kernel"))
    r1 = repro.solve(prob, particles=64, iters=16, seed=1,
                     method=Method(variant="queue_lock", backend="kernel"))
    # batched vs standalone kernel programs may round 1-2 ulp apart on
    # XLA:CPU for adapter-lowered objectives (same fusion-context class as
    # the S=4 caveat in core/multi_swarm.py); exact for built-ins is
    # asserted in tests/test_multi_swarm.py.
    np.testing.assert_allclose(np.asarray(rs[1].state.pos),
                               np.asarray(r1.state.pos),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(rs[1].gbest_fit, r1.gbest_fit, rtol=1e-5)


# --------------------------------------------------------------------------
# Serving: content-hashed compile keys
# --------------------------------------------------------------------------

def test_serve_distinct_custom_objectives_never_share_a_batch():
    from repro.launch.serve import SolveRequest

    f1 = lambda x: -jnp.sum(x * x, axis=-1)
    f2 = lambda x: -jnp.sum(x * x * x * x, axis=-1)
    a = SolveRequest(dim=2, particle_cnt=64,
                     fitness=Problem(name="mine", fn=f1))
    b = SolveRequest(dim=2, particle_cnt=64,
                     fitness=Problem(name="mine", fn=f2))
    assert a.batch_key != b.batch_key
    # a built-in by name and by Problem object DO share one
    c = SolveRequest(dim=2, particle_cnt=64, fitness="cubic")
    d = SolveRequest(dim=2, particle_cnt=64, fitness=get_problem("cubic"))
    assert c.batch_key == d.batch_key


def test_serve_solves_custom_problems():
    from repro.launch.serve import SolveRequest, SolveServer

    prob = _wbowl()
    srv = SolveServer(backend="jnp")
    reqs = [SolveRequest(dim=3, particle_cnt=64, fitness=prob, seed=i,
                         iters=50, variant="queue") for i in range(9)]
    out = srv.solve_all(reqs)
    assert len(out) == 9
    assert srv.stats.dispatches == 1           # one compile group
    for r in out:
        assert np.isfinite(r.gbest_fit)
        assert r.objective == -r.gbest_fit     # sense="min" reporting


# --------------------------------------------------------------------------
# Tuner + distributed + serial: Problems thread through
# --------------------------------------------------------------------------

def test_tuner_with_custom_problem():
    from repro.core.tuner import (PSO_COEFF_DIMS, PSOTuner,
                                  make_solve_many_fitness)

    cfg = PSOConfig(dim=3, particle_cnt=32, fitness=_wbowl())
    bf = make_solve_many_fitness(cfg, seeds=[0, 1], iters=15)
    tuner = PSOTuner(PSO_COEFF_DIMS, particles=3, seed=0)
    res = tuner.run(batch_fitness=bf, iters=2)
    assert np.isfinite(res.best_fitness)
    assert set(res.best_params) == {"w", "c1", "c2"}


def test_distributed_custom_problem():
    from repro.core.distributed import (init_sharded_swarm,
                                        make_distributed_run)

    mesh = jax.make_mesh((1,), ("data",))
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=_wbowl())
    state = init_sharded_swarm(cfg, 0, mesh)
    runner = make_distributed_run(cfg, mesh, iters=20, variant="queue",
                                  exchange_interval=5)
    out = runner(state)
    assert float(out.gbest_fit) >= float(state.gbest_fit)
    assert np.isfinite(float(out.gbest_fit))


def test_serial_baseline_custom_problem():
    from repro.core.serial import run_serial_fast

    cfg = PSOConfig(dim=3, particle_cnt=32, fitness=_wbowl())
    gf, gp = run_serial_fast(cfg.resolved(), seed=0, iters=30)
    assert np.isfinite(gf)
    assert gp.shape == (3,)
    assert np.all(gp >= np.array([-5.0, -10.0, -2.0]) - 1e-5)
    assert np.all(gp <= np.array([5.0, 10.0, 2.0]) + 1e-5)
