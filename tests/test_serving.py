"""The serving subsystem: continuous-batching scheduler, persistent AOT
compile cache, metrics — plus the flush server's failure isolation and
bucket-ladder edge cases (repro.serving, repro.launch.serve).

The load-bearing assertions:

* every result out of ``ContinuousScheduler`` is BITWISE identical to
  the standalone ``solve(cfg, seed, T, "async", sync_every)`` of that
  request — across heterogeneous lanes, row swaps mid-flight, tail
  ejections (sub-chunk remainders) and sub-chunk standalone fallbacks;
* a second ``CompileCache`` over the same directory serves the same
  trace from deserialized ``jax.export`` blobs with ``trace_events ==
  0`` (the zero-recompile restart story) and bitwise-equal results.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.pso import PSOConfig, solve
from repro.launch.serve import SolveRequest

NAMES = ("cubic", "sphere", "rastrigin", "ackley", "griewank", "rosenbrock")
# The hetero engines' validated bit-exactness shape (tests/test_hetero.py):
# at tiny shapes XLA:CPU fuses the switch-dispatched fitness a few ulp
# differently from the standalone program, so the bitwise contract is
# pinned where the engine pins it.
DIM, N, SE = 10, 128, 8


def _req(k, iters, fitness=None, variant="async"):
    return SolveRequest(dim=DIM, particle_cnt=N,
                        fitness=fitness or NAMES[k % len(NAMES)],
                        seed=k, iters=iters, variant=variant, sync_every=SE)


def _standalone(r):
    cfg = PSOConfig(dim=r.dim, particle_cnt=r.particle_cnt,
                    fitness=r.fitness, dtype=r.dtype)
    return solve(cfg, r.seed, r.iters, r.variant, r.sync_every)


def _assert_bit_exact(results, reqs):
    for res, r in zip(results, reqs):
        st = _standalone(r)
        assert res.ok
        assert res.gbest_fit == float(st.gbest_fit), (r.fitness, r.iters)
        np.testing.assert_array_equal(res.gbest_pos,
                                      np.asarray(st.gbest_pos))


# -- the tentpole: chunk-boundary admission, bit-exact ---------------------

def test_scheduler_bit_exact_vs_standalone_mixed_trace():
    """11 mixed requests through one hetero lane of width 8: multiples of
    sync_every (pure lane rides), a non-multiple (tail ejection), a
    sub-chunk budget (standalone fallback), and more requests than slots
    (row swaps at chunk boundaries). Every answer must be bitwise equal
    to its standalone solve."""
    from repro.serving import ContinuousScheduler
    reqs = [_req(k, iters) for k, iters in
            enumerate((16, 8, 24, 16, 8, 16, 24, 8, 16))]
    reqs.append(_req(9, 20))      # 2 chunks + remainder 4 -> ejection
    reqs.append(_req(10, 4))      # < sync_every -> standalone
    sched = ContinuousScheduler(lane_width=8)
    results = sched.run(reqs)
    _assert_bit_exact(results, reqs)
    m = sched.metrics
    assert m.get("completed") == len(reqs)
    assert m.get("row_swaps") >= 1        # a freed slot was re-admitted
    assert m.get("tail_ejections") == 1
    assert m.get("standalone_solves") == 1
    assert 0.0 < m.batch_fill <= 1.0
    snap = sched.snapshot()
    assert snap["lanes"] and snap["lanes"][0]["active"] == 0


def test_scheduler_sync_variant_runs_standalone():
    """Synchronous variants have no chunk boundary to preempt at: they
    bypass the lanes entirely and still come back exact."""
    from repro.serving import ContinuousScheduler
    reqs = [_req(0, 12, variant="queue"), _req(1, 16)]
    sched = ContinuousScheduler()
    results = sched.run(reqs)
    _assert_bit_exact(results, reqs)
    assert sched.metrics.get("standalone_solves") == 1


def test_scheduler_homogeneous_lane_for_custom_problem():
    """A custom Problem is not hetero-eligible: it gets its own
    content-keyed lane, same bit-exactness contract."""
    import jax.numpy as jnp

    from repro.core.problem import Problem
    from repro.serving import ContinuousScheduler
    prob = Problem(name="serving_quad",
                   fn=lambda x: -jnp.sum((x - 1.0) ** 2, axis=-1),
                   lo=-5.0, hi=5.0)
    reqs = [_req(k, 16, fitness=prob) for k in range(3)]
    results = ContinuousScheduler(lane_width=8).run(reqs)
    _assert_bit_exact(results, reqs)


# -- the restart story: persistent AOT compile cache -----------------------

def test_compile_cache_restart_zero_retrace_bit_exact(tmp_path):
    """Process A traces + exports the lane program; 'process' B (a fresh
    CompileCache over the same directory — empty memo, so resolution goes
    through the serialized blob) prewarms and serves the same trace with
    ZERO trace events and bitwise-equal results."""
    from repro.serving import CompileCache, ContinuousScheduler
    reqs = [_req(k, 16) for k in range(4)]

    cold = CompileCache(str(tmp_path))
    a = ContinuousScheduler(lane_width=8, compile_cache=cold).run(reqs)
    assert cold.aot_misses == 1 and cold.trace_events == 1

    warm = CompileCache(str(tmp_path))
    assert warm.prewarm() == 1
    sched = ContinuousScheduler(lane_width=8, compile_cache=warm)
    b = sched.run(reqs)
    assert warm.aot_hits == 1 and warm.aot_misses == 0
    assert warm.trace_events == 0          # the acceptance criterion
    for ra, rb in zip(a, b):
        assert ra.gbest_fit == rb.gbest_fit
        np.testing.assert_array_equal(ra.gbest_pos, rb.gbest_pos)
    _assert_bit_exact(b, reqs)
    assert sched.snapshot()["compile_cache"]["trace_events"] == 0


def test_compile_cache_memory_only_dedup():
    """No path, no env: the cache still memoizes within the process."""
    import jax.numpy as jnp

    from repro.serving import CompileCache
    cc = CompileCache(path="")
    calls = []

    def build(x):
        calls.append(1)
        return x * 2.0
    spec = jnp.ones((3,))
    f1 = cc.get("k", build, spec)
    f2 = cc.get("k", build, spec)
    assert f1 is f2
    assert cc.aot_misses == 1 and cc.aot_hits == 1
    assert cc.trace_events == 1 and len(calls) == 1
    np.testing.assert_allclose(np.asarray(f1(spec)), 2.0 * np.ones((3,)))


def test_compile_cache_manifest_fingerprint_mismatch(tmp_path):
    """A manifest from another jax/backend is ignored: the cache rebuilds
    rather than replaying an incompatible blob."""
    import json
    import os

    from repro.serving import CompileCache
    os.makedirs(tmp_path, exist_ok=True)
    with open(tmp_path / "manifest.json", "w") as f:
        json.dump({"fingerprint": {"jax": "0.0.0", "backend": "vaporware"},
                   "entries": {"deadbeef": {"key": "k", "file": "x.jaxexport",
                                            "bytes": 1}}}, f)
    cc = CompileCache(str(tmp_path))
    assert cc.prewarm() == 0               # incompatible manifest dropped


def test_solve_stream_facade(tmp_path):
    """repro.solve_stream: dict requests + a directory path for the
    compile cache, results in submit order."""
    import repro
    reqs = [dict(dim=DIM, particle_cnt=N, fitness=NAMES[k], seed=k,
                 iters=16, variant="async", sync_every=SE)
            for k in range(3)]
    results = repro.solve_stream(reqs, compile_cache=str(tmp_path))
    _assert_bit_exact(results, [SolveRequest(**r) for r in reqs])


# -- metrics ---------------------------------------------------------------

def test_latency_stat_percentiles_and_reservoir():
    from repro.serving import LatencyStat
    st = LatencyStat(cap=8)
    for v in (10.0, 20.0, 30.0, 40.0):
        st.add(v)
    assert st.mean_us == 25.0
    assert st.p50_us == 30.0               # nearest-rank over 4 samples
    assert st.p99_us == 40.0
    for v in range(100):                   # wrap the reservoir
        st.add(float(v))
    assert st.count == 104
    assert len(st._samples) == 8
    snap = st.snapshot()
    assert snap["count"] == 104 and snap["p99_us"] <= 99.0


def test_serving_metrics_snapshot_and_fill():
    from repro.serving import ServingMetrics
    m = ServingMetrics()
    assert m.batch_fill == 0.0             # no dispatched slots yet
    m.inc("lane_slots", 16)
    m.inc("lane_active_slots", 12)
    m.observe("e2e_us", 100.0)
    snap = m.snapshot()
    assert snap["batch_fill"] == 0.75
    assert snap["spans"]["e2e_us"]["count"] == 1
    m2 = ServingMetrics()
    m2.merge_from(m)
    assert m2.batch_fill == 0.75


# -- satellites: flush-server hardening ------------------------------------

def test_flush_partial_failure_isolated():
    """A poisoned custom objective fails ITS group only: other groups in
    the same flush return normally, the offending tickets carry the
    error, and ``objective`` refuses to report garbage."""
    from repro.core.problem import Problem
    from repro.launch.serve import SolveServer

    def poison(x):
        raise RuntimeError("poisoned objective")

    bad = Problem(name="serving_poison", fn=poison, lo=-1.0, hi=1.0)
    good = [_req(k, 16, variant="queue") for k in range(2)]
    reqs = [good[0], _req(2, 16, fitness=bad, variant="queue"), good[1]]
    srv = SolveServer()
    results = srv.solve_all(reqs)
    assert not results[1].ok
    assert isinstance(results[1].error, RuntimeError)
    with pytest.raises(RuntimeError, match="request failed"):
        results[1].objective
    for res, r in ((results[0], good[0]), (results[2], good[1])):
        assert res.ok
        st = _standalone(r)
        assert res.gbest_fit == float(st.gbest_fit)
    assert srv.stats.failed == 1
    assert srv.stats.requests == 2         # only the successful ones


def test_serve_stats_batch_fill_zero_flushes():
    from repro.launch.serve import ServeStats
    s = ServeStats()
    assert s.batch_fill == 0.0             # no dispatches: no div-by-zero
    d = s.as_dict()
    assert d["batch_fill"] == 0.0 and d["failed"] == 0


def test_bucket_size_edges():
    from repro.launch.serve import BUCKETS, _MIN_BUCKET, bucket_size
    assert bucket_size(1) == _MIN_BUCKET   # below the smallest rung
    assert bucket_size(_MIN_BUCKET) == _MIN_BUCKET
    assert bucket_size(5) == 8             # rounds up to the next rung
    assert bucket_size(BUCKETS[-1]) == BUCKETS[-1]
    assert bucket_size(10 ** 6) == BUCKETS[-1]    # capped at the top
    # max_batch below a ladder rung caps the pick
    assert bucket_size(100, max_batch=16) == 16
    assert bucket_size(3, max_batch=4) == 4
    # a custom (autotune-pruned) ladder is honored
    assert bucket_size(5, max_batch=64, buckets=(4, 32)) == 32
    assert bucket_size(40, max_batch=64, buckets=(4, 32)) == 64


def test_buckets_for_autotune_ladder_memoized():
    from repro.launch.serve import _MIN_BUCKET, SolveServer
    srv = SolveServer(max_batch=16, autotune=True)
    r = _req(0, 32, variant="queue")
    ladder = srv._buckets_for(r)
    assert ladder and ladder[0] >= _MIN_BUCKET
    assert all(b <= 16 for b in ladder)
    assert sorted(ladder) == list(ladder)
    assert srv._buckets_for(r) is ladder   # memoized per shape


# -- update-rule / topology plumbing + per-request rejection ---------------

def test_serve_rejects_invalid_requests_per_request():
    """Unknown variant/rule/topology fail the REQUEST, not the flush:
    valid requests in the same generation return normally on both front
    ends, and the bad ticket carries the enumerating error."""
    from repro.launch.serve import SolveServer
    from repro.serving import ContinuousScheduler
    good = [_req(0, 16, variant="queue"), _req(1, 16)]
    bad = [
        SolveRequest(dim=DIM, particle_cnt=N, fitness=NAMES[0], seed=7,
                     iters=16, variant="warp"),
        SolveRequest(dim=DIM, particle_cnt=N, fitness=NAMES[1], seed=8,
                     iters=16, variant="queue", rule="warp_speed"),
        SolveRequest(dim=DIM, particle_cnt=N, fitness=NAMES[2], seed=9,
                     iters=16, variant="async", sync_every=SE,
                     topology="hypercube"),
    ]
    reqs = [good[0]] + bad + [good[1]]
    for front_end in (SolveServer().solve_all,
                      ContinuousScheduler(lane_width=8).run):
        results = front_end(list(reqs))
        for res, want in zip(results[1:4], ("variant", "rule", "topology")):
            assert not res.ok
            assert want in str(res.error)
            assert np.isnan(res.gbest_fit)
        for res, r in ((results[0], good[0]), (results[4], good[1])):
            assert res.ok
            st = _standalone(r)
            assert res.gbest_fit == float(st.gbest_fit)


def test_serve_rule_topology_thread_to_engine():
    """``rule=`` / ``topology=`` on a request reach the engine: each
    group's answers match the standalone solve with the same PSOConfig
    (distinct rules/topologies must never share a compiled group)."""
    from repro.launch.serve import SolveServer
    combos = [("sso", "gbest"), ("lowcost", "ring"), ("pso", "vonneumann")]
    reqs = [SolveRequest(dim=DIM, particle_cnt=N, fitness=NAMES[k], seed=k,
                         iters=16, variant="async", sync_every=SE,
                         rule=rule, topology=topo)
            for k, (rule, topo) in enumerate(combos)]
    srv = SolveServer()
    results = srv.solve_all(list(reqs))
    for res, r in zip(results, reqs):
        assert res.ok
        cfg = PSOConfig(dim=r.dim, particle_cnt=r.particle_cnt,
                        fitness=r.fitness, dtype=r.dtype,
                        update_rule=r.rule, topology=r.topology)
        st = solve(cfg, r.seed, r.iters, r.variant, r.sync_every)
        assert res.gbest_fit == float(st.gbest_fit)
    # one dispatch per (rule, topology): the compile key split them
    assert srv.stats.dispatches == len(combos)
