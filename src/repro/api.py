"""The unified solve facade: ``repro.solve(problem, ...) -> Result``.

One entry point over the previously scattered surfaces (``core.pso.solve``,
``core.multi_swarm.solve_many``, ``kernels.ops.run_queue_lock_fused{,_batch,
_async,_async_batch}`` and the serving backend plumbing):

    import repro

    res = repro.solve("cubic", dim=120, particles=2048, iters=500)
    res = repro.solve(my_problem, iters=1000,
                      method=repro.Method(variant="async", backend="kernel"))

``problem`` is a registered name, a ``repro.Problem`` (user objective with
per-dimension bounds and min/max sense), or a bare pure-jnp callable.
``Method`` picks the aggregation variant and execution backend:

* ``variant``: ``reduction | queue | queue_lock | async`` (paper §3.2/§4).
* ``backend``: ``jnp`` (vmap-able XLA step functions), ``kernel`` (the
  fused/async Pallas TPU kernels; only ``queue_lock``/``async`` exist as
  kernels), or ``auto`` — kernel on a TPU backend for the two fused
  variants, jnp everywhere else.
* ``interpret``: Pallas interpret mode; ``None`` means auto (False only on
  an actual TPU backend).
* ``islands``/``exchange_interval``: shard the swarm over devices
  (``repro.core.distributed``) — ``variant="async"`` uses the barrier-free
  island ring exchange, the synchronous variants the ``_pmax_best``
  collective.

Results are reported in the problem's OWN sense: for a ``sense="min"``
problem ``Result.best_fit`` is the minimized objective value (the engine
maximizes internally; see ``repro.core.problem``).

Constrained problems (``Problem(constraints=ConstraintSet(...))`` — see
``repro.core.constraints``) report ``Result.feasible``/``violation``, and
``repro.best`` ranks results by the Deb feasibility rule. The adaptive
penalty ramp is applied here, by segmenting the run into static-weight
segments (each a plain solve on any backend) and re-weighting the carried
fitness at boundaries. ``Method(record_history=True)`` additionally
records the gbest-per-sync-point trajectory (``Result.history``,
``Result.first_feasible_iter``) on every single-device backend — the jnp
engines scan it in-program; the kernel backend chunks the launch at sync
points and reads the gbest back at each boundary.
``Method(telemetry=True)`` threads the in-kernel contention counters
(queue updates / gbest publications / per-block improvement events —
``repro.telemetry``) through the fused Pallas kernels onto
``Result.telemetry``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.multi_swarm import (SwarmBatch, batch_row, init_batch,
                                    run_many, run_many_with_history)
from repro.core.problem import Problem, resolve_problem
from repro.core.pso import (ASYNC_SYNC_EVERY, PSOConfig, SwarmState,
                            VARIANTS, init_swarm, run, run_with_history)
from repro.core.update_rules import (TOPOLOGIES, resolve_rule, rule_names)
from repro.telemetry import KernelCounters

_KERNEL_VARIANTS = ("queue_lock", "async")


def _default_backend() -> str:
    import jax
    return "tpu" if jax.default_backend() == "tpu" else "cpu-like"


@dataclasses.dataclass(frozen=True)
class Method:
    """How to run a solve: aggregation variant + execution backend.

    ``backend="auto"`` applies the fixed rule: the kernel backend on an
    actual TPU for the two fused variants (``queue_lock``/``async``), jnp
    everywhere else — EXCEPT when ``telemetry=True``, which always
    resolves to the kernel (the contention counters are collected inside
    the fused Pallas kernels; on non-TPU hosts the kernel runs in
    interpret mode). ``record_history=True`` works on either backend: the
    jnp engines scan the trajectory in-program, the kernel backend chunks
    its launch at sync points with a gbest readback per boundary.

    ``schedule="auto"`` goes further: instead of the fixed rule, the
    roofline autotuner (``repro.core.autotune``) picks the whole
    ``(variant, backend, block_n, sync_every)`` schedule per solve shape —
    cost-model ranking with a measured micro-run fallback, cached per
    shape. Under ``schedule="auto"`` the ``variant`` field is a
    preference, not a pin (the tuner may select a different variant); pin
    ``backend="jnp"``/``"kernel"`` to restrict the tuner's backend scope.
    The default ``schedule="fixed"`` keeps every knob exactly as given.

    ``islands > 0`` shards the swarm over that many devices
    (``repro.core.distributed``): particles split into equal islands, each
    island iterates locally and the global best is exchanged every
    ``exchange_interval`` iterations — via the barrier collective for the
    synchronous variants, via the asynchronous neighbor ring for
    ``variant="async"`` (staleness bound: ``sync_every`` iterations within
    an island plus ``islands`` exchange rounds across them).
    """

    variant: str = "queue"
    backend: str = "auto"                 # auto | jnp | kernel
    sync_every: int = ASYNC_SYNC_EVERY    # async variant publication interval
    block_n: Optional[int] = None         # kernel particle-block size
    interpret: Optional[bool] = None      # None: False only on real TPU
    islands: int = 0                      # >0: shard over this many devices
    exchange_interval: int = 1            # iterations between island syncs
    record_history: bool = False          # Result.history: gbest per sync
    # point (any single-device backend; islands do not surface it)
    telemetry: bool = False               # Result.telemetry: in-kernel
    # contention counters (kernel backend only — repro.telemetry)
    schedule: str = "fixed"               # fixed | auto (roofline autotuner)
    rule: str = "pso"                     # per-particle update rule
    # (repro.core.update_rules: pso | sso | lowcost | custom registrations)
    topology: str = "gbest"               # async block-neighborhood pull
    # (gbest star | ring | vonneumann — repro.core.topology)

    def __post_init__(self):
        if self.schedule not in ("fixed", "auto"):
            raise ValueError(
                f"unknown schedule {self.schedule!r}; one of fixed|auto")
        if self.schedule == "auto" and self.islands:
            raise ValueError(
                "schedule='auto' tunes single-device schedules; the island "
                "runners pick their own block layout — use schedule='fixed'")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r}; one of {VARIANTS}")
        if self.backend not in ("auto", "jnp", "kernel"):
            raise ValueError(
                f"unknown backend {self.backend!r}; one of auto|jnp|kernel")
        if self.backend == "kernel" and self.variant not in _KERNEL_VARIANTS:
            raise ValueError(
                f"backend='kernel' implements the kernel-eligible variants "
                f"{_KERNEL_VARIANTS}, not {self.variant!r}; use "
                f"backend='jnp'/'auto' for the other members of {VARIANTS}")
        r = resolve_rule(self.rule)       # raises listing rule_names()
        if (self.backend == "kernel" or self.telemetry) \
                and not r.kernel_eligible:
            eligible = tuple(n for n in rule_names()
                             if resolve_rule(n).kernel_eligible)
            raise ValueError(
                f"update rule {r.name!r} is not kernel-eligible; "
                f"kernel-eligible rules: {eligible} — use backend='jnp'")
        if self.telemetry and self.variant not in _KERNEL_VARIANTS:
            raise ValueError(
                f"telemetry counters are collected inside the fused Pallas "
                f"kernels, which implement {_KERNEL_VARIANTS} — "
                f"variant={self.variant!r} has no kernel to count in")
        if self.telemetry and self.backend == "jnp":
            raise ValueError(
                "telemetry counters are collected inside the fused Pallas "
                "kernels; use backend='kernel' or 'auto' (auto resolves to "
                "the kernel when telemetry is on)")
        if self.telemetry and self.islands:
            raise ValueError(
                "telemetry counters are single-device only (the island "
                "runners do not thread the counter outputs)")
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; one of {TOPOLOGIES}")
        if self.topology != "gbest" and self.variant != "async":
            raise ValueError(
                f"topology={self.topology!r} generalizes the async "
                f"variant's block-local pull; variant={self.variant!r} has "
                f"no block-local bests — use variant='async' (lbest "
                f"topologies: {TOPOLOGIES[1:]})")
        if self.islands < 0 or self.exchange_interval < 1:
            raise ValueError(
                f"islands={self.islands} must be >= 0 and "
                f"exchange_interval={self.exchange_interval} >= 1")
        if self.backend == "kernel" and self.islands and \
                self.variant == "async":
            raise ValueError(
                "async islands run the jnp ring local loop; use "
                "backend='auto'/'jnp' (the Pallas async kernel has no "
                "multi-device ring yet)")
        if self.record_history and self.islands:
            raise ValueError(
                "record_history is single-device only (the island runners "
                "do not surface per-iteration gbest); drop islands= or "
                "record the trajectory from a single-device solve")

    def resolve_backend(self) -> str:
        if self.backend != "auto":
            return self.backend
        if self.telemetry:
            # the contention counters only exist inside the fused kernels
            return "kernel"
        if self.variant in _KERNEL_VARIANTS and _default_backend() == "tpu":
            return "kernel"
        return "jnp"

    def resolve_schedule(self, problem, d: int, n: int, iters: int, *,
                         dtype: str = "float32", batch: int = 1,
                         hetero_table: int = 0, measure: bool = True):
        """The grown form of ``resolve_backend``: a full execution
        schedule for one solve shape. ``schedule="fixed"`` returns this
        Method's own knobs (backend resolved by the fixed rule);
        ``schedule="auto"`` asks the roofline autotuner — cost-model
        ranking, measured micro-run fallback (``measure=False`` stops at
        the model), on-disk cache per shape."""
        from repro.core.autotune import Schedule, resolve_schedule
        if self.schedule != "auto":
            return Schedule(variant=self.variant,
                            backend=self.resolve_backend(),
                            block_n=self.block_n,
                            sync_every=self.sync_every, source="fixed")
        kernel_ok = None
        if self.backend == "jnp":
            kernel_ok = False
        elif self.backend == "kernel" or self.telemetry:
            kernel_ok = True
        return resolve_schedule(
            problem, d, n, iters, dtype=dtype, batch=batch,
            hetero_table=hetero_table, record_history=self.record_history,
            measure=measure, kernel_ok=kernel_ok, rule=self.rule)

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return _default_backend() != "tpu"


@dataclasses.dataclass(frozen=True, eq=False)
class History:
    """Convergence history: the gbest trajectory sampled at sync points
    (every iteration for the synchronous jnp variants, every publication
    boundary for ``async``). ``violation`` is the recorded gbest's
    aggregate constraint violation — None for unconstrained problems."""

    iteration: np.ndarray              # [K] absolute iteration numbers
    gbest_fit: np.ndarray              # [K] canonical (maximized) fitness
    violation: Optional[np.ndarray]    # [K] or None (unconstrained)

    def __len__(self) -> int:
        return len(self.iteration)


@dataclasses.dataclass(frozen=True, eq=False)
class Result:
    """A finished solve. ``best_fit``/``best_pos`` are in the problem's own
    sense; ``state`` is the raw (canonical-max) SwarmState for resuming.

    Constrained problems additionally report ``feasible``/``violation``
    (the Deb-rule inputs — see ``repro.core.constraints``), and
    ``history``/``first_feasible_iter`` when the solve ran with
    ``Method(record_history=True)``. ``telemetry`` carries the in-kernel
    contention counters (``repro.telemetry.KernelCounters``) when the
    solve ran with ``Method(telemetry=True)``."""

    problem: Problem
    config: PSOConfig
    method: Method
    iters: int
    state: SwarmState
    history: Optional[History] = None
    telemetry: Optional[KernelCounters] = None

    @property
    def best_fit(self) -> float:
        return float(self.problem.user_value(self.state.gbest_fit))

    @property
    def best_pos(self) -> np.ndarray:
        return np.asarray(self.state.gbest_pos)

    @property
    def gbest_fit(self) -> float:
        """Canonical (maximized) fitness, as the engine tracks it."""
        return float(self.state.gbest_fit)

    @property
    def violation(self) -> float:
        """Aggregate constraint violation at ``best_pos`` (0.0 when
        unconstrained or exactly feasible)."""
        return self.problem.violation_at(self.state.gbest_pos)

    @property
    def feasible(self) -> bool:
        """True iff ``best_pos`` satisfies every constraint (trivially True
        for unconstrained problems)."""
        return self.violation <= 0.0

    @property
    def first_feasible_iter(self) -> Optional[int]:
        """The first recorded iteration whose gbest was feasible, or None
        (never feasible, or no history was recorded). Unconstrained
        problems report 0 — feasible from the start."""
        if not self.problem.constrained:
            return 0
        if self.history is None or self.history.violation is None:
            return None
        feas = np.flatnonzero(self.history.violation <= 0.0)
        return int(self.history.iteration[feas[0]]) if feas.size else None


def _effective_method(m: Method, problem, cfg: PSOConfig, iters: int,
                      batch: int = 1, hetero_table: int = 0) -> Method:
    """Collapse ``schedule="auto"`` into a concrete fixed Method via the
    autotuner (one resolution per solve, covering every ramp segment)."""
    if m.schedule != "auto":
        return m
    s = m.resolve_schedule(problem, cfg.dim, cfg.particle_cnt, iters,
                           dtype=cfg.dtype, batch=batch,
                           hetero_table=hetero_table)
    # lbest topologies only exist on the async variant's block-local
    # machinery — the tuner may not migrate such a request off async
    variant = s.variant if m.topology == "gbest" else m.variant
    return dataclasses.replace(m, variant=variant, backend=s.backend,
                               block_n=s.block_n, sync_every=s.sync_every,
                               schedule="fixed")


def _jnp_async_blocks(m: Method, n: int) -> Optional[int]:
    """The jnp engines take a block COUNT where the kernels take a block
    size; translate a tuned ``block_n`` for the async fallback."""
    if m.variant != "async" or m.block_n is None:
        return None
    return max(1, n // m.block_n)


def _make_method(method: Optional[Method], variant, backend, sync_every,
                 block_n, interpret, record_history=None,
                 schedule=None, rule=None, topology=None,
                 telemetry=None) -> Method:
    explicit = dict(variant=variant, backend=backend, sync_every=sync_every,
                    block_n=block_n, interpret=interpret,
                    record_history=record_history, schedule=schedule,
                    rule=rule, topology=topology, telemetry=telemetry)
    given = {k: v for k, v in explicit.items() if v is not None}
    if method is not None:
        if given:
            raise ValueError(
                f"pass either method= or the loose kwargs {sorted(given)}, "
                f"not both")
        return method
    return Method(**{**dict(variant="queue"), **given})


def _make_config(problem: Problem, dim, particles, w, c1, c2, dtype,
                 min_pos, max_pos, max_v, m: Optional[Method] = None
                 ) -> PSOConfig:
    if dim is None:
        dim = problem.ndim or 1
    kw = dict(dim=dim, particle_cnt=particles, fitness=problem, dtype=dtype,
              min_pos=min_pos, max_pos=max_pos, max_v=max_v)
    if m is not None:
        kw.update(update_rule=m.rule, topology=m.topology)
    for k, v in (("w", w), ("c1", c1), ("c2", c2)):
        if v is not None:
            kw[k] = v
    return PSOConfig(**kw).resolved()


def solve(problem: Union[str, Problem], *,
          dim: Optional[int] = None, particles: int = 1024,
          iters: int = 1000, seed: int = 0,
          method: Optional[Method] = None,
          variant: Optional[str] = None, backend: Optional[str] = None,
          sync_every: Optional[int] = None, block_n: Optional[int] = None,
          interpret: Optional[bool] = None,
          w: Optional[float] = None, c1: Optional[float] = None,
          c2: Optional[float] = None, dtype: str = "float32",
          min_pos=None, max_pos=None, max_v=None,
          record_history: Optional[bool] = None,
          schedule: Optional[str] = None,
          rule: Optional[str] = None,
          topology: Optional[str] = None,
          telemetry: Optional[bool] = None) -> Result:
    """Solve ``problem`` with ``particles`` particles for ``iters``
    iterations. Either pass a full ``method=Method(...)`` or the loose
    ``variant=``/``backend=``/... kwargs (not both). ``dim`` defaults to
    the problem's per-dimension bound length (else 1).
    ``schedule="auto"`` lets the roofline autotuner pick the execution
    schedule for this shape (see ``Method``).
    """
    prob = resolve_problem(problem)
    m = _make_method(method, variant, backend, sync_every, block_n,
                     interpret, record_history, schedule, rule, topology,
                     telemetry)
    cfg = _make_config(prob, dim, particles, w, c1, c2, dtype,
                       min_pos, max_pos, max_v, m)
    m = _effective_method(m, prob, cfg, iters)
    if m.islands:
        state = _run_islands(prob, cfg, seed, iters, m)
        hist, tel = None, None
    else:
        state = init_swarm(cfg, seed)
        state, hist, tel = _run_segmented(prob, cfg, state, iters, m)
    return Result(problem=prob, config=cfg, method=m, iters=iters,
                  state=state, history=hist, telemetry=tel)


def _run_islands(prob: Problem, cfg: PSOConfig, seed: int, iters: int,
                 m: Method) -> SwarmState:
    """The sharded path: init once, then one ``make_distributed_run`` per
    penalty-ramp segment over an ``m.islands``-device mesh (a single
    full-length runner when no ramp is configured). The mesh and sharded
    init are built once; only the per-segment runner re-jits, keyed on
    ``(weight, seg_iters)`` like every other backend's ramp."""
    import jax
    import numpy as _np
    from jax.sharding import Mesh
    from repro.core.distributed import (init_sharded_swarm,
                                        make_distributed_run)
    devs = jax.devices()
    if m.islands > len(devs):
        raise ValueError(
            f"islands={m.islands} exceeds the {len(devs)} available "
            f"device(s)")
    mesh = Mesh(_np.asarray(devs[:m.islands]), ("data",))
    local_step = None
    if m.variant != "async" and m.resolve_backend() == "kernel":
        from repro.kernels.ops import make_fused_local_step
        local_step = make_fused_local_step(
            block_n=m.block_n, interpret=m.resolve_interpret())
    state = init_sharded_swarm(cfg, seed, mesh)

    def run_seg(cfg_k: PSOConfig, s: SwarmState, seg_iters: int):
        runner = make_distributed_run(
            cfg_k, mesh, iters=seg_iters, variant=m.variant,
            exchange_interval=m.exchange_interval, local_step_fn=local_step,
            sync_every=m.sync_every)
        return runner(s), None

    def reweight(cfg_k: PSOConfig, s: SwarmState) -> SwarmState:
        # The async ring takes lbest-free inputs (its in_specs carry no
        # locals; each segment re-seeds its block caches), so drop them
        # before re-weighting — sync variants never carry them here.
        return _reweight_state(cfg_k, s._replace(lbest_pos=None,
                                                 lbest_fit=None))

    state, _ = _ramp_loop(prob, cfg, state, iters, run_seg, reweight)
    return state


def _ramp_segments(iters: int, cset):
    """(segment_iters, penalty_weight) pairs for the adaptive penalty ramp
    (``repro.core.constraints``): segment k of ``ramp_every`` iterations
    runs at ``weight * ramp**k``. A single ``(iters, None)`` segment (the
    unchanged problem) when no ramp is configured."""
    if (cset is None or cset.mode != "penalty" or cset.ramp_every <= 0
            or cset.ramp == 1.0):
        return [(iters, None)]
    segs, done, k = [], 0, 0
    while done < iters:
        n = min(cset.ramp_every, iters - done)
        segs.append((n, cset.weight * (cset.ramp ** k)))
        done += n
        k += 1
    return segs


def _reweight_state(cfg: PSOConfig, state: SwarmState) -> SwarmState:
    """Re-evaluate the carried fitness fields under a new penalty weight
    (ramp segment boundary): current/pbest/block-local fitness from their
    positions, gbest re-selected from the re-weighted pbests — so the
    selection invariants (gbest == max(pbest)) hold at every weight."""
    import jax.numpy as jnp
    fn = cfg.fitness_fn
    fit = fn(state.pos)
    pbf = fn(state.pbest_pos)
    b = jnp.argmax(pbf)
    state = state._replace(fit=fit, pbest_fit=pbf,
                           gbest_pos=state.pbest_pos[b], gbest_fit=pbf[b])
    if state.lbest_fit is not None:
        state = state._replace(lbest_fit=fn(state.lbest_pos))
    return state


def _ramp_loop(prob: Problem, cfg: PSOConfig, state, iters: int,
               run_seg, reweight):
    """The shared penalty-ramp scheduler: each segment is a plain
    static-weight run (so the ramp composes with every backend), with the
    carried fitness re-weighted at segment boundaries. ``run_seg(cfg,
    state, seg_iters) -> (state, history|None)``; ``reweight(cfg, state)
    -> state``. Used by both ``solve`` (SwarmState) and ``solve_many``
    (SwarmBatch). Returns (state, [history, ...])."""
    hists = []
    first = True
    for seg_iters, weight in _ramp_segments(iters, prob.constraints):
        if weight is None:
            cfg_k = cfg
        else:
            cfg_k = dataclasses.replace(
                cfg, fitness=prob.with_penalty_weight(weight))
            if not first:
                state = reweight(cfg_k, state)
        state, h = run_seg(cfg_k, state, seg_iters)
        if h is not None:
            hists.append(h)
        first = False
    return state, hists


def _sum_counters(cnts):
    """Fold per-segment counter records into one (None when empty)."""
    total = None
    for c in cnts:
        total = c if total is None else total + c
    return total


def _run_segmented(prob: Problem, cfg: PSOConfig, state: SwarmState,
                   iters: int, m: Method):
    cnts = []

    def seg(c, s, k):
        s, h, cnt = _run_state(c, s, k, m)
        if cnt is not None:
            cnts.append(cnt)
        return s, h

    state, hists = _ramp_loop(prob, cfg, state, iters, seg, _reweight_state)
    tel = _sum_counters(cnts)
    if not hists:
        return state, None, tel
    return state, History(
        iteration=np.concatenate([h[0] for h in hists]),
        gbest_fit=np.concatenate([h[1] for h in hists]),
        violation=(None if hists[0][2] is None
                   else np.concatenate([h[2] for h in hists]))), tel


def _run_state(cfg: PSOConfig, state: SwarmState, iters: int, m: Method):
    """One static-weight segment -> (state, history-or-None,
    KernelCounters-or-None)."""
    if m.resolve_backend() == "kernel":
        return _run_state_kernel(cfg, state, iters, m)
    if m.record_history:
        state, (its, fits, viols) = run_with_history(
            cfg, state, iters, m.variant, sync_every=m.sync_every)
        return state, (np.asarray(its, dtype=np.int64), np.asarray(fits),
                       None if viols is None else np.asarray(viols)), None
    return run(cfg, state, iters, m.variant, sync_every=m.sync_every,
               n_blocks=_jnp_async_blocks(m, state.pos.shape[0])), None, None


def _run_state_kernel(cfg: PSOConfig, state: SwarmState, iters: int,
                      m: Method):
    """The kernel-backend segment runner, optionally threading the
    in-kernel telemetry counters and/or recording the gbest trajectory.

    History chunks the launch at sync points with a gbest readback per
    boundary: every grid step for the fused sync kernel (its grid is
    iteration-major, so chunking the host loop is bit-exact) and every
    ``sync_every`` boundary for async (the chunk seams coincide with the
    kernel's own block-resident chunks; exact for a single particle block,
    a more-synchronous interleaving for multi-block layouts — see
    ``kernels.ops.run_queue_lock_fused_async``). Counters are additive, so
    per-chunk counts sum to the uninterrupted run's."""
    from repro.kernels.ops import (run_queue_lock_fused,
                                   run_queue_lock_fused_async)
    interp = m.resolve_interpret()

    def launch(s, k):
        if m.variant == "async":
            return run_queue_lock_fused_async(
                cfg, s, k, sync_every=m.sync_every, block_n=m.block_n,
                interpret=interp, telemetry=m.telemetry)
        return run_queue_lock_fused(cfg, s, k, block_n=m.block_n,
                                    interpret=interp, telemetry=m.telemetry)

    if not m.record_history:
        if m.telemetry:
            state, cnt = launch(state, iters)
            return state, None, KernelCounters.from_array(cnt)
        return launch(state, iters), None, None
    vf = cfg.problem.violation_fn
    stride = max(1, m.sync_every) if m.variant == "async" else 1
    its, fits, viols, cnts = [], [], [], []
    done = 0
    while done < iters:
        k = min(stride, iters - done)
        if m.telemetry:
            state, cnt = launch(state, k)
            cnts.append(KernelCounters.from_array(cnt))
        else:
            state = launch(state, k)
        done += k
        its.append(int(state.iteration))
        fits.append(np.asarray(state.gbest_fit))
        viols.append(np.asarray(vf(state.gbest_pos))
                     if vf is not None else 0.0)
    hist = (np.asarray(its, dtype=np.int64), np.asarray(fits),
            np.asarray(viols) if cfg.problem.constrained else None)
    return state, hist, _sum_counters(cnts)


def solve_many(problem: Union[str, Problem, None] = None,
               seeds: Sequence[int] = (), *,
               problems: Optional[Sequence[Union[str, Problem]]] = None,
               dim: Optional[int] = None, particles: int = 1024,
               iters: int = 1000,
               method: Optional[Method] = None,
               variant: Optional[str] = None, backend: Optional[str] = None,
               sync_every: Optional[int] = None,
               block_n: Optional[int] = None,
               interpret: Optional[bool] = None,
               coeffs: Optional[Tuple] = None,
               w: Optional[float] = None, c1: Optional[float] = None,
               c2: Optional[float] = None, dtype: str = "float32",
               min_pos=None, max_pos=None, max_v=None,
               record_history: Optional[bool] = None,
               schedule: Optional[str] = None,
               rule: Optional[str] = None,
               topology: Optional[str] = None,
               telemetry: Optional[bool] = None) -> List[Result]:
    """Batched facade: one independent solve per entry of ``seeds``, all in
    ONE device program (vmapped jnp engine, or the batched fused/async
    Pallas kernels for ``backend="kernel"``). Row ``s`` is bit-identical to
    ``solve(problem, seed=seeds[s], ...)`` with the same method when
    ``coeffs`` is None. Returns one ``Result`` per seed.

    ``problems=`` (instead of ``problem``) makes the batch heterogeneous:
    row ``s`` solves ``problems[s]`` — each a registered built-in — with
    its own objective and box bounds dispatched by ``lax.switch`` inside
    the one program (jnp engine and both batched kernels). Bounds come
    from each row's problem, so the ``min_pos``/``max_pos``/``max_v``
    overrides are rejected; penalty-ramp schedules don't apply (built-in
    table entries are unconstrained or static-penalty). The validated
    exactness surface is ``gbest_pos``/``gbest_fit`` (see
    ``repro.core.pso``'s heterogeneous-dispatch notes for the full
    envelope).
    """
    m = _make_method(method, variant, backend, sync_every, block_n,
                     interpret, record_history, schedule, rule, topology,
                     telemetry)
    if m.islands:
        raise ValueError("islands shard ONE swarm over devices; use solve()"
                         " — solve_many batches independent swarms instead")
    if (problem is None) == (problems is None):
        raise ValueError(
            "pass exactly one of problem= (homogeneous batch) or "
            "problems= (one problem per seed)")
    if problems is not None:
        return _solve_many_hetero(problems, seeds, m, dim, particles, iters,
                                  coeffs, w, c1, c2, dtype,
                                  min_pos, max_pos, max_v)
    prob = resolve_problem(problem)
    cfg = _make_config(prob, dim, particles, w, c1, c2, dtype,
                       min_pos, max_pos, max_v, m)
    m = _effective_method(m, prob, cfg, iters, batch=len(seeds))
    batch = init_batch(cfg, np.asarray(seeds, dtype=np.int64))
    cnts = []

    def seg(c, b, k):
        b, h, cnt = _run_batch(c, b, k, m, coeffs)
        if cnt is not None:
            cnts.append(cnt)
        return b, h

    batch, hists = _ramp_loop(prob, cfg, batch, iters, seg, _reweight_batch)
    rows_hist = _row_histories(hists, batch.swarm_cnt)
    rows_tel = _row_counters(cnts, batch.swarm_cnt)
    return [Result(problem=prob, config=cfg, method=m, iters=iters,
                   state=batch_row(batch, s), history=rows_hist[s],
                   telemetry=rows_tel[s])
            for s in range(batch.swarm_cnt)]


def _row_histories(hists, s_cnt: int) -> List[Optional[History]]:
    """Per-row History objects from per-segment ``(its, [K,S] fits,
    [K,S] viols|None)`` records (all-None when no history was recorded)."""
    if not hists:
        return [None] * s_cnt
    its = np.concatenate([np.asarray(h[0], dtype=np.int64) for h in hists])
    fits = np.concatenate([np.asarray(h[1]) for h in hists])
    viols = (None if hists[0][2] is None
             else np.concatenate([np.asarray(h[2]) for h in hists]))
    return [History(iteration=its, gbest_fit=fits[:, s],
                    violation=None if viols is None else viols[:, s])
            for s in range(s_cnt)]


def _row_counters(cnts, s_cnt: int) -> List[Optional[KernelCounters]]:
    """Per-row KernelCounters from per-segment ``[S, 3]`` count arrays."""
    total = _sum_counters([np.asarray(c) for c in cnts])
    if total is None:
        return [None] * s_cnt
    return [KernelCounters.from_array(total[s]) for s in range(s_cnt)]


def _solve_many_hetero(problems, seeds, m: Method, dim, particles, iters,
                       coeffs, w, c1, c2, dtype,
                       min_pos, max_pos, max_v) -> List[Result]:
    """``solve_many(problems=[...])``: per-row problem dispatch."""
    from repro.core.pso import hetero_member_config
    if min_pos is not None or max_pos is not None or max_v is not None:
        raise ValueError("heterogeneous batches take bounds from each "
                         "row's problem; drop min_pos/max_pos/max_v")
    probs = [resolve_problem(p) for p in problems]
    if len(probs) != len(seeds):
        raise ValueError(f"{len(probs)} problems for {len(seeds)} seeds")
    # cfg.fitness is a canonical placeholder: the rows carry the real
    # objectives, and a fixed value lets every mix share one compiled
    # program. Bounds stay unset — the core validates that.
    kw = dict(dim=dim if dim is not None else 1, particle_cnt=particles,
              fitness="cubic", dtype=dtype,
              update_rule=m.rule, topology=m.topology)
    for key, v in (("w", w), ("c1", c1), ("c2", c2)):
        if v is not None:
            kw[key] = v
    cfg = PSOConfig(**kw)
    m = _effective_method(m, probs[0], cfg, iters, batch=len(seeds),
                          hetero_table=len({p.cache_key() for p in probs}))
    seeds_arr = np.asarray(seeds, dtype=np.int64)
    hists, cnts = [], []
    if m.resolve_backend() == "kernel":
        if coeffs is not None:
            raise ValueError("per-swarm coeffs are a jnp-backend feature")
        from repro.core.multi_swarm import problem_rows
        rows, table = problem_rows(probs, cfg.dim, cfg.dtype)
        rcfg = cfg.resolved()
        batch = init_batch(rcfg, seeds_arr, rows=rows, table=table)
        batch, hist, cnt = _run_batch_kernel(rcfg, batch, iters, m,
                                             rows=rows, table=table)
        if hist is not None:
            hists.append(hist)
        if cnt is not None:
            cnts.append(cnt)
    elif m.record_history:
        from repro.core.multi_swarm import problem_rows
        rows, table = problem_rows(probs, cfg.dim, cfg.dtype)
        rcfg = cfg.resolved()
        batch = init_batch(rcfg, seeds_arr, rows=rows, table=table)
        batch, (its, fits, viols) = run_many_with_history(
            rcfg, batch, iters, m.variant, coeffs,
            sync_every=m.sync_every, rows=rows, table=table,
            n_blocks=_jnp_async_blocks(m, cfg.particle_cnt))
        hists.append((np.asarray(its, dtype=np.int64), np.asarray(fits),
                      None if viols is None else np.asarray(viols)))
    else:
        from repro.core.multi_swarm import solve_many as _core_solve_many
        batch = _core_solve_many(cfg, seeds_arr, iters=iters,
                                 variant=m.variant, coeffs=coeffs,
                                 sync_every=m.sync_every, problems=probs,
                                 n_blocks=_jnp_async_blocks(
                                     m, cfg.particle_cnt))
    rows_hist = _row_histories(hists, batch.swarm_cnt)
    rows_tel = _row_counters(cnts, batch.swarm_cnt)
    return [Result(problem=probs[s],
                   config=hetero_member_config(cfg, probs[s]),
                   method=m, iters=iters, state=batch_row(batch, s),
                   history=rows_hist[s], telemetry=rows_tel[s])
            for s in range(batch.swarm_cnt)]


def _reweight_batch(cfg: PSOConfig, batch: SwarmBatch) -> SwarmBatch:
    """Batched ``_reweight_state`` (ramp segment boundary)."""
    import jax.numpy as jnp
    fn = cfg.fitness_fn
    fit = fn(batch.pos)                               # [S, N]
    pbf = fn(batch.pbest_pos)
    b = jnp.argmax(pbf, axis=1)                       # [S]
    gp = jnp.take_along_axis(batch.pbest_pos, b[:, None, None], axis=1)[:, 0]
    gf = jnp.take_along_axis(pbf, b[:, None], axis=1)[:, 0]
    batch = batch._replace(fit=fit, pbest_fit=pbf, gbest_pos=gp,
                           gbest_fit=gf)
    if batch.lbest_fit is not None:
        batch = batch._replace(lbest_fit=fn(batch.lbest_pos))
    return batch


def _run_batch(cfg: PSOConfig, batch: SwarmBatch, iters: int, m: Method,
               coeffs):
    """One static-weight batched segment -> (batch, history-or-None,
    [S, 3] counter rows or None)."""
    if m.resolve_backend() == "kernel":
        if coeffs is not None:
            raise ValueError("per-swarm coeffs are a jnp-backend feature")
        return _run_batch_kernel(cfg, batch, iters, m)
    if m.record_history:
        batch, (its, fits, viols) = run_many_with_history(
            cfg, batch, iters, m.variant, coeffs, sync_every=m.sync_every,
            n_blocks=_jnp_async_blocks(m, batch.pos.shape[1]))
        return batch, (np.asarray(its, dtype=np.int64), np.asarray(fits),
                       None if viols is None else np.asarray(viols)), None
    return run_many(cfg, batch, iters, m.variant, coeffs,
                    sync_every=m.sync_every,
                    n_blocks=_jnp_async_blocks(m, batch.pos.shape[1])
                    ), None, None


def _run_batch_kernel(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                      m: Method, rows=None, table=None):
    """Batched-kernel segment runner: the batched fused/async Pallas
    kernels, with the same optional telemetry threading and chunked
    history readbacks as ``_run_state_kernel`` (one ``[K, S]`` trajectory
    sample per sync point). ``rows``/``table`` make the batch
    heterogeneous (per-row ``lax.switch`` objective dispatch)."""
    from repro.kernels.ops import (run_queue_lock_fused_batch,
                                   run_queue_lock_fused_async_batch)
    interp = m.resolve_interpret()
    fids = None if rows is None else rows.fid

    def launch(b, k):
        if m.variant == "async":
            return run_queue_lock_fused_async_batch(
                cfg, b, k, sync_every=m.sync_every, block_n=m.block_n,
                interpret=interp, fids=fids, table=table,
                telemetry=m.telemetry)
        return run_queue_lock_fused_batch(
            cfg, b, k, block_n=m.block_n, interpret=interp, fids=fids,
            table=table, telemetry=m.telemetry)

    if not m.record_history:
        if m.telemetry:
            batch, cnt = launch(batch, iters)
            return batch, None, cnt
        return launch(batch, iters), None, None
    vf = None if rows is not None else cfg.problem.violation_fn
    stride = max(1, m.sync_every) if m.variant == "async" else 1
    its, fits, viols, cnts = [], [], [], []
    done = 0
    while done < iters:
        k = min(stride, iters - done)
        if m.telemetry:
            batch, cnt = launch(batch, k)
            cnts.append(np.asarray(cnt))
        else:
            batch = launch(batch, k)
        done += k
        its.append(int(batch.iteration[0]))
        fits.append(np.asarray(batch.gbest_fit))
        if vf is not None:
            import jax
            viols.append(np.asarray(jax.vmap(vf)(batch.gbest_pos)))
    constrained = rows is None and cfg.problem.constrained
    hist = (np.asarray(its, dtype=np.int64), np.asarray(fits),
            np.asarray(viols) if constrained and viols else None)
    return batch, hist, _sum_counters(cnts)


def solve_stream(requests: Sequence, *, lane_width: int = 8,
                 coalesce_registry: bool = True,
                 compile_cache=None, autotune: bool = False,
                 metrics=None, record_history: bool = False,
                 trace=None, trace_path: Optional[str] = None) -> List:
    """Streaming facade: run a stream of independent solve requests
    through the continuous-batching scheduler
    (``repro.serving.ContinuousScheduler``).

    ``requests`` are ``repro.launch.serve.SolveRequest``s (or dicts of
    their fields). Async-variant requests ride persistent batched lanes
    with chunk-boundary admission — every result bit-identical to the
    standalone ``solve`` of its request — while synchronous-variant and
    sub-chunk requests fall back to standalone solves. ``compile_cache``
    (a ``repro.serving.CompileCache``, or a directory path for one) makes
    the lane programs persist across process restarts; ``metrics`` (a
    ``repro.serving.ServingMetrics``) collects latency spans and
    batch-fill counters. Returns one ``SolveResult`` per request, in
    request order.

    Telemetry: ``trace`` (a ``repro.telemetry.TraceWriter``) records the
    serving timeline — one Perfetto row per lane, a span per dispatched
    chunk — and ``trace_path`` writes it as ``trace.json`` on completion
    (allocating a writer if ``trace`` is None). ``record_history=True``
    accumulates each request's gbest-vs-iteration series at its lane's
    chunk boundaries onto ``SolveResult.history``.
    """
    from repro.launch.serve import SolveRequest
    from repro.serving import CompileCache, ContinuousScheduler
    if isinstance(compile_cache, str):
        compile_cache = CompileCache(path=compile_cache)
    if trace is None and trace_path is not None:
        from repro.telemetry import TraceWriter
        trace = TraceWriter()
    reqs = [r if isinstance(r, SolveRequest) else SolveRequest(**r)
            for r in requests]
    sched = ContinuousScheduler(
        lane_width=lane_width, coalesce_registry=coalesce_registry,
        compile_cache=compile_cache, autotune=autotune, metrics=metrics,
        trace=trace, record_history=record_history)
    out = sched.run(reqs)
    if trace is not None and trace_path is not None:
        trace.write(trace_path)
    return out


def best(results: Sequence[Result]) -> Result:
    """The best Result of a batch, by the Deb feasibility rule: a feasible
    result beats any infeasible one; feasible results compare on fitness
    (the problem's own sense); infeasible results compare on violation
    (smaller wins). For unconstrained problems every result is feasible at
    violation zero, so this is exactly the old max-fitness rule."""
    results = list(results)
    feas = [r for r in results if r.feasible]
    if feas:
        return max(feas, key=lambda r: r.gbest_fit)
    return min(results, key=lambda r: r.violation)
