"""MoE dispatch correctness: capacity accounting, gate weighting,
equivalence with a dense (loop-over-experts) reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import init_moe, moe_apply, _capacity


def _dense_reference(p, x, n_experts, top_k, act):
    """No-drop reference: every token runs through its top-k experts."""
    from repro.models.layers import act_fn
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    for e in range(n_experts):
        h = xt @ p["w_in"][e]
        if "w_gate" in p:
            h = act_fn(act)(xt @ p["w_gate"][e]) * h
        else:
            h = act_fn(act)(h)
        y = h @ p["w_out"][e]
        for j in range(top_k):
            w = jnp.where(experts[:, j] == e, gates[:, j], 0.0)
            out = out + y.astype(jnp.float32) * w[:, None]
    return out.reshape(b, s, d).astype(x.dtype)


@pytest.mark.slow
def test_moe_matches_dense_reference_when_capacity_ample():
    d, ff, e, k = 16, 32, 4, 2
    key = jax.random.key(0)
    p = init_moe(key, d, ff, e, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, d), jnp.float32)
    # capacity factor 8 => no token ever dropped
    got, aux = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=8.0,
                         act="silu", group_tokens=16)
    want = _dense_reference(p, x, e, k, "silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


@pytest.mark.slow
def test_moe_drops_only_over_capacity():
    """With tight capacity, output norm shrinks but stays finite, and
    groups are independent."""
    d, ff, e, k = 8, 16, 4, 2
    p = init_moe(jax.random.key(0), d, ff, e, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(2), (1, 64, d), jnp.float32)
    ample, _ = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=8.0,
                         act="silu", group_tokens=64)
    tight, _ = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=0.5,
                         act="silu", group_tokens=64)
    assert np.all(np.isfinite(np.asarray(tight)))
    assert (np.linalg.norm(np.asarray(tight))
            <= np.linalg.norm(np.asarray(ample)) + 1e-3)


def test_capacity_rounding():
    assert _capacity(4096, 16, 2, 1.25) == 640
    assert _capacity(64, 4, 2, 1.25) == 40
    assert _capacity(8, 128, 2, 1.25) == 8      # floor


@pytest.mark.slow
def test_moe_grads_flow_to_router_and_experts():
    d, ff, e, k = 8, 16, 4, 2
    p = init_moe(jax.random.key(0), d, ff, e, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(3), (1, 16, d), jnp.float32)

    def loss(p):
        y, aux = moe_apply(p, x, n_experts=e, top_k=k, capacity_factor=2.0,
                           act="silu", group_tokens=16)
        return jnp.sum(y * y) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_in", "w_out"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0.0, name
