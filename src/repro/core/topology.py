"""Block-neighborhood (lbest) topologies for the async variant.

The paper uses the star topology: every particle sees the swarm-wide
best, and the queue/queue-lock algorithms accelerate exactly that
aggregation. The async (enhanced queue-lock) variant already maintains
*block-local* bests between publication points — this module generalizes
the pull half of its sync: with ``PSOConfig(topology="ring")`` or
``"vonneumann"``, a block refreshes its local best from its
**neighborhood** of block-locals instead of the shared gbest, so swarm
knowledge diffuses hop by hop (classic lbest dynamics at block
granularity) while the shared gbest is still *flushed* every sync for
monitoring and the final answer.

Topologies:

* ``gbest`` — the paper's star (default; handled inline in
  ``core/pso.run_async`` / the Pallas async kernels, not here).
* ``ring`` — blocks on a cycle; neighborhood = {b-1, b, b+1} (mod nb).
* ``vonneumann`` — blocks on a near-square 2D torus (``grid_dims``);
  neighborhood = the 4-connected von Neumann stencil + self.

Both engines share the neighbor *definition*: the jnp engine folds rolls
over the ``[nb, D]`` local-best buffers (``block_neighbor_best``), and
the Pallas async kernels fold the same offsets as dynamic SMEM/column
reads (``kernel_neighbor_ids`` — see ``kernels/pso_step.py``). The two
engines still differ in *schedule* (lockstep blocks vs the kernels'
block-major grid), so each is validated against its own eager oracle,
exactly like the star-topology async variant.

``_neighborhood_best`` is the original seed helper (particle-granularity
ring max via vectorized rolls), now the implementation under the ring
topology's block-level pull.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

Array = jnp.ndarray


def _neighborhood_best(pbest_fit: Array, pbest_pos: Array, radius: int
                       ) -> Tuple[Array, Array]:
    """Best (fit, pos) among each row's ring neighborhood (incl. self)."""
    best_fit = pbest_fit
    best_pos = pbest_pos
    for off in range(1, radius + 1):
        for sign in (off, -off):
            f = jnp.roll(pbest_fit, sign, axis=0)
            p = jnp.roll(pbest_pos, sign, axis=0)
            take = f > best_fit
            best_fit = jnp.where(take, f, best_fit)
            best_pos = jnp.where(take[:, None], p, best_pos)
    return best_fit, best_pos


def grid_dims(nb: int) -> Tuple[int, int]:
    """Near-square (rows, cols) factorization of ``nb`` for the von
    Neumann torus: rows is the largest divisor ≤ sqrt(nb). Degenerate
    block counts (primes, nb < 4) fall back to a 1 x nb ring-like grid."""
    r = 1
    d = 1
    while d * d <= nb:
        if nb % d == 0:
            r = d
        d += 1
    return r, nb // r


def block_neighbor_best(lbf: Array, lbp: Array, topology: str
                       ) -> Tuple[Array, Array]:
    """Neighborhood max over the block-local bests: ``(lbp', lbf')``.

    ``lbf [nb]`` / ``lbp [nb, D]`` are the async variant's block-local
    bests; each block's slot is replaced by the best over its
    ``topology`` neighborhood (always including itself, so locals never
    regress). Pure rolls/wheres — vmap-clean for the batched engine.
    """
    if topology == "ring":
        bf, bp = _neighborhood_best(lbf, lbp, radius=1)
        return bp, bf
    if topology == "vonneumann":
        nb, d = lbp.shape
        rows, cols = grid_dims(nb)
        f = lbf.reshape(rows, cols)
        p = lbp.reshape(rows, cols, d)
        best_f, best_p = f, p
        for axis in (0, 1):
            for shift in (1, -1):
                ff = jnp.roll(f, shift, axis=axis)
                pp = jnp.roll(p, shift, axis=axis)
                take = ff > best_f
                best_f = jnp.where(take, ff, best_f)
                best_p = jnp.where(take[..., None], pp, best_p)
        return best_p.reshape(nb, d), best_f.reshape(nb)
    raise ValueError(f"unknown lbest topology {topology!r}; "
                     f"one of ('ring', 'vonneumann')")


def kernel_neighbor_ids(b, nb: int, topology: str) -> Tuple:
    """Traced neighbor block ids of block ``b`` (excluding self) under the
    same neighbor definition as ``block_neighbor_best`` — the Pallas
    async kernels fold these as dynamic reads of the local-best buffers.
    ``b`` may be a traced scalar; ``nb``/``topology`` are static."""
    if topology == "ring":
        return ((b + nb - 1) % nb, (b + 1) % nb)
    if topology == "vonneumann":
        rows, cols = grid_dims(nb)
        r, c = b // cols, b % cols
        return (((r + rows - 1) % rows) * cols + c,
                ((r + 1) % rows) * cols + c,
                r * cols + (c + cols - 1) % cols,
                r * cols + (c + 1) % cols)
    raise ValueError(f"unknown lbest topology {topology!r}; "
                     f"one of ('ring', 'vonneumann')")
