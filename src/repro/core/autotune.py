"""Schedule autotuning: pick ``(variant, backend, block_n, sync_every)``
per solve shape from the roofline cost model, with measured fallback.

``repro.Method(schedule="auto")`` routes here instead of the fixed
``resolve_backend`` rule. Resolution is three-stage:

1. **Cache** — measured optima persist per ``(backend scope, shape key)``
   in an on-disk JSON cache (``REPRO_AUTOTUNE_CACHE``, default
   ``~/.cache/repro/autotune.json``) fronted by an in-process LRU, so the
   second resolve of a shape never re-measures (and a serving replica
   inherits its predecessor's tuning).
2. **Model** — ``repro.roofline.pso_cost`` prices every candidate
   schedule (variants x block sizes x sync intervals) with a calibration
   fitted from the committed benchmark history; candidates rank by
   predicted microseconds per iteration.
3. **Measured fallback** — the top-``K`` model picks PLUS the fixed
   default schedule run timed micro-iterations (``tuner``-style
   self-measurement); the measured argmin wins, except that a challenger
   within ``MEASURE_NOISE_MARGIN`` of the fixed default loses to it
   (hysteresis — a within-noise win would flip sign on re-measurement).
   Including the fixed default makes the tuned choice never worse than
   the fixed rule by construction, model error notwithstanding.

Kernel-backend candidates only enter on an actual TPU backend — in
interpret mode the per-grid-step cost (~30us on this container, fitted
from the async_sweep history) makes every kernel schedule lose, and the
model would have to know interpret-mode throughput to price them fairly.

The serving layer (``repro.launch.serve``) uses the model-only entry
points: ``tuned_sync_every`` rewrites async requests' publication
interval before grouping (the tuned value is part of the batch compile
key, so cached programs are shared), and ``bucket_ladder`` drops bucket
sizes whose marginal per-row gain the model prices below threshold.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_LRU_SIZE = 512
#: measured micro-run length (iterations) and repeats for the fallback
MEASURE_ITERS = 24
MEASURE_REPEATS = 2
#: how many model-ranked candidates the measured fallback times
TOP_K = 3
#: hysteresis: a candidate must beat the measured fixed default by this
#: fraction to displace it. Micro-run timings on a busy host carry ~5%
#: noise; without a margin the tuner would "win" coin flips at resolve
#: time and lose them on the next independent measurement. Real schedule
#: gains (the async kernel's 3-4x, a wrong-variant pin's 1.5x) clear
#: this easily.
MEASURE_NOISE_MARGIN = 0.10
SYNC_EVERY_CHOICES = (1, 4, 8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A fully-resolved execution schedule for one solve shape.

    ``block_n`` is the kernel block size / jnp async block size (None:
    the ``pick_block_n`` default); ``sync_every`` only matters for
    ``variant="async"``. ``source`` records how the schedule was chosen:
    ``fixed`` (the legacy rule), ``model`` (analytic ranking only),
    ``measured`` (micro-run fallback) or ``cache`` (a previously measured
    optimum)."""

    variant: str
    backend: str
    block_n: Optional[int] = None
    sync_every: int = 8
    source: str = "fixed"
    predicted_us: Optional[float] = None
    measured_us: Optional[float] = None

    def replace(self, **kw) -> "Schedule":
        return dataclasses.replace(self, **kw)


def _kernel_ok() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def shape_key(problem, d: int, n: int, iters: int, dtype: str,
              batch: int = 1, hetero_table: int = 0,
              rule: str = "pso") -> str:
    """Stable cache key for one solve shape. ``iters`` is bucketed to its
    power-of-two ceiling — schedule choice is insensitive to small iter
    differences, and unbucketed keys would fragment the cache. The update
    rule is part of the shape: its op mix moves the compute roofline."""
    from repro.core.problem import resolve_problem

    it = 1
    while it < max(1, iters):
        it *= 2
    prob = resolve_problem(problem)
    pid = prob.name if not prob.constrained else f"{prob.name}+c"
    if not FITNESS_NAMED(prob):
        pid = f"custom:{hash(prob.cache_key()) & 0xffffffff:x}"
    return (f"{pid}|d{d}|n{n}|i{it}|{dtype}|b{batch}|h{hetero_table}"
            f"|r{rule}")


def FITNESS_NAMED(prob) -> bool:
    from repro.core.fitness import BUILTIN_PROBLEMS
    return any(prob.name == p.name for p in BUILTIN_PROBLEMS)


class AutotuneCache:
    """Measured-optima store: on-disk JSON + in-process LRU.

    The disk document maps ``{scope}::{shape_key} -> schedule dict``;
    writes are atomic (tmp + rename) and last-writer-wins — concurrent
    tuners may each measure once, which is safe, just redundant."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get(CACHE_ENV) or os.path.join(
            os.path.expanduser("~"), ".cache", "repro", "autotune.json")
        self._lru: "OrderedDict[str, Schedule]" = OrderedDict()
        self._disk_loaded = False

    def _load_disk(self) -> dict:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return {}

    def get(self, scope: str, key: str) -> Optional[Schedule]:
        k = f"{scope}::{key}"
        if k in self._lru:
            self._lru.move_to_end(k)
            return self._lru[k]
        if not self._disk_loaded:
            for dk, v in self._load_disk().items():
                try:
                    self._lru.setdefault(dk, Schedule(**v))
                except TypeError:
                    continue    # stale schema: ignore, will re-measure
            self._disk_loaded = True
            if k in self._lru:
                return self._lru[k]
        return None

    def put(self, scope: str, key: str, sched: Schedule) -> None:
        k = f"{scope}::{key}"
        self._lru[k] = sched.replace(source="cache")
        self._lru.move_to_end(k)
        while len(self._lru) > _LRU_SIZE:
            self._lru.popitem(last=False)
        doc = self._load_disk()
        doc[k] = dataclasses.asdict(sched.replace(source="cache"))
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass    # cache is an optimization; never fail the solve


_CACHE: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    global _CACHE
    if _CACHE is None or _CACHE.path != (
            os.environ.get(CACHE_ENV) or _CACHE.path):
        _CACHE = AutotuneCache()
    return _CACHE


def fixed_schedule(variant: str = "queue", *, record_history: bool = False,
                   sync_every: int = 8,
                   block_n: Optional[int] = None) -> Schedule:
    """The legacy ``Method.resolve_backend`` rule as a Schedule: kernel on
    TPU for the fused variants (unless history is requested), jnp else."""
    backend = ("kernel" if variant in ("queue_lock", "async")
               and not record_history and _kernel_ok() else "jnp")
    return Schedule(variant=variant, backend=backend, block_n=block_n,
                    sync_every=sync_every, source="fixed")


def _block_choices(n: int, kernel: bool) -> List[Optional[int]]:
    """Candidate block sizes: the heuristic default plus the divisors of
    ``n`` nearest the roofline-relevant range (a handful, not all)."""
    from repro.core.blocking import LANE, pick_block_n

    lane = LANE if kernel else 1
    default = pick_block_n(n, lane=lane)
    divs = [b for b in range(1, n + 1) if n % b == 0]
    good = [b for b in divs if 32 <= b <= 1024 and (b % lane == 0)]
    picks = {None, default}
    for target in (128, 256, 512):
        cands = [b for b in good if b <= target]
        if cands:
            picks.add(max(cands))
    if n <= 1024:
        picks.add(n)
    return sorted(picks, key=lambda b: (b is None, b))


def candidate_schedules(d: int, n: int, iters: int, *,
                        kernel_ok: Optional[bool] = None,
                        variants: Optional[Sequence[str]] = None,
                        max_candidates: int = 24) -> List[Schedule]:
    """Enumerate the schedule search space for one shape.

    Synchronous variants contribute one candidate each (their block/sync
    knobs don't exist or don't matter); ``async`` fans out over block
    sizes x sync intervals. Kernel backends join only when ``kernel_ok``
    (a real TPU)."""
    if kernel_ok is None:
        kernel_ok = _kernel_ok()
    variants = tuple(variants or ("reduction", "queue", "queue_lock",
                                  "async"))
    out: List[Schedule] = []
    for v in variants:
        if v != "async":
            out.append(Schedule(v, "jnp"))
            if kernel_ok and v == "queue_lock":
                for bn in _block_choices(n, kernel=True):
                    out.append(Schedule(v, "kernel", block_n=bn))
            continue
        syncs = [k for k in SYNC_EVERY_CHOICES if k <= max(1, iters)] or [1]
        for bn in _block_choices(n, kernel=False):
            for k in syncs:
                out.append(Schedule("async", "jnp", block_n=bn,
                                    sync_every=k))
        if kernel_ok:
            for bn in _block_choices(n, kernel=True):
                for k in syncs:
                    out.append(Schedule("async", "kernel", block_n=bn,
                                        sync_every=k))
    # Thin the async fan-out evenly if over budget (keep first/last knobs).
    if len(out) > max_candidates:
        sync_like = [s for s in out if s.variant != "async"]
        asyncs = [s for s in out if s.variant == "async"]
        keep = max(1, max_candidates - len(sync_like))
        step = max(1, len(asyncs) // keep)
        out = sync_like + asyncs[::step][:keep]
    return out


def _bench_baseline_path() -> Optional[str]:
    p = os.environ.get("REPRO_BENCH_BASELINE")
    if p:
        return p
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    cand = os.path.join(repo, "benchmarks", "BENCH_pso.json")
    return cand if os.path.exists(cand) else None


def rank_schedules(cands: Sequence[Schedule], problem, d: int, n: int,
                   iters: int, dtype: str = "float32", batch: int = 1,
                   hetero_table: int = 0, rule: str = "pso",
                   calib=None) -> List[Schedule]:
    """Model-rank candidates (ascending predicted us/iter). Candidates the
    model cannot price (e.g. a block size the kernel would reject) are
    dropped."""
    from repro.core.blocking import LANE
    from repro.roofline import pso_cost

    if calib is None:
        calib = pso_cost.fit_calibration(_bench_baseline_path())
    ranked = []
    for s in cands:
        if s.block_n is not None and (n % s.block_n
                                      or (s.backend == "kernel"
                                          and s.block_n % LANE
                                          and s.block_n != n)):
            continue
        us = pso_cost.estimate_us_per_iter(
            s.variant, problem, d, n, dtype=dtype, backend=s.backend,
            block_n=s.block_n, sync_every=s.sync_every, batch=batch,
            hetero_table=hetero_table, rule=rule, calib=calib)
        ranked.append(s.replace(source="model", predicted_us=us))
    ranked.sort(key=lambda s: s.predicted_us)
    return ranked


def measure_schedule(sched: Schedule, problem, d: int, n: int,
                     dtype: str = "float32", seed: int = 0,
                     iters: int = MEASURE_ITERS,
                     repeats: int = MEASURE_REPEATS,
                     rule: str = "pso") -> float:
    """Time a micro-run of ``sched`` (us per iteration, best of
    ``repeats`` after a compile warmup). Goes straight at the engine
    entry points — never back through the facade, so measurement cannot
    recurse into resolution."""
    from repro.core.pso import PSOConfig, init_swarm, run
    from repro.core.problem import resolve_problem

    prob = resolve_problem(problem)
    cfg = PSOConfig(dim=d, particle_cnt=n, fitness=prob,
                    dtype=dtype, update_rule=rule).resolved()
    state = init_swarm(cfg, seed)

    if sched.backend == "kernel":
        from repro.kernels.ops import (run_queue_lock_fused,
                                       run_queue_lock_fused_async)
        interpret = not _kernel_ok()
        if sched.variant == "async":
            def go():
                return run_queue_lock_fused_async(
                    cfg, state, iters, sync_every=sched.sync_every,
                    block_n=sched.block_n, interpret=interpret)
        else:
            def go():
                return run_queue_lock_fused(cfg, state, iters,
                                            block_n=sched.block_n,
                                            interpret=interpret)
    else:
        n_blocks = (n // sched.block_n
                    if sched.variant == "async" and sched.block_n else None)

        def go():
            return run(cfg, state, iters, sched.variant,
                       sync_every=sched.sync_every, n_blocks=n_blocks)

    go().gbest_fit.block_until_ready()          # compile + warm caches
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        go().gbest_fit.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def resolve_schedule(problem, d: int, n: int, iters: int, *,
                     dtype: str = "float32", batch: int = 1,
                     hetero_table: int = 0, record_history: bool = False,
                     measure: bool = True, top_k: int = TOP_K,
                     cache: Optional[AutotuneCache] = None,
                     kernel_ok: Optional[bool] = None,
                     variants: Optional[Sequence[str]] = None,
                     rule: str = "pso") -> Schedule:
    """The ``schedule="auto"`` entry point: cache -> model -> measured.

    ``measure=False`` (the serving layer) stops after the model ranking —
    no micro-runs, bounded latency — but still reads the cache, so a
    previously measured optimum wins. ``record_history`` restricts to the
    jnp engines (history is a jnp-engine feature). The fixed-default
    schedule is ALWAYS among the measured candidates, and a challenger
    must beat it by ``MEASURE_NOISE_MARGIN`` to displace it: the tuned
    pick is never worse than the fixed rule, and within-noise ties keep
    the default."""
    cache = cache or default_cache()
    if kernel_ok is None:
        kernel_ok = _kernel_ok() and not record_history
    scope = "kernel" if kernel_ok else "jnp"
    key = shape_key(problem, d, n, iters, dtype, batch, hetero_table,
                    rule=rule)
    hit = cache.get(scope, key)
    if hit is not None:
        return hit
    cands = candidate_schedules(d, n, iters, kernel_ok=kernel_ok,
                                variants=variants)
    ranked = rank_schedules(cands, problem, d, n, iters, dtype=dtype,
                            batch=batch, hetero_table=hetero_table,
                            rule=rule)
    if not ranked:
        return fixed_schedule(record_history=record_history)
    if not measure:
        return ranked[0]
    fixed = fixed_schedule(record_history=record_history)
    if not kernel_ok and fixed.backend == "kernel":
        fixed = fixed.replace(backend="jnp")
    def is_fixed(s: Schedule) -> bool:
        return (s.variant == fixed.variant and s.backend == fixed.backend
                and s.block_n == fixed.block_n
                and (s.variant != "async"
                     or s.sync_every == fixed.sync_every))

    to_measure = list(ranked[:max(1, top_k)])
    if not any(is_fixed(s) for s in to_measure):
        to_measure.append(fixed.replace(source="model"))
    timed = []
    for s in to_measure:
        try:
            timed.append(s.replace(source="measured",
                                   measured_us=measure_schedule(
                                       s, problem, d, n, dtype,
                                       rule=rule)))
        except Exception:
            continue    # an unmeasurable candidate just drops out
    if not timed:
        return ranked[0]
    best = min(timed, key=lambda s: s.measured_us)
    # Hysteresis: keep the fixed default unless the winner clearly beats
    # it — a within-noise "win" would not survive re-measurement.
    anchor = next((s for s in timed if is_fixed(s)), None)
    if (anchor is not None and not is_fixed(best)
            and best.measured_us
            > (1.0 - MEASURE_NOISE_MARGIN) * anchor.measured_us):
        best = anchor
    cache.put(scope, key, best)
    return best


# --------------------------------------------------------------------------
# Serving-layer entry points (model-only: bounded latency).
# --------------------------------------------------------------------------

def tuned_sync_every(problem, d: int, n: int, iters: int,
                     dtype: str = "float32", batch: int = 1,
                     cache: Optional[AutotuneCache] = None) -> int:
    """Best publication interval for an async solve at this shape (model
    ranking restricted to ``variant="async"``, cache-backed)."""
    s = resolve_schedule(problem, d, n, iters, dtype=dtype, batch=batch,
                         measure=False, cache=cache, variants=("async",))
    return s.sync_every


def seed_priors(cache: Optional[AutotuneCache] = None,
                problems: Optional[Sequence] = None,
                dims: Sequence[int] = (1, 8),
                particles: Sequence[int] = (256, 1024),
                iters: int = 1024, dtype: str = "float32") -> int:
    """Pre-populate the cache with model-ranked schedules for the
    registry x a small shape grid (per-problem autotune priors).

    A fresh replica resolving ``schedule="auto"`` for an unseen shape
    pays timed micro-runs; a CI-built priors file (uploaded as an
    artifact and installed via ``REPRO_AUTOTUNE_CACHE``) means the first
    solve of every common shape starts from the cost model's best pick
    instead — bounded latency, no measurement. Already-cached keys
    (including genuinely measured optima) are never overwritten. Returns
    the number of entries seeded.
    """
    from repro.core.fitness import BUILTIN_PROBLEMS

    cache = cache or default_cache()
    if problems is None:
        problems = [p.name for p in BUILTIN_PROBLEMS]
    scope = "kernel" if _kernel_ok() else "jnp"
    seeded = 0
    for prob in problems:
        for d in dims:
            for n in particles:
                key = shape_key(prob, d, n, iters, dtype)
                if cache.get(scope, key) is not None:
                    continue
                cands = candidate_schedules(d, n, iters,
                                            kernel_ok=_kernel_ok())
                ranked = rank_schedules(cands, prob, d, n, iters,
                                        dtype=dtype)
                if ranked:
                    cache.put(scope, key, ranked[0])
                    seeded += 1
    return seeded


def bucket_ladder(problem, d: int, n: int, iters: int, *,
                  max_batch: int = 128, variant: str = "queue",
                  dtype: str = "float32", min_bucket: int = 4,
                  gain_threshold: float = 0.05) -> Tuple[int, ...]:
    """Batch-size buckets for the serving layer, from the cost model.

    Doubling the bucket always doubles the work; it pays when the
    per-ROW predicted cost drops by at least ``gain_threshold`` (fixed
    overheads amortizing). Buckets past the point of diminishing returns
    are dropped, shrinking the jit-cache footprint without losing fill."""
    from repro.roofline import pso_cost

    calib = pso_cost.fit_calibration(_bench_baseline_path())
    ladder = [min_bucket]
    backend = "jnp"     # serving ladders are priced for the jnp engine
    prev_row = pso_cost.estimate_us_per_iter(
        variant, problem, d, n, dtype=dtype, backend=backend,
        batch=min_bucket, calib=calib) / min_bucket
    b = min_bucket * 2
    while b <= max_batch:
        row = pso_cost.estimate_us_per_iter(
            variant, problem, d, n, dtype=dtype, backend=backend,
            batch=b, calib=calib) / b
        ladder.append(b)
        if row >= prev_row * (1.0 - gain_threshold):
            break   # per-row cost flattened: larger buckets don't pay
        prev_row = row
        b *= 2
    return tuple(ladder)


def _main(argv=None) -> int:
    """CLI: ``python -m repro.core.autotune --seed-priors`` (the CI step
    that builds the priors artifact)."""
    import argparse

    ap = argparse.ArgumentParser(
        description="Autotune cache utilities (schedule priors)")
    ap.add_argument("--seed-priors", action="store_true",
                    help="seed model-ranked schedules for the registry "
                         "x shape grid")
    ap.add_argument("--cache", default=None,
                    help="cache file (default: REPRO_AUTOTUNE_CACHE or "
                         "~/.cache/repro/autotune.json)")
    ap.add_argument("--dims", default="1,8")
    ap.add_argument("--particles", default="256,1024")
    ap.add_argument("--iters", type=int, default=1024)
    args = ap.parse_args(argv)
    cache = AutotuneCache(args.cache) if args.cache else default_cache()
    if args.seed_priors:
        n = seed_priors(
            cache=cache,
            dims=tuple(int(x) for x in args.dims.split(",")),
            particles=tuple(int(x) for x in args.particles.split(",")),
            iters=args.iters)
        print(f"seeded {n} schedule prior(s) into {cache.path}")
        return 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(_main())
