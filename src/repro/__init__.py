"""repro — cuPSO (arXiv 2205.01313) grown into a jax_pallas serving system.

Top-level surface (lazily imported so ``import repro`` stays cheap):

    repro.solve(problem, ...) -> Result       # the unified facade
    repro.solve_many(problem, seeds, ...)     # batched facade
    repro.solve_many(problems=[...], seeds=...)  # heterogeneous batch
                                              # (one problem per row)
    repro.Method / repro.Result               # method spec / result
    repro.Problem / repro.register_problem    # first-class objectives
    repro.get_problem / repro.list_problems
    repro.PSOConfig
    repro.solve_stream(requests, ...)         # continuous-batching serving
    repro.ContinuousScheduler / repro.CompileCache / repro.ServingMetrics
    repro.SolveServer / repro.SolveRequest    # flush-batching front end

See ``repro.api`` and ``repro.core.problem`` for the full documentation,
``examples/quickstart.py`` and ``examples/custom_objective.py`` for usage.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "solve": "repro.api",
    "solve_many": "repro.api",
    "solve_stream": "repro.api",
    "best": "repro.api",
    "ContinuousScheduler": "repro.serving",
    "CompileCache": "repro.serving",
    "ServingMetrics": "repro.serving",
    "SolveServer": "repro.launch.serve",
    "SolveRequest": "repro.launch.serve",
    "Method": "repro.api",
    "Result": "repro.api",
    "History": "repro.api",
    "Problem": "repro.core.problem",
    "register_problem": "repro.core.problem",
    "get_problem": "repro.core.problem",
    "list_problems": "repro.core.problem",
    "resolve_problem": "repro.core.problem",
    "Constraint": "repro.core.constraints",
    "ConstraintSet": "repro.core.constraints",
    "constrain_problem": "repro.core.constraints",
    "project_simplex": "repro.core.constraints",
    "PSOConfig": "repro.core.pso",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
