"""Sequential SPSO — a faithful numpy implementation of the paper's
Algorithm 1, used as the CPU-serial baseline in benchmarks (paper Tables 3–5)
and as the semantic oracle in tests.

Faithfulness notes:
  * The particle loop is sequential and gbest updates *inside* the loop
    (Alg. 1 line 17-19), so particle i+1 can see a gbest improved by particle
    i within the same iteration. The parallel variants are synchronous and
    use the previous iteration's gbest — the same semantic split exists
    between the paper's CPU and GPU versions.
  * Uses the identical counter-based RNG as the parallel versions so that
    single-particle trajectories are comparable in tests.
  * ``step_vectorized_serial_semantics`` exists only for tests: it reproduces
    the *synchronous* semantics in numpy for bit-exact comparison against the
    jnp variants.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .pso import (PSOConfig, STREAM_INIT_POS, STREAM_INIT_VEL, STREAM_R1,
                  STREAM_R2)

_U32 = np.uint32


def _mix(x: np.ndarray) -> np.ndarray:
    x = x ^ (x >> _U32(16))
    x = (x * _U32(0x85EBCA6B)).astype(_U32)
    x = x ^ (x >> _U32(13))
    x = (x * _U32(0xC2B2AE35)).astype(_U32)
    x = x ^ (x >> _U32(16))
    return x


def _hash_u32(seed, iteration, stream, index):
    with np.errstate(over="ignore"):
        seed = _U32(seed)
        iteration = _U32(iteration)
        stream = _U32(stream)
        index = np.asarray(index, dtype=_U32)
        h = (seed * _U32(0x9E3779B9) + iteration * _U32(0x85EBCA6B)
             + stream * _U32(0xC2B2AE35) + index * _U32(0x27D4EB2F)).astype(_U32)
        h = _mix(h)
        h = _mix(h ^ (index * _U32(0x9E3779B9) + iteration * _U32(0xC2B2AE35)).astype(_U32))
    return h


def _uniform(seed, iteration, stream, index, dtype=np.float32):
    bits = _hash_u32(seed, iteration, stream, index)
    dtype = np.dtype(dtype)
    return (bits >> _U32(8)).astype(dtype) * dtype.type(1.0 / (1 << 24))


def _np_bound(v, dt):
    """Bound -> numpy operand: scalars stay Python floats (bit-identical
    seed arithmetic); per-dimension tuples become [D] arrays."""
    return v if isinstance(v, (int, float)) else np.asarray(v, dt)


def _fitness(cfg: PSOConfig, pos: np.ndarray) -> np.ndarray:
    """Pure-numpy fitness (mirrors repro.core.fitness; numpy to keep the
    serial baseline free of JAX dispatch overhead). A first-class Problem
    (user objective) — or a registered name outside the six numpy-mirrored
    built-ins, e.g. a constrained problem — falls back to evaluating its
    canonical-max jnp ``max_fn`` (penalty included) — correctness over
    speed; the serial path is a baseline, not a hot path."""
    x = pos
    name = cfg.fitness
    if not isinstance(name, str):
        return np.asarray(name.max_fn(pos))
    if name == "cubic":
        return np.sum(x * x * x - 0.8 * (x * x) - 1000.0 * x + 8000.0, axis=-1)
    if name == "sphere":
        return -np.sum(x * x, axis=-1)
    if name == "rosenbrock":
        if x.shape[-1] == 1:
            return -np.squeeze((1.0 - x) ** 2, axis=-1)
        a, b = x[..., :-1], x[..., 1:]
        return -np.sum(100.0 * (b - a * a) ** 2 + (1.0 - a) ** 2, axis=-1)
    if name == "griewank":
        d = x.shape[-1]
        idx = np.arange(1, d + 1, dtype=x.dtype)
        return -(np.sum(x * x, axis=-1) / 4000.0
                 - np.prod(np.cos(x / np.sqrt(idx)), axis=-1) + 1.0)
    if name == "rastrigin":
        d = x.shape[-1]
        return -(10.0 * d + np.sum(x * x - 10.0 * np.cos(2 * np.pi * x), axis=-1))
    if name == "ackley":
        d = x.shape[-1]
        s1 = np.sqrt(np.sum(x * x, axis=-1) / d)
        s2 = np.sum(np.cos(2 * np.pi * x), axis=-1) / d
        return -(-20.0 * np.exp(-0.2 * s1) - np.exp(s2) + 20.0 + np.e)
    # any other registered name (constrained/custom) resolves through the
    # registry to its canonical-max jnp form; unknown names KeyError there
    return np.asarray(cfg.problem.max_fn(pos))


def _projection(cfg: PSOConfig):
    """The problem's feasibility projection as a numpy-in/numpy-out
    callable, or None (mode != "projection")."""
    proj = cfg.problem.projection_fn
    if proj is None:
        return None
    return lambda pos: np.asarray(proj(pos), dtype=pos.dtype)


def _constrained_init(cfg: PSOConfig, pos: np.ndarray, seed: int,
                      lo, span, idx, dt) -> np.ndarray:
    """Mirror of ``init_swarm``'s constrained init: project (projection
    mode) or resample infeasible draws (repair mode) — using the numpy
    RNG mirror, so serial init stays bit-comparable to the jnp path."""
    prob = cfg.problem
    proj = _projection(cfg)
    if proj is not None:
        return proj(pos)
    if not (prob.constrained and prob.constraints.mode == "repair"):
        return pos
    # one point of truth: the jnp repair fold (its counter RNG is the
    # bit-identical mirror of _uniform, so serial init == jnp init exactly;
    # same correctness-over-speed tradeoff as _fitness's jnp fallback)
    from .constraints import repair_init_positions
    return np.asarray(
        repair_init_positions(prob.constraints, prob.violation_fn, pos,
                              lo, span, seed, STREAM_INIT_POS, idx, dt),
        dtype=pos.dtype)


class SerialSwarm:
    """Alg. 1 state + sequential iteration."""

    def __init__(self, cfg: PSOConfig, seed: int = 0):
        cfg = cfg.resolved()
        self.cfg = cfg
        self.seed = seed
        n, d = cfg.particle_cnt, cfg.dim
        dt = np.dtype(cfg.dtype)
        idx = np.arange(n * d, dtype=_U32).reshape(n, d)
        lo, hi = _np_bound(cfg.min_pos, dt), _np_bound(cfg.max_pos, dt)
        mv = _np_bound(cfg.max_v, dt)
        span = hi - lo
        self.pos = (lo + span * _uniform(seed, 0, STREAM_INIT_POS, idx, dt))
        self.pos = _constrained_init(cfg, self.pos, seed, lo, span, idx, dt)
        self._project = _projection(cfg)
        self.vel = (-mv + 2 * mv * _uniform(seed, 0, STREAM_INIT_VEL, idx, dt))
        self.fit = _fitness(cfg, self.pos)
        self.pbest_pos = self.pos.copy()
        self.pbest_fit = self.fit.copy()
        b = int(np.argmax(self.fit))
        self.gbest_pos = self.pos[b].copy()
        self.gbest_fit = float(self.fit[b])
        self.iteration = 0

    def step(self) -> None:
        """One sequential iteration: the inner loop of Alg. 1 lines 8-20."""
        cfg = self.cfg
        n, d = self.pos.shape
        it = self.iteration + 1
        idx = np.arange(n * d, dtype=_U32).reshape(n, d)
        r1 = _uniform(self.seed, it, STREAM_R1, idx, self.pos.dtype)
        r2 = _uniform(self.seed, it, STREAM_R2, idx, self.pos.dtype)
        for i in range(n):  # sequential: later particles see updated gbest
            v = (cfg.w * self.vel[i]
                 + cfg.c1 * r1[i] * (self.pbest_pos[i] - self.pos[i])
                 + cfg.c2 * r2[i] * (self.gbest_pos - self.pos[i]))
            mv = _np_bound(cfg.max_v, v.dtype)
            v = np.clip(v, -mv, mv)
            p = np.clip(self.pos[i] + v, _np_bound(cfg.min_pos, v.dtype),
                        _np_bound(cfg.max_pos, v.dtype))
            if self._project is not None:   # post-advance feasibility hook
                p = self._project(p[None])[0]
            f = float(_fitness(cfg, p[None])[0])
            self.vel[i] = v
            self.pos[i] = p
            self.fit[i] = f
            if f > self.pbest_fit[i]:                 # Alg. 1 step 4
                self.pbest_fit[i] = f
                self.pbest_pos[i] = p
                if f > self.gbest_fit:                # Alg. 1 step 5
                    self.gbest_fit = f
                    self.gbest_pos = p.copy()
        self.iteration = it

    def run(self, iters: int) -> Tuple[float, np.ndarray]:
        for _ in range(iters):
            self.step()
        return self.gbest_fit, self.gbest_pos


def run_serial_fast(cfg: PSOConfig, seed: int, iters: int) -> Tuple[float, np.ndarray]:
    """Vectorized-numpy serial baseline for *timing* (benchmarks).

    Keeps Alg. 1's per-iteration work (no short-cuts: full pbest/gbest argmax
    every iteration, matching the paper's CPU version) but vectorizes the
    particle loop so the Python interpreter is not what we benchmark. Uses
    synchronous gbest semantics — the same work per iteration as the paper's
    serial C code, which is the quantity the speedup tables compare.
    """
    cfg = cfg.resolved()
    n, d = cfg.particle_cnt, cfg.dim
    dt = np.dtype(cfg.dtype)
    idx = np.arange(n * d, dtype=_U32).reshape(n, d)
    lo, hi = _np_bound(cfg.min_pos, dt), _np_bound(cfg.max_pos, dt)
    mv = _np_bound(cfg.max_v, dt)
    span = hi - lo
    pos = lo + span * _uniform(seed, 0, STREAM_INIT_POS, idx, dt)
    pos = _constrained_init(cfg, pos, seed, lo, span, idx, dt)
    project = _projection(cfg)
    vel = -mv + 2 * mv * _uniform(seed, 0, STREAM_INIT_VEL, idx, dt)
    fit = _fitness(cfg, pos)
    pbest_pos, pbest_fit = pos.copy(), fit.copy()
    b = int(np.argmax(fit))
    gbest_pos, gbest_fit = pos[b].copy(), float(fit[b])
    for it in range(1, iters + 1):
        r1 = _uniform(seed, it, STREAM_R1, idx, dt)
        r2 = _uniform(seed, it, STREAM_R2, idx, dt)
        vel = (cfg.w * vel + cfg.c1 * r1 * (pbest_pos - pos)
               + cfg.c2 * r2 * (gbest_pos[None] - pos))
        np.clip(vel, -mv, mv, out=vel)
        pos = np.clip(pos + vel, lo, hi)
        if project is not None:             # post-advance feasibility hook
            pos = project(pos)
        fit = _fitness(cfg, pos)
        m = fit > pbest_fit
        pbest_fit = np.where(m, fit, pbest_fit)
        pbest_pos = np.where(m[:, None], pos, pbest_pos)
        b = int(np.argmax(pbest_fit))
        if pbest_fit[b] > gbest_fit:
            gbest_fit = float(pbest_fit[b])
            gbest_pos = pbest_pos[b].copy()
    return gbest_fit, gbest_pos
