"""Persistent AOT compile cache for serving programs (``jax.export``).

The cold-start problem: a restarted serving replica owns an empty jit
cache, so its first request at every (compile key, bucket) pays a full
Python trace + XLA compile — exactly the tail-latency spike a
time-critical tier cannot afford. This module closes it in two layers:

1. **Python/trace layer** — each serving program (a lane's chunk-advance,
   a flush bucket's ``solve_many``) is traced ONCE via
   ``jax.export.export(jax.jit(fn))(*specs)``, serialized, and written to
   ``<dir>/<sha1(key)>.jaxexport``. A later process (or a restarted
   replica) deserializes the blob and calls ``jax.jit(exported.call)``
   instead of re-tracing the original Python — the original function body
   never runs again. Exported programs replay the captured StableHLO
   bit-for-bit, so the cached program's outputs are bitwise identical to
   the freshly traced one (verified in tests/test_serving.py).
2. **XLA layer** — ``enable_xla_cache()`` points JAX's persistent
   compilation cache at ``<dir>/xla`` so even the backend compile of the
   replayed module is a disk hit on restart.

Keys are the serving layer's hetero-aware compile keys (strings built
from ``SolveRequest.group_key``-style tuples) plus the program shape; the
manifest records the jax version and backend and the whole cache is
ignored on mismatch (serialized modules are not portable across them).

Observability: the cache counts ``aot_hits`` / ``aot_misses`` and —
the honest "zero recompiles" signal — ``trace_events``: the build
function is wrapped so its body increments the counter, and a body only
executes while JAX is tracing it. A warm replica serving its first
request reports ``trace_events == 0``.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Callable, Dict, Optional

CACHE_ENV = "REPRO_COMPILE_CACHE"
_MANIFEST = "manifest.json"


def _fingerprint() -> Dict[str, str]:
    import jax
    return {"jax": jax.__version__, "backend": jax.default_backend()}


_SERIALIZATION_REGISTERED = False


def _ensure_serialization_registry() -> None:
    """Register the engine NamedTuples with ``jax.export`` so exported
    programs whose signatures carry them can serialize. Stable names keep
    blobs readable across processes; idempotent."""
    global _SERIALIZATION_REGISTERED
    if _SERIALIZATION_REGISTERED:
        return
    from jax import export

    from repro.core.multi_swarm import ProblemRows, SwarmBatch
    from repro.core.pso import HeteroRow, SwarmState
    for cls, name in ((SwarmBatch, "repro.core.multi_swarm.SwarmBatch"),
                      (ProblemRows, "repro.core.multi_swarm.ProblemRows"),
                      (SwarmState, "repro.core.pso.SwarmState"),
                      (HeteroRow, "repro.core.pso.HeteroRow")):
        try:
            export.register_namedtuple_serialization(
                cls, serialized_name=name)
        except ValueError:
            pass    # already registered (re-import, repeated init)
    _SERIALIZATION_REGISTERED = True


class CompileCache:
    """Disk-backed store of exported (AOT-traced) serving programs.

    ``path=None`` reads ``REPRO_COMPILE_CACHE``; if that is unset too the
    cache is memory-only (still deduplicates traces within one process,
    nothing persists). ``metrics`` is an optional
    ``repro.serving.metrics.ServingMetrics`` sink for the hit/miss/trace
    counters (kept locally as well, so the cache is usable standalone).
    """

    def __init__(self, path: Optional[str] = None, metrics=None):
        self.path = path if path is not None else os.environ.get(CACHE_ENV)
        self.metrics = metrics
        self._mem: Dict[str, Callable] = {}
        self.aot_hits = 0
        self.aot_misses = 0
        self.trace_events = 0
        self._manifest: Optional[dict] = None

    # -- bookkeeping -------------------------------------------------------
    def _count(self, name: str, k: int = 1) -> None:
        setattr(self, name, getattr(self, name) + k)
        if self.metrics is not None:
            self.metrics.inc(name, k)

    def _counted(self, fn: Callable) -> Callable:
        def traced_body(*args):
            # Runs only while JAX traces it — the recompile detector.
            self._count("trace_events")
            return fn(*args)
        return traced_body

    @staticmethod
    def _file_key(key: str) -> str:
        return hashlib.sha1(key.encode()).hexdigest()

    # -- manifest ----------------------------------------------------------
    def _load_manifest(self) -> dict:
        if self._manifest is not None:
            return self._manifest
        fp = _fingerprint()
        doc = {"fingerprint": fp, "entries": {}}
        if self.path:
            try:
                with open(os.path.join(self.path, _MANIFEST)) as f:
                    on_disk = json.load(f)
                if on_disk.get("fingerprint") == fp:
                    doc = on_disk
            except (OSError, ValueError):
                pass
        self._manifest = doc
        return doc

    def _save_manifest(self) -> None:
        if not self.path or self._manifest is None:
            return
        try:
            os.makedirs(self.path, exist_ok=True)
            tmp = os.path.join(self.path, f".{_MANIFEST}.{os.getpid()}")
            with open(tmp, "w") as f:
                json.dump(self._manifest, f, indent=1, sort_keys=True)
            os.replace(tmp, os.path.join(self.path, _MANIFEST))
        except OSError:
            pass    # the cache is an optimization; never fail a solve

    # -- the cache ---------------------------------------------------------
    def get(self, key: str, build: Callable, *specs) -> Callable:
        """The compiled program for ``key``, building at most once ever.

        ``build`` is the pure function to trace and ``specs`` are its
        example arguments (arrays or ``jax.ShapeDtypeStruct`` pytrees).
        Resolution order: in-process memo -> disk blob (deserialize, no
        re-trace) -> fresh ``jax.export`` (trace once, persist).
        """
        import jax
        from jax import export

        _ensure_serialization_registry()
        hit = self._mem.get(key)
        if hit is not None:
            self._count("aot_hits")
            return hit
        blob = self._load_blob(key)
        if blob is not None:
            try:
                call = jax.jit(export.deserialize(blob).call)
                self._mem[key] = call
                self._count("aot_hits")
                return call
            except Exception:
                pass    # corrupt/stale blob: fall through and rebuild
        self._count("aot_misses")
        exported = export.export(jax.jit(self._counted(build)))(*specs)
        self._store_blob(key, exported.serialize())
        call = jax.jit(exported.call)
        self._mem[key] = call
        return call

    def _load_blob(self, key: str) -> Optional[bytes]:
        if not self.path:
            return None
        man = self._load_manifest()
        entry = man["entries"].get(self._file_key(key))
        if entry is None:
            return None
        try:
            with open(os.path.join(self.path, entry["file"]), "rb") as f:
                return f.read()
        except OSError:
            return None

    def _store_blob(self, key: str, blob: bytes) -> None:
        if not self.path:
            return
        h = self._file_key(key)
        fname = f"{h}.jaxexport"
        try:
            os.makedirs(self.path, exist_ok=True)
            tmp = os.path.join(self.path, f".{fname}.{os.getpid()}")
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, os.path.join(self.path, fname))
        except OSError:
            return
        man = self._load_manifest()
        man["entries"][h] = {"key": key, "file": fname, "bytes": len(blob)}
        self._save_manifest()

    def prewarm(self) -> int:
        """Deserialize every on-disk blob into the in-process memo (replica
        startup). Returns how many programs are now servable without a
        trace; backend compiles of the replayed modules additionally hit
        the XLA persistent cache when ``enable_xla_cache`` ran."""
        import jax
        from jax import export

        if not self.path:
            return 0
        _ensure_serialization_registry()
        man = self._load_manifest()
        for h, entry in list(man["entries"].items()):
            key = entry["key"]
            if key in self._mem:
                continue
            try:
                with open(os.path.join(self.path, entry["file"]), "rb") as f:
                    blob = f.read()
                self._mem[key] = jax.jit(export.deserialize(blob).call)
            except Exception:
                continue
        return len(self._mem)

    def enable_xla_cache(self) -> bool:
        """Point JAX's persistent compilation cache at ``<dir>/xla`` so the
        backend compile of replayed modules is a disk hit too. Safe to call
        repeatedly; returns False when the cache is memory-only or the
        config knobs are unavailable."""
        if not self.path:
            return False
        import jax
        try:
            os.makedirs(os.path.join(self.path, "xla"), exist_ok=True)
            jax.config.update("jax_compilation_cache_dir",
                              os.path.join(self.path, "xla"))
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.0)
            jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                              -1)
            return True
        except Exception:
            return False

    def snapshot(self) -> dict:
        return {"path": self.path, "programs": len(self._mem),
                "aot_hits": self.aot_hits, "aot_misses": self.aot_misses,
                "trace_events": self.trace_events}
