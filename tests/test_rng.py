"""Counter-RNG quality + determinism (the cuRAND substitute, DESIGN.md §2)."""
import jax.numpy as jnp
import numpy as np

from repro.core import rng


def test_deterministic_and_jnp_numpy_agree():
    from repro.core import serial
    idx = np.arange(4096, dtype=np.uint32)
    a = np.asarray(rng.uniform(123, 7, 2, jnp.asarray(idx)))
    b = serial._uniform(123, 7, 2, idx)
    np.testing.assert_array_equal(a, b)


def test_uniformity():
    u = np.asarray(rng.uniform(0, 1, 0, jnp.arange(1 << 16, dtype=jnp.uint32)))
    assert 0.0 <= u.min() and u.max() < 1.0
    # mean/var of U(0,1)
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1.0 / 12.0) < 5e-3
    # chi-square over 64 bins, very loose gate
    hist, _ = np.histogram(u, bins=64, range=(0, 1))
    expected = len(u) / 64
    chi2 = ((hist - expected) ** 2 / expected).sum()
    assert chi2 < 2 * 64


def test_streams_and_iterations_decorrelated():
    idx = jnp.arange(1 << 14, dtype=jnp.uint32)
    a = np.asarray(rng.uniform(0, 1, 0, idx))
    b = np.asarray(rng.uniform(0, 1, 1, idx))   # different stream
    c = np.asarray(rng.uniform(0, 2, 0, idx))   # different iteration
    assert abs(np.corrcoef(a, b)[0, 1]) < 0.02
    assert abs(np.corrcoef(a, c)[0, 1]) < 0.02
    assert not np.array_equal(a, b)


def test_no_collisions_across_particles():
    """Adjacent counter values must not produce identical draws."""
    u = np.asarray(rng.uniform(9, 3, 0, jnp.arange(100000, dtype=jnp.uint32)))
    assert np.unique(u).size > 0.99 * u.size
