"""Quickstart: solve the paper's two benchmark problems (1D and 120D cubic)
with all four aggregation variants + the fused Pallas kernels, and verify
they agree — the paper's §4.1 claim that queueing is an optimization, not
an approximation, extended to the enhanced (asynchronous) queue-lock whose
relaxed consistency is likewise answer-preserving.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import PSOConfig, init_swarm, run, solve
from repro.kernels.ops import run_queue_lock_fused, run_queue_lock_fused_async


def solve_and_report(dim: int, particles: int, iters: int):
    print(f"\n=== cubic, dim={dim}, particles={particles}, iters={iters} ===")
    print(f"{'variant':28s} {'gbest_fit':>14s} {'wall_s':>8s}")
    cfg = PSOConfig(dim=dim, particle_cnt=particles, fitness="cubic")
    for variant in ("reduction", "queue", "queue_lock", "async"):
        t0 = time.time()
        s = solve(cfg, seed=0, iters=iters, variant=variant)
        jax.block_until_ready(s.gbest_fit)
        print(f"{variant:28s} {float(s.gbest_fit):14.4f} "
              f"{time.time() - t0:8.3f}")
    # fused Pallas kernels (TPU target; interpret mode here)
    s0 = init_swarm(cfg.resolved(), 0)
    k_iters = min(iters, 100)             # interpret mode = python loop
    for name, fn in (
            ("queue_lock pallas (interp)",
             lambda: run_queue_lock_fused(cfg.resolved(), s0,
                                          iters=k_iters)),
            ("async pallas (interp)",
             lambda: run_queue_lock_fused_async(cfg.resolved(), s0,
                                                iters=k_iters,
                                                sync_every=10))):
        t0 = time.time()
        s = fn()
        jax.block_until_ready(s.gbest_fit)
        print(f"{name:28s} {float(s.gbest_fit):14.4f} "
              f"{time.time() - t0:8.3f}  ({k_iters} iters)")
    ideal = dim * 900000.0
    print(f"{'analytic optimum f(100)*d':28s} {ideal:14.4f}")


def main():
    solve_and_report(dim=1, particles=1024, iters=1000)
    solve_and_report(dim=120, particles=2048, iters=500)


if __name__ == "__main__":
    main()
