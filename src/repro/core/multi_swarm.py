"""Batched multi-swarm engine: many independent PSO solves in ONE device
program (DESIGN: the scaling layer on top of the paper's single-swarm
queue/queue-lock algorithms).

The paper (arXiv 2205.01313) amortizes aggregation cost *within* one swarm;
serving-scale workloads (tuning sweeps, per-request optimizations) need to
amortize across *many* swarms — different seeds, and optionally different
(w, c1, c2) hyper-parameters — without paying one dispatch + compile per
swarm. This module vmaps the three step variants from ``repro.core.pso``
over a leading swarm axis, so a batch of S solves costs one compile and one
dispatch per ``run_many`` call. PSO-PS (arXiv 2009.03816) makes the same
move to keep distributed populations device-resident.

RNG stream convention
---------------------
Each swarm carries its own ``seed`` and the counter RNG is keyed by
``(seed, iteration, stream, element_index)`` with element indices local to
the swarm (particle * D + dim, exactly the single-swarm ``index_offset=0``
convention of ``init_swarm``/``_advance``). Because vmap changes neither the
counters nor the arithmetic, row ``s`` of a batch is **bit-identical** to a
standalone ``solve(cfg, seeds[s])`` — batching is a pure scheduling
transform, never a semantic one. This is asserted exactly (``==`` on
float bits) in tests/test_multi_swarm.py.

Caveat (CPU backend): XLA:CPU chooses loop-body fusion + FMA contraction
per compiled shape, and for a few tiny batch shapes the batched program
rounds the velocity chain one ulp differently from the standalone program,
which chaotic PSO dynamics then amplify. Root cause (isolated at S=4,
dim=3, n=64, sphere): ``vel`` diverges on the SECOND iteration inside one
``fori_loop`` program while separate per-iteration dispatches stay
bit-identical — i.e. the in-loop fusion, not the vmapped step, makes the
shape-dependent contraction choice; and pinning the loop carry with
``optimization_barrier`` merely moves the anomaly to other shapes (S=3).
The pin therefore lives at the dispatch level: ``run_many`` pads batches
smaller than ``MIN_VALIDATED_SWARMS`` (= 8) with dead rows and slices the
result back, so every dispatch runs a validated program shape and the
serving layer buckets at 4 again. This also constrains step-function
design: a ``lax.cond`` carrying an [N, D] branch output changes XLA's
fusion clustering enough to break the identity at *every* batch size (see
``step_queue_lock``).

Per-swarm hyper-parameters
--------------------------
``coeffs=(w, c1, c2)`` (each shape ``[S]``) rides the same vmap, which is
what lets ``repro.core.tuner.make_solve_many_fitness`` evaluate a whole
population of PSO hyper-parameter candidates as one batched solve.

The Pallas counterpart (one ``pallas_call`` advancing S swarms x iters with
per-swarm gbest buffers) is ``repro.kernels.ops.run_queue_lock_fused_batch``.

Problems: ``cfg.fitness`` may be a registered benchmark name or a
first-class ``repro.core.problem.Problem`` (user objective, per-dimension
bounds, min/max sense) — the vmapped step functions and the batched Pallas
kernels both resolve it through the same registry/adapter machinery, so a
batch of custom-objective solves is one device program too. The serving
front end (``repro.launch.serve``) relies on this plus content-hashed
compile keys to batch identical custom objectives safely.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .pso import (ASYNC_SYNC_EVERY, PSOConfig, STEP_FNS, SwarmState,
                  init_swarm, run_async)

Array = jnp.ndarray


class SwarmBatch(NamedTuple):
    """S independent swarms, stacked on a leading axis.

    Field order matches ``SwarmState`` exactly so the two convert by
    positional splat (``SwarmBatch(*state_pytree)``) and vmapped SwarmState
    functions apply directly.
    """

    pos: Array        # [S, N, D]
    vel: Array        # [S, N, D]
    fit: Array        # [S, N]
    pbest_pos: Array  # [S, N, D]
    pbest_fit: Array  # [S, N]
    gbest_pos: Array  # [S, D]
    gbest_fit: Array  # [S]
    iteration: Array  # [S] int32
    seed: Array       # [S] uint32
    lbest_pos: Optional[Array] = None  # [S, nb, D] async block-local bests
    lbest_fit: Optional[Array] = None  # [S, nb]

    @property
    def swarm_cnt(self) -> int:
        return self.gbest_fit.shape[0]


def init_batch(cfg: PSOConfig, seeds) -> SwarmBatch:
    """Initialize S swarms, one per entry of ``seeds``.

    Row ``s`` is bit-identical to ``init_swarm(cfg, seeds[s])`` (see module
    docstring: the RNG counters are untouched by the vmap).
    """
    cfg = cfg.resolved()
    seeds = jnp.asarray(seeds)
    return SwarmBatch(*jax.vmap(lambda sd: init_swarm(cfg, sd))(seeds))


def batch_row(batch: SwarmBatch, s: int) -> SwarmState:
    """Extract swarm ``s`` as a standalone SwarmState."""
    return SwarmState(*(jax.tree_util.tree_map(lambda a: a[s], tuple(batch))))


def stack_states(states: Sequence[SwarmState]) -> SwarmBatch:
    """Stack standalone swarms into a batch (inverse of ``batch_row``)."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    return SwarmBatch(*stacked)


@partial(jax.jit, static_argnames=("cfg", "iters", "sync_every"))
def _run_many_async(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                    sync_every: int,
                    coeffs: Optional[Tuple[Array, Array, Array]] = None
                    ) -> SwarmBatch:
    if coeffs is None:
        fn = jax.vmap(lambda s: run_async(
            cfg, s, iters, sync_every=sync_every))
        return SwarmBatch(*fn(SwarmState(*batch)))
    w, c1, c2 = (jnp.asarray(c) for c in coeffs)
    fn = jax.vmap(lambda s, w_, c1_, c2_: run_async(
        cfg, s, iters, sync_every=sync_every, coeffs=(w_, c1_, c2_)))
    return SwarmBatch(*fn(SwarmState(*batch), w, c1, c2))


@partial(jax.jit, static_argnames=("cfg", "iters", "variant"))
def _run_many_stepped(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                      variant: str,
                      coeffs: Optional[Tuple[Array, Array, Array]] = None
                      ) -> SwarmBatch:
    step = STEP_FNS[variant]
    if coeffs is None:
        step_b = jax.vmap(lambda s: step(cfg, s))

        def body(_, b):
            return SwarmBatch(*step_b(SwarmState(*b)))
    else:
        w, c1, c2 = (jnp.asarray(c) for c in coeffs)
        step_b = jax.vmap(
            lambda s, w_, c1_, c2_: step(cfg, s, coeffs=(w_, c1_, c2_)))

        def body(_, b):
            return SwarmBatch(*step_b(SwarmState(*b), w, c1, c2))

    return jax.lax.fori_loop(0, iters, body, batch)


# Smallest batch row count whose compiled program is covered by the
# row-bit-identity validation. XLA:CPU picks loop-body fusion (and with it
# FMA contraction of the velocity chain) per compiled batch shape; for a few
# tiny batches the choice rounds 1 ulp differently from the standalone
# program (root-caused at S=4, dim=3, n=64, sphere: `vel` diverges on the
# second in-loop iteration while separate per-iteration dispatches match).
# Rather than chase codegen across every tiny shape, sub-validated batches
# ride the smallest validated shape with dead rows (sliced off afterwards),
# which also keeps the jit cache to one program for all S < 8.
MIN_VALIDATED_SWARMS = 8


def _pad_rows(batch: SwarmBatch, target: int) -> SwarmBatch:
    """Pad a batch to ``target`` rows by replicating row 0 (dead rows)."""
    k = target - batch.swarm_cnt
    return SwarmBatch(*jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (k,) + a.shape[1:])]),
        tuple(batch)))


def run_many(cfg: PSOConfig, batch: SwarmBatch, iters: int,
             variant: str = "queue",
             coeffs: Optional[Tuple[Array, Array, Array]] = None,
             sync_every: int = ASYNC_SYNC_EVERY) -> SwarmBatch:
    """Advance every swarm of the batch ``iters`` iterations in lockstep.

    One fori_loop over one vmapped step: a single compiled program, a single
    dispatch, no host round-trips between iterations or between swarms.
    ``variant="async"`` vmaps the whole ``run_async`` loop nest instead (it
    carries block-local bests across iterations, so it cannot ride the
    per-step registry); ``run_async`` is cond-free, so the vmap is a pure
    scheduling transform and per-row bit-identity holds like the others.
    A thin dispatcher over the jitted implementations, so synchronous
    variants never key their jit cache on the (irrelevant) ``sync_every``.

    Batches smaller than ``MIN_VALIDATED_SWARMS`` are padded to it with
    dead rows and sliced back, so every dispatch runs a program shape whose
    row-bit-identity is validated (see the constant's comment — the S=4
    XLA:CPU contraction anomaly), and the serving layer can bucket at 4
    again.
    """
    cfg = cfg.resolved()
    s_cnt = batch.swarm_cnt
    if s_cnt < MIN_VALIDATED_SWARMS:
        pad = MIN_VALIDATED_SWARMS
        batch = _pad_rows(batch, pad)
        if coeffs is not None:
            coeffs = tuple(
                jnp.concatenate([jnp.asarray(c),
                                 jnp.broadcast_to(jnp.asarray(c)[:1],
                                                  (pad - s_cnt,))])
                for c in coeffs)
        out = run_many(cfg, batch, iters, variant, coeffs, sync_every)
        return SwarmBatch(*jax.tree_util.tree_map(lambda a: a[:s_cnt],
                                                  tuple(out)))
    if variant == "async":
        return _run_many_async(cfg, batch, iters, sync_every, coeffs)
    if batch.lbest_fit is not None:
        # mirror run(): sync variants advance gbest without maintaining the
        # async block-local cache — drop it so a later async run re-seeds
        batch = batch._replace(lbest_pos=None, lbest_fit=None)
    return _run_many_stepped(cfg, batch, iters, variant, coeffs)


def solve_many(cfg: PSOConfig, seeds, iters: int = 1000,
               variant: str = "queue",
               coeffs: Optional[Tuple[Array, Array, Array]] = None,
               sync_every: int = ASYNC_SYNC_EVERY) -> SwarmBatch:
    """Batched one-shot: init + run for S independent solves.

    ``seeds`` is any int sequence/array of length S; ``variant`` is one of
    ``reduction | queue | queue_lock | async``; ``coeffs`` optionally
    supplies per-swarm ``(w, c1, c2)`` arrays; ``sync_every`` is the async
    variant's publication interval. Row ``s`` of the result is
    bit-identical to ``solve(cfg, seeds[s], iters, variant)`` when
    ``coeffs`` is None.
    """
    cfg = cfg.resolved()
    return run_many(cfg, init_batch(cfg, seeds), iters, variant, coeffs,
                    sync_every)


def best_of_batch(batch: SwarmBatch) -> Tuple[Array, Array, Array]:
    """(best gbest_fit, its gbest_pos, winning swarm index) over the batch."""
    b = jnp.argmax(batch.gbest_fit)
    return batch.gbest_fit[b], batch.gbest_pos[b], b
