"""Benchmark harness — one function per paper table/figure.

  table3  — 1D problem: execution time of the five implementations
            (CPU serial, Reduction, Loop-unrolled*, Queue, Queue-Lock)
            across particle counts (paper Table 3 / Fig. 3).
  table4  — 1D speedup of Queue-Lock vs CPU serial (paper Table 4).
  table5  — 120D speedup of Queue vs CPU serial (paper Table 5).
  multi_swarm — batched engine: S independent solves via ONE solve_many
            device program vs a Python loop of solve() (swarms/sec).
  mixed_traffic — serving-layer registry coalescing: a mixed trace of
            built-in objectives at one solve shape, heterogeneous batches
            (launch/serve.py, lax.switch row dispatch) vs the legacy
            content-hash grouping — batch fill, dispatches, flush p50/p99.
  serving — continuous-batching scheduler (repro.serving) vs the flush
            server on ONE mixed trace (six built-ins x four iteration
            budgets, staggered waves): requests/s, e2e p50/p99, batch
            fill per leg (benchmarks/loadgen.py; steady-state pass).
            Hard-gated in CI (promoted from warn-only after a cycle of
            baseline-refresh history).
  async_sweep — the enhanced (asynchronous) queue-lock: per-iteration cost
            and solution quality vs the synchronous kernel across
            sync_every ∈ {1, 4, 16, 64}. Fewer chunk boundaries = fewer
            cross-block synchronization points = fewer grid steps; the
            per-iteration cost must fall monotonically as sync_every grows.
  islands_ring — distributed exchange cost: the async island ring
            (neighbor ppermute pushes, core.distributed) vs the barrier
            ``_pmax_best`` collective at the same exchange cadence.
  custom_objective — Problem-API adapter overhead: a user-written cubic
            lowered by the generic d-major adapter vs the hand-tuned
            kernel form, through the fused queue-lock kernel.
  constrained — constraint-handling cost: penalty vs projection us/iter
            on the sphere-on-simplex built-in (repro.core.constraints),
            with final gbest + violation as quality columns.
  autotune — roofline schedule autotuner: auto-picked (variant, backend,
            block_n, sync_every) vs the fixed default schedule per suite
            shape, plus the measured-optima cache-hit check. Warn-only in
            compare.py until it accumulates noise-floor history.
  portfolio — update-rule portfolio: solution quality at EQUAL WALL-CLOCK
            across the registered rules (pso / sso / lowcost) on one
            landscape — per-rule us/iter plus final gbest when each rule
            spends the default rule's time budget. Warn-only in
            compare.py until it accumulates noise-floor history.
  telemetry — in-kernel contention-counter overhead: the fused
            queue-lock kernel with counters off (A/A control; CI asserts
            the disabled ratio ≤ 1.05) vs counters on (real enabled
            ratio + counter totals). Warn-only in compare.py until it
            accumulates noise-floor history; docs/observability.md.
  lm_bench— LM substrate micro-bench (tokens/s on the smoke configs).

Cross-PR trend: ``compare.py OLD.json NEW.json`` diffs two artifacts
(per-record us/call delta; nonzero exit above --threshold). CI runs it
warning-only against the committed benchmarks/BENCH_pso.json baseline.

This container is CPU-only, so the "GPU" columns run the same JAX
algorithms on the CPU backend, jit-compiled, and the Pallas kernels run in
interpret mode (which measures *semantics*, not TPU silicon). Relative
orderings therefore reflect algorithmic work (the paper's claim), while
absolute numbers are CPU numbers — EXPERIMENTS.md §Benchmarks discusses
the mapping onto the paper's GTX-1080Ti results.

*Loop-unrolled on TPU: the CUDA unrolling trick has no TPU counterpart
(DESIGN.md §2); the reduction variant is its closest analogue and is
reported once.

Output: ``name,us_per_call,derived`` CSV rows on stdout, plus a
machine-readable ``BENCH_pso.json`` (``--out``) with the same records and
backend/interpret metadata, so the perf trajectory is tracked across PRs.
``--smoke`` shrinks every benchmark to CI-sized iteration counts and skips
the LM substrate.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

ITERS_1D = 2000           # paper uses 100k; scaled for CPU wall-time — the
REPEATS = 3               # us/iter metric is iteration-count invariant

# How this harness invokes the Pallas kernels. Recorded in the JSON meta so
# interpret-mode and TPU-compiled timings can never be silently compared.
KERNEL_INTERPRET = True

# Machine-readable result records: [{"name": ..., "us_per_call": ...,
# <derived k/v>}, ...], dumped to BENCH_pso.json by main().
RESULTS = []


def emit(name: str, us_per_call: float, **derived) -> None:
    """Print the CSV row and record it for the JSON dump."""
    tail = ",".join(f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in derived.items())
    print(f"{name},{us_per_call:.3f}" + ("," + tail if tail else ""))
    RESULTS.append({"name": name, "us_per_call": float(us_per_call),
                    **{k: (float(v) if isinstance(v, (int, float, np.floating))
                           and not isinstance(v, bool) else v)
                       for k, v in derived.items()}})


def _time(fn, repeats=REPEATS):
    fn()                                  # warmup / compile
    ts = []
    for _ in range(repeats + 2):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    if len(ts) > 2:
        ts = sorted(ts)[1:-1]             # drop min/max (paper §6.1)
    return float(np.mean(ts))


def _pso_variants(dim: int, particles: int, iters: int):
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    from repro.kernels.ops import run_queue_lock_fused
    cfg = PSOConfig(dim=dim, particle_cnt=particles,
                    fitness="cubic").resolved()
    s0 = init_swarm(cfg, 0)
    out = {}
    out["cpu_serial"] = _time(lambda: run_serial_fast(cfg, 0, iters),
                              repeats=1)
    for variant in ("reduction", "queue", "queue_lock"):
        out[variant] = _time(lambda v=variant: jax.block_until_ready(
            run(cfg, s0, iters, v).gbest_fit))
    # fused Pallas queue-lock kernel (interpret mode: semantics on CPU)
    kiters = min(iters, 50)               # interpret mode is a python loop
    t = _time(lambda: jax.block_until_ready(
        run_queue_lock_fused(cfg, s0, iters=kiters).gbest_fit), repeats=1)
    out["queue_lock_pallas_interp"] = t * (iters / kiters)
    return out


def table3(smoke=False) -> None:
    """1D problem across particle counts (paper Table 3)."""
    iters = 200 if smoke else ITERS_1D
    sweep = (64, 256) if smoke else (32, 64, 128, 256, 512, 1024, 2048)
    for particles in sweep:
        res = _pso_variants(1, particles, iters)
        base = res["cpu_serial"]
        for name, t in res.items():
            emit(f"table3/p{particles}/{name}", 1e6 * t / iters,
                 speedup_vs_serial=base / t)


def table4(smoke=False) -> None:
    """Queue-Lock speedup scaling, 1D (paper Table 4)."""
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    iters = 100 if smoke else ITERS_1D // 2
    sweep = (128, 2048) if smoke else (128, 512, 2048, 8192, 32768, 131072)
    for particles in sweep:
        cfg = PSOConfig(dim=1, particle_cnt=particles).resolved()
        s0 = init_swarm(cfg, 0)
        t_cpu = _time(lambda: run_serial_fast(cfg, 0, iters), repeats=1)
        t_ql = _time(lambda: jax.block_until_ready(
            run(cfg, s0, iters, "queue_lock").gbest_fit))
        emit(f"table4/p{particles}/queue_lock", 1e6 * t_ql / iters,
             speedup=t_cpu / t_ql)


def table5(smoke=False) -> None:
    """Queue speedup scaling, 120D (paper Table 5)."""
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    sweep = (((128, 50), (1024, 25)) if smoke else
             ((128, 200), (1024, 150), (8192, 100), (32768, 50)))
    for particles, iters in sweep:
        cfg = PSOConfig(dim=120, particle_cnt=particles).resolved()
        s0 = init_swarm(cfg, 0)
        t_cpu = _time(lambda: run_serial_fast(cfg, 0, iters), repeats=1)
        t_q = _time(lambda: jax.block_until_ready(
            run(cfg, s0, iters, "queue").gbest_fit))
        emit(f"table5/p{particles}/queue", 1e6 * t_q / iters,
             speedup=t_cpu / t_q)


def convergence_equivalence(smoke=False) -> None:
    """The queue variants must match reduction's answer (paper §4.1) —
    report final gbest per variant on the paper's two problems."""
    from repro.core import PSOConfig, solve
    sweep = ((1, 200),) if smoke else ((1, 1000), (120, 500))
    for dim, iters in sweep:
        vals = {}
        for v in ("reduction", "queue", "queue_lock"):
            s = solve(PSOConfig(dim=dim, particle_cnt=1024), seed=0,
                      iters=iters, variant=v)
            vals[v] = float(s.gbest_fit)
        spread = max(vals.values()) - min(vals.values())
        emit(f"equiv/{dim}d/gbest_spread", spread, gbest=vals["queue"])


def async_sweep(smoke=False) -> None:
    """Async queue-lock: cost and quality vs sync across sync_every.

    Kernel leg (interpret mode): the grid has ``blocks * iters/sync_every``
    steps, so per-iteration cost measures exactly what the async algorithm
    removes — cross-block synchronization points (on TPU silicon: the
    serialized gbest publication + state round-trips; in interpret mode:
    the per-grid-step machinery standing in for them). It must fall
    monotonically as sync_every grows. Timing protocol: the K values are
    sampled round-robin (interleaved) and the per-K minimum is kept, so
    shared-machine scheduling drift hits every K equally instead of
    whichever K ran last. Library leg: final gbest quality of the relaxed
    semantics vs the synchronous queue_lock on the same seed.
    """
    from repro.core import PSOConfig, init_swarm, run, run_async
    from repro.kernels.ops import (run_queue_lock_fused,
                                   run_queue_lock_fused_async)
    dim, particles, block_n = 1, 4096, 64     # 64 particle blocks
    iters = 128                                # long calls: stable us/iter
    rounds = 6 if smoke else 10
    sweep = (1, 4, 16, 64)
    cfg = PSOConfig(dim=dim, particle_cnt=particles,
                    fitness="rastrigin").resolved()
    s0 = init_swarm(cfg, 0)

    def async_call(k):
        return run_queue_lock_fused_async(cfg, s0, iters=iters,
                                          sync_every=k, block_n=block_n,
                                          interpret=KERNEL_INTERPRET)

    def sync_call():
        return run_queue_lock_fused(cfg, s0, iters=iters, block_n=block_n,
                                    interpret=KERNEL_INTERPRET)

    fns = {k: (lambda k=k: jax.block_until_ready(async_call(k).gbest_fit))
           for k in sweep}
    fns["sync"] = lambda: jax.block_until_ready(sync_call().gbest_fit)
    # warmup/compile; the calls are deterministic, so the warmup results
    # double as the quality numbers (no re-execution after timing)
    gbest = {k: float(fn()) for k, fn in fns.items()}
    best = {k: float("inf") for k in fns}
    for _ in range(rounds):                   # interleaved, keep the min
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[k] = min(best[k], time.perf_counter() - t0)
    tag = f"async_sweep/d{dim}_n{particles}_b{block_n}"
    emit(f"{tag}/sync_kernel", 1e6 * best["sync"] / iters,
         gbest_fit=gbest["sync"])
    for k in sweep:
        emit(f"{tag}/sync_every_{k}", 1e6 * best[k] / iters,
             speedup_vs_sync=best["sync"] / best[k],
             gbest_fit=gbest[k],
             gbest_gap_vs_sync=gbest["sync"] - gbest[k])
    # library (jnp) leg: relaxed-consistency quality at production iteration
    # counts — the async answer must stay in the sync answer's neighborhood.
    qcfg = PSOConfig(dim=8, particle_cnt=256, fitness="rastrigin").resolved()
    q0 = init_swarm(qcfg, 0)
    jiters = 100 if smoke else 400
    gf_ql = float(run(qcfg, q0, jiters, "queue_lock").gbest_fit)
    for k in sweep:
        st = run_async(qcfg, q0, jiters, sync_every=k, n_blocks=4)
        emit(f"async_sweep/jnp_d8_n256/sync_every_{k}",
             0.0, gbest_fit=float(st.gbest_fit),
             gbest_gap_vs_queue_lock=gf_ql - float(st.gbest_fit))


def islands_ring(smoke=False) -> None:
    """Async island ring vs barrier exchange (core.distributed).

    Same island layout and exchange cadence; the sync leg pays the
    ``_pmax_best`` barrier collective per exchange, the async leg a
    neighbor-only ring push (plus the run_async local loop). On this
    container the mesh is 1-device, so absolute numbers measure program
    overhead rather than network latency — the record exists to track the
    ring path's cost trajectory and its convergence quality (the final
    gbest must equal max(pbest): the final-flush invariant).
    """
    import jax
    from repro.core import PSOConfig
    from repro.core.distributed import init_sharded_swarm, make_distributed_run
    dim, particles = 8, 2048
    iters = 64 if smoke else 256
    exchange = 16
    cfg = PSOConfig(dim=dim, particle_cnt=particles,
                    fitness="rastrigin").resolved()
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    s0 = init_sharded_swarm(cfg, 0, mesh)
    legs = {
        "barrier": make_distributed_run(cfg, mesh, iters=iters,
                                        variant="queue",
                                        exchange_interval=exchange),
        "ring_async": make_distributed_run(cfg, mesh, iters=iters,
                                           variant="async",
                                           exchange_interval=exchange,
                                           sync_every=8),
    }
    tag = f"islands_ring/d{dim}_n{particles}_x{exchange}"
    times, quality = {}, {}
    for name, fn in legs.items():
        quality[name] = float(jax.block_until_ready(fn(s0).gbest_fit))
    for _ in range(3 if smoke else 6):        # interleaved, keep the min
        for name, fn in legs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(s0).gbest_fit)
            dt = time.perf_counter() - t0
            times[name] = min(times.get(name, float("inf")), dt)
    for name in legs:
        emit(f"{tag}/{name}", 1e6 * times[name] / iters,
             gbest_fit=quality[name],
             speedup_vs_barrier=times["barrier"] / times[name])


def multi_swarm(smoke=False) -> None:
    """Batched multi-swarm engine vs loop-of-solve (swarms/sec).

    The loop baseline compiles once (cfg/iters static) and pays per-solve
    dispatch + eager init; solve_many pays one dispatch for the whole batch.
    Note the 1D/tiny-swarm regime can favor the loop on CPU: vmap turns the
    queue variant's rare-improvement ``cond`` into an always-both-branches
    ``select``, so batching wins where per-dispatch overhead and vector
    width dominate (realistic dims / particle counts), not on toy shapes.
    """
    import jax
    from repro.core import PSOConfig, solve, solve_many
    sweep = (((10, 256, 8, 50),) if smoke else
             ((10, 256, 8, 200), (10, 256, 16, 200), (10, 1024, 32, 100)))
    for dim, particles, s_cnt, iters in sweep:
        cfg = PSOConfig(dim=dim, particle_cnt=particles, fitness="rastrigin")
        seeds = list(range(s_cnt))
        t_loop = _time(lambda: [jax.block_until_ready(
            solve(cfg, sd, iters, "queue").gbest_fit) for sd in seeds],
            repeats=1)
        t_batch = _time(lambda: jax.block_until_ready(
            solve_many(cfg, seeds, iters, "queue").gbest_fit), repeats=1)
        tag = f"multi_swarm/d{dim}_n{particles}_s{s_cnt}"
        emit(f"{tag}/loop_of_solve", 1e6 * t_loop,
             swarms_per_s=s_cnt / t_loop)
        emit(f"{tag}/solve_many", 1e6 * t_batch,
             swarms_per_s=s_cnt / t_batch,
             speedup_vs_loop=t_loop / t_batch)


def mixed_traffic(smoke=False) -> None:
    """Serving-layer registry coalescing (launch/serve.py): a stream of
    requests cycling through the six built-in objectives at ONE solve
    shape, flushed in waves. With ``coalesce_registry`` every wave is a
    single heterogeneous dispatch (one compiled program for the whole
    mix); the legacy content-hash grouping pays one dispatch — and one
    compiled program — per distinct objective. ``first_flush_us`` carries
    the compile cost of each mode; later flushes are steady-state, so the
    p50/p99 columns are the serving-latency claim. ``fill_vs_content_hash``
    (real rows per dispatch, ratio of the two modes) is the coalescing
    payoff — 6 distinct objectives per wave means a >=2x floor."""
    from repro.launch.serve import SolveRequest, SolveServer
    names = ("cubic", "sphere", "rosenbrock", "griewank", "rastrigin",
             "ackley")
    dim, n, iters = 10, 128, (20 if smoke else 100)
    waves, per_wave = (3, 6) if smoke else (6, 12)
    stats, flushes = {}, {}
    for label, coalesce in (("hetero", True), ("content_hash", False)):
        srv = SolveServer(coalesce_registry=coalesce)
        lat = []
        k = 0
        for _ in range(waves):
            for _ in range(per_wave):
                srv.submit(SolveRequest(
                    dim=dim, particle_cnt=n, fitness=names[k % len(names)],
                    seed=k, iters=iters, variant="queue"))
                k += 1
            t0 = time.perf_counter()
            srv.flush()
            lat.append(1e6 * (time.perf_counter() - t0))
        stats[label], flushes[label] = srv.stats, lat
    for label in ("hetero", "content_hash"):
        s, lat = stats[label], flushes[label]
        steady = lat[1:] or lat
        kv = dict(first_flush_us=lat[0],
                  p50_us=float(np.percentile(steady, 50)),
                  p99_us=float(np.percentile(steady, 99)),
                  dispatches=s.dispatches, batch_fill=s.batch_fill,
                  padded_rows=s.padded_rows)
        if label == "hetero":
            kv["fill_vs_content_hash"] = (
                s.batch_fill / stats["content_hash"].batch_fill)
        emit(f"mixed_traffic/d{dim}_n{n}/{label}", float(np.mean(steady)),
             **kv)


def serving_bench(smoke=False) -> None:
    """Continuous batching vs flush batching on the mixed-traffic stream
    (benchmarks/loadgen.py): six built-ins crossed with four iteration
    budgets at one solve shape, arriving in waves. Flush group keys
    include ``iters`` so every wave fragments into padded groups; the
    continuous scheduler's lane keys drop ``iters`` and admit at chunk
    boundaries, so the same trace rides one full lane. Both legs are
    steady-state (warmup pass untimed) and the per-request results are
    cross-checked bitwise (``gbest_agree`` must be True). The continuous
    leg's ``speedup_vs_flush`` (steady-state requests/s ratio) is the
    serving claim; ``batch_fill`` is the mechanism."""
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import loadgen
    rep = loadgen.run_loadgen(smoke=smoke)
    tag = "serving/mixed_d6_n64"
    for leg in ("flush", "continuous"):
        s = rep[leg]
        kv = dict(requests_per_s=s["requests_per_s"], p50_us=s["p50_us"],
                  p99_us=s["p99_us"], batch_fill=s["batch_fill"],
                  dispatches=s["dispatches"],
                  first_pass_s=rep[f"{leg}_first_pass_s"])
        if leg == "continuous":
            kv["speedup_vs_flush"] = rep["speedup_vs_flush"]
            kv["gbest_agree"] = rep["gbest_agree"]
            sc = rep["continuous_snapshot"]["counters"]
            kv["row_swaps"] = int(sc.get("row_swaps", 0))
            kv["tail_ejections"] = int(sc.get("tail_ejections", 0))
        emit(f"{tag}/{leg}", s["us_per_request"], **kv)


def custom_objective(smoke=False) -> None:
    """Problem-API adapter overhead: the generic d-major adapter
    (``repro.kernels.pso_step.dmajor_adapter`` — transpose + sliced user
    fn + hoisted consts + pinned advance) vs the hand-tuned ``cubic``
    kernel form, same landscape, same fused queue-lock kernel. The
    ``overhead_vs_hand_tuned`` ratio is the price of a user-defined
    objective on the kernel path; the gbest gap must be ~0 (identical
    landscape, same seed)."""
    import jax.numpy as jnp
    from repro.core import PSOConfig, init_swarm
    from repro.core.problem import Problem
    from repro.kernels.ops import run_queue_lock_fused

    def cubic_user(x):      # the paper's Eq. 3, as a user would write it
        return jnp.sum(x * x * x - 0.8 * (x * x) - 1000.0 * x + 8000.0,
                       axis=-1)

    custom = Problem(name="cubic_user", fn=cubic_user, lo=-100.0, hi=100.0)
    dim, particles, iters = 8, 1024, (10 if smoke else 40)
    results = {}
    for label, fitness in (("hand_tuned", "cubic"), ("adapter", custom)):
        cfg = PSOConfig(dim=dim, particle_cnt=particles,
                        fitness=fitness).resolved()
        s0 = init_swarm(cfg, 0)
        last = {}

        def call(cfg=cfg, s0=s0, last=last):
            out = run_queue_lock_fused(cfg, s0, iters=iters,
                                       interpret=KERNEL_INTERPRET)
            last["gbest"] = float(jax.block_until_ready(out.gbest_fit))

        t = _time(call, repeats=1)  # deterministic: timed runs = quality run
        results[label] = (t, last["gbest"])
    tag = f"custom_objective/d{dim}_n{particles}"
    t_hand, g_hand = results["hand_tuned"]
    t_adpt, g_adpt = results["adapter"]
    emit(f"{tag}/hand_tuned", 1e6 * t_hand / iters, gbest_fit=g_hand)
    emit(f"{tag}/adapter", 1e6 * t_adpt / iters,
         overhead_vs_hand_tuned=t_adpt / t_hand,
         gbest_fit=g_adpt, gbest_gap_vs_hand_tuned=g_hand - g_adpt)


def constrained(smoke=False) -> None:
    """Constrained-optimization subsystem: penalty vs projection us/iter on
    the sphere-on-simplex built-in (repro.core.constraints), through the
    jnp queue-lock engine. Penalty pays one extra objective-sized violation
    evaluation per fitness call; projection pays a sort-based simplex
    projection per advance. Both records carry the final gbest (user sense:
    minimized, optimum 1/D) and its violation so constraint-handling
    quality is tracked alongside cost."""
    from repro.core import PSOConfig, init_swarm, run
    from repro.core.problem import get_problem
    dim, particles = 8, 1024
    iters = 50 if smoke else 200
    for label, name in (("penalty", "sphere_simplex_pen"),
                        ("projection", "sphere_simplex")):
        prob = get_problem(name)
        cfg = PSOConfig(dim=dim, particle_cnt=particles, fitness=prob,
                        w=0.7).resolved()
        s0 = init_swarm(cfg, 0)
        last = {}

        def call(cfg=cfg, s0=s0, last=last):
            out = run(cfg, s0, iters, "queue_lock")
            jax.block_until_ready(out.gbest_fit)
            last["out"] = out

        t = _time(call)  # deterministic: timed runs = quality run
        out = last["out"]
        viol = prob.violation_at(out.gbest_pos)
        emit(f"constrained/d{dim}_n{particles}/{label}", 1e6 * t / iters,
             best=float(prob.user_value(out.gbest_fit)),
             violation=float(viol), feasible=bool(viol <= 0.0))


def autotune_bench(smoke=False) -> None:
    """Roofline schedule autotuner (repro.core.autotune): auto-picked
    ``(variant, backend, block_n, sync_every)`` vs the fixed schedule a
    user would pin, across built-in suite shapes.

    Each shape carries the variant a fixed-schedule user plausibly
    requests — ``queue`` (the repo default) on some, the paper's
    GPU-winning ``queue_lock``/``async`` on others. The fixed leg honors
    that pin exactly (``Method(variant=...)``); the auto leg is
    ``schedule="auto"``, where the variant is a preference the tuner may
    override — on hosts whose roofline disagrees with the paper's GPU
    (this CPU container), walking a pinned fused/async variant back to
    the cheapest engine is precisely the tuner's job.

    Both legs are timed with the tuner's own micro-run harness so the
    comparison is apples-to-apples; when the tuner picks exactly the fixed
    schedule the fixed timing is REUSED (ratio exactly 1.0) — the measured
    fallback always includes the default fixed schedule as a candidate, so
    auto is never worse than the default rule by construction. ``cache_hit``
    records that the second resolve of each shape was served from the
    measured-optima cache (no re-measurement) — the serving-layer latency
    guarantee."""
    from repro.core import autotune as at
    shapes = ([("sphere", 4, 256, "queue"),
               ("rastrigin", 8, 512, "async"),
               ("cubic", 1, 2048, "async")] if smoke else
              [("sphere", 4, 256, "queue"),
               ("rastrigin", 8, 512, "async"),
               ("cubic", 1, 2048, "async"),
               ("ackley", 16, 1024, "async"),
               ("griewank", 2, 64, "queue"),
               ("rosenbrock", 32, 4096, "async")])
    iters = 40 if smoke else 120
    cache = at.AutotuneCache()
    for prob, d, n, req_variant in shapes:
        fixed = at.fixed_schedule(variant=req_variant)
        tuned = at.resolve_schedule(prob, d, n, iters, cache=cache)
        hit = at.resolve_schedule(prob, d, n, iters, cache=cache)
        same = (tuned.variant == fixed.variant
                and tuned.backend == fixed.backend
                and tuned.block_n == fixed.block_n
                and (tuned.variant != "async"
                     or tuned.sync_every == fixed.sync_every))
        t_fixed = at.measure_schedule(fixed, prob, d, n, iters=iters,
                                      repeats=3)
        t_auto = t_fixed if same else at.measure_schedule(
            tuned, prob, d, n, iters=iters, repeats=3)
        tag = f"autotune/{prob}_d{d}_n{n}"
        emit(f"{tag}/fixed", t_fixed, variant=fixed.variant,
             backend=fixed.backend, requested=req_variant)
        emit(f"{tag}/auto", t_auto, speedup_vs_fixed=t_fixed / t_auto,
             variant=tuned.variant, backend=tuned.backend,
             block_n=tuned.block_n, sync_every=tuned.sync_every,
             source=tuned.source, cache_hit=bool(hit.source == "cache"))


def portfolio(smoke=False) -> None:
    """Update-rule portfolio: quality at equal wall-clock.

    Rules trade per-iteration cost against per-iteration progress (sso
    has no velocity chain, lowcost drops the stochastic multiplies), so
    comparing them at equal ITERATION counts is the wrong frame for a
    serving deployment. This suite times each registered rule's us/iter
    on the jnp queue-lock engine, then reruns each rule with the
    iteration count that fits the DEFAULT rule's wall-clock budget —
    ``gbest_fit`` is the quality-at-equal-time column and
    ``gbest_gap_vs_pso`` the portfolio signal (positive = the canonical
    rule is ahead at this budget on this landscape)."""
    from repro.core import PSOConfig, init_swarm, run
    from repro.core.update_rules import rule_names
    dim, particles = 8, 512
    base_iters = 60 if smoke else 300
    rules = rule_names()
    cfgs = {r: PSOConfig(dim=dim, particle_cnt=particles,
                         fitness="rastrigin", update_rule=r).resolved()
            for r in rules}
    s0 = {r: init_swarm(cfgs[r], 0) for r in rules}
    t = {r: _time(lambda r=r: jax.block_until_ready(
        run(cfgs[r], s0[r], base_iters, "queue_lock").gbest_fit))
        for r in rules}
    budget = t["pso"]                     # the default rule's wall-clock
    tag = f"portfolio/rastrigin_d{dim}_n{particles}"
    quality = {}
    iters_at = {}
    for r in rules:
        iters_at[r] = max(1, int(round(base_iters * budget / t[r])))
        quality[r] = float(jax.block_until_ready(
            run(cfgs[r], s0[r], iters_at[r], "queue_lock").gbest_fit))
    for r in rules:
        emit(f"{tag}/{r}", 1e6 * t[r] / base_iters,
             iters_at_budget=iters_at[r], gbest_fit=quality[r],
             gbest_gap_vs_pso=quality["pso"] - quality[r])


def telemetry_bench(smoke=False) -> None:
    """Telemetry overhead: the in-kernel contention-counter plumbing.

    ``telemetry/.../off`` times the fused queue-lock kernel with
    counters disabled. The disabled program lowers bit-identically to
    the pre-telemetry kernel (digest-pinned in tests/test_kernels.py),
    so the ``disabled_ratio`` derived column is an A/A control — the
    same program timed twice — and its value is the runner's timing
    noise floor. CI asserts it stays ≤ 1.05 (the ≤5% budget for the
    disabled path; the digest pin is the structural zero-overhead
    guarantee). ``.../on`` times the counter-instrumented program and
    reports the real ``enabled_ratio`` plus the counter totals.
    Warn-only in compare.py until it accumulates noise-floor history.
    """
    from repro.core import PSOConfig, init_swarm
    from repro.kernels.ops import run_queue_lock_fused
    from repro.telemetry import KernelCounters
    dim, particles = 8, 512
    iters = 8 if smoke else 32
    cfg = PSOConfig(dim=dim, particle_cnt=particles,
                    fitness="rastrigin").resolved()
    s0 = init_swarm(cfg, 0)
    t_off = _time(lambda: jax.block_until_ready(
        run_queue_lock_fused(cfg, s0, iters=iters).gbest_fit))
    t_off2 = _time(lambda: jax.block_until_ready(
        run_queue_lock_fused(cfg, s0, iters=iters).gbest_fit))
    t_on = _time(lambda: jax.block_until_ready(
        run_queue_lock_fused(cfg, s0, iters=iters,
                             telemetry=True)[0].gbest_fit))
    _, cnt = run_queue_lock_fused(cfg, s0, iters=iters, telemetry=True)
    c = KernelCounters.from_array(cnt)
    tag = f"telemetry/queue_lock_d{dim}_n{particles}"
    emit(f"{tag}/off", 1e6 * t_off / iters,
         disabled_ratio=t_off2 / t_off)
    emit(f"{tag}/on", 1e6 * t_on / iters,
         enabled_ratio=t_on / t_off, **c.as_dict())


def lm_bench() -> None:
    """LM substrate: smoke-config train-step tokens/s per arch family."""
    from repro.configs import get_arch
    from repro.launch.steps import make_train_step
    from repro.models import zoo
    for arch in ("stablelm-3b", "phi3.5-moe-42b-a6.6b", "hymba-1.5b",
                 "xlstm-350m", "whisper-small"):
        cfg = get_arch(arch).smoke()
        params = zoo.init_params(cfg, jax.random.key(0))
        step, opt_init = make_train_step(cfg)
        opt = opt_init(params)
        jstep = jax.jit(step)
        b, s = 4, 128
        batch = zoo.make_batch(cfg, "train_4k", b, s, jax.random.key(1))
        t = _time(lambda: jax.block_until_ready(
            jstep(params, opt, batch)[2]["loss"]))
        toks = b * s
        emit(f"lm/{arch}/train_step", 1e6 * t, tokens_per_s=toks / t)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized iteration counts; skips the LM substrate")
    ap.add_argument("--out", default="BENCH_pso.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    convergence_equivalence(args.smoke)
    table3(args.smoke)
    table4(args.smoke)
    table5(args.smoke)
    multi_swarm(args.smoke)
    mixed_traffic(args.smoke)
    serving_bench(args.smoke)
    async_sweep(args.smoke)
    islands_ring(args.smoke)
    custom_objective(args.smoke)
    constrained(args.smoke)
    autotune_bench(args.smoke)
    portfolio(args.smoke)
    telemetry_bench(args.smoke)
    if not args.smoke:
        lm_bench()
    if args.out:
        import platform
        doc = {
            "meta": {
                "backend": jax.default_backend(),
                "jax_version": jax.__version__,
                "pallas_interpret": KERNEL_INTERPRET,
                "smoke": bool(args.smoke),
                # recorded so compare.py can tell same-runner A/Bs (where
                # the hard gate is meaningful) from cross-machine diffs.
                # BENCH_HOST_ID overrides the hostname for fleets of
                # interchangeable machines (CI sets it to the runner class:
                # GitHub-hosted VMs get a fresh hostname per job, which
                # would otherwise disarm the gate on every run)
                "host": os.environ.get("BENCH_HOST_ID") or platform.node(),
                # host fingerprint for the roofline calibration fit
                # (repro.roofline.pso_cost.fit_calibration): model fits
                # must never mix hosts, and hostname alone is too weak
                # (CI runner classes share BENCH_HOST_ID across VM sizes)
                "cpu_count": os.cpu_count(),
                "device_kind": jax.devices()[0].device_kind,
            },
            "benchmarks": RESULTS,
        }
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(RESULTS)} records to {args.out}")


if __name__ == "__main__":
    main()
