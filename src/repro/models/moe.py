"""Mixture-of-Experts with top-k routing and capacity-based, gather/scatter
("sort-free") dispatch.

Why gather-based and not one-hot-einsum dispatch: the dispatch einsum
[T, E, C] × [T, d] costs 2·T·E·C·d FLOPs — for arctic-480b that is ~35 % of
the expert FLOPs themselves, and it pollutes HLO_FLOPs so the roofline's
MODEL_FLOPS/HLO ratio misreports useful work. Gather/scatter dispatch costs
zero FLOPs (memory ops only): slot indices are computed with a cumsum over
the token→expert one-hot, tokens are ``take``-n into [E, C, d], experts run
as one batched einsum, and results scatter-add back weighted by router
probs. Tokens beyond capacity are dropped (standard) — the router loss
includes the load-balancing auxiliary term to keep drops rare.

This is also where the paper's transferable insight lands outside PSO
(DESIGN.md §5): routing is an argmax-class reduction per token, and
dispatch communicates *indices*, not payload vectors, until the winner is
known — exactly the queue algorithm's §5.3 index trick.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import act_fn, dense_init

Params = Dict[str, Any]


def init_moe(key, d: int, ff: int, n_experts: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, n_experts, dtype, scale=0.02),
        "w_in": (jax.random.normal(ks[1], (n_experts, d, ff), jnp.float32)
                 * scale).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (n_experts, ff, d), jnp.float32)
                  * (ff ** -0.5)).astype(dtype),
    }
    if act == "silu":
        p["w_gate"] = (jax.random.normal(ks[3], (n_experts, d, ff),
                                         jnp.float32) * scale).astype(dtype)
    return p


def _capacity(tokens: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(tokens * top_k * cf / n_experts)
    return max(8, -(-c // 8) * 8)                    # round up to 8


def moe_apply(p: Params, x, *, n_experts: int, top_k: int,
              capacity_factor: float, act: str, group_tokens: int,
              expert_sharding: str = "tp"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Tokens are processed in groups of ``group_tokens`` (capacity is
    per-group, keeping the routing tensors small and shardable).
    """
    b, s, d = x.shape
    t_total = b * s
    g_tok = min(group_tokens, t_total)
    assert t_total % g_tok == 0, (t_total, g_tok)
    n_groups = t_total // g_tok
    xg = x.reshape(n_groups, g_tok, d)
    cap = _capacity(g_tok, n_experts, top_k, capacity_factor)

    logits = (xg @ p["router"]).astype(jnp.float32)       # [G, T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)      # [G, T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)           # renormalize

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(axis=1)                               # [G, E]
    one_hot_top1 = jax.nn.one_hot(experts[..., 0], n_experts)
    ce = one_hot_top1.mean(axis=1)                        # [G, E]
    aux = (me * ce).sum(-1).mean() * n_experts

    def route(expert_t, gate_t):
        """Integer-only routing for one group: (src [E,C], slot gate [E*C])."""
        t = expert_t.shape[0]
        flat_e = expert_t.reshape(-1)                     # [T*k]
        one_hot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
        slot = jnp.cumsum(one_hot, axis=0) * one_hot - 1  # slot within expert
        slot = slot.max(axis=-1)                          # [T*k]
        keep = slot < cap
        tok_idx = jnp.arange(t * top_k) // top_k
        dest = flat_e * cap + jnp.where(keep, slot, cap)  # dropped -> sentinel
        src = jnp.full((n_experts * cap + 1,), t, jnp.int32)  # t = pad token
        src = src.at[dest].set(tok_idx, mode="drop")
        src = src[:n_experts * cap]
        w = _slot_gate(jnp.where(keep, gate_t.reshape(-1), 0.0), dest,
                       n_experts * cap)
        return src, w

    # vmap only the cheap integer routing; keep expert matmuls batched so
    # they shard cleanly ([G]→dp, [E]→tp — the queue-style index-only
    # dispatch: payload vectors move once, via gather, after routing).
    src, w = jax.vmap(route)(experts, gate_vals)          # [G,E*C], [G,E*C]
    from .policy import constrain
    # EP: experts over the model axis; TP: expert weights sharded on ff,
    # expert dim replicated (activation layouts must match the weights).
    e_tag = "tp" if expert_sharding == "ep" else None
    f_tag = None if expert_sharding == "ep" else "tp"
    xg_pad = jnp.concatenate([xg, jnp.zeros((n_groups, 1, d), xg.dtype)], 1)
    gathered = jnp.take_along_axis(xg_pad, src[..., None], axis=1)
    gathered = constrain(
        gathered.reshape(n_groups, n_experts, cap, d),
        ("dp", e_tag, None, None))
    h = constrain(jnp.einsum("gecd,edf->gecf", gathered, p["w_in"]),
                  ("dp", e_tag, None, f_tag))
    if "w_gate" in p:
        h = act_fn(act)(constrain(
            jnp.einsum("gecd,edf->gecf", gathered, p["w_gate"]),
            ("dp", e_tag, None, f_tag))) * h
    else:
        h = act_fn(act)(h)
    out_ec = constrain(jnp.einsum("gecf,efd->gecd", h, p["w_out"]),
                       ("dp", e_tag, None, None))
    contrib = (out_ec.reshape(n_groups, n_experts * cap, d)
               * w[..., None].astype(out_ec.dtype))

    def scatter_back(contrib_g, src_g):
        out = jnp.zeros((g_tok + 1, d), jnp.float32)
        return out.at[src_g].add(contrib_g.astype(jnp.float32))[:g_tok]

    out = jax.vmap(scatter_back)(contrib, src)
    out = constrain(out.astype(x.dtype).reshape(b, s, d),
                    ("dp", None, None))
    return out, aux


def _slot_gate(w_flat, dest, n_slots):
    """Route per-(token,k) gate weights to their (expert,slot) cells."""
    g = jnp.zeros((n_slots + 1,), jnp.float32)
    g = g.at[dest].set(w_flat, mode="drop")
    return g[:n_slots]
