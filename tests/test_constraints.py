"""Constrained-optimization subsystem (repro.core.constraints).

Layers covered: core/constraints.py (Constraint/ConstraintSet, violation,
projection, repair, CLI spec parser), core/problem.py (constraints field,
penalized max_fn, cache_key content), core/pso.py (projection hook, repair
init, run_with_history), core/serial.py (constrained mirror),
kernels/pso_step.py + kernels/ref.py (projection/penalty lowering, the new
constrained oracle), repro.api (Result.feasible/violation/history, Deb
best(), penalty ramp), launch/serve.py (constraint-aware batch keys +
feasibility reporting), core/tuner.py (constrained batch fitness), and the
pso_run CLI (--constraint presets).
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import Constraint, ConstraintSet, Method, Problem
from repro.core import PSOConfig, init_swarm, run, run_async, solve
from repro.core.constraints import (constrain_problem, constraint_from_spec,
                                    constraint_set_from_cli, project_simplex,
                                    simplex_constraints)
from repro.core.problem import get_problem
from repro.core.pso import run_with_history
from repro.kernels import ops, ref
from repro.kernels.pso_step import is_converted, kernel_projection

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _ball_repair(tries=8):
    return Problem(
        name="ball_repair", fn=lambda x: -jnp.sum(x * x, -1),
        lo=-2.0, hi=2.0,
        constraints=ConstraintSet(
            constraints=(Constraint(fn=lambda x: jnp.sum(x * x, -1) - 4.0,
                                    name="ball"),),
            mode="repair", repair_tries=tries))


def _plane_ball(tries=64):
    """Repair-mode problem whose UNCONSTRAINED optimum is infeasible:
    maximize sum(x) in [-2, 2]^D subject to ||x||^2 <= 1.5^2. The box
    corner beats every feasible point, so a raw ``fit > pbest`` fold
    drives pbests out of the feasible set — the Deb-rule litmus."""
    return Problem(
        name="plane_ball", fn=lambda x: jnp.sum(x, -1), lo=-2.0, hi=2.0,
        constraints=ConstraintSet(
            constraints=(Constraint(fn=lambda x: jnp.sum(x * x, -1) - 2.25,
                                    name="ball"),),
            mode="repair", repair_tries=tries))


# --------------------------------------------------------------------------
# Constraint / ConstraintSet semantics
# --------------------------------------------------------------------------

def test_constraint_violation_forms():
    ineq = Constraint(fn=lambda x: jnp.sum(x, -1) - 1.0)
    x = jnp.asarray([[0.3, 0.3], [0.9, 0.9]])
    np.testing.assert_allclose(np.asarray(ineq.violation(x)),
                               [0.0, 0.8], atol=1e-6)
    eq = Constraint(fn=lambda x: jnp.sum(x, -1) - 1.0, kind="eq", tol=0.1)
    np.testing.assert_allclose(np.asarray(eq.violation(x)),
                               [0.3, 0.7], atol=1e-6)
    # aggregate sums contributions; empty set is identically feasible
    cs = ConstraintSet(constraints=(ineq, eq), mode="penalty")
    np.testing.assert_allclose(np.asarray(cs.violation(x)),
                               [0.3, 1.5], atol=1e-6)


def test_constraint_validation():
    fn = lambda x: jnp.sum(x, -1)
    with pytest.raises(ValueError, match="kind"):
        Constraint(fn=fn, kind="leq")
    with pytest.raises(ValueError, match="mode"):
        ConstraintSet(constraints=(Constraint(fn=fn),), mode="clip")
    with pytest.raises(ValueError, match="projection"):
        ConstraintSet(constraints=(Constraint(fn=fn),), mode="projection")
    with pytest.raises(ValueError, match="projection"):
        ConstraintSet(constraints=(Constraint(fn=fn),), mode="penalty",
                      projection=lambda x: x)
    with pytest.raises(ValueError, match="at least one"):
        ConstraintSet(constraints=(), mode="penalty")
    # projection mode with no declared constraints is fine (reporting-only)
    cs = ConstraintSet(mode="projection", projection=project_simplex)
    assert float(cs.violation(jnp.asarray([5.0, 5.0]))) == 0.0
    # hashable (jit-static requirement), like Problem
    hash(cs)
    hash(Problem(name="c", fn=fn, constraints=ConstraintSet(
        constraints=(Constraint(fn=fn),))))


def test_problem_constraint_validation():
    fn = lambda x: -jnp.sum(x * x, -1)
    with pytest.raises(TypeError, match="ConstraintSet"):
        Problem(name="x", fn=fn, constraints="simplex")
    with pytest.raises(ValueError, match="mutually exclusive"):
        Problem(name="x", fn=fn,
                kernel_fn=lambda p, m, d: -jnp.sum(p, 0, keepdims=True),
                constraints=ConstraintSet(
                    constraints=(Constraint(fn=fn),)))


def test_cache_key_covers_constraints():
    fn = lambda x: -jnp.sum(x * x, -1)
    g = lambda x: jnp.sum(x, -1) - 1.0
    base = Problem(name="p", fn=fn)
    pen = Problem(name="p", fn=fn, constraints=ConstraintSet(
        constraints=(Constraint(fn=g),), mode="penalty", weight=10.0))
    pen2 = Problem(name="p", fn=fn, constraints=ConstraintSet(
        constraints=(Constraint(fn=g),), mode="penalty", weight=20.0))
    rep = Problem(name="p", fn=fn, constraints=ConstraintSet(
        constraints=(Constraint(fn=g),), mode="repair"))
    keys = {base.cache_key(), pen.cache_key(), pen2.cache_key(),
            rep.cache_key()}
    assert len(keys) == 4                      # mode and weight are content
    # identical reconstruction shares the key (serving batches together)
    pen_again = Problem(name="p", fn=fn, constraints=ConstraintSet(
        constraints=(Constraint(fn=g),), mode="penalty", weight=10.0))
    assert pen_again.cache_key() == pen.cache_key()


def test_penalized_max_fn():
    p = get_problem("sphere_simplex_pen")
    x = jnp.asarray([0.25, 0.25, 0.25, 0.25])     # feasible: penalty-free
    assert float(p.max_fn(x)) == pytest.approx(-0.25, rel=1e-6)
    y = jnp.asarray([0.5, 0.5, 0.5, 0.5])         # sum=2: viol ~ 1 - tol
    w = p.constraints.weight
    assert float(p.max_fn(y)) == pytest.approx(-1.0 - w * (1.0 - 1e-5),
                                               rel=1e-5)
    assert p.max_fn is p.max_fn                   # stable wrapper identity
    # the unconstrained fast path is untouched (object identity)
    sphere = get_problem("sphere")
    assert sphere.max_fn is sphere.fn


def test_project_simplex_known_points():
    got = project_simplex(jnp.asarray([[0.25, 0.25, 0.5],   # already on it
                                       [1.0, 1.0, 1.0],     # uniform
                                       [10.0, 0.0, 0.0]]))  # vertex
    want = np.asarray([[0.25, 0.25, 0.5],
                       [1 / 3, 1 / 3, 1 / 3],
                       [1.0, 0.0, 0.0]])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)
    # random points project onto the simplex (nonneg, sum 1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-3, 3, size=(64, 7)).astype(np.float32))
    px = np.asarray(project_simplex(x))
    assert px.min() >= 0.0
    np.testing.assert_allclose(px.sum(-1), 1.0, atol=1e-5)


# --------------------------------------------------------------------------
# jnp engines: projection/penalty/repair through init + every variant
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock",
                                     "async"])
def test_projection_mode_stays_feasible_and_converges(variant):
    cfg = PSOConfig(dim=6, particle_cnt=128,
                    fitness=get_problem("sphere_simplex"), w=0.7)
    s = solve(cfg.resolved(), seed=0, iters=150, variant=variant)
    pos = np.asarray(s.pos)
    assert pos.min() >= 0.0                       # never leaves the simplex
    np.testing.assert_allclose(pos.sum(-1), 1.0, atol=1e-5)
    # optimum is 1/D (canonical max: -1/D)
    assert float(s.gbest_fit) == pytest.approx(-1.0 / 6.0, abs=1e-4)


def test_penalty_mode_converges_near_feasible():
    cfg = PSOConfig(dim=6, particle_cnt=256,
                    fitness=get_problem("sphere_simplex_pen"), w=0.7)
    s = solve(cfg.resolved(), seed=0, iters=200, variant="queue_lock")
    p = get_problem("sphere_simplex_pen")
    assert p.violation_at(s.gbest_pos) < 1e-2     # near-feasible
    assert float(p.user_value(s.gbest_fit)) < 0.5  # well below random (~1)


def test_repair_mode_feasible_init():
    p = _ball_repair()
    cfg = PSOConfig(dim=3, particle_cnt=256, fitness=p).resolved()
    s0 = init_swarm(cfg, 0)
    frac = float((np.asarray(p.violation_fn(s0.pos)) <= 0).mean())
    assert frac > 0.95                            # vs ~0.52 unrepaired
    cfg_u = PSOConfig(dim=3, particle_cnt=256, fitness="sphere",
                      min_pos=-2.0, max_pos=2.0).resolved()
    frac_u = float((np.asarray(p.violation_fn(init_swarm(cfg_u, 0).pos))
                    <= 0).mean())
    assert frac_u < 0.7
    # velocities and the RNG chain are untouched by the resampling
    assert np.array_equal(np.asarray(s0.vel), np.asarray(init_swarm(
        cfg_u, 0).vel))


def test_serial_mirror_matches_constrained_init_and_runs():
    from repro.core.serial import SerialSwarm, run_serial_fast
    for prob in (get_problem("sphere_simplex"),
                 get_problem("sphere_simplex_pen"), _ball_repair()):
        cfg = PSOConfig(dim=4, particle_cnt=64, fitness=prob).resolved()
        ser = SerialSwarm(cfg, seed=0)
        jnp_init = init_swarm(cfg, 0)
        assert np.array_equal(ser.pos, np.asarray(jnp_init.pos))
        gf, gp = run_serial_fast(cfg, 0, 20)
        assert np.isfinite(gf)
        if prob.projection_fn is not None:
            assert prob.violation_at(gp) <= 1e-5
    # string spelling of a registered constrained problem works too
    cfg = PSOConfig(dim=4, particle_cnt=32, fitness="sphere_simplex")
    gf, _ = run_serial_fast(cfg.resolved(), 0, 10)
    assert np.isfinite(gf)


# --------------------------------------------------------------------------
# The new eager oracle: jnp engine bit-exactness (per-dispatch granularity)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("prob_name", ["sphere_simplex",
                                       "sphere_simplex_pen", "repair"])
def test_jnp_queue_lock_bit_exact_vs_constrained_oracle(prob_name):
    """The jnp engine, dispatched per iteration, matches the independent
    eager oracle BIT-EXACTLY (float equality on every field). The
    multi-iteration fori_loop program additionally FMA-fuses across
    iterations (pre-existing XLA:CPU caveat, see multi_swarm) and is
    checked exact-on-gbest / ulp-tight-on-positions below."""
    prob = _ball_repair() if prob_name == "repair" else get_problem(prob_name)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    o = ref.run_constrained_oracle(cfg, 3, 12, variant="queue_lock")
    s = init_swarm(cfg, 3)
    for _ in range(12):
        s = run(cfg, s, 1, "queue_lock")
    assert np.array_equal(np.asarray(s.pos), np.asarray(o.pos))
    assert np.array_equal(np.asarray(s.vel), np.asarray(o.vel))
    assert np.array_equal(np.asarray(s.pbest_fit), np.asarray(o.pbest_fit))
    assert float(s.gbest_fit) == float(o.gbest_fit)
    assert np.array_equal(np.asarray(s.gbest_pos), np.asarray(o.gbest_pos))
    # the fused loop program: exact gbest value, ulp-tight positions
    sf = solve(cfg, seed=3, iters=12, variant="queue_lock")
    np.testing.assert_allclose(np.asarray(sf.pos), np.asarray(o.pos),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(sf.gbest_fit), float(o.gbest_fit),
                               rtol=1e-5)


@pytest.mark.parametrize("prob_name,sync_every,n_blocks",
                         [("sphere_simplex", 4, 4),
                          ("sphere_simplex_pen", 4, 2),
                          ("sphere_simplex_pen", 3, 4),
                          ("repair", 4, 2)])
def test_jnp_async_bit_exact_vs_constrained_oracle(prob_name, sync_every,
                                                   n_blocks):
    prob = _ball_repair() if prob_name == "repair" else get_problem(prob_name)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    iters = 14
    o = ref.run_constrained_oracle(cfg, 3, iters, variant="async",
                                   sync_every=sync_every, n_blocks=n_blocks)
    s = init_swarm(cfg, 3)
    for _ in range(iters):      # per-iteration windows, phase auto-aligned
        s = run_async(cfg, s, 1, sync_every=sync_every, n_blocks=n_blocks)
    assert np.array_equal(np.asarray(s.pos), np.asarray(o.pos))
    assert np.array_equal(np.asarray(s.pbest_fit), np.asarray(o.pbest_fit))
    assert np.array_equal(np.asarray(s.lbest_fit), np.asarray(o.lbest_fit))
    assert float(s.gbest_fit) == float(o.gbest_fit)
    # full fori_loop program: exact gbest, ulp-tight positions
    sf = run_async(cfg, init_swarm(cfg, 3), iters, sync_every=sync_every,
                   n_blocks=n_blocks)
    np.testing.assert_allclose(np.asarray(sf.pos), np.asarray(o.pos),
                               rtol=1e-4, atol=1e-5)
    assert float(sf.gbest_fit) == pytest.approx(float(o.gbest_fit),
                                                rel=1e-6)


def test_deb_improved_predicate():
    """The shared Deb mask: feasible beats infeasible regardless of fitness,
    two feasible compare on fitness, two infeasible on violation; strict
    comparisons keep the incumbent on ties."""
    from repro.core.constraints import deb_improved
    fit_n = jnp.asarray([5.0, 5.0, 1.0, 1.0, 5.0, 1.0])
    viol_n = jnp.asarray([0.0, 2.0, 0.0, 1.0, 3.0, 1.0])
    fit_o = jnp.asarray([1.0, 1.0, 5.0, 5.0, 1.0, 0.0])
    viol_o = jnp.asarray([1.0, 0.0, 0.0, 2.0, 2.0, 1.0])
    np.testing.assert_array_equal(
        np.asarray(deb_improved(fit_n, viol_n, fit_o, viol_o)),
        [True, False, False, True, False, False])
    # unconstrained degeneration: all-zero violations == raw fitness fold
    z = jnp.zeros_like(fit_n)
    np.testing.assert_array_equal(
        np.asarray(deb_improved(fit_n, z, fit_o, z)),
        np.asarray(fit_n > fit_o))


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock",
                                     "async"])
def test_deb_pbest_selection_keeps_feasible_pbests(variant):
    """Engine-level Deb rule (every jnp variant): on a repair-mode problem
    whose unconstrained optimum is infeasible, the raw fold would drive
    pbests out of the feasible set; with Deb selection no infeasible
    candidate ever displaces a feasible pbest, so the (feasible-at-init)
    pbest population stays feasible through the run."""
    p = _plane_ball()
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=p, w=0.7).resolved()
    vf = p.violation_fn
    s0 = init_swarm(cfg, 0)
    assert float(np.asarray(vf(s0.pbest_pos)).max()) <= 0.0   # feasible init
    s = solve(cfg, seed=0, iters=40, variant=variant)
    assert float(np.asarray(vf(s.pbest_pos)).max()) <= 0.0
    # ...and the rule actually bit: the final population holds infeasible
    # candidates whose raw fitness beats their (feasible) pbest — exactly
    # the swaps the old fold would have taken
    blocked = ((np.asarray(vf(s.pos)) > 0)
               & (np.asarray(s.fit) > np.asarray(s.pbest_fit)))
    assert blocked.any()


# --------------------------------------------------------------------------
# Pallas kernels: constrained problems through fused/async, vs the oracles
# --------------------------------------------------------------------------

def _oracle_inputs(cfg, seed):
    s0 = init_swarm(cfg, seed)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s0, cfg.dim)
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = cfg.dim
    fitness = kw.pop("fitness")
    return s0, (pos, vel, pbp, pbf, gp, float(gf[0])), fitness, kw


def test_constrained_problems_lower_by_conversion():
    assert is_converted(get_problem("sphere_simplex"))
    assert is_converted(get_problem("sphere_simplex_pen"))
    assert kernel_projection(get_problem("sphere_simplex")) is not None
    assert kernel_projection(get_problem("sphere_simplex_pen")) is None
    assert kernel_projection("sphere") is None
    # built-ins stay on the hand-tuned fast path
    assert not is_converted(get_problem("sphere"))


def test_registered_constrained_name_resolves_on_kernel_path():
    """A registered non-builtin STRING fitness must resolve through the
    registry on the kernel path (regression: it used to hit the
    hand-tuned ``_fitness_dmajor`` and raise NotImplementedError — and
    ``kernel_projection`` silently dropped the projection)."""
    assert kernel_projection("sphere_simplex") is not None
    assert is_converted("sphere_simplex_pen")
    cfg_s = PSOConfig(dim=4, particle_cnt=64,
                      fitness="sphere_simplex").resolved()
    cfg_p = PSOConfig(dim=4, particle_cnt=64,
                      fitness=get_problem("sphere_simplex")).resolved()
    a = ops.run_queue_lock_fused(cfg_s, init_swarm(cfg_s, 0), iters=6,
                                 block_n=32)
    b = ops.run_queue_lock_fused(cfg_p, init_swarm(cfg_p, 0), iters=6,
                                 block_n=32)
    assert np.array_equal(np.asarray(a.pos), np.asarray(b.pos))
    pos = np.asarray(a.pos)
    assert pos.min() >= 0.0                    # projection actually applied
    np.testing.assert_allclose(pos.sum(-1), 1.0, atol=1e-5)


@pytest.mark.parametrize("prob_name", ["sphere_simplex",
                                       "sphere_simplex_pen"])
def test_constrained_fused_kernel_single_block_bit_exact_vs_oracle(
        prob_name):
    prob = get_problem(prob_name)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=64)
    o = ref.run_fused_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                             8, 64, fitness=fitness, **kw)
    assert np.array_equal(np.asarray(ops.pack_dmajor(out.pos, 5)),
                          np.asarray(o[0]))
    assert float(out.gbest_fit) == float(o[5])
    # the penalized fitness VALUE can round an ulp apart between the
    # interpret program and the eager oracle even at bit-identical
    # positions (the violation-sum chain fuses differently); positions and
    # the gbest trajectory above are the bit-exact contract
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o[3])[0], rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("prob_name", ["sphere_simplex",
                                       "sphere_simplex_pen"])
def test_constrained_fused_kernel_multi_block_vs_oracle(prob_name):
    """Multi-block: same validation class as adapter-lowered customs —
    exact gbest trajectory value, ulp-tight positions (XLA:CPU
    fusion-context rounding; see ROADMAP kernel-batch caveat)."""
    prob = get_problem(prob_name)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=32)
    o = ref.run_fused_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                             8, 32, fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 5)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-6)
    assert float(out.gbest_fit) == pytest.approx(float(o[5]), rel=1e-6)


@pytest.mark.parametrize("prob_name,iters,sync_every,block_n",
                         [("sphere_simplex", 8, 4, 32),
                          ("sphere_simplex", 10, 4, 32),
                          ("sphere_simplex_pen", 8, 4, 32),
                          ("sphere_simplex_pen", 7, 7, 64)])
def test_constrained_async_kernel_vs_oracle(prob_name, iters, sync_every,
                                            block_n):
    prob = get_problem(prob_name)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused_async(cfg, s0, iters=iters,
                                         sync_every=sync_every,
                                         block_n=block_n)
    o = ref.run_fused_async_oracle(int(s0.seed), 0, pos, vel, pbp, pbf,
                                   gp, gf, iters, block_n, sync_every,
                                   fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 5)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-6)
    assert float(out.gbest_fit) == pytest.approx(float(o[5]), rel=1e-6)


def test_constrained_async_single_block_equals_fused_bitwise():
    """Kernel-to-kernel invariant (exact float equality): one block ⇒ the
    async kernel IS the fused kernel — for constrained problems too."""
    for prob_name in ("sphere_simplex", "sphere_simplex_pen"):
        prob = get_problem(prob_name)
        cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
        s0 = init_swarm(cfg, 1)
        f = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=64)
        for se in (1, 2, 4, 8):
            a = ops.run_queue_lock_fused_async(cfg, s0, iters=8,
                                               sync_every=se, block_n=64)
            assert np.array_equal(np.asarray(f.pos), np.asarray(a.pos))
            assert float(f.gbest_fit) == float(a.gbest_fit)


def test_constrained_kernel_projection_output_feasible():
    prob = get_problem("sphere_simplex")
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness=prob).resolved()
    s0 = init_swarm(cfg, 0)
    out = ops.run_queue_lock_fused(cfg, s0, iters=12, block_n=32)
    pos = np.asarray(out.pos)
    assert pos.min() >= 0.0
    np.testing.assert_allclose(pos.sum(-1), 1.0, atol=1e-5)


def test_constrained_kernel_repair_deb_pbest():
    """Kernel-level Deb rule: repair-mode ``_plane_ball`` through the fused
    kernel matches the (Deb-ized) d-major oracle bit-for-bit, and the pbest
    population stays feasible — the raw fold would have let the infeasible
    box corner displace feasible pbests. Gbest feasibility is NOT asserted:
    the kernel publishes from the current fitness (documented seam)."""
    p = _plane_ball()
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness=p, w=0.7).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 0)
    out = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=64)
    o = ref.run_fused_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                             8, 64, fitness=fitness, **kw)
    assert np.array_equal(np.asarray(ops.pack_dmajor(out.pos, 3)),
                          np.asarray(o[0]))
    assert float(out.gbest_fit) == float(o[5])
    assert float(np.asarray(p.violation_fn(out.pbest_pos)).max()) <= 0.0


def test_constrained_batched_kernel_row_matches_standalone():
    from repro.core.multi_swarm import init_batch, batch_row
    prob = get_problem("sphere_simplex_pen")
    cfg = PSOConfig(dim=4, particle_cnt=64, fitness=prob).resolved()
    batch = init_batch(cfg, np.asarray([0, 1, 2], np.int64))
    out = ops.run_queue_lock_fused_batch(cfg, batch, iters=6, block_n=32)
    lone = ops.run_queue_lock_fused(cfg, init_swarm(cfg, 1), iters=6,
                                    block_n=32)
    # adapter-lowered rows are ulp-tight vs standalone on XLA:CPU (same
    # class as test_facade_solve_many_kernel_backend)
    np.testing.assert_allclose(np.asarray(batch_row(out, 1).pos),
                               np.asarray(lone.pos), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(out.gbest_fit[1]),
                               float(lone.gbest_fit), rtol=1e-5)


# --------------------------------------------------------------------------
# Facade: feasibility reporting, Deb best(), history, ramp
# --------------------------------------------------------------------------

def test_result_feasibility_fields():
    res = repro.solve("sphere_simplex", dim=4, particles=128, iters=100,
                      seed=0, w=0.7, variant="queue_lock")
    assert res.feasible and res.violation == 0.0
    assert res.best_fit == pytest.approx(0.25, abs=1e-3)
    # unconstrained results are trivially feasible
    r2 = repro.solve("cubic", dim=1, particles=64, iters=20, seed=0)
    assert r2.feasible and r2.violation == 0.0 and r2.first_feasible_iter == 0


def test_deb_rule_best():
    feas_good = repro.solve("sphere_simplex", dim=4, particles=128,
                            iters=100, seed=0, w=0.7)
    feas_bad = repro.solve("sphere_simplex", dim=4, particles=8, iters=2,
                           seed=1, w=0.7)
    pen = repro.solve("sphere_simplex_pen", dim=4, particles=32, iters=3,
                      seed=2, w=0.7)
    assert feas_good.feasible and feas_bad.feasible
    # among feasible: fitness decides (regardless of infeasible entries)
    assert repro.best([feas_bad, pen, feas_good]) is feas_good
    if not pen.feasible:
        # all-infeasible: min violation decides
        pen2 = repro.solve("sphere_simplex_pen", dim=4, particles=256,
                           iters=150, seed=0, w=0.7)
        picked = repro.best([pen, pen2])
        assert picked.violation == min(pen.violation, pen2.violation)


def test_record_history_and_first_feasible():
    res = repro.solve("sphere_simplex", dim=4, particles=128, iters=60,
                      seed=0, w=0.7, variant="queue_lock",
                      record_history=True)
    h = res.history
    assert len(h) == 60
    assert np.array_equal(h.iteration, np.arange(1, 61))
    assert np.all(np.diff(h.gbest_fit) >= 0)          # gbest monotone
    assert float(h.gbest_fit[-1]) == res.gbest_fit
    assert res.first_feasible_iter == 1               # projected from init
    # async: one record per sync point + the tail
    ra = repro.solve("sphere_simplex_pen", dim=4, particles=64, iters=30,
                     seed=0, w=0.7, variant="async", sync_every=8,
                     record_history=True)
    assert list(ra.history.iteration) == [8, 16, 24, 30]
    assert ra.history.violation is not None
    # unconstrained history has no violation track
    ru = repro.solve("cubic", dim=1, particles=64, iters=10, seed=0,
                     variant="queue", record_history=True)
    assert ru.history.violation is None and len(ru.history) == 10


def test_record_history_identical_final_state():
    """History mode must not change the answer (async segmentation is the
    checkpoint-exact split; the scan records without re-steering)."""
    kw = dict(dim=4, particles=64, iters=40, seed=0, w=0.7)
    plain = repro.solve("sphere_simplex", variant="async", sync_every=8,
                        **kw)
    hist = repro.solve("sphere_simplex", variant="async", sync_every=8,
                       record_history=True, **kw)
    assert np.array_equal(np.asarray(plain.state.pos),
                          np.asarray(hist.state.pos))
    assert plain.gbest_fit == hist.gbest_fit


def test_record_history_validation():
    # the kernel backend records history now (chunked launches with a
    # gbest readback per sync point) — constructing the Method is legal
    Method(variant="queue_lock", backend="kernel", record_history=True)
    # islands stay genuinely unsupported: precise error
    with pytest.raises(ValueError, match="single-device"):
        Method(variant="queue", islands=1, record_history=True)
    # the batch engine surfaces per-row histories now
    rs = repro.solve_many("cubic", [0, 1], dim=1, particles=64, iters=5,
                          method=Method(record_history=True))
    assert all(r.history is not None and len(r.history) == 5 for r in rs)


def test_penalty_ramp_segments_and_improves_feasibility():
    cset = ConstraintSet(
        constraints=simplex_constraints(), mode="penalty",
        weight=1.0, ramp=4.0, ramp_every=50)
    ramped = Problem(name="simplex_ramp", fn=lambda x: jnp.sum(x * x, -1),
                     lo=0.0, hi=1.0, sense="min", constraints=cset)
    static = get_problem("sphere_simplex_pen")
    kw = dict(dim=6, particles=128, iters=200, seed=0, w=0.7,
              variant="queue_lock")
    r_ramp = repro.solve(ramped, record_history=True, **kw)
    r_stat = repro.solve(static, **kw)
    assert len(r_ramp.history) == 200            # segments concatenate
    assert r_ramp.violation <= r_stat.violation + 1e-6
    assert r_ramp.violation < 1e-3
    # ramp also rides solve_many (segmented batch engine)
    rs = repro.solve_many(ramped, [0, 1], dim=6, particles=64, iters=100,
                          w=0.7, variant="queue_lock")
    assert len(rs) == 2 and all(np.isfinite(r.best_fit) for r in rs)


def test_penalty_ramp_composes_with_islands():
    """The ramp now rides islands: one ``make_distributed_run`` per
    segment, carried fitness re-weighted at the boundaries. Ground truth
    is the manual per-segment composition — bit-identical."""
    import dataclasses
    import jax
    from repro.api import _reweight_state
    from repro.core.distributed import (init_sharded_swarm,
                                        make_distributed_run)
    cset = ConstraintSet(
        constraints=simplex_constraints(), mode="penalty",
        weight=1.0, ramp=4.0, ramp_every=50)
    ramped = Problem(name="simplex_ramp_i", fn=lambda x: jnp.sum(x * x, -1),
                     lo=0.0, hi=1.0, sense="min", constraints=cset)
    m = Method(variant="queue", islands=1)
    r = repro.solve(ramped, dim=6, particles=64, iters=100, w=0.7, method=m)
    assert np.isfinite(r.best_fit)
    mesh = jax.make_mesh((1,), ("data",))
    st = init_sharded_swarm(r.config, 0, mesh)
    for k, wgt in enumerate([1.0, 4.0]):
        cfg_k = dataclasses.replace(
            r.config, fitness=ramped.with_penalty_weight(wgt))
        if k:
            st = _reweight_state(cfg_k, st)
        st = make_distributed_run(cfg_k, mesh, iters=50, variant="queue",
                                  exchange_interval=m.exchange_interval)(st)
    assert float(st.gbest_fit) == float(r.state.gbest_fit)
    np.testing.assert_array_equal(np.asarray(st.pos), np.asarray(r.state.pos))
    np.testing.assert_array_equal(np.asarray(st.gbest_pos),
                                  np.asarray(r.state.gbest_pos))
    # the async ring re-seeds its block locals at each segment boundary
    # (the reweight drops them) — the composition must run end to end
    ra = repro.solve(ramped, dim=6, particles=64, iters=100, w=0.7,
                     method=Method(variant="async", islands=1))
    assert np.isfinite(ra.best_fit) and ra.state.lbest_fit is not None


def test_solve_many_feasibility_roundtrip():
    rs = repro.solve_many("sphere_simplex", [0, 1, 2], dim=4, particles=64,
                          iters=80, w=0.7, variant="queue")
    for r in rs:
        assert r.feasible and r.violation == 0.0
    lone = repro.solve("sphere_simplex", dim=4, particles=64, iters=80,
                       seed=1, w=0.7, variant="queue")
    assert rs[1].gbest_fit == pytest.approx(lone.gbest_fit, rel=1e-6)


# --------------------------------------------------------------------------
# Serving + tuner + CLI
# --------------------------------------------------------------------------

def test_serve_constraint_aware_batch_keys_and_results():
    from repro.launch.serve import SolveRequest, SolveServer

    p = get_problem("sphere_simplex_pen")
    a = SolveRequest(dim=4, particle_cnt=64, fitness=p)
    b = SolveRequest(dim=4, particle_cnt=64,
                     fitness=p.with_penalty_weight(99.0))
    assert a.batch_key != b.batch_key            # weight is content
    c = SolveRequest(dim=4, particle_cnt=64, fitness="sphere_simplex_pen")
    assert a.batch_key == c.batch_key            # name == object spelling
    srv = SolveServer(backend="jnp")
    out = srv.solve_all([SolveRequest(dim=4, particle_cnt=64, fitness=p,
                                      seed=i, iters=40, variant="queue")
                         for i in range(5)])
    assert srv.stats.dispatches == 1             # one compile group
    for r in out:
        assert isinstance(r.feasible, bool)
        assert r.violation >= 0.0
        assert r.objective == -r.gbest_fit       # sense="min" reporting


def test_tuner_with_constrained_problem():
    from repro.core.tuner import (PSO_COEFF_DIMS, PSOTuner,
                                  make_solve_many_fitness)

    cfg = PSOConfig(dim=4, particle_cnt=32,
                    fitness=get_problem("sphere_simplex"))
    bf = make_solve_many_fitness(cfg, seeds=[0, 1], iters=10)
    tuner = PSOTuner(PSO_COEFF_DIMS, particles=3, seed=0)
    res = tuner.run(batch_fitness=bf, iters=2)
    assert np.isfinite(res.best_fitness)
    assert res.best_fitness <= 0.0               # canonical max of -||x||^2


def test_cli_constraint_parsing_helpers():
    c = constraint_from_spec("norm(x)<=2.5")
    assert c.kind == "ineq"
    assert float(c.violation(jnp.asarray([3.0, 4.0]))) == pytest.approx(2.5)
    c2 = constraint_from_spec("min(x)>=0")
    assert float(c2.violation(jnp.asarray([-0.5, 1.0]))) == pytest.approx(0.5)
    c3 = constraint_from_spec("sum(x)==1")
    assert c3.kind == "eq"
    with pytest.raises(ValueError, match="cannot parse"):
        constraint_from_spec("x[0]<=1")
    with pytest.raises(ValueError, match="simplex"):
        constraint_set_from_cli(["sum(x)<=1"], mode="projection")
    cs = constraint_set_from_cli(["simplex"], mode="projection")
    assert cs.projection is project_simplex
    p = constrain_problem("sphere", cs)
    assert p.constrained and p.name == "sphere_constrained"


def test_pso_run_cli_constrained():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.pso_run", "--dim", "3",
         "--particles", "64", "--iters", "30", "--fitness", "sphere",
         "--constraint", "simplex", "--constraint-mode", "projection"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "feasible=True" in r.stdout
    assert "violation=" in r.stdout
    # registered constrained built-in by name
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.pso_run", "--dim", "3",
         "--particles", "64", "--iters", "20", "--fitness",
         "sphere_simplex_pen"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "violation=" in r2.stdout


def test_distributed_constrained_problem():
    import jax
    from repro.core.distributed import (init_sharded_swarm,
                                        make_distributed_run)

    mesh = jax.make_mesh((1,), ("data",))
    cfg = PSOConfig(dim=4, particle_cnt=64,
                    fitness=get_problem("sphere_simplex"), w=0.7)
    state = init_sharded_swarm(cfg.resolved(), 0, mesh)
    runner = make_distributed_run(cfg.resolved(), mesh, iters=30,
                                  variant="queue", exchange_interval=5)
    out = runner(state)
    pos = np.asarray(out.pos)
    assert pos.min() >= 0.0                       # projection held on-shard
    np.testing.assert_allclose(pos.sum(-1), 1.0, atol=1e-5)


def test_history_run_with_history_matches_plain_run_async():
    """Core-level: async history segmentation is the checkpoint-exact
    split (bit-identical final state to the uninterrupted run)."""
    cfg = PSOConfig(dim=5, particle_cnt=64,
                    fitness=get_problem("sphere_simplex_pen")).resolved()
    s0 = init_swarm(cfg, 0)
    plain = run_async(cfg, s0, 22, sync_every=4)
    st, (its, fits, viols) = run_with_history(cfg, s0, 22, "async",
                                              sync_every=4)
    assert np.array_equal(np.asarray(plain.pos), np.asarray(st.pos))
    assert float(plain.gbest_fit) == float(st.gbest_fit)
    assert its == (4, 8, 12, 16, 20, 22)
    assert float(fits[-1]) == float(st.gbest_fit)
