"""Distributed PSO: sharded-init equivalence, island semantics, elastic
resharding. Single CPU device here: meshes are (1,)-shaped, which still
exercises shard_map plumbing, specs and collectives end-to-end; the 512-way
versions are exercised by launch/dryrun.py (--pso)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PSOConfig, init_swarm, run
from repro.core.distributed import (gather_swarm, init_sharded_swarm,
                                    make_distributed_run)


def _mesh():
    return jax.make_mesh((1,), ("data",))


def test_sharded_init_matches_monolithic():
    cfg = PSOConfig(dim=7, particle_cnt=128, fitness="ackley").resolved()
    mesh = _mesh()
    sh = init_sharded_swarm(cfg, 11, mesh)
    mono = init_swarm(cfg, 11)
    np.testing.assert_allclose(np.asarray(sh.pos), np.asarray(mono.pos),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(sh.gbest_fit), float(mono.gbest_fit),
                               rtol=1e-6)


def test_sync_distributed_equals_single_device():
    """exchange_interval=1 on a 1-shard mesh ≡ the plain queue variant."""
    cfg = PSOConfig(dim=4, particle_cnt=64, fitness="sphere").resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 0, mesh)
    runner = make_distributed_run(cfg, mesh, iters=25, variant="queue",
                                  exchange_interval=1)
    out = runner(st)
    ref = run(cfg, init_swarm(cfg, 0), 25, "queue")
    # atol: the shard_map program fuses differently from the plain path, and
    # 1-ulp arithmetic differences compound over 25 chaotic iterations.
    np.testing.assert_allclose(np.asarray(out.pos), np.asarray(ref.pos),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(out.gbest_fit), float(ref.gbest_fit),
                               rtol=1e-4)


@pytest.mark.parametrize("exchange", [5, 25])
def test_island_mode_converges(exchange):
    cfg = PSOConfig(dim=10, particle_cnt=128, fitness="rastrigin",
                    w=0.72).resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 2, mesh)
    f0 = float(st.gbest_fit)
    runner = make_distributed_run(cfg, mesh, iters=100, variant="queue",
                                  exchange_interval=exchange)
    out = runner(st)
    assert float(out.gbest_fit) > f0
    assert float(out.gbest_fit) > -50.0       # near the rastrigin optimum 0


def test_elastic_reshard_checkpoint(tmp_path):
    """Swarm checkpointed from a sharded run restores into a monolithic
    swarm (device-count change) with identical state."""
    from repro import checkpoint as ckpt
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="cubic").resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 4, mesh)
    runner = make_distributed_run(cfg, mesh, iters=10, variant="queue",
                                  exchange_interval=5)
    st = runner(st)
    ckpt.save(str(tmp_path), 10, gather_swarm(st))
    _, restored = ckpt.restore_latest(
        str(tmp_path), jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st))
    # "new cluster": continue on plain single-device path
    from repro.core.pso import SwarmState
    cont = run(cfg, SwarmState(*restored), 10, "queue")
    assert np.isfinite(float(cont.gbest_fit))
    assert float(cont.gbest_fit) >= float(st.gbest_fit)


def test_kernel_local_step_in_distributed():
    """Fused Pallas kernel as the shard-local step under shard_map."""
    from repro.kernels.ops import make_fused_local_step
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="sphere").resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 6, mesh)
    runner = make_distributed_run(
        cfg, mesh, iters=4, variant="queue", exchange_interval=2,
        local_step_fn=make_fused_local_step(iters_per_call=1))
    out = runner(st)
    assert float(out.gbest_fit) >= float(st.gbest_fit)
    assert not np.any(np.isnan(np.asarray(out.pos)))
