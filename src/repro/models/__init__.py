from . import attention, encdec, layers, moe, ssm, transformer, zoo

__all__ = ["attention", "encdec", "layers", "moe", "ssm", "transformer",
           "zoo"]
