"""Benchmark harness — one function per paper table/figure.

  table3  — 1D problem: execution time of the five implementations
            (CPU serial, Reduction, Loop-unrolled*, Queue, Queue-Lock)
            across particle counts (paper Table 3 / Fig. 3).
  table4  — 1D speedup of Queue-Lock vs CPU serial (paper Table 4).
  table5  — 120D speedup of Queue vs CPU serial (paper Table 5).
  multi_swarm — batched engine: S independent solves via ONE solve_many
            device program vs a Python loop of solve() (swarms/sec).
  lm_bench— LM substrate micro-bench (tokens/s on the smoke configs).

This container is CPU-only, so the "GPU" columns run the same JAX
algorithms on the CPU backend, jit-compiled, and the Pallas kernels run in
interpret mode (which measures *semantics*, not TPU silicon). Relative
orderings therefore reflect algorithmic work (the paper's claim), while
absolute numbers are CPU numbers — EXPERIMENTS.md §Benchmarks discusses
the mapping onto the paper's GTX-1080Ti results.

*Loop-unrolled on TPU: the CUDA unrolling trick has no TPU counterpart
(DESIGN.md §2); the reduction variant is its closest analogue and is
reported once.

Output: ``name,us_per_call,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import time

import jax
import numpy as np

ITERS_1D = 2000           # paper uses 100k; scaled for CPU wall-time — the
REPEATS = 3               # us/iter metric is iteration-count invariant


def _time(fn, repeats=REPEATS):
    fn()                                  # warmup / compile
    ts = []
    for _ in range(repeats + 2):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    if len(ts) > 2:
        ts = sorted(ts)[1:-1]             # drop min/max (paper §6.1)
    return float(np.mean(ts))


def _pso_variants(dim: int, particles: int, iters: int):
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    from repro.kernels.ops import run_queue_lock_fused
    cfg = PSOConfig(dim=dim, particle_cnt=particles,
                    fitness="cubic").resolved()
    s0 = init_swarm(cfg, 0)
    out = {}
    out["cpu_serial"] = _time(lambda: run_serial_fast(cfg, 0, iters),
                              repeats=1)
    for variant in ("reduction", "queue", "queue_lock"):
        out[variant] = _time(lambda v=variant: jax.block_until_ready(
            run(cfg, s0, iters, v).gbest_fit))
    # fused Pallas queue-lock kernel (interpret mode: semantics on CPU)
    kiters = min(iters, 50)               # interpret mode is a python loop
    t = _time(lambda: jax.block_until_ready(
        run_queue_lock_fused(cfg, s0, iters=kiters).gbest_fit), repeats=1)
    out["queue_lock_pallas_interp"] = t * (iters / kiters)
    return out


def table3() -> None:
    """1D problem across particle counts (paper Table 3)."""
    iters = ITERS_1D
    for particles in (32, 64, 128, 256, 512, 1024, 2048):
        res = _pso_variants(1, particles, iters)
        base = res["cpu_serial"]
        for name, t in res.items():
            us = 1e6 * t / iters
            print(f"table3/p{particles}/{name},{us:.3f},"
                  f"speedup_vs_serial={base / t:.2f}")


def table4() -> None:
    """Queue-Lock speedup scaling, 1D (paper Table 4)."""
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    iters = ITERS_1D // 2
    for particles in (128, 512, 2048, 8192, 32768, 131072):
        cfg = PSOConfig(dim=1, particle_cnt=particles).resolved()
        s0 = init_swarm(cfg, 0)
        t_cpu = _time(lambda: run_serial_fast(cfg, 0, iters), repeats=1)
        t_ql = _time(lambda: jax.block_until_ready(
            run(cfg, s0, iters, "queue_lock").gbest_fit))
        print(f"table4/p{particles}/queue_lock,{1e6*t_ql/iters:.3f},"
              f"speedup={t_cpu/t_ql:.2f}")


def table5() -> None:
    """Queue speedup scaling, 120D (paper Table 5)."""
    from repro.core import PSOConfig, init_swarm, run, run_serial_fast
    for particles, iters in ((128, 200), (1024, 150), (8192, 100),
                             (32768, 50)):
        cfg = PSOConfig(dim=120, particle_cnt=particles).resolved()
        s0 = init_swarm(cfg, 0)
        t_cpu = _time(lambda: run_serial_fast(cfg, 0, iters), repeats=1)
        t_q = _time(lambda: jax.block_until_ready(
            run(cfg, s0, iters, "queue").gbest_fit))
        print(f"table5/p{particles}/queue,{1e6*t_q/iters:.3f},"
              f"speedup={t_cpu/t_q:.2f}")


def convergence_equivalence() -> None:
    """The queue variants must match reduction's answer (paper §4.1) —
    report final gbest per variant on the paper's two problems."""
    from repro.core import PSOConfig, solve
    for dim, iters in ((1, 1000), (120, 500)):
        vals = {}
        for v in ("reduction", "queue", "queue_lock"):
            s = solve(PSOConfig(dim=dim, particle_cnt=1024), seed=0,
                      iters=iters, variant=v)
            vals[v] = float(s.gbest_fit)
        spread = max(vals.values()) - min(vals.values())
        print(f"equiv/{dim}d/gbest_spread,{spread:.6g},"
              f"gbest={vals['queue']:.6g}")


def multi_swarm() -> None:
    """Batched multi-swarm engine vs loop-of-solve (swarms/sec).

    The loop baseline compiles once (cfg/iters static) and pays per-solve
    dispatch + eager init; solve_many pays one dispatch for the whole batch.
    Note the 1D/tiny-swarm regime can favor the loop on CPU: vmap turns the
    queue variant's rare-improvement ``cond`` into an always-both-branches
    ``select``, so batching wins where per-dispatch overhead and vector
    width dominate (realistic dims / particle counts), not on toy shapes.
    """
    import jax
    from repro.core import PSOConfig, solve, solve_many
    for dim, particles, s_cnt, iters in ((10, 256, 8, 200),
                                         (10, 256, 16, 200),
                                         (10, 1024, 32, 100)):
        cfg = PSOConfig(dim=dim, particle_cnt=particles, fitness="rastrigin")
        seeds = list(range(s_cnt))
        t_loop = _time(lambda: [jax.block_until_ready(
            solve(cfg, sd, iters, "queue").gbest_fit) for sd in seeds],
            repeats=1)
        t_batch = _time(lambda: jax.block_until_ready(
            solve_many(cfg, seeds, iters, "queue").gbest_fit), repeats=1)
        tag = f"multi_swarm/d{dim}_n{particles}_s{s_cnt}"
        print(f"{tag}/loop_of_solve,{1e6 * t_loop:.1f},"
              f"swarms_per_s={s_cnt / t_loop:.2f}")
        print(f"{tag}/solve_many,{1e6 * t_batch:.1f},"
              f"swarms_per_s={s_cnt / t_batch:.2f},"
              f"speedup_vs_loop={t_loop / t_batch:.2f}")


def lm_bench() -> None:
    """LM substrate: smoke-config train-step tokens/s per arch family."""
    from repro.configs import get_arch
    from repro.launch.steps import make_train_step
    from repro.models import zoo
    for arch in ("stablelm-3b", "phi3.5-moe-42b-a6.6b", "hymba-1.5b",
                 "xlstm-350m", "whisper-small"):
        cfg = get_arch(arch).smoke()
        params = zoo.init_params(cfg, jax.random.key(0))
        step, opt_init = make_train_step(cfg)
        opt = opt_init(params)
        jstep = jax.jit(step)
        b, s = 4, 128
        batch = zoo.make_batch(cfg, "train_4k", b, s, jax.random.key(1))
        t = _time(lambda: jax.block_until_ready(
            jstep(params, opt, batch)[2]["loss"]))
        toks = b * s
        print(f"lm/{arch}/train_step,{1e6*t:.1f},tokens_per_s={toks/t:.0f}")


def main() -> None:
    print("name,us_per_call,derived")
    convergence_equivalence()
    table3()
    table4()
    table5()
    multi_swarm()
    lm_bench()


if __name__ == "__main__":
    main()
