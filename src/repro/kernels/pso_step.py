"""Pallas TPU kernels for cuPSO (DESIGN.md §2) — one scaffold, seven calls.

Layout — SoA, D-major (the paper's §5.1 coalescing rule, translated):
arrays are ``[Dpad, N]`` with the *particle* index on the 128-wide lane
dimension and the problem dimension on sublanes (padded to a multiple of 8).
A VPU lane plays the role of a CUDA thread: all lanes touch consecutive
particles of the same dimension — Fig. 2 of the paper, verbatim, in TPU tile
terms. For D=1 this packs 16× denser than a dim-on-lanes layout.

Scaffold vs update rule
-----------------------
The paper's contribution is the queue-lock *aggregation* scaffold — grids,
the intra-block candidate queue, block-local bests, sparse publication —
which is orthogonal to the per-particle *update rule*. This module keeps
exactly ONE copy of that scaffold: two generators,

``_make_sync_kernel(queue=..., batched=..., hetero=...)``
    emits the synchronous bodies (one advance + pbest fold + publication
    per grid step) — ``_queue_kernel``, ``_fused_kernel``,
    ``_fused_batch_kernel`` and ``_hetero_fused_batch_kernel`` are its four
    instantiations;

``_make_async_kernel(batched=..., hetero=...)``
    emits the block-resident asynchronous bodies (``sync_every``-iteration
    chunks against a block-local best, shared-best pull at chunk entry and
    predicated publish at chunk exit) — ``_fused_async_kernel``,
    ``_fused_async_batch_kernel`` and ``_hetero_fused_async_batch_kernel``
    are its three instantiations.

Every body closes over an ``repro.core.update_rules.UpdateRule`` — the
algorithm half. The default ``"pso"`` rule reproduces the pre-refactor
``_advance_block`` velocity/position chain bit-for-bit (pinned by the
trajectory digests in tests/test_problem.py); ``"sso"`` and ``"lowcost"``
ride the same scaffold with zero kernel-side changes, validated per
``(rule, variant)`` against the matching ``ref.py`` oracles in
tests/test_update_rules.py. Cross-cutting features (constraints, per-dim
bounds, hetero dispatch) are now threaded through the scaffold once instead
of through seven hand-maintained bodies.

The async builders additionally take a block-neighborhood ``topology``
(``"gbest"`` | ``"ring"`` | ``"vonneumann"``, see ``repro.core.topology``):
with an lbest topology a block refreshes its chunk-entry local best from
its *neighbor blocks'* local slots (``kernel_neighbor_ids``) instead of the
shared gbest, which is still flushed at chunk exit for monitoring and the
final answer. ``topology="gbest"`` compiles the exact pre-refactor pull.

The seven pallas_call builders:

``queue`` (single iteration, grid = particle blocks)
    The paper's §4.1 two-kernel structure. Kernel 1 advances particles,
    evaluates fitness, updates pbest, and publishes a per-block
    ``(aux_fit, aux_idx)`` candidate — computed as a *masked* max over only
    the lanes that improve on the stale gbest (the SIMD degeneration of the
    shared-memory queue: membership mask == queue, one vectorized max ==
    thread-0's scan). The "2nd kernel" (cross-block argmax + conditional
    gbest update) is a tiny jnp epilogue in ``ops.py`` operating on
    ``nblocks`` scalars. Only the particle *index* is published, never the
    D-dim position (paper §5.3): the position is gathered once, after the
    cross-block winner is known.

``fused_batch`` (queue-lock, grid = (swarms, iterations, particle blocks))
    The multi-swarm extension of ``fused``: one ``pallas_call`` advances S
    *independent* swarms x iters. State is packed ``[Dpad, S*N]`` (swarm s
    owns columns [s*N, (s+1)*N)); each swarm has its own gbest column in a
    ``[Dpad, S]`` buffer, its own SMEM gbest_fit slot, and its own
    ``(seed, iteration)`` RNG counters, so swarm s is bit-identical to a
    standalone ``fused`` run with the same seed and block size. The grid is
    swarm-major: a swarm's gbest buffers stay resident across all its
    iterations before the next swarm is touched. This is the kernel behind
    ``repro.kernels.ops.run_queue_lock_fused_batch`` and the Pallas leg of
    ``repro.core.multi_swarm.solve_many``.

``fused`` (queue-lock, grid = (iterations, particle blocks))
    The paper's §4.2 fusion, strengthened: ONE ``pallas_call`` spans *all*
    iterations. The global best lives in output buffers whose block index is
    constant across the grid, so (a) on TPU they are fetched/flushed once,
    not per step, and (b) sequential grid execution serializes every block's
    conditional publication — the atomicCAS spin-lock costs literally
    nothing. State arrays are input/output-aliased, so the swarm never
    round-trips to HBM between iterations when the block count is 1.
    Semantics: block b at iteration t sees the gbest already updated by
    blocks 0..b-1 of iteration t (fresher than synchronous PPSO; mirrored
    exactly by ``ref.run_fused_oracle``).

``fused_async`` (async queue-lock, grid = (particle blocks, iter chunks))
    The paper's *enhanced* queue-lock: thread groups run asynchronously and
    update the shared best only occasionally. The grid is the TRANSPOSE of
    ``fused`` — block-major — so each particle block stays resident (state
    tile fetched/flushed once for its entire iteration span, not per
    iteration) and runs ``sync_every`` iterations per grid step against a
    *block-local* best carried in the fori-loop registers and persisted in
    small ``[Dpad, nb]``/SMEM ``[nb]`` side buffers. The shared ``[Dpad,1]``
    + SMEM gbest is touched only at chunk boundaries: a pull (read) at chunk
    entry and a predicated publish (write) at chunk exit — the lock
    acquisition shrinks from every (iteration x block) to every
    ``sync_every`` iterations, and the rare-improvement predicate usually
    skips the write entirely.

    Consistency model: a block's view of the swarm-wide best is at most
    ``sync_every`` iterations stale, and (block-major order) block b
    additionally inherits everything blocks 0..b-1 published during their
    whole span. With a single block the local best IS the global best, so
    the trajectory is bit-identical to ``fused`` for every ``sync_every``
    (the sync kernel is the async kernel's special case); with several
    blocks the schedule is genuinely relaxed and is mirrored bit-exactly by
    ``ref.run_fused_async_oracle``. ``fused_async_batch`` adds the leading
    swarm axis (grid (swarms, blocks, chunks)) with per-swarm gbest buffers
    and per-(swarm, block) local-best slots.

Objectives: every kernel takes ``fitness`` as a registered name or a
``repro.core.problem.Problem``. Names and built-in Problems select the
hand-tuned ``_fitness_dmajor`` forms below (bit-identical to the
pre-Problem-API kernels); any other Problem is lowered automatically by
``dmajor_adapter`` (transpose into the user's ``[bn, d]`` view) with its
captured array constants hoisted into explicit pallas_call operands by
``lower_statics`` — Pallas forbids captured consts — and its advance
outputs pinned via ``optimization_barrier`` so interpret-mode runs stay
bit-comparable to the eager oracles (see ``_resolve_statics``).
Per-dimension bounds ride the same const-threading as ``[Dpad, 1]``
columns.

Constraints: a Problem carrying a ``repro.core.constraints.ConstraintSet``
always lowers by conversion — ``penalty`` mode is invisible here (the
penalty rides ``Problem.max_fn`` like any custom objective), ``projection``
mode adds a pinned post-clip transform inside ``_advance_block``
(``kernel_projection`` lifts the user operator to the d-major tile layout;
its captured consts hoist through ``lower_statics`` exactly like objective
consts), and ``repair`` mode only affects ``init_swarm`` (kernels receive
an already-repaired state). For ``projection``/``repair`` modes the pbest
fold inside every kernel body applies the Deb rule (feasible > fitness >
violation, ``repro.core.constraints.deb_improved``) via the d-major
``kernel_violation`` form — the same engine-level gate as
``repro.core.pso.deb_selection_fn``; ``penalty`` mode and unconstrained
problems keep the raw ``fit > pbest`` fold bit-for-bit.

Validated in ``interpret=True`` mode against ``ref.py`` (same counter RNG ⇒
bit-exact trajectories) over shape/dtype sweeps in tests/test_kernels.py
and tests/test_async.py; custom-objective parity in tests/test_problem.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng
from repro.core.blocking import LANE
from repro.core.constraints import deb_improved
from repro.core.pso import STREAM_R1, STREAM_R2
from repro.core.problem import Problem
from repro.core.topology import kernel_neighbor_ids
from repro.core.update_rules import resolve_rule

from .compat import CompilerParams as _CompilerParams

SUBLANE = 8
_BIG_I32 = np.int32(2 ** 30)


def pad_dim(d: int) -> int:
    return max(SUBLANE, -(-d // SUBLANE) * SUBLANE)


# --------------------------------------------------------------------------
# In-kernel fitness (D-major layout): pos [Dpad, bn] -> fit [1, bn].
# Padded sublanes are masked. Must match repro.core.fitness row-for-row.
# --------------------------------------------------------------------------

def _fitness_dmajor(name: str, pos, dmask, d_real: int):
    zero = jnp.zeros_like(pos)
    if name == "cubic":
        v = pos * pos * pos - 0.8 * (pos * pos) - 1000.0 * pos + 8000.0
        return jnp.sum(jnp.where(dmask, v, zero), axis=0, keepdims=True)
    if name == "sphere":
        return -jnp.sum(jnp.where(dmask, pos * pos, zero), axis=0, keepdims=True)
    if name == "rastrigin":
        v = pos * pos - 10.0 * jnp.cos(2.0 * jnp.pi * pos)
        s = jnp.sum(jnp.where(dmask, v, zero), axis=0, keepdims=True)
        return -(10.0 * d_real + s)
    if name == "griewank":
        dsub = lax.broadcasted_iota(jnp.float32, pos.shape, 0) + 1.0
        s = jnp.sum(jnp.where(dmask, pos * pos, zero), axis=0, keepdims=True) / 4000.0
        c = jnp.cos(pos / jnp.sqrt(dsub))
        p = jnp.prod(jnp.where(dmask, c, jnp.ones_like(c)), axis=0, keepdims=True)
        return -(s - p + 1.0)
    if name == "ackley":
        s1 = jnp.sqrt(jnp.sum(jnp.where(dmask, pos * pos, zero), axis=0,
                              keepdims=True) / d_real)
        c = jnp.cos(2.0 * jnp.pi * pos)
        s2 = jnp.sum(jnp.where(dmask, c, zero), axis=0, keepdims=True) / d_real
        return -(-20.0 * jnp.exp(-0.2 * s1) - jnp.exp(s2) + 20.0 + jnp.e)
    if name == "rosenbrock":
        if d_real == 1:          # library convention: degenerates to -(1-x)^2
            v = (1.0 - pos) * (1.0 - pos)
            return -jnp.sum(jnp.where(dmask, v, zero), axis=0, keepdims=True)
        # Coupled-dim sum over pairs (x_i, x_{i+1}): shift the sublane axis
        # down by one so every row i also sees row i+1. The wrapped row is
        # masked out (pairs exist only for i < d_real - 1).
        nxt = jnp.concatenate([pos[1:], pos[:1]], axis=0)
        dsub = lax.broadcasted_iota(jnp.int32, pos.shape, 0)
        pair_mask = dsub < (d_real - 1)
        v = (100.0 * (nxt - pos * pos) * (nxt - pos * pos)
             + (1.0 - pos) * (1.0 - pos))
        return -jnp.sum(jnp.where(pair_mask, v, zero), axis=0, keepdims=True)
    raise NotImplementedError(f"kernel fitness {name!r}")


KERNEL_FITNESS = ("cubic", "sphere", "rastrigin", "griewank", "ackley",
                  "rosenbrock")


def dmajor_adapter(fn):
    """Lift a pure-jnp objective ``fn(pos[..., D]) -> fit[...]`` into the
    masked d-major kernel layout ``(pos [Dpad, bn], dmask, d_real) ->
    fit [1, bn]``.

    The padded sublanes are removed by a static slice (they are already
    zero-masked by ``_advance_block``, but slicing means ``fn`` never sees
    them at all — no masking contract is imposed on user objectives), then
    the tile is transposed so ``fn`` receives its documented particle-major
    ``[bn, d]`` view. This is what lets ANY registered/user Problem run
    inside the fused, async and batched Pallas kernels without a
    hand-written d-major form; the six built-ins keep their hand-tuned
    ``_fitness_dmajor`` forms as fast paths (transpose-free), parity-tested
    against this adapter in tests/test_problem.py.
    """
    def lifted(pos, dmask, d_real):
        del dmask
        return fn(pos[:d_real, :].T)[None, :]
    lifted.__name__ = f"dmajor[{getattr(fn, '__name__', 'fn')}]"
    return lifted


def kernel_fitness(fitness):
    """Resolve a config's ``fitness`` (str | Problem) to the in-kernel
    d-major callable ``(pos, dmask, d_real) -> [1, bn]`` in canonical
    (maximization) form.

    Built-in names and built-in Problems take the hand-tuned
    ``_fitness_dmajor`` fast path (bit-identical to the pre-Problem-API
    kernels); any other registered name resolves through the registry
    first (a registered custom/constrained problem is addressable by
    string everywhere, including the kernel backend); a Problem with a
    user ``kernel_fn`` uses it verbatim (it must already be
    canonical-max, see ``repro.core.problem``); any other Problem is
    lowered by ``dmajor_adapter``.
    """
    if isinstance(fitness, str):
        if fitness in KERNEL_FITNESS:
            return functools.partial(_fitness_dmajor, fitness)
        from repro.core.problem import get_problem
        fitness = get_problem(fitness)
    if not isinstance(fitness, Problem):
        raise TypeError(f"fitness must be str or Problem, got {fitness!r}")
    if fitness.kernel_fn is not None:
        return fitness.kernel_fn
    from repro.core.fitness import FITNESS_FNS
    if (fitness.sense == "max" and fitness.name in KERNEL_FITNESS
            and fitness.fn is FITNESS_FNS.get(fitness.name)
            and fitness.constraints is None):
        # constrained problems never take the hand-tuned fast path: the
        # penalty must ride max_fn, and projection-mode advances must be
        # pinned like any converted objective (see _resolve_statics).
        return functools.partial(_fitness_dmajor, fitness.name)
    return dmajor_adapter(fitness.max_fn)


def kernel_projection(fitness):
    """Resolve a Problem's feasibility projection to the d-major tile form
    ``pos [Dpad, bn] -> pos [Dpad, bn]`` (padded sublanes re-zeroed), or
    None when the objective has no projection-mode constraints.

    Mirrors ``dmajor_adapter``: the user operator sees its documented
    particle-major ``[bn, d]`` view. Applied AFTER the box clip inside
    ``_advance_block`` (the box-clip composition); its captured array
    constants are hoisted into pallas_call operands by ``lower_statics``
    exactly like objective consts.
    """
    if isinstance(fitness, str):
        if fitness in KERNEL_FITNESS:
            return None                        # built-ins are box-only
        from repro.core.problem import get_problem
        fitness = get_problem(fitness)
    if not isinstance(fitness, Problem):
        return None
    proj = fitness.projection_fn
    if proj is None:
        return None

    def lifted(pos, d_real):
        dpad, bn = pos.shape
        out = proj(pos[:d_real, :].T).T            # [d_real, bn]
        if dpad == d_real:
            return out
        return jnp.concatenate(
            [out, jnp.zeros((dpad - d_real, bn), pos.dtype)], axis=0)

    lifted.__name__ = f"dmajor_proj[{getattr(proj, '__name__', 'fn')}]"
    return lifted


def kernel_violation(fitness):
    """Resolve a Problem's aggregate constraint violation to the d-major
    tile form ``(pos [Dpad, bn], d_real) -> viol [1, bn]``, or None when
    Deb-rule pbest selection does not apply (unconstrained problems and
    ``penalty`` mode, whose penalty already rides ``max_fn``).

    Drives the kernels' constrained pbest fold (feasible > fitness >
    violation — ``repro.core.constraints.deb_improved``, the same gate as
    the jnp engine's ``deb_selection_fn``). Mirrors ``dmajor_adapter``:
    the user constraint functions see their documented particle-major
    ``[bn, d]`` view; captured array constants hoist through
    ``lower_statics`` exactly like objective/projection consts.
    """
    if isinstance(fitness, str):
        if fitness in KERNEL_FITNESS:
            return None                        # built-ins are unconstrained
        from repro.core.problem import get_problem
        fitness = get_problem(fitness)
    if not isinstance(fitness, Problem):
        return None
    if not fitness.constrained or fitness.constraints.mode == "penalty":
        return None
    vf = fitness.violation_fn

    def lifted(pos, d_real):
        return vf(pos[:d_real, :].T)[None, :]

    lifted.__name__ = f"dmajor_viol[{fitness.name}]"
    return lifted


def _pbest_improved(fit, pos, pbf, pbp, viol):
    """The kernels' pbest-selection mask [1, bn]: raw fitness compare, or
    the Deb rule when a ``kernel_violation`` form is present (projection /
    repair constraint modes)."""
    if viol is None:
        return fit > pbf
    return deb_improved(fit, viol(pos), pbf, viol(pbp))


def is_converted(fitness) -> bool:
    """True when ``kernel_fitness`` lowers ``fitness`` by conversion (the
    d-major adapter or a user ``kernel_fn``) rather than the hand-tuned
    ``_fitness_dmajor`` forms. Converted kernels pin their advance outputs
    (see ``_resolve_statics``); ``ref.py`` keys its matching behavior on
    this predicate."""
    return getattr(kernel_fitness(fitness), "func", None) is not _fitness_dmajor


def _bound_col(v, dpad, dtype):
    """Bound -> kernel operand: scalars stay Python floats (the seed
    arithmetic, bit-for-bit); per-dimension tuples become a [Dpad, 1]
    constant column (padded sublanes get 0 — their lanes are re-masked
    after the clip anyway) broadcasting over the lane axis."""
    if not isinstance(v, tuple):
        return v
    col = np.zeros((dpad, 1), np.dtype(dtype))
    col[:len(v), 0] = v
    return jnp.asarray(col)


# --------------------------------------------------------------------------
# Static lowering: objectives + per-dim bounds as pallas-legal operands.
#
# Pallas forbids kernels that capture array constants, but a user objective
# is free to close over weight/target vectors (and per-dimension bounds ARE
# [Dpad, 1] columns). ``lower_statics`` closure-converts the resolved
# fitness (jax.closure_convert hoists every captured array into an explicit
# argument) and collects bound columns, returning a ``consts`` tuple the
# call builders append as extra pallas_call inputs; ``_resolve_statics``
# rebuilds the operands inside the kernel from the fetched const values.
# The legacy path (string fitness, scalar bounds) produces ZERO consts and
# bypasses closure conversion entirely — its kernels are the seed kernels,
# bit-for-bit.
# --------------------------------------------------------------------------

class _Slot:
    """Marker: the operand lives in the kernel's const inputs at ``index``."""
    __slots__ = ("index",)

    def __init__(self, index: int):
        self.index = index


def lower_statics(fitness, *, d, dpad, bn, dtype,
                  min_pos, max_pos, max_v):
    """Lower (fitness, bounds) statics to (statics dict, const arrays).

    ``consts`` must be appended, in order, to the pallas_call operands
    (specs from ``_const_specs``). ``statics`` entries are Python scalars,
    ``_Slot`` markers pointing into the const values, and the fitness
    callable (plus its own const slots when closure conversion ran).
    """
    consts = []

    def slot(arr):
        consts.append(arr)
        return _Slot(len(consts) - 1)

    st = {}
    for name, v in (("min_pos", min_pos), ("max_pos", max_pos),
                    ("max_v", max_v)):
        st[name] = slot(_bound_col(v, dpad, dtype)) if isinstance(v, tuple) \
            else v
    fitfn = kernel_fitness(fitness)
    if not is_converted(fitness):
        # Hand-tuned forms are const-free by construction: skip conversion
        # so the legacy jaxpr (and its compiled bits) are untouched.
        st["fit"] = fitfn
        st["fit_slots"] = None
    else:
        # Trace once to hoist every array constant the objective bakes in
        # (weight/target vectors etc. — jax.closure_convert only hoists
        # closed-over *tracers*, so pull the jaxpr consts out ourselves).
        closed = jax.make_jaxpr(lambda p, m: fitfn(p, m, d))(
            jax.ShapeDtypeStruct((dpad, bn), dtype),
            jax.ShapeDtypeStruct((dpad, bn), jnp.bool_))

        def pure(p, m, *cvals, _jaxpr=closed.jaxpr):
            out = jax.core.eval_jaxpr(_jaxpr, cvals, p, m)
            if len(out) != 1:
                raise ValueError("objective must return a single array")
            return out[0]

        st["fit"] = pure
        st["fit_slots"] = tuple(slot(jnp.asarray(c)) for c in closed.consts)
    projfn = kernel_projection(fitness)
    if projfn is None:
        st["proj"] = None
        st["proj_slots"] = None
    else:
        # Same hoisting for the feasibility projection: user operators may
        # close over arrays (targets, metric weights), which Pallas forbids
        # as captured consts.
        pclosed = jax.make_jaxpr(lambda p: projfn(p, d))(
            jax.ShapeDtypeStruct((dpad, bn), dtype))

        def pure_proj(p, *cvals, _jaxpr=pclosed.jaxpr):
            out = jax.core.eval_jaxpr(_jaxpr, cvals, p)
            if len(out) != 1:
                raise ValueError("projection must return a single array")
            return out[0]

        st["proj"] = pure_proj
        st["proj_slots"] = tuple(slot(jnp.asarray(c))
                                 for c in pclosed.consts)
    violfn = kernel_violation(fitness)
    if violfn is None:
        st["viol"] = None
        st["viol_slots"] = None
    else:
        # And for the Deb-fold violation form (projection/repair modes):
        # user constraint fns may close over arrays too.
        vclosed = jax.make_jaxpr(lambda p: violfn(p, d))(
            jax.ShapeDtypeStruct((dpad, bn), dtype))

        def pure_viol(p, *cvals, _jaxpr=vclosed.jaxpr):
            out = jax.core.eval_jaxpr(_jaxpr, cvals, p)
            if len(out) != 1:
                raise ValueError("violation must return a single array")
            return out[0]

        st["viol"] = pure_viol
        st["viol_slots"] = tuple(slot(jnp.asarray(c))
                                 for c in vclosed.consts)
    st["n_consts"] = len(consts)
    return st, tuple(consts)


def _resolve_statics(st, const_vals):
    """Kernel-side inverse of ``lower_statics``: returns
    (min_pos, max_pos, max_v, fitfn, proj, viol, pin) with
    fitfn(pos, dmask, d_real), proj(pos) and viol(pos) (each or None).

    ``pin`` is True for converted (non-hand-tuned) objectives and whenever
    a feasibility projection is present: the kernel body must pass the
    advance outputs through ``_pin`` before storing or evaluating fitness.
    Without it, XLA:CPU fuses the user objective into the velocity/position
    chain and re-derives a differently-rounded ``pos`` per consumer,
    drifting 1 ulp from the eager ``ref.py`` oracles and breaking the
    bit-exact validation contract. The barrier is a no-op eagerly and is
    skipped entirely for the hand-tuned built-in forms, whose jaxprs (and
    compiled bits) stay exactly the seed kernels'.
    """
    def get(v):
        return const_vals[v.index] if isinstance(v, _Slot) else v

    if st["fit_slots"] is None:
        fit = st["fit"]
    else:
        pure = st["fit"]
        extra = tuple(const_vals[s.index] for s in st["fit_slots"])

        def fit(pos, dmask, d_real, _pure=pure, _extra=extra):
            del d_real  # baked in at closure-conversion time
            return _pure(pos, dmask, *_extra)

    if st["proj"] is None:
        proj = None
    else:
        pure_proj = st["proj"]
        pextra = tuple(const_vals[s.index] for s in st["proj_slots"])

        def proj(pos, _pure=pure_proj, _extra=pextra):
            return _pure(pos, *_extra)

    if st["viol"] is None:
        viol = None
    else:
        pure_viol = st["viol"]
        vextra = tuple(const_vals[s.index] for s in st["viol_slots"])

        def viol(pos, _pure=pure_viol, _extra=vextra):
            return _pure(pos, *_extra)

    return (get(st["min_pos"]), get(st["max_pos"]), get(st["max_v"]), fit,
            proj, viol, st["fit_slots"] is not None or proj is not None)


def _pin(pin, pos, vel):
    """Materialize the advance outputs (see ``_resolve_statics``)."""
    return lax.optimization_barrier((pos, vel)) if pin else (pos, vel)


def _const_specs(consts):
    """Whole-array BlockSpecs for the const inputs (grid-invariant)."""
    return [pl.BlockSpec(c.shape, lambda *g, _r=c.ndim: (0,) * _r)
            for c in consts]


#: the default rule — its ``advance`` is the seed kernels' velocity chain
_PSO_RULE = resolve_rule("pso")


def _advance_block(seed, it, pos, vel, pbp, gp_col, block_base, *,
                   w, c1, c2, min_pos, max_pos, max_v, d_real, project=None,
                   rule=None):
    """Paper Alg. 1 steps 2–3 for one [Dpad, bn] tile.

    Shared verbatim by the kernel bodies and the ``ref.py`` oracle so that
    interpret-mode validation isolates the *pallas orchestration* (grid,
    aliasing, blocking, predication); the math itself is validated against
    the independent ``repro.core.pso`` implementation in tests.
    ``min_pos``/``max_pos``/``max_v`` are scalars or per-dimension tuples
    (lowered to constant [Dpad, 1] columns). ``project`` is the optional
    feasibility projection ``pos [Dpad, bn] -> pos`` applied after the box
    clip (constrained problems, mode="projection" — see
    ``repro.core.constraints``). ``rule`` is the pluggable
    ``repro.core.update_rules.UpdateRule`` (None -> the default ``"pso"``
    rule, whose elementwise chain is the pre-refactor body bit-for-bit);
    the scaffold owns RNG indexing, sublane masking and projection, the
    rule owns only the pos/vel math. Returns (pos, vel, dmask, lane).
    """
    dpad, bn = pos.shape
    min_pos = _bound_col(min_pos, dpad, pos.dtype)
    max_pos = _bound_col(max_pos, dpad, pos.dtype)
    max_v = _bound_col(max_v, dpad, pos.dtype)
    dsub = lax.broadcasted_iota(jnp.int32, (dpad, bn), 0)
    lane = lax.broadcasted_iota(jnp.int32, (dpad, bn), 1)
    dmask = dsub < d_real
    # Global RNG index: particle * D + dim — identical to the library path.
    gidx = ((block_base + lane) * d_real + dsub).astype(jnp.uint32)
    r1 = rng.uniform(seed, it, STREAM_R1, gidx, dtype=pos.dtype)
    r2 = rng.uniform(seed, it, STREAM_R2, gidx, dtype=pos.dtype)
    rule = _PSO_RULE if rule is None else rule
    # gp_col [Dpad, 1] broadcasts over lanes inside the rule.
    pos, vel = rule.advance(r1, r2, pos, vel, pbp, gp_col,
                            w=w, c1=c1, c2=c2, mv=max_v,
                            lo=min_pos, hi=max_pos)
    if project is not None:
        pos = project(pos)
    zero = jnp.zeros_like(pos)
    return jnp.where(dmask, pos, zero), jnp.where(dmask, vel, zero), dmask, lane


# --------------------------------------------------------------------------
# Shared scaffold machinery: pbest fold, candidate queue, winner gather.
# --------------------------------------------------------------------------

def _kernel_rule(rule):
    """Resolve + gate a rule for the Pallas scaffolds (builder entry)."""
    rule = resolve_rule(rule)
    if not rule.kernel_eligible:
        raise ValueError(
            f"update rule {rule.name!r} is not kernel-eligible "
            f"(non-elementwise advance); use the jnp backend")
    return rule


def _fold_pbest(fit, pos, pbf_ref, pbp_ref, viol):
    """Alg. 1 step 4: fold the pbest refs in place (raw fitness compare,
    or the Deb rule when a ``kernel_violation`` form is present).

    Returns the per-lane improvement mask so telemetry-enabled scaffolds
    can count block-improvement events without recomputing the compare."""
    pbf = pbf_ref[...]
    pbp = pbp_ref[...]
    imp = _pbest_improved(fit, pos, pbf, pbp, viol)
    pbf_ref[...] = jnp.where(imp, fit, pbf)
    pbp_ref[...] = jnp.where(imp, pos, pbp)
    return imp


def _queue_best(fit, best):
    """The paper's intra-block queue, degenerated to SIMD folds: membership
    mask (lanes improving on ``best``) == the queue, one vectorized masked
    max == thread-0's scan, first-lane tie-break. Returns ``(bf, bidx)``
    with ``bf == -inf`` when the queue is empty."""
    neg = jnp.full_like(fit, -jnp.inf)
    q_fit = jnp.where(fit > best, fit, neg)
    bf = jnp.max(q_fit)
    lane_row = lax.broadcasted_iota(jnp.int32, fit.shape, 1)
    bidx = jnp.min(jnp.where(q_fit >= bf, lane_row, _BIG_I32))
    return bf, bidx


def _gather_winner(pos, dmask, lane, bidx):
    """§5.3 trick: gather the winning lane's position column as a masked
    sum — one vectorized pass, only run on (rare) improvement."""
    sel = (lane == bidx) & dmask
    return jnp.sum(jnp.where(sel, pos, jnp.zeros_like(pos)),
                   axis=1, keepdims=True)


# --------------------------------------------------------------------------
# THE synchronous scaffold: one generator, four kernel bodies.
# --------------------------------------------------------------------------

def _make_sync_kernel(*, queue=False, batched=False, hetero=False,
                      telemetry=False):
    """Generate a synchronous kernel body from the shared scaffold.

    One advance + pbest fold + publication per grid step. Modes:

    * ``queue``   — kernel 1: gbest is a read-only input; publication is an
      unconditional per-block ``(aux_fit, aux_idx)`` pair (the cross-block
      argmax is ops.py's tiny jnp epilogue — the paper's "2nd kernel").
    * default     — kernel 2 (fused queue-lock): in-place predicated
      publication under sequential-grid serialization (the lock).
    * ``batched`` — kernel 3: leading swarm grid axis with per-swarm RNG
      counters and gbest slots; row s is bit-identical to a standalone
      kernel-2 run.
    * ``hetero``  — kernel 3h: per-swarm objective via ``lax.switch`` over
      branch-static member configs (``statics`` is the
      ``_hetero_branches`` tuple, not a ``lower_statics`` dict); the
      scalar switch index makes this a real conditional — one branch
      executes per grid step.

    The returned body is specialized by the call builders via
    ``functools.partial`` with the static kwargs
    ``(w, c1, c2, d_real, rule, statics)``; ``rule`` is the resolved
    :class:`repro.core.update_rules.UpdateRule` every variant closes over.

    ``telemetry`` appends one aliased int32 SMEM counter buffer (3 slots
    per swarm: queue updates / publications / block improvements — see
    ``repro.telemetry.counters``) and accumulates into it per grid step.
    The gate is Python-level, so a telemetry-off body traces to exactly
    the pre-telemetry jaxpr (the bit-identity pins never see it).
    """
    if queue and telemetry:
        raise ValueError("the two-kernel queue variant publishes via the "
                         "jnp epilogue; count there, not in-kernel")

    def kernel(*refs, w, c1, c2, d_real, rule, statics):
        # --- scalar prefix / aliased-input placeholders / const + out refs
        if queue:
            scal_ref, gp_in_ref, gf_in_ref = refs[:3]
            rest = refs[3 + 4:]              # 4 aliased state inputs
        elif hetero:
            seeds_ref, its_ref, fids_ref = refs[:3]
            rest = refs[3 + 6:]
        elif batched:
            seeds_ref, its_ref = refs[:2]
            rest = refs[2 + 6:]
        else:
            scal_ref = refs[0]
            rest = refs[1 + 6:]
        if hetero:
            branches = statics
            if telemetry:
                # rest[0] is the aliased counts-input placeholder
                (pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref,
                 cnt_ref) = rest[1:]
            else:
                pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref = rest
        else:
            nc = statics["n_consts"]
            const_vals = tuple(r[...] for r in rest[:nc])
            if queue:
                (pos_ref, vel_ref, pbp_ref, pbf_ref,
                 aux_fit_ref, aux_idx_ref) = rest[nc:]
            elif telemetry:
                # rest[nc] is the aliased counts-input placeholder
                (pos_ref, vel_ref, pbp_ref, pbf_ref,
                 gp_ref, gf_ref, cnt_ref) = rest[nc + 1:]
            else:
                (pos_ref, vel_ref, pbp_ref, pbf_ref,
                 gp_ref, gf_ref) = rest[nc:]
            min_pos, max_pos, max_v, fitness, proj, viol, pin = \
                _resolve_statics(statics, const_vals)
        # --- grid coordinates and RNG counters
        if batched or hetero:
            s = pl.program_id(0)
            b = pl.program_id(2)
            seed = seeds_ref[s]
            it = its_ref[s] + pl.program_id(1) + 1
            slot = s
        elif queue:
            b = pl.program_id(0)
            seed = scal_ref[0]
            it = scal_ref[1] + 1
            slot = 0
        else:
            b = pl.program_id(1)
            seed = scal_ref[0]
            it = scal_ref[1] + pl.program_id(0) + 1
            slot = 0
        bn = pos_ref.shape[1]
        base = b * bn      # block base LOCAL to the swarm: RNG indices
                           # match a standalone swarm bit-for-bit
        # --- advance + objective
        if hetero:
            def mk(st):
                min_pos, max_pos, max_v, fitness, proj, viol, pin = \
                    _resolve_statics(st, ())
                del viol   # hetero tables are unconstrained/penalty-mode

                def branch(op):
                    pos0, vel0, pbp0, gp0 = op
                    pos, vel, dmask, _ = _advance_block(
                        seed, it, pos0, vel0, pbp0, gp0, base,
                        w=w, c1=c1, c2=c2, min_pos=min_pos,
                        max_pos=max_pos, max_v=max_v, d_real=d_real,
                        project=proj, rule=rule)
                    pos, vel = _pin(pin, pos, vel)
                    return pos, vel, fitness(pos, dmask, d_real)

                return branch

            pos, vel, fit = lax.switch(
                fids_ref[s], [mk(st) for st in branches],
                (pos_ref[...], vel_ref[...], pbp_ref[...], gp_ref[...]))
            dpad = pos.shape[0]
            dmask = lax.broadcasted_iota(jnp.int32, (dpad, bn), 0) < d_real
            lane = lax.broadcasted_iota(jnp.int32, (dpad, bn), 1)
            viol = None
        else:
            gp_src = gp_in_ref if queue else gp_ref
            pos, vel, dmask, lane = _advance_block(
                seed, it, pos_ref[...], vel_ref[...], pbp_ref[...],
                gp_src[...], base, w=w, c1=c1, c2=c2, min_pos=min_pos,
                max_pos=max_pos, max_v=max_v, d_real=d_real, project=proj,
                rule=rule)
            pos, vel = _pin(pin, pos, vel)
            fit = fitness(pos, dmask, d_real)                # [1, bn]
        # --- pbest fold + state writes
        imp = _fold_pbest(fit, pos, pbf_ref, pbp_ref, viol)
        pos_ref[...] = pos
        vel_ref[...] = vel
        # --- publication
        if queue:
            # Candidates are lanes improving on the (stale) global best;
            # published as (fit, index) only — §5.3, never the position.
            bf, bidx = _queue_best(fit, gf_in_ref[0])
            aux_fit_ref[0] = bf                          # -inf if empty
            aux_idx_ref[0] = base + bidx
        else:
            # Queue-lock: serialized in-kernel publication (grid order =
            # the lock) behind the rare-improvement predicate (§4.1).
            gf = gf_ref[slot]
            q_mask = fit > gf

            @pl.when(jnp.any(q_mask))
            def _publish():
                bf, bidx = _queue_best(fit, gf)
                gf_ref[slot] = bf
                gp_ref[...] = _gather_winner(pos, dmask, lane, bidx)

            if telemetry:
                # One conditional guards both the queue fold and the
                # publication here, so queue_updates == publications by
                # construction (docs/observability.md) — matching the
                # oracle's single ``if any(q_mask)`` program point.
                inc = jnp.any(q_mask).astype(jnp.int32)
                c0 = 3 * slot
                cnt_ref[c0] = cnt_ref[c0] + inc
                cnt_ref[c0 + 1] = cnt_ref[c0 + 1] + inc
                cnt_ref[c0 + 2] = (cnt_ref[c0 + 2]
                                   + jnp.any(imp).astype(jnp.int32))

    kernel.__name__ = ("_queue_kernel" if queue else
                       "_hetero_fused_batch_kernel" if hetero else
                       "_fused_batch_kernel" if batched else "_fused_kernel")
    if telemetry:
        kernel.__name__ += "_tel"
    return kernel


# The four synchronous kernel bodies: thin instantiations of the scaffold,
# plus the telemetry (counter-carrying) twins of the three fused ones.
_queue_kernel = _make_sync_kernel(queue=True)
_fused_kernel = _make_sync_kernel()
_fused_batch_kernel = _make_sync_kernel(batched=True)
_hetero_fused_batch_kernel = _make_sync_kernel(batched=True, hetero=True)
_fused_kernel_tel = _make_sync_kernel(telemetry=True)
_fused_batch_kernel_tel = _make_sync_kernel(batched=True, telemetry=True)
_hetero_fused_batch_kernel_tel = _make_sync_kernel(batched=True, hetero=True,
                                                   telemetry=True)


# --------------------------------------------------------------------------
# Kernel 1: queue algorithm — one iteration, grid over particle blocks.
# --------------------------------------------------------------------------

def queue_step_call(n: int, d: int, block_n: int, dtype, *,
                    w, c1, c2, min_pos, max_pos, max_v, fitness,
                    rule="pso", interpret=True):
    """Build the pallas_call for one queue iteration.

    Args (runtime): scal[2]i32, gbest_pos[Dpad,1], gbest_fit[1],
                    pos/vel/pbest_pos [Dpad,N], pbest_fit [1,N]
    Returns: (pos, vel, pbest_pos, pbest_fit, aux_fit[nb], aux_idx[nb])
    """
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    dpad = pad_dim(d)
    st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=block_n,
                               dtype=dtype, min_pos=min_pos,
                               max_pos=max_pos, max_v=max_v)
    kern = functools.partial(_queue_kernel, w=w, c1=c1, c2=c2, d_real=d,
                             rule=_kernel_rule(rule), statics=st)
    mat = pl.BlockSpec((dpad, block_n), lambda b: (0, b))
    row = pl.BlockSpec((1, block_n), lambda b: (0, b))
    call = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scal
            pl.BlockSpec((dpad, 1), lambda b: (0, 0)),        # gbest_pos
            pl.BlockSpec(memory_space=pltpu.SMEM),            # gbest_fit
            mat, mat, mat, row,                               # pos vel pbp pbf
        ] + _const_specs(consts),
        out_specs=[
            mat, mat, mat, row,
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b: (b,), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dpad, n), dtype),           # pos
            jax.ShapeDtypeStruct((dpad, n), dtype),           # vel
            jax.ShapeDtypeStruct((dpad, n), dtype),           # pbest_pos
            jax.ShapeDtypeStruct((1, n), dtype),              # pbest_fit
            jax.ShapeDtypeStruct((nb,), dtype),               # aux_fit
            jax.ShapeDtypeStruct((nb,), jnp.int32),           # aux_idx
        ],
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
        name="cupso_queue_step",
    )
    return lambda *args: call(*args, *consts)


# --------------------------------------------------------------------------
# Kernel 2: fused queue-lock — grid (iterations, particle blocks).
# --------------------------------------------------------------------------

def fused_call(n: int, d: int, iters: int, block_n: int, dtype, *,
               w, c1, c2, min_pos, max_pos, max_v, fitness,
               rule="pso", interpret=True, telemetry=False):
    """Build the fused multi-iteration queue-lock pallas_call.

    Args (runtime): scal[2]i32, pos/vel/pbest_pos [Dpad,N], pbest_fit [1,N],
                    gbest_pos [Dpad,1], gbest_fit [1]
    Returns the same six state arrays after ``iters`` iterations.
    ``telemetry`` appends an aliased counts[3]i32 operand (last arg, last
    result) accumulating the contention counters — see repro.telemetry.
    """
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    dpad = pad_dim(d)
    st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=block_n,
                               dtype=dtype, min_pos=min_pos,
                               max_pos=max_pos, max_v=max_v)
    body = _fused_kernel_tel if telemetry else _fused_kernel
    kern = functools.partial(body, w=w, c1=c1, c2=c2, d_real=d,
                             rule=_kernel_rule(rule), statics=st)
    mat = pl.BlockSpec((dpad, block_n), lambda t, b: (0, b))
    row = pl.BlockSpec((1, block_n), lambda t, b: (0, b))
    gpc = pl.BlockSpec((dpad, 1), lambda t, b: (0, 0))
    gfs = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM),        # scal
                mat, mat, mat, row, gpc, gfs] + _const_specs(consts)
    out_specs = [mat, mat, mat, row, gpc, gfs]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, n), dtype),               # pos
        jax.ShapeDtypeStruct((dpad, n), dtype),               # vel
        jax.ShapeDtypeStruct((dpad, n), dtype),               # pbest_pos
        jax.ShapeDtypeStruct((1, n), dtype),                  # pbest_fit
        jax.ShapeDtypeStruct((dpad, 1), dtype),               # gbest_pos
        jax.ShapeDtypeStruct((1,), dtype),                    # gbest_fit
    ]
    aliases = {1: 0, 2: 1, 3: 2, 4: 3, 5: 4, 6: 5}
    if telemetry:
        in_specs.append(gfs)                                  # counts in
        out_specs.append(gfs)
        out_shape.append(jax.ShapeDtypeStruct((3,), jnp.int32))
        aliases[7 + len(consts)] = 6
    call = pl.pallas_call(
        kern,
        grid=(iters, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_fused_queue_lock",
    )
    if telemetry:
        # counts is the caller's LAST positional arg; consts slot in
        # before it to keep the kernel's operand order (consts then cnt).
        return lambda *args: call(*args[:-1], *consts, args[-1])
    return lambda *args: call(*args, *consts)


# --------------------------------------------------------------------------
# Kernel 3: batched fused queue-lock — grid (swarms, iterations, blocks).
# --------------------------------------------------------------------------

def fused_batch_call(s_cnt: int, n: int, d: int, iters: int, block_n: int,
                     dtype, *, w, c1, c2, min_pos, max_pos, max_v, fitness,
                     rule="pso", interpret=True, telemetry=False):
    """Build the batched fused queue-lock pallas_call (S swarms x iters).

    Args (runtime): seeds[S]i32, iterations[S]i32,
                    pos/vel/pbest_pos [Dpad, S*N], pbest_fit [1, S*N],
                    gbest_pos [Dpad, S], gbest_fit [S]
    Returns the same six state arrays after ``iters`` iterations of every
    swarm. Swarm-major grid: the per-swarm gbest column and SMEM fitness
    slot are revisited only within one swarm's iteration span.
    ``telemetry`` appends an aliased counts[3*S]i32 operand (per-swarm
    contention counters — see repro.telemetry).
    """
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    dpad = pad_dim(d)
    st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=block_n,
                               dtype=dtype, min_pos=min_pos,
                               max_pos=max_pos, max_v=max_v)
    body = _fused_batch_kernel_tel if telemetry else _fused_batch_kernel
    kern = functools.partial(body, w=w, c1=c1, c2=c2,
                             d_real=d, rule=_kernel_rule(rule), statics=st)
    mat = pl.BlockSpec((dpad, block_n), lambda s, t, b: (0, s * nb + b))
    row = pl.BlockSpec((1, block_n), lambda s, t, b: (0, s * nb + b))
    gpc = pl.BlockSpec((dpad, 1), lambda s, t, b: (0, s))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem, smem,                                   # seeds, iters
                mat, mat, mat, row, gpc, smem] + _const_specs(consts)
    out_specs = [mat, mat, mat, row, gpc, smem]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pos
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # vel
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pbest_pos
        jax.ShapeDtypeStruct((1, s_cnt * n), dtype),          # pbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt), dtype),           # gbest_pos
        jax.ShapeDtypeStruct((s_cnt,), dtype),                # gbest_fit
    ]
    aliases = {2: 0, 3: 1, 4: 2, 5: 3, 6: 4, 7: 5}
    if telemetry:
        in_specs.append(smem)                                 # counts in
        out_specs.append(smem)
        out_shape.append(jax.ShapeDtypeStruct((3 * s_cnt,), jnp.int32))
        aliases[8 + len(consts)] = 6
    call = pl.pallas_call(
        kern,
        grid=(s_cnt, iters, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_fused_queue_lock_batch",
    )
    if telemetry:
        return lambda *args: call(*args[:-1], *consts, args[-1])
    return lambda *args: call(*args, *consts)


# --------------------------------------------------------------------------
# Kernel 3h: heterogeneous batched fused queue-lock — per-swarm objective.
#
# Same grid and orchestration as kernel 3, plus a per-swarm ``fid`` scalar
# (SMEM) indexing a static problem table. The advance + objective go through
# ``lax.switch`` with each branch closing over its member's *static* bounds
# and hand-tuned fitness — exactly the subgraph kernel 3 would compile for a
# homogeneous batch of that problem. The switch index is a scalar (one
# swarm per grid step), so this is a real conditional: one branch executes
# per grid step and a mixed batch does NOT pay a compute-all-branches
# ``select_n`` the way the vmapped jnp engine does. The pbest fold and the
# queue-lock publication are objective-independent and stay outside the
# switch. Table members must lower const-free (the built-in registry does;
# ``lower_statics`` consts would need per-branch operand plumbing).
# --------------------------------------------------------------------------

def _hetero_branches(members, *, d, dpad, bn, dtype):
    """Per-member kernel statics for a hetero dispatch table.

    ``members`` is a tuple of ``(fitness, min_pos, max_pos, max_v)``; each
    must lower without const operands and without a feasibility projection
    (``problem_rows`` rejects projection/repair members before this).
    """
    branches = []
    for fitness, mn, mx, mv in members:
        st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=bn,
                                   dtype=dtype, min_pos=mn, max_pos=mx,
                                   max_v=mv)
        if consts:
            raise ValueError(
                "heterogeneous kernel dispatch requires const-free table "
                "members (array-closing objectives need their own batch)")
        branches.append(st)
    return tuple(branches)


def hetero_fused_batch_call(s_cnt: int, n: int, d: int, iters: int,
                            block_n: int, dtype, *, w, c1, c2, members,
                            rule="pso", interpret=True, telemetry=False):
    """Batched fused queue-lock with a per-swarm problem (kernel 3h).

    Args (runtime): seeds[S]i32, iterations[S]i32, fids[S]i32, then the six
    state arrays of ``fused_batch_call``. ``members[k]`` is the static
    ``(fitness, min_pos, max_pos, max_v)`` branch ``fids == k`` dispatches
    to. ``telemetry`` appends an aliased counts[3*S]i32 operand.
    """
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    dpad = pad_dim(d)
    branches = _hetero_branches(members, d=d, dpad=dpad, bn=block_n,
                                dtype=dtype)
    body = (_hetero_fused_batch_kernel_tel if telemetry
            else _hetero_fused_batch_kernel)
    kern = functools.partial(body, w=w, c1=c1, c2=c2,
                             d_real=d, rule=_kernel_rule(rule),
                             statics=branches)
    mat = pl.BlockSpec((dpad, block_n), lambda s, t, b: (0, s * nb + b))
    row = pl.BlockSpec((1, block_n), lambda s, t, b: (0, s * nb + b))
    gpc = pl.BlockSpec((dpad, 1), lambda s, t, b: (0, s))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem, smem, smem,                      # seeds, iters, fids
                mat, mat, mat, row, gpc, smem]
    out_specs = [mat, mat, mat, row, gpc, smem]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pos
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # vel
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pbest_pos
        jax.ShapeDtypeStruct((1, s_cnt * n), dtype),          # pbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt), dtype),           # gbest_pos
        jax.ShapeDtypeStruct((s_cnt,), dtype),                # gbest_fit
    ]
    aliases = {3: 0, 4: 1, 5: 2, 6: 3, 7: 4, 8: 5}
    if telemetry:
        in_specs.append(smem)                                 # counts in
        out_specs.append(smem)
        out_shape.append(jax.ShapeDtypeStruct((3 * s_cnt,), jnp.int32))
        aliases[9] = 6
    return pl.pallas_call(
        kern,
        grid=(s_cnt, iters, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_hetero_fused_queue_lock_batch",
    )


# --------------------------------------------------------------------------
# Kernel 4: async queue-lock — grid (blocks, iteration chunks), block-major.
# --------------------------------------------------------------------------

def _async_chunk_body(scal0, it_base, sync_every, base,
                      pos, vel, pbp, pbf, lp, lf, *,
                      w, c1, c2, min_pos, max_pos, max_v, d_real, fitness,
                      project=None, viol=None, pin=False, rule=None,
                      counts=False):
    """``sync_every`` iterations of one block against its block-local best.

    Pure value-level fori_loop (no ref writes inside the loop) shared by
    the single and batched async kernels. The local-best update applies
    exactly the fused kernel's publication rule (masked max, first-lane
    tie-break, masked-sum position gather), but into the loop carry instead
    of the shared SMEM/VMEM gbest buffers — so with a single block the
    trajectory is bit-identical to the synchronous fused kernel.

    ``counts`` (telemetry) extends the carry with two scalar int32 event
    counters — iterations where the local queue was non-empty, and
    iterations where any lane improved its pbest — returned as trailing
    elements for the scaffold to fold into the counter buffer.
    """
    def body(tl, carry):
        if counts:
            pos, vel, pbp, pbf, lp, lf, nq, nimp = carry
        else:
            pos, vel, pbp, pbf, lp, lf = carry
        pos, vel, dmask, lane = _advance_block(
            scal0, it_base + tl + 1, pos, vel, pbp, lp, base,
            w=w, c1=c1, c2=c2, min_pos=min_pos, max_pos=max_pos,
            max_v=max_v, d_real=d_real, project=project, rule=rule)
        pos, vel = _pin(pin, pos, vel)
        fit = fitness(pos, dmask, d_real)
        imp = _pbest_improved(fit, pos, pbf, pbp, viol)
        pbf = jnp.where(imp, fit, pbf)
        pbp = jnp.where(imp, pos, pbp)
        # Block-local queue: same rule as the fused kernel's _publish, as
        # unconditional where-folds (a fori carry cannot be predicated).
        bf, bidx = _queue_best(fit, lf)        # bf == -inf when queue empty
        cand = _gather_winner(pos, dmask, lane, bidx)
        anyq = bf > lf                         # == jnp.any(fit > lf)
        lf = jnp.where(anyq, bf, lf)
        lp = jnp.where(anyq, cand, lp)
        if counts:
            nq = nq + anyq.astype(jnp.int32)
            nimp = nimp + jnp.any(imp).astype(jnp.int32)
            return pos, vel, pbp, pbf, lp, lf, nq, nimp
        return pos, vel, pbp, pbf, lp, lf

    init = (pos, vel, pbp, pbf, lp, lf)
    if counts:
        zero = jnp.zeros((), jnp.int32)
        init = init + (zero, zero)
    return lax.fori_loop(0, sync_every, body, init)


# --------------------------------------------------------------------------
# THE asynchronous scaffold: one generator, three kernel bodies.
# --------------------------------------------------------------------------

def _make_async_kernel(*, batched=False, hetero=False, telemetry=False):
    """Generate an asynchronous (block-resident) kernel body from the
    shared scaffold.

    Each grid step runs one ``sync_every``-iteration chunk of one particle
    block against its block-local best (``_async_chunk_body``), touching
    the shared buffers only at the chunk boundary: a local-best refresh on
    entry (the read half of the paper's lock) and a predicated publish on
    exit. Modes mirror ``_make_sync_kernel``: ``batched`` adds the leading
    swarm axis (per-swarm gbest slots, per-(swarm, block) local slots);
    ``hetero`` dispatches the whole chunk body through ``lax.switch``
    (``statics`` is the ``_hetero_branches`` tuple).

    ``topology`` selects the chunk-entry refresh source (see
    ``repro.core.topology``): ``"gbest"`` pulls the shared gbest — the
    paper's star, compiled exactly as before — while ``"ring"`` /
    ``"vonneumann"`` fold the NEIGHBOR blocks' local slots instead
    (``kernel_neighbor_ids``; ``lp_ref`` is whole-array blocked in this
    mode so neighbor columns are addressable), so swarm knowledge diffuses
    hop by hop while the shared gbest remains a monitoring/final-answer
    flush target only.

    ``telemetry`` mirrors ``_make_sync_kernel``: an aliased int32 SMEM
    counter buffer rides as the last operand, accumulating the chunk's
    local-queue updates and pbest improvements plus the chunk-exit
    publication, per swarm. Python-gated — off means the untouched jaxpr.
    """
    def kernel(*refs, nb, sync_every, w, c1, c2, d_real, rule, topology,
               statics):
        # --- scalar prefix / aliased-input placeholders / const + out refs
        if hetero:
            seeds_ref, its_ref, fids_ref = refs[:3]
            rest = refs[3 + 8:]
        elif batched:
            seeds_ref, its_ref = refs[:2]
            rest = refs[2 + 8:]
        else:
            scal_ref = refs[0]
            rest = refs[1 + 8:]
        if hetero:
            branches = statics
            if telemetry:
                # rest[0] is the aliased counts-input placeholder
                (pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref,
                 lp_ref, lf_ref, cnt_ref) = rest[1:]
            else:
                (pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref,
                 lp_ref, lf_ref) = rest
        else:
            nc = statics["n_consts"]
            const_vals = tuple(r[...] for r in rest[:nc])
            if telemetry:
                # rest[nc] is the aliased counts-input placeholder
                (pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref,
                 lp_ref, lf_ref, cnt_ref) = rest[nc + 1:]
            else:
                (pos_ref, vel_ref, pbp_ref, pbf_ref, gp_ref, gf_ref,
                 lp_ref, lf_ref) = rest[nc:]
            min_pos, max_pos, max_v, fitness, proj, viol, pin = \
                _resolve_statics(statics, const_vals)
        # --- grid coordinates, RNG counters, local/global slots
        if batched or hetero:
            s = pl.program_id(0)
            b = pl.program_id(1)
            c = pl.program_id(2)
            seed = seeds_ref[s]
            it0 = its_ref[s] + c * sync_every
            gslot = s
            slot = s * nb + b      # per-(swarm, block) local-best slot
        else:
            b = pl.program_id(0)
            c = pl.program_id(1)
            seed = scal_ref[0]
            it0 = scal_ref[1] + c * sync_every
            gslot = 0
            slot = b
        bn = pos_ref.shape[1]
        base = b * bn      # swarm-local: RNG matches a standalone run
        # --- chunk entry: refresh the block-local best (the read half of
        # the paper's lock).
        lf = lf_ref[slot]
        if topology == "gbest":
            # Star: pull the shared gbest. A no-op for the first grid
            # block and for nb == 1; later blocks inherit everything
            # earlier blocks published.
            lp = lp_ref[...]
            gf0 = gf_ref[gslot]
            pull = gf0 > lf
            lf = jnp.where(pull, gf0, lf)
            lp = jnp.where(pull, gp_ref[...], lp)
        else:
            # lbest: fold the neighbor blocks' local slots instead — the
            # shared gbest is never read back, so swarm knowledge diffuses
            # hop by hop (classic lbest dynamics at block granularity).
            lp = lp_ref[:, pl.ds(slot, 1)]
            for nbr in kernel_neighbor_ids(b, nb, topology):
                nslot = s * nb + nbr if (batched or hetero) else nbr
                nf = lf_ref[nslot]
                take = nf > lf
                lf = jnp.where(take, nf, lf)
                lp = jnp.where(take, lp_ref[:, pl.ds(nslot, 1)], lp)
        # --- the resident chunk: sync_every iterations vs the local best
        if hetero:
            def mk(st):
                min_pos, max_pos, max_v, fitness, proj, viol, pin = \
                    _resolve_statics(st, ())
                del viol   # hetero tables are unconstrained/penalty-mode

                def branch(op):
                    pos, vel, pbp, pbf, lp_, lf_ = op
                    return _async_chunk_body(
                        seed, it0, sync_every, base, pos, vel, pbp, pbf,
                        lp_, lf_, w=w, c1=c1, c2=c2, min_pos=min_pos,
                        max_pos=max_pos, max_v=max_v, d_real=d_real,
                        fitness=fitness, project=proj, viol=None, pin=pin,
                        rule=rule, counts=telemetry)

                return branch

            out = lax.switch(
                fids_ref[s], [mk(st) for st in branches],
                (pos_ref[...], vel_ref[...], pbp_ref[...], pbf_ref[...],
                 lp, lf))
        else:
            out = _async_chunk_body(
                seed, it0, sync_every, base,
                pos_ref[...], vel_ref[...], pbp_ref[...], pbf_ref[...],
                lp, lf, w=w, c1=c1, c2=c2, min_pos=min_pos,
                max_pos=max_pos, max_v=max_v, d_real=d_real,
                fitness=fitness, project=proj, viol=viol, pin=pin,
                rule=rule, counts=telemetry)
        if telemetry:
            pos, vel, pbp, pbf, lp, lf, nq, nimp = out
        else:
            pos, vel, pbp, pbf, lp, lf = out
        pos_ref[...] = pos
        vel_ref[...] = vel
        pbp_ref[...] = pbp
        pbf_ref[...] = pbf
        if topology == "gbest":
            lp_ref[...] = lp
        else:
            lp_ref[:, pl.ds(slot, 1)] = lp
        lf_ref[slot] = lf

        # --- chunk boundary: the ONLY cross-block write, and only on the
        # rare improvement (the paper's occasional lock acquisition). With
        # an lbest topology this is the monitoring/final-answer flush; the
        # entry refresh above never reads it back.
        if telemetry:
            # Fold the chunk's event counts before the publish mutates
            # gf_ref: publications counts shared-slot writes (the lock
            # acquisitions), queue_updates the block-local folds — their
            # ratio is the paper's contention-avoidance story measured.
            c0 = 3 * gslot
            pub = (lf > gf_ref[gslot]).astype(jnp.int32)
            cnt_ref[c0] = cnt_ref[c0] + nq
            cnt_ref[c0 + 1] = cnt_ref[c0 + 1] + pub
            cnt_ref[c0 + 2] = cnt_ref[c0 + 2] + nimp

        @pl.when(lf > gf_ref[gslot])
        def _publish():
            gf_ref[gslot] = lf
            gp_ref[...] = lp

    kernel.__name__ = (
        "_hetero_fused_async_batch_kernel" if hetero else
        "_fused_async_batch_kernel" if batched else "_fused_async_kernel")
    if telemetry:
        kernel.__name__ += "_tel"
    return kernel


# The three asynchronous kernel bodies: instantiations of the scaffold,
# plus their telemetry (counter-carrying) twins.
_fused_async_kernel = _make_async_kernel()
_fused_async_batch_kernel = _make_async_kernel(batched=True)
_hetero_fused_async_batch_kernel = _make_async_kernel(batched=True,
                                                      hetero=True)
_fused_async_kernel_tel = _make_async_kernel(telemetry=True)
_fused_async_batch_kernel_tel = _make_async_kernel(batched=True,
                                                   telemetry=True)
_hetero_fused_async_batch_kernel_tel = _make_async_kernel(
    batched=True, hetero=True, telemetry=True)


def _async_local_spec(topology, dpad, nb_total, index_map_own):
    """BlockSpec for the ``local_pos`` buffer: the block's own [Dpad, 1]
    column under the star topology (the seed kernels' spec, untouched), or
    the whole [Dpad, nb_total] array under an lbest topology so neighbor
    columns are dynamically addressable."""
    if topology == "gbest":
        return pl.BlockSpec((dpad, 1), index_map_own)
    return pl.BlockSpec((dpad, nb_total), lambda *g: (0, 0))


def fused_async_call(n: int, d: int, iters: int, block_n: int,
                     sync_every: int, dtype, *, w, c1, c2, min_pos, max_pos,
                     max_v, fitness, rule="pso", topology="gbest",
                     interpret=True, telemetry=False):
    """Build the asynchronous queue-lock pallas_call (grid (blocks, chunks)).

    Args (runtime): scal[2]i32, pos/vel/pbest_pos [Dpad,N], pbest_fit [1,N],
                    gbest_pos [Dpad,1], gbest_fit [1],
                    local_pos [Dpad,nb], local_fit [nb]
    Returns the same eight state arrays after ``iters`` iterations. The
    caller seeds local_pos/local_fit from the shared gbest (one column/slot
    per block); ``iters`` must be a multiple of ``sync_every`` (the ops
    wrapper splits a remainder into a second call). ``telemetry`` appends
    an aliased counts[3]i32 operand — see repro.telemetry.
    """
    assert n % block_n == 0, (n, block_n)
    assert iters % sync_every == 0, (iters, sync_every)
    nb = n // block_n
    chunks = iters // sync_every
    dpad = pad_dim(d)
    st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=block_n,
                               dtype=dtype, min_pos=min_pos,
                               max_pos=max_pos, max_v=max_v)
    body = _fused_async_kernel_tel if telemetry else _fused_async_kernel
    kern = functools.partial(body, nb=nb,
                             sync_every=sync_every, w=w, c1=c1, c2=c2,
                             d_real=d, rule=_kernel_rule(rule),
                             topology=topology, statics=st)
    mat = pl.BlockSpec((dpad, block_n), lambda b, c: (0, b))
    row = pl.BlockSpec((1, block_n), lambda b, c: (0, b))
    gpc = pl.BlockSpec((dpad, 1), lambda b, c: (0, 0))
    lpc = _async_local_spec(topology, dpad, nb, lambda b, c: (0, b))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem,                                         # scal
                mat, mat, mat, row, gpc, smem, lpc, smem] \
        + _const_specs(consts)
    out_specs = [mat, mat, mat, row, gpc, smem, lpc, smem]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, n), dtype),               # pos
        jax.ShapeDtypeStruct((dpad, n), dtype),               # vel
        jax.ShapeDtypeStruct((dpad, n), dtype),               # pbest_pos
        jax.ShapeDtypeStruct((1, n), dtype),                  # pbest_fit
        jax.ShapeDtypeStruct((dpad, 1), dtype),               # gbest_pos
        jax.ShapeDtypeStruct((1,), dtype),                    # gbest_fit
        jax.ShapeDtypeStruct((dpad, nb), dtype),              # local_pos
        jax.ShapeDtypeStruct((nb,), dtype),                   # local_fit
    ]
    aliases = {1: 0, 2: 1, 3: 2, 4: 3, 5: 4, 6: 5, 7: 6, 8: 7}
    if telemetry:
        in_specs.append(smem)                                 # counts in
        out_specs.append(smem)
        out_shape.append(jax.ShapeDtypeStruct((3,), jnp.int32))
        aliases[9 + len(consts)] = 8
    call = pl.pallas_call(
        kern,
        grid=(nb, chunks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_fused_queue_lock_async",
    )
    if telemetry:
        return lambda *args: call(*args[:-1], *consts, args[-1])
    return lambda *args: call(*args, *consts)


def fused_async_batch_call(s_cnt: int, n: int, d: int, iters: int,
                           block_n: int, sync_every: int, dtype, *,
                           w, c1, c2, min_pos, max_pos, max_v, fitness,
                           rule="pso", topology="gbest", interpret=True,
                           telemetry=False):
    """Batched async queue-lock: grid (swarms, blocks, chunks).

    Args (runtime): seeds[S]i32, iterations[S]i32,
                    pos/vel/pbest_pos [Dpad, S*N], pbest_fit [1, S*N],
                    gbest_pos [Dpad, S], gbest_fit [S],
                    local_pos [Dpad, S*nb], local_fit [S*nb]
    Swarm-major then block-major: swarm s's block b runs its whole iteration
    span while resident, exactly like a standalone ``fused_async_call`` —
    row s is bit-identical to the single-swarm async kernel. ``telemetry``
    appends an aliased counts[3*S]i32 operand.
    """
    assert n % block_n == 0, (n, block_n)
    assert iters % sync_every == 0, (iters, sync_every)
    nb = n // block_n
    chunks = iters // sync_every
    dpad = pad_dim(d)
    st, consts = lower_statics(fitness, d=d, dpad=dpad, bn=block_n,
                               dtype=dtype, min_pos=min_pos,
                               max_pos=max_pos, max_v=max_v)
    body = (_fused_async_batch_kernel_tel if telemetry
            else _fused_async_batch_kernel)
    kern = functools.partial(body, nb=nb,
                             sync_every=sync_every, w=w, c1=c1, c2=c2,
                             d_real=d, rule=_kernel_rule(rule),
                             topology=topology, statics=st)
    mat = pl.BlockSpec((dpad, block_n), lambda s, b, c: (0, s * nb + b))
    row = pl.BlockSpec((1, block_n), lambda s, b, c: (0, s * nb + b))
    gpc = pl.BlockSpec((dpad, 1), lambda s, b, c: (0, s))
    lpc = _async_local_spec(topology, dpad, s_cnt * nb,
                            lambda s, b, c: (0, s * nb + b))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem, smem,                                   # seeds, iters
                mat, mat, mat, row, gpc, smem, lpc, smem] \
        + _const_specs(consts)
    out_specs = [mat, mat, mat, row, gpc, smem, lpc, smem]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pos
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # vel
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pbest_pos
        jax.ShapeDtypeStruct((1, s_cnt * n), dtype),          # pbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt), dtype),           # gbest_pos
        jax.ShapeDtypeStruct((s_cnt,), dtype),                # gbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt * nb), dtype),      # local_pos
        jax.ShapeDtypeStruct((s_cnt * nb,), dtype),           # local_fit
    ]
    aliases = {2: 0, 3: 1, 4: 2, 5: 3, 6: 4, 7: 5, 8: 6, 9: 7}
    if telemetry:
        in_specs.append(smem)                                 # counts in
        out_specs.append(smem)
        out_shape.append(jax.ShapeDtypeStruct((3 * s_cnt,), jnp.int32))
        aliases[10 + len(consts)] = 8
    call = pl.pallas_call(
        kern,
        grid=(s_cnt, nb, chunks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_fused_queue_lock_async_batch",
    )
    if telemetry:
        return lambda *args: call(*args[:-1], *consts, args[-1])
    return lambda *args: call(*args, *consts)


# --------------------------------------------------------------------------
# Kernel 4h: heterogeneous batched async queue-lock — per-swarm objective.
# Kernel 3h's dispatch (scalar per-swarm fid, branch-static member configs,
# one branch executed per grid step) applied to kernel 4's batched grid:
# each branch runs the whole ``sync_every``-iteration chunk body.
# --------------------------------------------------------------------------

def hetero_fused_async_batch_call(s_cnt: int, n: int, d: int, iters: int,
                                  block_n: int, sync_every: int, dtype, *,
                                  w, c1, c2, members, rule="pso",
                                  topology="gbest", interpret=True,
                                  telemetry=False):
    """Batched async queue-lock with a per-swarm problem (kernel 4h).

    Args (runtime): seeds[S]i32, iterations[S]i32, fids[S]i32, then the
    eight state arrays of ``fused_async_batch_call``. ``members`` as in
    ``hetero_fused_batch_call``. ``telemetry`` appends an aliased
    counts[3*S]i32 operand.
    """
    assert n % block_n == 0, (n, block_n)
    assert iters % sync_every == 0, (iters, sync_every)
    nb = n // block_n
    chunks = iters // sync_every
    dpad = pad_dim(d)
    branches = _hetero_branches(members, d=d, dpad=dpad, bn=block_n,
                                dtype=dtype)
    body = (_hetero_fused_async_batch_kernel_tel if telemetry
            else _hetero_fused_async_batch_kernel)
    kern = functools.partial(body, nb=nb,
                             sync_every=sync_every, w=w, c1=c1, c2=c2,
                             d_real=d, rule=_kernel_rule(rule),
                             topology=topology, statics=branches)
    mat = pl.BlockSpec((dpad, block_n), lambda s, b, c: (0, s * nb + b))
    row = pl.BlockSpec((1, block_n), lambda s, b, c: (0, s * nb + b))
    gpc = pl.BlockSpec((dpad, 1), lambda s, b, c: (0, s))
    lpc = _async_local_spec(topology, dpad, s_cnt * nb,
                            lambda s, b, c: (0, s * nb + b))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    in_specs = [smem, smem, smem,                      # seeds, iters, fids
                mat, mat, mat, row, gpc, smem, lpc, smem]
    out_specs = [mat, mat, mat, row, gpc, smem, lpc, smem]
    out_shape = [
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pos
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # vel
        jax.ShapeDtypeStruct((dpad, s_cnt * n), dtype),       # pbest_pos
        jax.ShapeDtypeStruct((1, s_cnt * n), dtype),          # pbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt), dtype),           # gbest_pos
        jax.ShapeDtypeStruct((s_cnt,), dtype),                # gbest_fit
        jax.ShapeDtypeStruct((dpad, s_cnt * nb), dtype),      # local_pos
        jax.ShapeDtypeStruct((s_cnt * nb,), dtype),           # local_fit
    ]
    aliases = {3: 0, 4: 1, 5: 2, 6: 3, 7: 4, 8: 5, 9: 6, 10: 7}
    if telemetry:
        in_specs.append(smem)                                 # counts in
        out_specs.append(smem)
        out_shape.append(jax.ShapeDtypeStruct((3 * s_cnt,), jnp.int32))
        aliases[11] = 8
    return pl.pallas_call(
        kern,
        grid=(s_cnt, nb, chunks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="cupso_hetero_fused_queue_lock_async_batch",
    )
