"""State-space & recurrent blocks: mamba-2-style SSD (hymba's parallel SSM
heads), and xLSTM's mLSTM / sLSTM.

One chunked *gated linear attention* engine serves both SSD and mLSTM:

    H_t = exp(log_decay_t) · H_{t-1} + inc_t · k_t ⊗ v_t
    y_t = q_t · H_t

computed chunk-parallel (intra-chunk masked matmul in log-decay space +
inter-chunk scan over [N, P] states). This is the sub-quadratic form that
makes the long_500k cell well-defined: train/prefill cost is O(S·L) per
chunk pair, decode is a single O(N·P) state update.

mLSTM's normalizer is folded in by augmenting v with a ones-column, so the
engine runs once and yields numerator and denominator together.

Simplifications vs the source papers (documented in DESIGN.md §5): no
depthwise conv frontend in the SSD branch; mLSTM uses log-space gate
clamping instead of the running-max stabilizer; sLSTM keeps the true
sequential recurrence (lax.scan over time) since that is its defining
feature.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

Params = Dict[str, Any]

_CLAMP = 20.0  # log-space clamp for gate stability


# ---------------------------------------------------------------------------
# Chunked gated linear attention engine
# ---------------------------------------------------------------------------

def gla_chunked(q, k, v, log_decay, log_inc, chunk: int = 128,
                h0=None, chunk_remat: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k: [B,S,H,N]; v: [B,S,H,P]; log_decay/log_inc: [B,S,H].
    Returns (y [B,S,H,P], h_final [B,H,N,P]).

    chunk_remat (§Perf hymba/xlstm iteration): checkpoint each chunk step
    so autodiff saves only the O(B·H·N·P) inter-chunk carries instead of
    every intra-chunk [B,L,L,H] weight tile and stacked qkv residual —
    the dominant memory term of hybrid/ssm training (HLO inspection:
    f32[16,33,128,25,128] residual stacks ×229 on hymba-1.5b). Backward
    recomputes the intra-chunk forward (+~1/3 of this piece's flops)."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        log_decay = jnp.pad(log_decay, [(0, 0), (0, pad), (0, 0)])
        log_inc = jnp.pad(log_inc, [(0, 0), (0, pad), (0, 0)],
                          constant_values=-_CLAMP * 2)
    sp = s + pad
    nc = sp // chunk
    # [B, nc, L, H, ...] -> scan over nc
    resh = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = resh(q), resh(k), resh(v)
    ldc, lic = resh(log_decay), resh(log_inc)

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]                    # j <= i

    def chunk_step(hprev, xs):
        qi, ki, vi, ld, li = xs                           # [B,L,H,...]
        cum = jnp.cumsum(ld, axis=1)                      # [B,L,H]
        # intra-chunk: S_ij = (q_i·k_j) exp(cum_i - cum_j + li_j), j<=i
        # log-space math stays f32 (decay spans ±80); the materialized
        # [B,L,L,H] weight/score tiles are bf16 with f32 accumulation —
        # halves the dominant memory-term tensors (§Perf hymba iteration).
        logw = cum[:, :, None] - cum[:, None, :] + li[:, None, :]  # [B,L,L,H]
        logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)
        w = jnp.exp(jnp.clip(logw, -_CLAMP * 4, _CLAMP)).astype(vi.dtype)
        qk = jnp.einsum("blhn,bmhn->blmh", qi, ki,
                        preferred_element_type=vi.dtype)
        y_intra = jnp.einsum("blmh,bmhp->blhp", qk * w, vi,
                             preferred_element_type=jnp.float32)
        # inter-chunk: q_i · exp(cum_i) · h_prev
        ei = jnp.exp(jnp.clip(cum, -_CLAMP * 4, _CLAMP))  # [B,L,H]
        y_inter = jnp.einsum("blhn,bhnp->blhp", qi * ei[..., None],
                             hprev.astype(qi.dtype),
                             preferred_element_type=jnp.float32)
        # new state
        tot = cum[:, -1:, :]                              # [B,1,H]
        wj = jnp.exp(jnp.clip(tot - cum + li, -_CLAMP * 4, _CLAMP))
        dstate = jnp.einsum("blhn,blhp->bhnp", ki * wj[..., None], vi,
                            preferred_element_type=jnp.float32)
        decay_tot = jnp.exp(jnp.clip(tot[:, 0], -_CLAMP * 4, _CLAMP))
        hnew = hprev * decay_tot[:, :, None, None] + dstate
        return hnew, (y_intra + y_inter).astype(v.dtype)

    from .unroll import maybe_scan
    step = jax.checkpoint(chunk_step) if chunk_remat else chunk_step
    hf, ys = maybe_scan(step, h0, (qc, kc, vc, ldc, lic))
    y = ys.swapaxes(0, 1).reshape(b, sp, h, p)[:, :s]
    return y, hf


def gla_step(hprev, q, k, v, log_decay, log_inc):
    """Single decode step. q,k: [B,H,N]; v: [B,H,P]; gates: [B,H].
    Returns (y [B,H,P], h_new)."""
    d = jnp.exp(jnp.clip(log_decay, -_CLAMP * 4, _CLAMP))[..., None, None]
    i = jnp.exp(jnp.clip(log_inc, -_CLAMP * 4, _CLAMP))[..., None, None]
    hnew = hprev * d + i * jnp.einsum("bhn,bhp->bhnp", k, v,
                                      preferred_element_type=jnp.float32)
    y = jnp.einsum("bhn,bhnp->bhp", q, hnew.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(v.dtype), hnew


# ---------------------------------------------------------------------------
# SSD (mamba-2 scalar-A) branch — hymba's parallel SSM heads
# ---------------------------------------------------------------------------

def init_ssd(key, d: int, heads: int, state: int, expand: int, dtype) -> Params:
    d_in = expand * d
    hd = d_in // heads
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, d_in, dtype),
        "w_z": dense_init(ks[1], d, d_in, dtype),
        "w_B": dense_init(ks[2], d, heads * state, dtype),
        "w_C": dense_init(ks[3], d, heads * state, dtype),
        "w_dt": dense_init(ks[4], d, heads, dtype, scale=0.02),
        "dt_bias": jnp.zeros((heads,), dtype),
        "a_log": jnp.zeros((heads,), jnp.float32),        # A = -exp(a_log)
        "d_skip": jnp.ones((heads,), dtype),
        "w_out": dense_init(ks[5], d_in, d, dtype),
    }


def _ssd_gates(p, x, heads):
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,S,H]
    a = -jnp.exp(p["a_log"])                                      # [H]
    log_decay = dt * a                                            # ≤ 0
    log_inc = jnp.log(dt + 1e-9)
    return log_decay, log_inc


def ssd_forward(p: Params, x, *, heads: int, state: int, expand: int,
                chunk: int = 128, h0=None, return_state: bool = False):
    """x: [B,S,d] -> [B,S,d] (+ final state)."""
    b, s, d = x.shape
    d_in = expand * d
    hd = d_in // heads
    xs = (x @ p["w_x"]).reshape(b, s, heads, hd)
    z = (x @ p["w_z"]).reshape(b, s, heads, hd)
    bb = (x @ p["w_B"]).reshape(b, s, heads, state)
    cc = (x @ p["w_C"]).reshape(b, s, heads, state)
    log_decay, log_inc = _ssd_gates(p, x, heads)
    y, hf = gla_chunked(cc, bb, xs, log_decay, log_inc, chunk=chunk, h0=h0)
    y = y + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y * jax.nn.silu(z)
    out = y.reshape(b, s, d_in) @ p["w_out"]
    return (out, hf) if return_state else out


def ssd_decode(p: Params, x, h, *, heads: int, state: int, expand: int):
    """x: [B,1,d]; h: [B,H,N,hd] recurrent state. Returns (out, h_new)."""
    b, _, d = x.shape
    d_in = expand * d
    hd = d_in // heads
    xs = (x @ p["w_x"]).reshape(b, heads, hd)
    z = (x @ p["w_z"]).reshape(b, heads, hd)
    bb = (x @ p["w_B"]).reshape(b, heads, state)
    cc = (x @ p["w_C"]).reshape(b, heads, state)
    ld, li = _ssd_gates(p, x, heads)
    y, hnew = gla_step(h, cc, bb, xs, ld[:, 0], li[:, 0])
    y = y + xs * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y * jax.nn.silu(z)
    return (y.reshape(b, 1, d_in) @ p["w_out"]), hnew


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, heads: int, dtype) -> Params:
    hd = d // heads
    ks = jax.random.split(key, 7)
    return {
        "w_q": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_i": dense_init(ks[3], d, heads, dtype, scale=0.02),
        "w_f": dense_init(ks[4], d, heads, dtype, scale=0.02),
        "f_bias": jnp.full((heads,), 3.0, dtype),     # open forget gates
        "w_o": dense_init(ks[5], d, d, dtype),
        "w_out": dense_init(ks[6], d, d, dtype),
    }


def _mlstm_qkv_gates(p, x, heads):
    b, s, d = x.shape
    hd = d // heads
    q = (x @ p["w_q"]).reshape(b, s, heads, hd) * (hd ** -0.5)
    k = (x @ p["w_k"]).reshape(b, s, heads, hd) * (hd ** -0.5)
    v = (x @ p["w_v"]).reshape(b, s, heads, hd)
    log_f = jax.nn.log_sigmoid(
        (x @ p["w_f"]).astype(jnp.float32) + p["f_bias"].astype(jnp.float32))
    log_i = jnp.clip((x @ p["w_i"]).astype(jnp.float32), -_CLAMP, _CLAMP)
    return q, k, v, log_f, log_i


def mlstm_forward(p: Params, x, *, heads: int, chunk: int = 128, h0=None,
                  return_state: bool = False):
    b, s, d = x.shape
    hd = d // heads
    q, k, v, log_f, log_i = _mlstm_qkv_gates(p, x, heads)
    # ones-column fold-in: engine yields numerator and normalizer together
    v_aug = jnp.concatenate([v, jnp.ones((b, s, heads, 1), v.dtype)], -1)
    y_aug, hf = gla_chunked(q, k, v_aug, log_f, log_i, chunk=chunk, h0=h0)
    num, den = y_aug[..., :hd], y_aug[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    o = jax.nn.sigmoid(x @ p["w_o"]).reshape(b, s, heads, hd)
    out = (y * o).reshape(b, s, d) @ p["w_out"]
    return (out, hf) if return_state else out


def mlstm_decode(p: Params, x, h, *, heads: int):
    b, _, d = x.shape
    hd = d // heads
    q, k, v, log_f, log_i = _mlstm_qkv_gates(p, x, heads)
    v_aug = jnp.concatenate([v, jnp.ones((b, 1, heads, 1), v.dtype)], -1)
    y_aug, hnew = gla_step(h, q[:, 0], k[:, 0], v_aug[:, 0],
                           log_f[:, 0], log_i[:, 0])
    num, den = y_aug[..., :hd], y_aug[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    o = jax.nn.sigmoid(x @ p["w_o"]).reshape(b, heads, hd)
    out = (y * o).reshape(b, 1, d) @ p["w_out"]
    return out, hnew


def mlstm_state_shape(batch: int, d: int, heads: int):
    hd = d // heads
    return (batch, heads, hd, hd + 1)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — true sequential recurrence
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),    # i, f, z, o from x
        "r_gates": dense_init(ks[1], d, 4 * d, dtype, scale=0.02),  # from h
        "b_gates": jnp.zeros((4 * d,), dtype),
        "w_out": dense_init(ks[2], d, d, dtype),
    }


def _slstm_cell(p, x_t, carry):
    """x_t: [B, 4d] pre-projected gates; carry: (h, c, n) each [B, d]."""
    h, c, n = carry
    gates = x_t + h @ p["r_gates"] + p["b_gates"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(gates.astype(jnp.float32), 4, -1)
    i = jnp.exp(jnp.clip(i_pre, -_CLAMP, _CLAMP))
    f = jax.nn.sigmoid(f_pre)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f * c + i * z
    n = f * n + i
    h_new = (o * c / jnp.maximum(jnp.abs(n), 1.0)).astype(x_t.dtype)
    return h_new, c, n


def slstm_forward(p: Params, x, carry=None, return_state: bool = False):
    b, s, d = x.shape
    if carry is None:
        carry = (jnp.zeros((b, d), x.dtype),
                 jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), jnp.float32))
    xg = x @ p["w_gates"]                                 # hoisted matmul

    def step(carry, x_t):
        new = _slstm_cell(p, x_t, carry)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry, xg.swapaxes(0, 1))
    out = hs.swapaxes(0, 1) @ p["w_out"]
    return (out, carry) if return_state else out


def slstm_decode(p: Params, x, carry):
    xg = x[:, 0] @ p["w_gates"]
    new = _slstm_cell(p, xg, carry)
    return (new[0] @ p["w_out"])[:, None], new


def slstm_state_shape(batch: int, d: int):
    return [(batch, d)] * 3
