"""Jittable train/serve step builders shared by dryrun.py, train.py and the
benchmarks."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import zoo
from repro.optim import get_optimizer
from repro.optim.schedules import cosine_schedule


def make_train_step(cfg: ArchConfig, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000
                    ) -> Tuple[Callable, Callable]:
    """Returns (train_step, opt_init). train_step: (params, opt_state,
    batch) -> (params, opt_state, metrics)."""
    opt_init, opt_update = get_optimizer(cfg.optimizer)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: zoo.loss_fn(cfg, p, batch))(params)
        lr = cosine_schedule(opt_state.step, base_lr, warmup, total_steps)
        params, opt_state = opt_update(params, grads, opt_state, lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt_init


def make_prefill_step(cfg: ArchConfig) -> Callable:
    """Forward-only loss evaluation at prefill shapes (throughput proxy for
    inference prefill; cache write-back excluded — a small bytes-only term,
    see EXPERIMENTS.md §Dry-run notes)."""
    def prefill_step(params, batch):
        return zoo.loss_fn(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """One-token decode: (params, cache, cache_len, token) ->
    (logits, new_cache)."""
    def serve_step(params, cache, cache_len, token):
        return zoo.decode_fn(cfg, params, cache, cache_len, token)

    return serve_step
