"""Flash attention with a hand-written VJP (beyond-paper optimization #1,
EXPERIMENTS.md §Perf).

Autodiff through the online-softmax scan saves every per-step probability
tile as a residual — O(S²) bytes per layer — and differentiates the
max/rescale chain op-by-op. The standard flash backward instead saves only
(q, k, v, o, lse) — O(S·d) — and recomputes probability tiles blockwise:

    D_i  = rowsum(do_i ∘ o_i)
    p_ij = exp(q_i k_jᵀ·scale − lse_i)
    dv_j += p_ijᵀ do_i
    ds_ij = p_ij ∘ (do_i v_jᵀ − D_i)
    dq_i += ds_ij k_j · scale ;  dk_j += ds_ijᵀ q_i · scale

Same blockwise structure as the forward (python loop over q blocks wraps a
scan over the causal/window KV range); dk/dv accumulate in full-size
buffers threaded through the scans via dynamic-slice updates, so peak
memory stays O(S·d) and HLO FLOPs reflect exactly 2.5× the forward matmul
work — the textbook flash cost — instead of autodiff's ~3.5×.

Interface-compatible with ``attention.flash_attention``; validated against
jax.grad of the reference in tests/test_flash_vjp.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .unroll import maybe_scan

NEG_INF = -1e30


def _ranges(sq, sk, q_block, kv_block, q_offset, causal, window, sk_real):
    """Static per-q-block KV block ranges (mirrors the forward)."""
    nq, nk = sq // q_block, sk // kv_block
    out = []
    for i in range(nq):
        if causal:
            hi_pos = q_offset + (i + 1) * q_block
            k_hi = min(nk, -(-min(hi_pos, sk_real) // kv_block))
        else:
            k_hi = nk
        if window and causal:
            k_lo = max(0, (q_offset + i * q_block - window) // kv_block)
        else:
            k_lo = 0
        out.append((k_lo, max(k_hi - k_lo, 1)))
    return out


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention_vjp(q, k, v, causal: bool = True, window: int = 0,
                        q_offset: int = 0, q_block: int = 1024,
                        kv_block: int = 1024,
                        scale: Optional[float] = None,
                        prefix_len: int = 0):
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, q_block,
                        kv_block, scale, prefix_len)
    return out


def _mask_for(qpos, kpos, causal, window, prefix_len, sk_real):
    m = kpos[None, :] < sk_real
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
        if window:
            w = qpos[:, None] - kpos[None, :] < window
            if prefix_len:
                w = w | (kpos[None, :] < prefix_len)
            m = m & w
    return m


def _flash_fwd(q, k, v, causal, window, q_offset, q_block, kv_block,
               scale, prefix_len):
    from .attention import _pad_to
    b, sq, h, hdq = q.shape
    _, sk, kh, hdv = v.shape
    g = h // kh
    scale = scale or (hdq ** -0.5)
    q_block = min(q_block, max(sq, 16))
    kv_block = min(kv_block, max(sk, 16))
    q, sq_real = _pad_to(q, q_block, axis=1)
    k, sk_real = _pad_to(k, kv_block, axis=1)
    v, _ = _pad_to(v, kv_block, axis=1)
    sqp, skp = q.shape[1], k.shape[1]
    qg = q.reshape(b, sqp, kh, g, hdq)
    ranges = _ranges(sqp, skp, q_block, kv_block, q_offset, causal, window,
                     sk_real)
    outs, lses = [], []
    for i, (k_lo, n_steps) in enumerate(ranges):
        q_i = (qg[:, i * q_block:(i + 1) * q_block] * scale).astype(q.dtype)
        qpos = q_offset + i * q_block + jnp.arange(q_block)

        def kv_step(carry, blk):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(k, blk * kv_block, kv_block, 1)
            v_j = jax.lax.dynamic_slice_in_dim(v, blk * kv_block, kv_block, 1)
            kpos = blk * kv_block + jnp.arange(kv_block)
            s_ij = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                              preferred_element_type=jnp.float32)
            msk = _mask_for(qpos, kpos, causal, window, prefix_len, sk_real)
            s_ij = jnp.where(msk[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, hdv), jnp.float32)
        (m, l, acc), _ = maybe_scan(kv_step, (m0, l0, a0),
                                    jnp.arange(k_lo, k_lo + n_steps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))            # [B,K,G,qb]
        outs.append(out.transpose(0, 3, 1, 2, 4))
        lses.append(lse)
    out = jnp.concatenate(outs, axis=1)[:, :sq_real]
    out = out.reshape(b, sq_real, h, hdv).astype(q.dtype)
    lse = jnp.stack(lses, axis=0)                           # [nq,B,K,G,qb]
    return out, lse


def _fwd_rule(q, k, v, causal, window, q_offset, q_block, kv_block, scale,
              prefix_len):
    out, lse = _flash_fwd(q, k, v, causal, window, q_offset, q_block,
                          kv_block, scale, prefix_len)
    return out, (q, k, v, out, lse)


def _bwd_rule(causal, window, q_offset, q_block, kv_block, scale,
              prefix_len, res, dout):
    from .attention import _pad_to
    q, k, v, out, lse = res
    b, sq, h, hdq = q.shape
    _, sk, kh, hdv = v.shape
    g = h // kh
    scale_v = scale or (hdq ** -0.5)
    q_blk = min(q_block, max(sq, 16))
    kv_blk = min(kv_block, max(sk, 16))
    qp, sq_real = _pad_to(q, q_blk, axis=1)
    kp, sk_real = _pad_to(k, kv_blk, axis=1)
    vp, _ = _pad_to(v, kv_blk, axis=1)
    dop, _ = _pad_to(dout, q_blk, axis=1)
    op, _ = _pad_to(out, q_blk, axis=1)
    sqp, skp = qp.shape[1], kp.shape[1]
    qg = qp.reshape(b, sqp, kh, g, hdq)
    dog = dop.reshape(b, sqp, kh, g, hdv)
    og = op.reshape(b, sqp, kh, g, hdv)
    ranges = _ranges(sqp, skp, q_blk, kv_blk, q_offset, causal, window,
                     sk_real)
    dq_blocks = []
    dk = jnp.zeros((b, skp, kh, hdq), jnp.float32)
    dv = jnp.zeros((b, skp, kh, hdv), jnp.float32)
    for i, (k_lo, n_steps) in enumerate(ranges):
        sl = slice(i * q_blk, (i + 1) * q_blk)
        q_i = qg[:, sl]
        do_i = dog[:, sl]
        o_i = og[:, sl]
        lse_i = lse[i]                                      # [B,K,G,qb]
        d_i = jnp.sum(do_i.astype(jnp.float32)
                      * o_i.astype(jnp.float32), axis=-1)   # [B,qb,K,G]
        d_i = d_i.transpose(0, 2, 3, 1)                     # [B,K,G,qb]
        qpos = q_offset + i * q_blk + jnp.arange(q_blk)

        def kv_step(carry, blk):
            dq_i, dk_acc, dv_acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(kp, blk * kv_blk, kv_blk, 1)
            v_j = jax.lax.dynamic_slice_in_dim(vp, blk * kv_blk, kv_blk, 1)
            kpos = blk * kv_blk + jnp.arange(kv_blk)
            s_ij = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                              preferred_element_type=jnp.float32) * scale_v
            msk = _mask_for(qpos, kpos, causal, window, prefix_len, sk_real)
            s_ij = jnp.where(msk[None, None, None], s_ij, NEG_INF)
            p = jnp.exp(s_ij - lse_i[..., None])            # [B,K,G,qb,kb]
            dv_j = jnp.einsum("bkgqs,bqkgh->bskh", p,
                              do_i.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgh,bskh->bkgqs", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - d_i[..., None])                  # [B,K,G,qb,kb]
            dq_i = dq_i + jnp.einsum("bkgqs,bskh->bqkgh", ds,
                                     k_j.astype(jnp.float32),
                                     preferred_element_type=jnp.float32
                                     ) * scale_v
            dk_j = jnp.einsum("bkgqs,bqkgh->bskh", ds,
                              q_i.astype(jnp.float32),
                              preferred_element_type=jnp.float32) * scale_v
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(
                    dk_acc, blk * kv_blk, kv_blk, 1) + dk_j,
                blk * kv_blk, 1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(
                    dv_acc, blk * kv_blk, kv_blk, 1) + dv_j,
                blk * kv_blk, 1)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, q_blk, kh, g, hdq), jnp.float32)
        (dq_i, dk, dv), _ = maybe_scan(
            kv_step, (dq0, dk, dv), jnp.arange(k_lo, k_lo + n_steps))
        dq_blocks.append(dq_i)
    dq = jnp.concatenate(dq_blocks, axis=1)[:, :sq_real]
    dq = dq.reshape(b, sq_real, h, hdq).astype(q.dtype)
    # NOTE: q_i in the fwd carries the scale; here ds already includes it.
    dk = dk[:, :sk].astype(k.dtype)
    dv = dv[:, :sk].astype(v.dtype)
    return dq, dk, dv


flash_attention_vjp.defvjp(_fwd_rule, _bwd_rule)
