"""Benchmark fitness functions for PSO.

The paper (§6.1, Eq. 3) uses the Cubic function and a *maximization*
convention ("if fit_i > pbest_fit_i then update"): larger fitness is better.
All functions here follow that convention; classical minimization benchmarks
(sphere, rosenbrock, ...) are negated so that every landscape is maximized.

Every function maps ``pos[..., D] -> fit[...]`` and is pure jnp so it can be
used inside jit, grad (not needed for PSO, but free), shard_map and the
Pallas reference oracle.

Each benchmark is registered as a first-class ``repro.core.problem.Problem``
(the negation is baked into ``fn`` itself, so every built-in registers with
``sense="max"`` — exactly the seed convention). The legacy views
``FITNESS_FNS`` / ``FITNESS_IDS`` / ``DEFAULT_BOUNDS`` are derived from the
registered Problems and carry the *same function objects and float bounds*
as before the registry existed, so string-configured runs are bit-identical
to seed behavior (tests/test_problem.py pins this with trajectory digests).
The hand-tuned d-major kernel forms live in
``repro.kernels.pso_step._fitness_dmajor`` and are selected by name.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from .problem import Problem, register_problem

Array = jnp.ndarray


def cubic(pos: Array) -> Array:
    """Paper Eq. 3: f = sum_i x_i^3 - 0.8 x_i^2 - 1000 x_i + 8000 (maximize)."""
    x = pos
    return jnp.sum(x * x * x - 0.8 * (x * x) - 1000.0 * x + 8000.0, axis=-1)


def sphere(pos: Array) -> Array:
    """Negated sphere: max at origin, f(0) = 0."""
    return -jnp.sum(pos * pos, axis=-1)


def rosenbrock(pos: Array) -> Array:
    """Negated Rosenbrock (D >= 2; for D == 1 degenerates to -(1-x)^2)."""
    x = pos
    if x.shape[-1] == 1:
        return -jnp.squeeze((1.0 - x) ** 2, axis=-1)
    a, b = x[..., :-1], x[..., 1:]
    return -jnp.sum(100.0 * (b - a * a) ** 2 + (1.0 - a) ** 2, axis=-1)


def griewank(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    idx = jnp.arange(1, d + 1, dtype=x.dtype)
    s = jnp.sum(x * x, axis=-1) / 4000.0
    p = jnp.prod(jnp.cos(x / jnp.sqrt(idx)), axis=-1)
    return -(s - p + 1.0)


def rastrigin(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    return -(10.0 * d + jnp.sum(x * x - 10.0 * jnp.cos(2.0 * jnp.pi * x), axis=-1))


def ackley(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    s1 = jnp.sqrt(jnp.sum(x * x, axis=-1) / d)
    s2 = jnp.sum(jnp.cos(2.0 * jnp.pi * x), axis=-1) / d
    return -(-20.0 * jnp.exp(-0.2 * s1) - jnp.exp(s2) + 20.0 + jnp.e)


# The six built-ins as registered Problems (paper: cubic on [-100, 100]).
# Declaration order fixes FITNESS_IDS, so keep it stable.
BUILTIN_PROBLEMS = tuple(register_problem(p) for p in (
    Problem(name="cubic", fn=cubic, lo=-100.0, hi=100.0),
    Problem(name="sphere", fn=sphere, lo=-100.0, hi=100.0),
    Problem(name="rosenbrock", fn=rosenbrock, lo=-30.0, hi=30.0),
    Problem(name="griewank", fn=griewank, lo=-600.0, hi=600.0),
    Problem(name="rastrigin", fn=rastrigin, lo=-5.12, hi=5.12),
    Problem(name="ackley", fn=ackley, lo=-32.0, hi=32.0),
))

# Legacy views, derived from the registry (same objects/values as the seed).
FITNESS_FNS: Dict[str, Callable[[Array], Array]] = {
    p.name: p.fn for p in BUILTIN_PROBLEMS}

# Stable integer ids for kernel-side selection (compile-time static).
FITNESS_IDS: Dict[str, int] = {name: i for i, name in enumerate(FITNESS_FNS)}

# Search-domain defaults per function.
DEFAULT_BOUNDS: Dict[str, tuple] = {
    p.name: (p.lo, p.hi) for p in BUILTIN_PROBLEMS}
