"""Roofline analysis: analytic FLOP/byte accounting for cost-model-driven
scheduling.

Two layers:

* ``analysis`` — generic machinery: hardware ceilings (``PEAK_FLOPS``,
  ``HBM_BW``, ``ICI_BW``), the ``Roofline`` report, XLA
  ``cost_analysis`` normalization, and parameter/flop counting for the
  model zoo.
* ``pso_cost`` — the PSO-specific cost model that powers the schedule
  autotuner (``repro.core.autotune``): per-iteration flop/byte counts
  for every engine variant (fitness op mix per built-in, gbest
  publication traffic as a function of ``sync_every``, adapter
  const-operand streaming, Pallas grid-step/dispatch overheads) and a
  ``Calibration`` fitted from committed benchmark history. This is what
  ``Method(schedule="auto")`` ranks candidate schedules with before the
  measured fallback.
"""
from .analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, analyze,
                       collective_bytes, count_active_params, count_params,
                       model_flops)
from .pso_cost import (DEFAULT_CALIBRATION, Calibration, IterCost, OpMix,
                       RuleMix, estimate_us_per_iter, fit_calibration,
                       fitness_op_mix, iteration_cost, rule_op_mix)

__all__ = ["Roofline", "analyze", "collective_bytes", "count_params",
           "count_active_params", "model_flops", "PEAK_FLOPS", "HBM_BW",
           "ICI_BW", "Calibration", "DEFAULT_CALIBRATION", "IterCost",
           "OpMix", "RuleMix", "estimate_us_per_iter", "fit_calibration",
           "fitness_op_mix", "iteration_cost", "rule_op_mix"]
