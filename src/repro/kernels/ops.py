"""Jitted public wrappers around the Pallas cuPSO kernels.

Handles layout packing ([N, D] particle-major library layout ↔ [Dpad, N]
D-major kernel layout), block-size selection, the queue algorithm's tiny
cross-block second stage, and SwarmState plumbing so kernels are drop-in
replacements for the ``repro.core.pso`` step functions.

``interpret`` defaults to True: this container is CPU-only and the kernels
TARGET TPU; on a real TPU pass interpret=False (the pallas_calls carry
TPU-valid BlockSpecs, dtypes and memory spaces).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocking import LANE, pick_block_n  # noqa: F401 (re-export:
# pick_block_n is the shared block-sizing helper in repro.core.blocking,
# also used by core.pso._default_async_blocks with lane=1)
from repro.core.multi_swarm import SwarmBatch
from repro.core.pso import (ASYNC_SYNC_EVERY, PSOConfig, SwarmState,
                            hetero_member_config)
from .pso_step import (fused_async_batch_call, fused_async_call,
                       fused_batch_call, fused_call,
                       hetero_fused_async_batch_call,
                       hetero_fused_batch_call, pad_dim,
                       queue_step_call)


def _resolve_block(n: int, block_n: Optional[int]) -> int:
    """Validate an explicit ``block_n`` override (the autotuner and users
    both pass them now) or fall back to the heuristic pick. Every kernel
    wrapper routes through here so a non-dividing override fails loudly at
    the call site instead of as a shape error inside the pallas_call."""
    bn = block_n or pick_block_n(n)
    if bn < 1 or n % bn:
        raise ValueError(
            f"block_n={bn} must be a positive divisor of particle_cnt={n}")
    return bn


def pack_dmajor(pos, d: int):
    """[N, D] -> [Dpad, N] (zero-padded sublanes)."""
    n = pos.shape[0]
    dpad = pad_dim(d)
    out = jnp.zeros((dpad, n), pos.dtype)
    return out.at[:d, :].set(pos.T)


def unpack_dmajor(arr, d: int):
    """[Dpad, N] -> [N, D]."""
    return arr[:d, :].T


def _cfg_kwargs(cfg: PSOConfig):
    """Static kernel parameters from a config. ``fitness`` stays a
    str | Problem (resolved to the d-major callable by the call builders
    via ``pso_step.kernel_fitness``); bounds stay scalars or per-dimension
    tuples (lowered to [Dpad, 1] columns by ``pso_step._advance_block``)."""
    cfg = cfg.resolved()
    return dict(w=cfg.w, c1=cfg.c1, c2=cfg.c2, min_pos=cfg.min_pos,
                max_pos=cfg.max_pos, max_v=cfg.max_v, fitness=cfg.fitness,
                rule=cfg.update_rule)


def state_to_kernel(s: SwarmState, d: int):
    """SwarmState -> packed kernel operands."""
    scal = jnp.stack([s.seed.astype(jnp.int32),
                      s.iteration.astype(jnp.int32)])
    return (scal,
            pack_dmajor(s.pos, d), pack_dmajor(s.vel, d),
            pack_dmajor(s.pbest_pos, d), s.pbest_fit[None, :],
            pack_dmajor(s.gbest_pos[None, :], d), s.gbest_fit[None])


def kernel_to_state(s: SwarmState, d: int, pos, vel, pbp, pbf, gp, gf,
                    iters: int) -> SwarmState:
    return s._replace(
        pos=unpack_dmajor(pos, d), vel=unpack_dmajor(vel, d),
        fit=pbf[0],  # NOTE: kernels do not retain raw fit; pbest_fit ≥ fit
        pbest_pos=unpack_dmajor(pbp, d), pbest_fit=pbf[0],
        gbest_pos=gp[:d, 0], gbest_fit=gf[0],
        iteration=s.iteration + iters,
        # sync kernels invalidate any async block-local cache; the async
        # wrapper re-attaches its (externalized) buffers afterwards
        lbest_pos=None, lbest_fit=None)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "block_n", "interpret"))
def queue_step(cfg: PSOConfig, s: SwarmState, block_n: Optional[int] = None,
               interpret: bool = True) -> SwarmState:
    """One PSO iteration via the queue kernel + jnp cross-block epilogue.

    Semantics match ``repro.core.pso.step_queue`` (stale-gbest comparison).
    """
    cfg = cfg.resolved()
    n, d = s.pos.shape
    bn = _resolve_block(n, block_n)
    scal, pos, vel, pbp, pbf, gp, gf = state_to_kernel(s, d)
    call = queue_step_call(n, d, bn, s.pos.dtype, interpret=interpret,
                           **_cfg_kwargs(cfg))
    pos, vel, pbp, pbf, aux_fit, aux_idx = call(
        scal, gp, gf, pos, vel, pbp, pbf)
    # --- 2nd kernel (paper Fig. 1), shrunk to an O(nblocks) jnp epilogue.
    wb = jnp.argmax(aux_fit)
    cand_fit = aux_fit[wb]
    take = cand_fit > s.gbest_fit
    cand_pos = jax.lax.dynamic_index_in_dim(  # §5.3: gather pos by index once
        pos, aux_idx[wb], axis=1, keepdims=True)
    gp = jnp.where(take, cand_pos, gp)
    gf = jnp.where(take, cand_fit[None], gf)
    return kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, 1)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "block_n", "interpret",
                                    "telemetry"))
def run_queue_lock_fused(cfg: PSOConfig, s: SwarmState, iters: int,
                         block_n: Optional[int] = None,
                         interpret: bool = True, telemetry: bool = False):
    """``iters`` iterations in ONE pallas_call (fused queue-lock, §4.2+).

    On TPU this is the roofline-relevant path: state stays resident, the
    global best is published in-kernel under sequential-grid serialization,
    and there are zero kernel launches or HBM round-trips per iteration.

    ``telemetry=True`` returns ``(state, counts)`` where ``counts`` is the
    in-kernel contention counter buffer ([3] int32 — see
    ``repro.telemetry.counters``); off (the default) returns the state
    alone from the byte-identical pre-telemetry program.
    """
    cfg = cfg.resolved()
    n, d = s.pos.shape
    bn = _resolve_block(n, block_n)
    scal, pos, vel, pbp, pbf, gp, gf = state_to_kernel(s, d)
    call = fused_call(n, d, iters, bn, s.pos.dtype, interpret=interpret,
                      telemetry=telemetry, **_cfg_kwargs(cfg))
    if telemetry:
        cnt = jnp.zeros((3,), jnp.int32)
        pos, vel, pbp, pbf, gp, gf, cnt = call(scal, pos, vel, pbp, pbf,
                                               gp, gf, cnt)
        return kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, iters), cnt
    pos, vel, pbp, pbf, gp, gf = call(scal, pos, vel, pbp, pbf, gp, gf)
    return kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, iters)


def pack_dmajor_batch(x, d: int):
    """[S, N, D] -> [Dpad, S*N] (swarm s owns columns [s*N, (s+1)*N))."""
    s_cnt, n, _ = x.shape
    return pack_dmajor(x.reshape(s_cnt * n, d), d)


def unpack_dmajor_batch(arr, s_cnt: int, d: int):
    """[Dpad, S*N] -> [S, N, D]."""
    n = arr.shape[1] // s_cnt
    return unpack_dmajor(arr, d).reshape(s_cnt, n, d)


def _hetero_members(cfg: PSOConfig, table):
    """Static kernel branch descriptors for a hetero dispatch table.

    Branch ``k`` closes over exactly the statics a homogeneous kernel of
    ``table[k]`` at this dim/coeffs/dtype would compile with
    (``hetero_member_config`` re-derives the member's resolved bounds).
    """
    return tuple(
        (ck.fitness, ck.min_pos, ck.max_pos, ck.max_v)
        for ck in (hetero_member_config(cfg, p) for p in table))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "block_n", "interpret",
                                    "table", "telemetry"))
def run_queue_lock_fused_batch(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                               block_n: Optional[int] = None,
                               interpret: bool = True, fids=None,
                               table=None, telemetry: bool = False):
    """S independent swarms x ``iters`` iterations in ONE pallas_call.

    The multi-swarm analogue of ``run_queue_lock_fused``: per-swarm gbest
    buffers and per-swarm ``(seed, iteration)`` RNG counters ride a third
    (swarm-major) grid dimension, so row ``s`` of the batch is bit-identical
    to ``run_queue_lock_fused`` on ``batch_row(batch, s)`` with the same
    ``block_n`` — asserted in tests/test_multi_swarm.py. On TPU this is the
    serving hot path: a whole request batch advances with zero host
    round-trips and one kernel launch.

    ``telemetry=True`` returns ``(batch, counts)`` with ``counts`` shaped
    [S, 3] — row ``s`` holds swarm ``s``'s contention counters.
    """
    cfg = cfg.resolved()
    s_cnt, n, d = batch.pos.shape
    bn = _resolve_block(n, block_n)
    seeds = batch.seed.astype(jnp.int32)
    its = batch.iteration.astype(jnp.int32)
    pos = pack_dmajor_batch(batch.pos, d)
    vel = pack_dmajor_batch(batch.vel, d)
    pbp = pack_dmajor_batch(batch.pbest_pos, d)
    pbf = batch.pbest_fit.reshape(1, s_cnt * n)
    gp = jnp.zeros((pad_dim(d), s_cnt), batch.pos.dtype).at[:d].set(
        batch.gbest_pos.T)
    gf = batch.gbest_fit
    cnt = jnp.zeros((3 * s_cnt,), jnp.int32) if telemetry else None
    if fids is None:
        call = fused_batch_call(s_cnt, n, d, iters, bn, batch.pos.dtype,
                                interpret=interpret, telemetry=telemetry,
                                **_cfg_kwargs(cfg))
        if telemetry:
            pos, vel, pbp, pbf, gp, gf, cnt = call(
                seeds, its, pos, vel, pbp, pbf, gp, gf, cnt)
        else:
            pos, vel, pbp, pbf, gp, gf = call(seeds, its, pos, vel, pbp,
                                              pbf, gp, gf)
    else:
        # Heterogeneous batch: per-swarm objective via kernel 3h. The cfg
        # contributes dim/coeffs/dtype only; bounds and objective come from
        # the member table (see ``multi_swarm.problem_rows``).
        rcfg = cfg.resolved()
        call = hetero_fused_batch_call(
            s_cnt, n, d, iters, bn, batch.pos.dtype, w=rcfg.w, c1=rcfg.c1,
            c2=rcfg.c2, members=_hetero_members(cfg, table),
            rule=rcfg.update_rule, interpret=interpret, telemetry=telemetry)
        if telemetry:
            pos, vel, pbp, pbf, gp, gf, cnt = call(
                seeds, its, fids.astype(jnp.int32), pos, vel, pbp, pbf,
                gp, gf, cnt)
        else:
            pos, vel, pbp, pbf, gp, gf = call(
                seeds, its, fids.astype(jnp.int32), pos, vel, pbp, pbf,
                gp, gf)
    pbf = pbf.reshape(s_cnt, n)
    out = batch._replace(
        pos=unpack_dmajor_batch(pos, s_cnt, d),
        vel=unpack_dmajor_batch(vel, s_cnt, d),
        fit=pbf,  # kernels do not retain raw fit; pbest_fit >= fit
        pbest_pos=unpack_dmajor_batch(pbp, s_cnt, d), pbest_fit=pbf,
        gbest_pos=gp[:d].T, gbest_fit=gf,
        iteration=batch.iteration + iters,
        lbest_pos=None, lbest_fit=None)
    if telemetry:
        return out, cnt.reshape(s_cnt, 3)
    return out


def _async_spans(iters: int, sync_every: int):
    """Split ``iters`` into (offset, span, chunk) phases for the async kernel.

    The kernel requires span % chunk == 0, so a non-multiple ``iters`` runs
    as a main phase of full ``sync_every`` chunks plus one remainder phase
    (a single shorter chunk). RNG counters chain across phases, and the
    block-local bests ride along, so the split is semantics-preserving
    (mirrored by ``ref.run_fused_async_oracle``). Degenerate inputs clamp
    the same way the jnp ``run_async`` does: ``iters <= 0`` is a no-op and
    ``sync_every`` is forced into [1, iters].
    """
    if iters <= 0:
        return []
    sync_every = max(1, min(sync_every, iters))
    main = (iters // sync_every) * sync_every
    phases = [(0, main, sync_every)]
    if iters - main:
        phases.append((main, iters - main, iters - main))
    return phases


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "sync_every", "block_n",
                                    "interpret", "telemetry"))
def run_queue_lock_fused_async(cfg: PSOConfig, s: SwarmState, iters: int,
                               sync_every: int = ASYNC_SYNC_EVERY,
                               block_n: Optional[int] = None,
                               interpret: bool = True,
                               telemetry: bool = False):
    """``iters`` iterations of the ASYNC queue-lock in one pallas_call.

    The paper's enhanced algorithm: the grid is block-major
    ``(blocks, iter_chunks)`` — each particle block stays resident for its
    whole iteration span and runs ``sync_every`` iterations per grid step
    against a block-local best, touching the shared gbest buffers only at
    chunk boundaries (pull on entry, predicated publish on exit). Each
    block's view of the swarm best is therefore at most ``sync_every``
    iterations stale. With ``block_n == n`` (a single block — the default
    pick for n ≤ 512) the local best IS the global best and the result is
    bit-identical to ``run_queue_lock_fused`` for every ``sync_every``;
    the synchronous kernel is the ``sync_every=1`` single-block special
    case of this one.

    ``telemetry=True`` returns ``(state, counts)`` ([3] int32 contention
    counters, accumulated across the remainder-phase split via the
    aliased buffer).
    """
    cfg = cfg.resolved()
    n, d = s.pos.shape
    bn = _resolve_block(n, block_n)
    nb = n // bn
    scal, pos, vel, pbp, pbf, gp, gf = state_to_kernel(s, d)
    if s.lbest_fit is not None and s.lbest_fit.shape == (nb,):
        # resume the externalized block-local bests (checkpoint/resume
        # keeps the staleness window instead of restarting it)
        lp = pack_dmajor(s.lbest_pos, d)
        lf = s.lbest_fit
    else:
        lp = jnp.tile(gp, (1, nb))             # local bests seeded from gbest
        lf = jnp.tile(gf, nb)
    cnt = jnp.zeros((3,), jnp.int32) if telemetry else None
    for off, span, chunk in _async_spans(iters, sync_every):
        call = fused_async_call(n, d, span, bn, chunk, s.pos.dtype,
                                topology=cfg.topology, interpret=interpret,
                                telemetry=telemetry, **_cfg_kwargs(cfg))
        args = (scal + jnp.array([0, off], jnp.int32),
                pos, vel, pbp, pbf, gp, gf, lp, lf)
        if telemetry:
            pos, vel, pbp, pbf, gp, gf, lp, lf, cnt = call(*args, cnt)
        else:
            pos, vel, pbp, pbf, gp, gf, lp, lf = call(*args)
    out = kernel_to_state(s, d, pos, vel, pbp, pbf, gp, gf, iters)
    out = out._replace(lbest_pos=unpack_dmajor(lp, d), lbest_fit=lf)
    if telemetry:
        return out, cnt
    return out


@functools.partial(jax.jit,
                   static_argnames=("cfg", "iters", "sync_every", "block_n",
                                    "interpret", "table", "telemetry"))
def run_queue_lock_fused_async_batch(cfg: PSOConfig, batch: SwarmBatch,
                                     iters: int,
                                     sync_every: int = ASYNC_SYNC_EVERY,
                                     block_n: Optional[int] = None,
                                     interpret: bool = True, fids=None,
                                     table=None, telemetry: bool = False):
    """S independent swarms through the async queue-lock in one pallas_call.

    Grid ``(swarms, blocks, iter_chunks)``: per-swarm gbest buffers and
    per-(swarm, block) local-best slots, so row ``s`` is bit-identical to
    ``run_queue_lock_fused_async`` on ``batch_row(batch, s)`` with the same
    ``block_n``/``sync_every``. The serving hot path for ``variant="async"``.

    ``telemetry=True`` returns ``(batch, counts)`` with [S, 3] per-swarm
    contention counters.
    """
    cfg = cfg.resolved()
    s_cnt, n, d = batch.pos.shape
    bn = _resolve_block(n, block_n)
    nb = n // bn
    seeds = batch.seed.astype(jnp.int32)
    its = batch.iteration.astype(jnp.int32)
    pos = pack_dmajor_batch(batch.pos, d)
    vel = pack_dmajor_batch(batch.vel, d)
    pbp = pack_dmajor_batch(batch.pbest_pos, d)
    pbf = batch.pbest_fit.reshape(1, s_cnt * n)
    gp = jnp.zeros((pad_dim(d), s_cnt), batch.pos.dtype).at[:d].set(
        batch.gbest_pos.T)
    gf = batch.gbest_fit
    if batch.lbest_fit is not None and batch.lbest_fit.shape == (s_cnt, nb):
        lp = pack_dmajor(batch.lbest_pos.reshape(s_cnt * nb, d), d)
        lf = batch.lbest_fit.reshape(s_cnt * nb)
    else:
        lp = jnp.repeat(gp, nb, axis=1)        # [Dpad, S*nb], swarm-major
        lf = jnp.repeat(gf, nb)
    cnt = jnp.zeros((3 * s_cnt,), jnp.int32) if telemetry else None
    for off, span, chunk in _async_spans(iters, sync_every):
        if fids is None:
            call = fused_async_batch_call(s_cnt, n, d, span, bn, chunk,
                                          batch.pos.dtype,
                                          topology=cfg.topology,
                                          interpret=interpret,
                                          telemetry=telemetry,
                                          **_cfg_kwargs(cfg))
            args = (seeds, its + jnp.int32(off), pos, vel, pbp, pbf, gp,
                    gf, lp, lf)
        else:
            rcfg = cfg.resolved()
            call = hetero_fused_async_batch_call(
                s_cnt, n, d, span, bn, chunk, batch.pos.dtype, w=rcfg.w,
                c1=rcfg.c1, c2=rcfg.c2, members=_hetero_members(cfg, table),
                rule=rcfg.update_rule, topology=cfg.topology,
                interpret=interpret, telemetry=telemetry)
            args = (seeds, its + jnp.int32(off), fids.astype(jnp.int32),
                    pos, vel, pbp, pbf, gp, gf, lp, lf)
        if telemetry:
            pos, vel, pbp, pbf, gp, gf, lp, lf, cnt = call(*args, cnt)
        else:
            pos, vel, pbp, pbf, gp, gf, lp, lf = call(*args)
    pbf = pbf.reshape(s_cnt, n)
    out = batch._replace(
        pos=unpack_dmajor_batch(pos, s_cnt, d),
        vel=unpack_dmajor_batch(vel, s_cnt, d),
        fit=pbf,  # kernels do not retain raw fit; pbest_fit >= fit
        pbest_pos=unpack_dmajor_batch(pbp, s_cnt, d), pbest_fit=pbf,
        gbest_pos=gp[:d].T, gbest_fit=gf,
        iteration=batch.iteration + iters,
        lbest_pos=unpack_dmajor(lp, d).reshape(s_cnt, nb, d),
        lbest_fit=lf.reshape(s_cnt, nb))
    if telemetry:
        return out, cnt.reshape(s_cnt, 3)
    return out


def make_fused_local_step(iters_per_call: int = 1, block_n=None,
                          interpret: bool = True):
    """Adapter: fused kernel as a ``local_step_fn`` for distributed swarms."""
    def step(cfg: PSOConfig, s: SwarmState) -> SwarmState:
        return run_queue_lock_fused(cfg, s, iters_per_call,
                                    block_n=block_n, interpret=interpret)
    return step
