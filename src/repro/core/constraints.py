"""Constrained optimization: feasibility as first-class Problem data.

cuPSO's kernels assume a pure box domain, but real PSO workloads are rarely
pure boxes — time-critical estimation (Low-Complexity PSO, arXiv 1401.0546)
and multiagent coordination (MCO convergence analysis, arXiv 1508.04973)
both optimize under feasibility constraints. ``ConstraintSet`` attaches a
frozen, hashable set of constraints to a ``repro.core.problem.Problem`` so
constrained problems travel through every layer the Problem API reaches:
the jnp step variants, the fused/async/batched Pallas kernels, the eager
oracles, the solve facade, the batched serving front end and the tuner.

Constraint forms
----------------
* ``Constraint(fn, kind="ineq")`` — inequality ``g(x) <= 0`` is feasible.
* ``Constraint(fn, kind="eq", tol=...)`` — equality ``|h(x)| <= tol``.

``fn`` is pure jnp, maps ``pos[..., D] -> residual[...]`` (one scalar per
position), and must be jit/vmap/shard_map-safe — exactly the ``Problem.fn``
contract. The aggregate **violation** of a position is::

    viol(x) = sum_i max(0, g_i(x)) + sum_j max(0, |h_j(x)| - tol_j)

so ``viol(x) == 0`` iff ``x`` is feasible.

Modes (``ConstraintSet.mode``) and backend composition
------------------------------------------------------
``penalty``
    Fitness is wrapped: canonical (maximized) fitness becomes
    ``max_fn(x) - weight * viol(x)``. Because the penalized objective is
    just another pure-jnp objective, it composes with EVERY backend for
    free: the jnp sync/async/ring engines, the serial baseline, and the
    Pallas kernels (the wrapped ``max_fn`` lowers through
    ``repro.kernels.pso_step.dmajor_adapter`` like any custom objective,
    its captured constants hoisted by ``lower_statics``). An adaptive ramp
    (``ramp``/``ramp_every``) multiplies the weight per segment; the solve
    facade applies it by segmenting the run and re-weighting the carried
    pbest/gbest fitness at each boundary, so the ramp also works on every
    backend (see ``repro.api``).
``projection``
    Positions are projected back onto the feasible set by a user operator
    ``projection(pos[..., N, D]) -> pos`` applied AFTER the box clip (the
    box-clip composition), both at init and after every advance — the
    post-advance hook in ``repro.core.pso._advance``, ``core.serial``, and
    (lowered to the d-major tile layout, constants hoisted) inside all
    Pallas kernel bodies via ``pso_step.lower_statics``. The declared
    ``constraints`` are then only used for violation REPORTING; projected
    swarms stay feasible by construction (up to the constraint ``tol``).
``repair``
    Infeasible particles are resampled at init time (``repair_tries``
    fresh draws from the box; the first feasible draw wins, an
    always-infeasible particle keeps its original draw). The dynamics stay
    unconstrained — feasibility preference happens at selection/reporting
    time through the Deb rule (below). Because repair only touches
    ``init_swarm`` (and the serial mirror), it composes with every backend
    trivially: kernels receive an already-repaired state.

The Deb feasibility rule
------------------------
Results of constrained solves are compared with Deb's standard rule
(K. Deb, "An efficient constraint handling method for genetic algorithms",
2000): (1) a feasible solution beats any infeasible one, (2) two feasible
solutions compare on fitness, (3) two infeasible solutions compare on
violation (smaller wins). ``repro.best`` implements this over a batch of
``Result``s and degenerates to plain max-fitness for unconstrained
problems (everything is feasible at violation zero). For ``projection``
and ``repair`` modes the SAME rule also drives the engine-level *pbest*
selection (``deb_improved`` below, threaded through the jnp step
functions, the Pallas kernel bodies, and the validating oracles): a
feasible personal best is never displaced by a higher-fitness infeasible
candidate, so with the feasibility-seeking init the pbest population stays
feasible and the pbest-sourced gbest publication rules need no change.
``penalty`` mode deliberately stays on raw canonical fitness — the penalty
IS the feasibility pressure, already baked into ``Problem.max_fn`` — which
also keeps unconstrained and penalty-mode jaxprs bit-identical to the
pre-Deb engines.

Hashability: ``Constraint``/``ConstraintSet`` are frozen dataclasses (jit
static-argument safe), and their CONTENT (mode, weights, constraint
bytecode/closures) enters ``Problem.cache_key()`` so the serving layer can
never batch two differently-constrained objectives into one compiled
program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from . import rng
from .problem import Problem, register_problem

Array = jnp.ndarray

MODES = ("penalty", "projection", "repair")


def deb_improved(fit_new: Array, viol_new: Array, fit_old: Array,
                 viol_old: Array) -> Array:
    """Deb-rule selection mask: True where the new point displaces the old.

    (1) A feasible point (violation <= 0) beats any infeasible one, (2) two
    feasible points compare on canonical fitness, (3) two infeasible points
    compare on aggregate violation (smaller wins). Strict comparisons
    throughout, so ties keep the incumbent — exactly like the raw
    ``fit > pbest`` fold this replaces, to which it degenerates when both
    violations are zero. Shared by the jnp step functions
    (``pso.deb_selection_fn``), the Pallas kernel bodies
    (``pso_step._pbest_improved``) and the eager oracles, so the bit-exact
    validation chain compares like with like.
    """
    feas_new = viol_new <= 0.0
    feas_old = viol_old <= 0.0
    return ((feas_new & ~feas_old)
            | (feas_new & feas_old & (fit_new > fit_old))
            | (~feas_new & ~feas_old & (viol_new < viol_old)))


@dataclasses.dataclass(frozen=True)
class Constraint:
    """One scalar constraint residual.

    ``kind="ineq"``: feasible iff ``fn(x) <= 0``.
    ``kind="eq"``:   feasible iff ``|fn(x)| <= tol``.
    """

    fn: Callable
    kind: str = "ineq"
    tol: float = 1e-6
    name: str = ""

    def __post_init__(self):
        if self.kind not in ("ineq", "eq"):
            raise ValueError(
                f"kind must be 'ineq' or 'eq', got {self.kind!r}")
        if not callable(self.fn):
            raise TypeError("Constraint.fn must be callable")
        if self.tol < 0:
            raise ValueError(f"tol must be >= 0, got {self.tol}")

    def violation(self, pos):
        """Per-position violation contribution (0 where satisfied)."""
        r = self.fn(pos)
        if self.kind == "eq":
            return jnp.maximum(jnp.abs(r) - self.tol, 0.0)
        return jnp.maximum(r, 0.0)


@dataclasses.dataclass(frozen=True)
class ConstraintSet:
    """A frozen set of constraints plus the handling mode (see module doc).

    ``weight`` is the penalty coefficient in canonical (maximization)
    fitness units per unit of violation. ``ramp``/``ramp_every`` describe
    the optional adaptive schedule: segment ``k`` (of ``ramp_every``
    iterations) runs with ``weight * ramp**k`` — applied by the solve
    facade, a no-op when ``ramp_every == 0`` or ``ramp == 1``.
    """

    constraints: Tuple[Constraint, ...] = ()
    mode: str = "penalty"
    weight: float = 1000.0
    ramp: float = 1.0
    ramp_every: int = 0
    projection: Optional[Callable] = None
    repair_tries: int = 8

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        cons = tuple(self.constraints)
        if not all(isinstance(c, Constraint) for c in cons):
            raise TypeError("constraints must be Constraint instances")
        object.__setattr__(self, "constraints", cons)
        if self.mode == "projection":
            if self.projection is None:
                raise ValueError(
                    "mode='projection' needs a projection= operator "
                    "(pos[..., D] -> pos on the feasible set)")
        elif self.projection is not None:
            raise ValueError(
                f"projection= only applies to mode='projection', "
                f"not {self.mode!r}")
        if self.mode in ("penalty", "repair") and not cons:
            raise ValueError(
                f"mode={self.mode!r} needs at least one Constraint")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.ramp <= 0 or self.ramp_every < 0 or self.repair_tries < 1:
            raise ValueError(
                f"need ramp > 0, ramp_every >= 0, repair_tries >= 1; got "
                f"{self.ramp}/{self.ramp_every}/{self.repair_tries}")

    # -- violation ---------------------------------------------------------
    def violation_fn(self) -> Callable:
        """Pure-jnp aggregate violation ``pos[..., D] -> viol[...] >= 0``.

        Cached on the instance so jit tracing sees a stable callable.
        """
        cached = self.__dict__.get("_violation_fn")
        if cached is None:
            cons = self.constraints

            def viol(pos):
                if not cons:
                    return jnp.zeros(jnp.shape(pos)[:-1],
                                     jnp.result_type(pos))
                total = cons[0].violation(pos)
                for c in cons[1:]:
                    total = total + c.violation(pos)
                return total

            object.__setattr__(self, "_violation_fn", viol)
            cached = viol
        return cached

    def violation(self, pos):
        return self.violation_fn()(pos)

    def with_weight(self, weight: float) -> "ConstraintSet":
        """The same set at a different (ramped) penalty weight."""
        return dataclasses.replace(self, weight=float(weight))

    # -- content identity (see problem._hash_value) ------------------------
    def _content(self) -> Tuple:
        """Hashable decomposition for ``Problem.cache_key`` — explicit
        fields + raw callables (recursed into by ``problem._hash_fn``),
        never ``repr`` (dataclass reprs embed function addresses)."""
        return ("cset", self.mode, self.weight, self.ramp, self.ramp_every,
                self.repair_tries, self.projection,
                tuple((c.kind, c.tol, c.name, c.fn)
                      for c in self.constraints))


def repair_init_positions(cset: ConstraintSet, viol_fn: Callable, pos,
                          lo, span, seed, stream: int, idx, dtype):
    """Resample infeasible initial positions (mode="repair").

    Up to ``cset.repair_tries`` fresh box draws per particle, using the
    counter RNG at ``iteration = attempt`` on the init-position stream
    (attempts 1..tries never collide with the init draw at iteration 0 or
    the advance streams). The FIRST feasible draw wins; a particle with no
    feasible draw keeps its original sample (the Deb rule at the facade
    still ranks it last). Pure where-folds over a static attempt count:
    vmap-safe, so batched/serving inits repair identically per row.
    """
    feas = viol_fn(pos) <= 0.0
    for attempt in range(1, cset.repair_tries + 1):
        u = rng.uniform(seed, attempt, stream, idx, dtype=dtype)
        cand = lo + span * u
        take = (~feas) & (viol_fn(cand) <= 0.0)
        pos = jnp.where(take[..., None], cand, pos)
        feas = feas | take
    return pos


# --------------------------------------------------------------------------
# Ready-made operators + the sphere-on-simplex built-ins.
# --------------------------------------------------------------------------

def project_simplex(pos, radius: float = 1.0):
    """Euclidean projection of ``pos[..., D]`` onto the probability simplex
    ``{x : x >= 0, sum(x) = radius}`` (Duchi et al. 2008, sort-based).

    Pure jnp with static shapes — jit/vmap-safe, and lowers into the Pallas
    kernels through the projection const-threading in ``pso_step``.
    """
    d = pos.shape[-1]
    u = jnp.sort(pos, axis=-1)[..., ::-1]              # descending
    css = jnp.cumsum(u, axis=-1) - radius
    k = jnp.arange(1, d + 1, dtype=pos.dtype)
    rho = jnp.sum((u - css / k > 0).astype(jnp.int32), axis=-1)
    rho = jnp.maximum(rho, 1)                          # numerical guard
    theta = (jnp.take_along_axis(css, rho[..., None] - 1, axis=-1)
             / rho[..., None].astype(pos.dtype))
    return jnp.maximum(pos - theta, 0.0)


def _simplex_sum(x):
    return jnp.sum(x, axis=-1) - 1.0


def _simplex_nonneg(x):
    return jnp.max(-x, axis=-1)


def simplex_constraints(tol: float = 1e-5) -> Tuple[Constraint, ...]:
    """``sum(x) == 1`` (within ``tol``) and ``x >= 0``."""
    return (Constraint(fn=_simplex_sum, kind="eq", tol=tol, name="sum=1"),
            Constraint(fn=_simplex_nonneg, kind="ineq", name="x>=0"))


def _sphere_obj(x):
    """Sphere in the problem's OWN (minimization) sense."""
    return jnp.sum(x * x, axis=-1)


# The first non-box built-in workload: minimize ||x||^2 on the probability
# simplex (optimum x_i = 1/D, f = 1/D). Registered in both constraint
# modes so penalty-vs-projection is benchmark-able on the same landscape
# (benchmarks/run.py::constrained).
SPHERE_SIMPLEX = register_problem(Problem(
    name="sphere_simplex", fn=_sphere_obj, lo=0.0, hi=1.0, sense="min",
    constraints=ConstraintSet(constraints=simplex_constraints(),
                              mode="projection",
                              projection=project_simplex)))

SPHERE_SIMPLEX_PENALTY = register_problem(Problem(
    name="sphere_simplex_pen", fn=_sphere_obj, lo=0.0, hi=1.0, sense="min",
    constraints=ConstraintSet(constraints=simplex_constraints(),
                              mode="penalty", weight=50.0)))


# --------------------------------------------------------------------------
# CLI presets: tiny expression grammar for pso_run --constraint.
# --------------------------------------------------------------------------

# "<reduce>(x) <op> <float>" with reduce in _REDUCERS; plus the named
# preset "simplex" (handled by constraint_set_from_cli: it implies the
# simplex constraint pair and, in projection mode, project_simplex).
_REDUCERS = {
    "sum": lambda x: jnp.sum(x, axis=-1),
    "norm": lambda x: jnp.sqrt(jnp.sum(x * x, axis=-1)),
    "norm2": lambda x: jnp.sum(x * x, axis=-1),
    "min": lambda x: jnp.min(x, axis=-1),
    "max": lambda x: jnp.max(x, axis=-1),
}
_SPEC_RE = re.compile(
    r"^\s*(sum|norm2|norm|min|max)\(x\)\s*(<=|>=|==)\s*"
    r"([-+]?\d+(?:\.\d*)?(?:[eE][-+]?\d+)?)\s*$")


def constraint_from_spec(spec: str, tol: float = 1e-5) -> Constraint:
    """Parse an expression preset like ``"sum(x)<=1"`` into a Constraint.

    Grammar: ``reduce(x) op value`` with ``reduce`` in
    sum|norm|norm2|min|max and ``op`` in ``<= | >= | ==``. Used by the
    ``pso_run --constraint`` CLI; library users construct ``Constraint``
    directly.
    """
    m = _SPEC_RE.match(spec)
    if not m:
        raise ValueError(
            f"cannot parse constraint spec {spec!r}; expected e.g. "
            f"'sum(x)<=1', 'norm(x)<=2.5', 'min(x)>=0', 'sum(x)==1', "
            f"or the named preset 'simplex'")
    red, op, val = _REDUCERS[m.group(1)], m.group(2), float(m.group(3))
    if op == "<=":
        fn = lambda x, _r=red, _v=val: _r(x) - _v
        kind = "ineq"
    elif op == ">=":
        fn = lambda x, _r=red, _v=val: _v - _r(x)
        kind = "ineq"
    else:
        fn = lambda x, _r=red, _v=val: _r(x) - _v
        kind = "eq"
    return Constraint(fn=fn, kind=kind, tol=tol, name=spec.strip())


def constraint_set_from_cli(specs: Sequence[str], mode: str = "penalty",
                            weight: float = 1000.0) -> ConstraintSet:
    """Build a ConstraintSet from CLI ``--constraint`` specs.

    The named preset ``"simplex"`` expands to the simplex constraint pair
    and (in projection mode) supplies ``project_simplex``; expression
    specs only support penalty/repair modes — a general ``g(x) <= 0`` has
    no automatic projection operator.
    """
    specs = list(specs)
    cons: list = []
    projection = None
    for s in specs:
        if s.strip() == "simplex":
            cons.extend(simplex_constraints())
            projection = project_simplex
        else:
            cons.append(constraint_from_spec(s))
    if mode == "projection" and projection is None:
        raise ValueError(
            "mode='projection' from the CLI requires the 'simplex' preset "
            "(expression constraints have no automatic projection operator);"
            " use --constraint-mode penalty or repair")
    return ConstraintSet(
        constraints=tuple(cons), mode=mode, weight=weight,
        projection=projection if mode == "projection" else None)


def constrain_problem(problem: Union[str, Problem], cset: ConstraintSet,
                      name: Optional[str] = None) -> Problem:
    """A copy of ``problem`` carrying ``cset`` (drops any hand-tuned
    ``kernel_fn`` — it could not apply the penalty/projection)."""
    from .problem import resolve_problem
    base = resolve_problem(problem)
    return dataclasses.replace(
        base, name=name or f"{base.name}_constrained", constraints=cset,
        kernel_fn=None)
