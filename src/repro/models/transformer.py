"""Decoder-only LM assembly for all non-enc-dec families.

Layer stacks are ``lax.scan``-ned over stacked parameters [L, ...] so the
HLO stays one-layer-sized regardless of depth (qwen1.5-110b's 80 layers
compile as fast as 2). Non-uniform archs are handled structurally:

  * hymba    — SWA layers scanned in two runs around the 3 unrolled
               global-attention layers (exact interleave 0/16/31), so SWA
               layers keep their O(S·W) flash path and global layers their
               O(S²/2) path — no masking-only fake windows that would
               inflate HLO FLOPs.
  * xlstm    — outer scan over groups of (slstm_group-1 mLSTM + 1 sLSTM).

Remat policy per config: "full" (checkpoint whole layer), "dots"
(checkpoint_dots), "nothing".
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import (chunked_xent, dense_init, embed_init, init_mlp, mlp,
                     rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


def _remat(fn, mode: str):
    if mode == "nothing":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, key, kind: str) -> Params:
    """kind: dense | moe | hybrid | mlstm | slstm."""
    dt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    if kind == "mlstm":
        return {"ln": rmsnorm_init(d, dt),
                "mlstm": ssm.init_mlstm(ks[0], d, cfg.n_heads, dt)}
    if kind == "slstm":
        return {"ln": rmsnorm_init(d, dt),
                "slstm": ssm.init_slstm(ks[0], d, dt)}
    p: Params = {"ln1": rmsnorm_init(d, dt), "ln2": rmsnorm_init(d, dt)}
    if cfg.mla:
        p["attn"] = attn.init_mla(
            ks[0], d, cfg.n_heads, q_rank=cfg.q_rank, kv_rank=cfg.kv_rank,
            rope_hd=cfg.rope_head_dim, nope_hd=cfg.nope_head_dim,
            v_hd=cfg.v_head_dim, dtype=dt)
    else:
        p["attn"] = attn.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads, hd,
                                  cfg.qkv_bias, dt)
    if kind == "hybrid":
        p["ssd"] = ssm.init_ssd(ks[1], d, cfg.ssm_heads, cfg.ssm_state,
                                cfg.ssm_expand, dt)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[2], d, cfg.d_ff, cfg.n_experts,
                                    cfg.act, dt)
        if cfg.dense_residual:
            p["dense_mlp"] = init_mlp(ks[3], d, cfg.dense_residual_ff,
                                      cfg.act, dt)
    elif cfg.d_ff:
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.act, dt)
    return p


def _attn_kwargs(cfg: ArchConfig, window: int):
    return dict(h=cfg.n_heads, kh=cfg.n_kv_heads, hd=cfg.resolved_head_dim,
                theta=cfg.rope_theta, window=window,
                prefix_len=cfg.meta_tokens,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block,
                use_custom_vjp=cfg.flash_custom_vjp)


def _mla_kwargs(cfg: ArchConfig):
    return dict(h=cfg.n_heads, q_rank=cfg.q_rank, kv_rank=cfg.kv_rank,
                rope_hd=cfg.rope_head_dim, nope_hd=cfg.nope_head_dim,
                v_hd=cfg.v_head_dim, theta=cfg.rope_theta, eps=cfg.norm_eps,
                q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)


def _apply_layer(cfg: ArchConfig, lp: Params, x, positions, kind: str,
                 window: int):
    """Training/prefill forward for one layer. Returns (x, aux_loss)."""
    aux = jnp.float32(0)
    if kind == "mlstm":
        return x + ssm.mlstm_forward(lp["mlstm"], rmsnorm(lp["ln"], x,
                                                          cfg.norm_eps),
                                     heads=cfg.n_heads,
                                     chunk=cfg.ssm_chunk), aux
    if kind == "slstm":
        return x + ssm.slstm_forward(lp["slstm"], rmsnorm(lp["ln"], x,
                                                          cfg.norm_eps)), aux
    from .policy import constrain
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a = attn.mla_forward(lp["attn"], h, positions, **_mla_kwargs(cfg))
    else:
        a = attn.gqa_forward(lp["attn"], h, positions,
                             **_attn_kwargs(cfg, window))
    if kind == "hybrid":
        s = ssm.ssd_forward(lp["ssd"], h, heads=cfg.ssm_heads,
                            state=cfg.ssm_state, expand=cfg.ssm_expand,
                            chunk=cfg.ssm_chunk)
        a = 0.5 * (a + s)                    # hymba: parallel heads, fused
    x = constrain(x + a, ("dp", None, None))
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        m, aux = moe_mod.moe_apply(lp["moe"], h2, n_experts=cfg.n_experts,
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   act=cfg.act,
                                   group_tokens=cfg.moe_group_tokens,
                                   expert_sharding=cfg.moe_expert_sharding)
        if cfg.dense_residual:
            m = m + mlp(lp["dense_mlp"], h2, cfg.act)
        x = x + m
    elif cfg.d_ff:
        x = x + mlp(lp["mlp"], h2, cfg.act)
    return constrain(x, ("dp", None, None)), aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def _stacked_init(cfg: ArchConfig, key, kind: str, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(cfg, k, kind))(keys)


def _layer_plan(cfg: ArchConfig):
    """Structural plan of the layer stack."""
    if cfg.xlstm:
        g = cfg.slstm_group
        n_groups = cfg.n_layers // g
        return ("xlstm", n_groups, g)
    if cfg.hybrid_ssm:
        return ("hymba",)
    kind = "moe" if cfg.moe else ("hybrid" if cfg.hybrid_ssm else "dense")
    return ("uniform", kind)


def init_params(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_head, k_meta = jax.random.split(key, 4)
    p: Params = {"embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dt),
                 "final_norm": rmsnorm_init(cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    if cfg.meta_tokens:
        p["meta"] = (jax.random.normal(k_meta, (cfg.meta_tokens, cfg.d_model),
                                       jnp.float32) * 0.02).astype(dt)
    plan = _layer_plan(cfg)
    if plan[0] == "xlstm":
        _, n_groups, g = plan
        km, ks_ = jax.random.split(k_layers)
        m_keys = jax.random.split(km, n_groups * (g - 1))
        m_stack = jax.vmap(lambda k: _init_layer(cfg, k, "mlstm"))(m_keys)
        m_stack = jax.tree.map(
            lambda a: a.reshape(n_groups, g - 1, *a.shape[1:]), m_stack)
        p["layers"] = {"m": m_stack,
                       "s": _stacked_init(cfg, ks_, "slstm", n_groups)}
    elif plan[0] == "hymba":
        kg, ks_ = jax.random.split(k_layers)
        n_global = len(cfg.global_attn_layers)
        p["layers"] = {
            "global": _stacked_init(cfg, kg, "hybrid", n_global),
            "swa": _stacked_init(cfg, ks_, "hybrid",
                                 cfg.n_layers - n_global)}
    else:
        p["layers"] = _stacked_init(cfg, k_layers, plan[1], cfg.n_layers)
    return p


# ---------------------------------------------------------------------------
# Forward (training / prefill), scan over layers
# ---------------------------------------------------------------------------

def _scan_stack(cfg: ArchConfig, stacked: Params, x, positions, kind: str,
                window: int):
    body = _remat(
        functools.partial(_apply_layer, cfg, positions=positions, kind=kind,
                          window=window), cfg.remat)

    def step(carry, lp):
        x, aux = carry
        x, a = body(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0)), stacked)
    return x, aux


def _hymba_segments(cfg: ArchConfig):
    """Yield ('global', idx) and ('swa', start, count) in layer order."""
    gl = sorted(cfg.global_attn_layers)
    segs = []
    prev = 0
    swa_seen = 0
    for gi, g in enumerate(gl):
        if g > prev:
            segs.append(("swa", swa_seen, g - prev))
            swa_seen += g - prev
        segs.append(("global", gi))
        prev = g + 1
    if prev < cfg.n_layers:
        segs.append(("swa", swa_seen, cfg.n_layers - prev))
    return segs


def forward(cfg: ArchConfig, params: Params, tokens,
            extra_embeds: Optional[jnp.ndarray] = None):
    """tokens: [B, S_text]; extra_embeds (vlm frames/patches): [B, P, d].
    Returns (hidden [B, S_total, d], aux_loss, n_prefix) where n_prefix =
    meta + extra positions that carry no loss."""
    x = jnp.take(params["embed"], tokens, axis=0)
    n_prefix = 0
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        n_prefix += extra_embeds.shape[1]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None],
                                (x.shape[0], cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
        n_prefix += cfg.meta_tokens
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux = jnp.float32(0)
    plan = _layer_plan(cfg)
    if plan[0] == "xlstm":
        def group_step(carry, gp):
            x, aux = carry
            for i in range(cfg.slstm_group - 1):
                lp = jax.tree.map(lambda a: a[i], gp["m"])
                x, a = _remat(functools.partial(
                    _apply_layer, cfg, positions=positions, kind="mlstm",
                    window=0), cfg.remat)(lp, x)
                aux = aux + a
            x, a = _remat(functools.partial(
                _apply_layer, cfg, positions=positions, kind="slstm",
                window=0), cfg.remat)(gp["s"], x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(group_step, (x, aux), params["layers"])
    elif plan[0] == "hymba":
        for seg in _hymba_segments(cfg):
            if seg[0] == "global":
                lp = jax.tree.map(lambda a: a[seg[1]],
                                  params["layers"]["global"])
                x, a = _apply_layer(cfg, lp, x, positions, "hybrid", 0)
                aux = aux + a
            else:
                _, start, count = seg
                sub = jax.tree.map(lambda a: a[start:start + count],
                                   params["layers"]["swa"])
                x, a = _scan_stack(cfg, sub, x, positions, "hybrid",
                                   cfg.swa_window)
                aux = aux + a
    else:
        x, aux = _scan_stack(cfg, params["layers"], x, positions, plan[1], 0)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux, n_prefix


def unembed_matrix(cfg: ArchConfig, params: Params):
    return (params["embed"].T if cfg.tie_embeddings else params["unembed"])


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    """batch: tokens [B,S], labels [B,S] (−1 = masked), optional
    vision_embeds. Returns scalar loss (fp32)."""
    h, aux, n_prefix = forward(cfg, params, batch["tokens"],
                               batch.get("vision_embeds"))
    h = h[:, n_prefix:]                       # loss only over text positions
    nll = chunked_xent(h, unembed_matrix(cfg, params), batch["labels"],
                       cfg.loss_chunk, pad_vocab=cfg.pad_vocab)
    return nll + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve_step) with caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Cache pytree for one-token decode; shapes are family-specific."""
    dt = jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    if cfg.xlstm:
        g = cfg.slstm_group
        ng = L // g
        return {
            "m": jnp.zeros((ng, g - 1,
                            *ssm.mlstm_state_shape(batch, cfg.d_model,
                                                   cfg.n_heads)), jnp.float32),
            "s": [jnp.zeros((ng, batch, cfg.d_model),
                            jnp.float32 if i else dt) for i in range(3)],
        }
    total = max_len + cfg.meta_tokens
    if cfg.mla:
        return {"c_kv": jnp.zeros((L, batch, total, cfg.kv_rank), dt),
                "k_rope": jnp.zeros((L, batch, total, cfg.rope_head_dim), dt)}
    if cfg.hybrid_ssm:
        d_in = cfg.ssm_expand * cfg.d_model

        def sub(n):
            return {"k": jnp.zeros((n, batch, total, cfg.n_kv_heads, hd), dt),
                    "v": jnp.zeros((n, batch, total, cfg.n_kv_heads, hd), dt),
                    "ssm": jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_state,
                                      d_in // cfg.ssm_heads), jnp.float32)}

        ng = len(cfg.global_attn_layers)
        return {"global": sub(ng), "swa": sub(L - ng)}
    return {"k": jnp.zeros((L, batch, total, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((L, batch, total, cfg.n_kv_heads, hd), dt)}


def _decode_layer(cfg: ArchConfig, lp, cache_l, x, cache_len, kind, window):
    if kind == "mlstm":
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        out, st = ssm.mlstm_decode(lp["mlstm"], h, cache_l,
                                   heads=cfg.n_heads)
        return x + out, st
    if kind == "slstm":
        h = rmsnorm(lp["ln"], x, cfg.norm_eps)
        out, st = ssm.slstm_decode(lp["slstm"], h, tuple(cache_l))
        return x + out, list(st)
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a, new_cache = attn.mla_decode(
            lp["attn"], h, cache_l, cache_len,
            **{k: v for k, v in _mla_kwargs(cfg).items()
               if k not in ("q_block", "kv_block")})
    else:
        kw = _attn_kwargs(cfg, window)
        for drop in ("q_block", "kv_block", "use_custom_vjp"):
            kw.pop(drop, None)
        kw["window_only_reads"] = cfg.swa_window_decode
        kv_cache = {"k": cache_l["k"], "v": cache_l["v"]}
        a, new_cache = attn.gqa_decode(lp["attn"], h, kv_cache, cache_len,
                                       **kw)
    if kind == "hybrid":
        s_out, ssm_state = ssm.ssd_decode(
            lp["ssd"], h, cache_l["ssm"], heads=cfg.ssm_heads,
            state=cfg.ssm_state, expand=cfg.ssm_expand)
        a = 0.5 * (a + s_out)
        new_cache = dict(new_cache, ssm=ssm_state)
    x = x + a
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        m, _ = moe_mod.moe_apply(lp["moe"], h2, n_experts=cfg.n_experts,
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act, group_tokens=x.shape[0],
                                 expert_sharding=cfg.moe_expert_sharding)
        if cfg.dense_residual:
            m = m + mlp(lp["dense_mlp"], h2, cfg.act)
        x = x + m
    elif cfg.d_ff:
        x = x + mlp(lp["mlp"], h2, cfg.act)
    return x, new_cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params, cache_len,
                token):
    """One-token decode. token: [B, 1] int32; cache_len: [] int32 —
    number of positions already in the cache (incl. meta tokens).
    Returns (logits [B, V], new_cache)."""
    x = jnp.take(params["embed"], token, axis=0)
    plan = _layer_plan(cfg)
    if plan[0] == "xlstm":
        def group_step(x, gc):
            gp, cc = gc
            new_m = []
            for i in range(cfg.slstm_group - 1):
                lp = jax.tree.map(lambda a: a[i], gp["m"])
                x, st = _decode_layer(cfg, lp, cc["m"][i], x, cache_len,
                                      "mlstm", 0)
                new_m.append(st)
            x, s_st = _decode_layer(cfg, gp["s"],
                                    [c for c in cc["s"]], x,
                                    cache_len, "slstm", 0)
            return x, {"m": jnp.stack(new_m), "s": s_st}

        def scan_body(x, gc):
            x, nc = group_step(x, gc)
            return x, nc

        cache_in = {"m": cache["m"], "s": cache["s"]}
        x, new_cache = jax.lax.scan(scan_body, x,
                                    (params["layers"], cache_in))
    elif plan[0] == "hymba":
        gi_ct, sw_ct = 0, 0
        new_g, new_s = [], []
        for seg in _hymba_segments(cfg):
            if seg[0] == "global":
                lp = jax.tree.map(lambda a: a[seg[1]],
                                  params["layers"]["global"])
                cl = jax.tree.map(lambda a: a[gi_ct], cache["global"])
                x, nc = _decode_layer(cfg, lp, cl, x, cache_len, "hybrid", 0)
                new_g.append(nc)
                gi_ct += 1
            else:
                _, start, count = seg
                for i in range(count):
                    lp = jax.tree.map(lambda a: a[start + i],
                                      params["layers"]["swa"])
                    cl = jax.tree.map(lambda a: a[start + i], cache["swa"])
                    x, nc = _decode_layer(cfg, lp, cl, x, cache_len,
                                          "hybrid", cfg.swa_window)
                    new_s.append(nc)
        stack = lambda lst: jax.tree.map(lambda *a: jnp.stack(a), *lst)
        new_cache = {"global": stack(new_g), "swa": stack(new_s)}
    else:
        kind = plan[1]

        def body(x, lc):
            lp, cl = lc
            x, nc = _decode_layer(cfg, lp, cl, x, cache_len, kind, 0)
            return x, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x[:, 0] @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, new_cache
