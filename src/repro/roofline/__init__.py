from .analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, analyze,
                       collective_bytes, count_active_params, count_params,
                       model_flops)

__all__ = ["Roofline", "analyze", "collective_bytes", "count_params",
           "count_active_params", "model_flops", "PEAK_FLOPS", "HBM_BW",
           "ICI_BW"]
