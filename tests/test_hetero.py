"""Heterogeneous multi-problem batching: per-row ``lax.switch`` dispatch
through the jnp engine (``solve_many(problems=...)``), the batched fused
Pallas kernels (``fids``/``table``), the ``repro.solve_many`` facade and
the serving layer's registry coalescing.

Exactness assertions follow the validated envelope (see the
heterogeneous-dispatch notes in ``repro.core.pso``): trajectory fields
(pos/vel/pbest_pos) and the gbest fields are bitwise at the validated
shapes; fitness-VALUED fields (fit/pbest_fit) may round 1-2 ulp on
griewank/rastrigin rows in the jnp engine (the vmapped switch evaluates
every branch via select_n, which perturbs the fitness reduction's fusion),
and rosenbrock rows drift a few ulp in the sync kernel (its pair-coupled
FMA chain rounds differently inside a real conditional branch).
"""
import numpy as np
import pytest

import repro
from repro.core import PSOConfig, init_swarm, solve
from repro.core.multi_swarm import (batch_row, hetero_fid, init_batch,
                                    problem_rows, solve_many)
from repro.core.problem import Problem, resolve_problem
from repro.kernels import ops

ALL_BUILTINS = ["cubic", "sphere", "rosenbrock", "griewank", "rastrigin",
                "ackley"]
SEEDS = list(range(len(ALL_BUILTINS)))
TRAJ_FIELDS = ("pos", "vel", "pbest_pos")
FIT_FIELDS = ("fit", "pbest_fit")


def _cfg(dim, n, fitness="cubic"):
    return PSOConfig(dim=dim, particle_cnt=n, fitness=fitness).resolved()


def test_hetero_fid_eligibility():
    assert hetero_fid("sphere") is not None
    assert hetero_fid(resolve_problem("rastrigin")) is not None
    custom = Problem(name="mine", fn=lambda x: -(x * x).sum(-1),
                     lo=-1.0, hi=1.0)
    assert hetero_fid(custom) is None
    # a lookalike re-built sphere is NOT the registered instance
    sphere = resolve_problem("sphere")
    lookalike = Problem(name="sphere", fn=lambda x: -(x * x).sum(-1),
                        lo=sphere.lo, hi=sphere.hi)
    assert hetero_fid(lookalike) is None


def test_problem_rows_bounds_match_standalone_configs():
    rows, table = problem_rows(ALL_BUILTINS, 3, "float32")
    for s, nm in enumerate(ALL_BUILTINS):
        r = PSOConfig(dim=3, fitness=nm).resolved()
        np.testing.assert_array_equal(np.asarray(rows.lo[s]),
                                      np.full(3, r.min_pos, np.float32))
        np.testing.assert_array_equal(np.asarray(rows.hi[s]),
                                      np.full(3, r.max_pos, np.float32))
        np.testing.assert_array_equal(np.asarray(rows.mv[s]),
                                      np.full(3, r.max_v, np.float32))
        assert table[int(rows.fid[s])] == resolve_problem(nm)


def test_problem_rows_rejects_non_table_and_hooked_members():
    custom = Problem(name="mine", fn=lambda x: -(x * x).sum(-1),
                     lo=-1.0, hi=1.0)
    with pytest.raises(ValueError, match="dispatch table"):
        problem_rows(["sphere", custom], 2, "float32")
    proj = resolve_problem("sphere_simplex")    # mode="projection"
    assert proj.projection_fn is not None
    with pytest.raises(ValueError, match="projection/repair"):
        problem_rows([proj], 2, "float32", table=(proj,))


def test_hetero_init_rows_bit_identical_to_standalone():
    cfg = _cfg(10, 128)
    rows, table = problem_rows(ALL_BUILTINS, 10, cfg.dtype)
    batch = init_batch(cfg, SEEDS, rows=rows, table=table)
    for s, (nm, sd) in enumerate(zip(ALL_BUILTINS, SEEDS)):
        ref = init_swarm(_cfg(10, 128, nm), sd)
        row = batch_row(batch, s)
        for f in ("pos", "vel", "fit", "pbest_fit", "gbest_pos",
                  "gbest_fit"):
            np.testing.assert_array_equal(np.asarray(getattr(row, f)),
                                          np.asarray(getattr(ref, f)),
                                          err_msg=f"row {s} ({nm}): {f}")


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock",
                                     "async"])
def test_jnp_switch_dispatch_parity_all_builtins(variant):
    """All six built-ins in ONE batch: every row's trajectory and gbest
    fields are bitwise the standalone solve; fitness-valued fields within
    the documented ulp envelope."""
    cfg = PSOConfig(dim=10, particle_cnt=128)
    out = solve_many(cfg, SEEDS, iters=20, variant=variant,
                     problems=ALL_BUILTINS)
    for s, (nm, sd) in enumerate(zip(ALL_BUILTINS, SEEDS)):
        ref = solve(_cfg(10, 128, nm), seed=sd, iters=20, variant=variant)
        row = batch_row(out, s)
        for f in TRAJ_FIELDS + ("gbest_pos", "gbest_fit"):
            np.testing.assert_array_equal(np.asarray(getattr(row, f)),
                                          np.asarray(getattr(ref, f)),
                                          err_msg=f"row {s} ({nm}): {f}")
        for f in FIT_FIELDS:
            np.testing.assert_allclose(np.asarray(getattr(row, f)),
                                       np.asarray(getattr(ref, f)),
                                       rtol=1e-5, atol=1e-4,
                                       err_msg=f"row {s} ({nm}): {f}")


def test_kernel_sync_hetero_batch_parity():
    """Kernel 3h (scalar-fid conditional dispatch) vs per-row standalone
    fused kernel runs: trajectory and gbest_pos bitwise; gbest_fit bitwise
    except rosenbrock's few-ulp FMA drift."""
    cfg = _cfg(10, 128)
    rows, table = problem_rows(ALL_BUILTINS, 10, cfg.dtype)
    batch = init_batch(cfg, SEEDS, rows=rows, table=table)
    out = ops.run_queue_lock_fused_batch(cfg, batch, iters=8,
                                         fids=rows.fid, table=table)
    for s, (nm, sd) in enumerate(zip(ALL_BUILTINS, SEEDS)):
        ck = _cfg(10, 128, nm)
        ref = ops.run_queue_lock_fused(ck, init_swarm(ck, sd), iters=8)
        row = batch_row(out, s)
        for f in ("pos", "vel", "pbest_pos", "gbest_pos"):
            np.testing.assert_array_equal(np.asarray(getattr(row, f)),
                                          np.asarray(getattr(ref, f)),
                                          err_msg=f"row {s} ({nm}): {f}")
        if nm == "rosenbrock":
            np.testing.assert_allclose(float(row.gbest_fit),
                                       float(ref.gbest_fit), rtol=1e-5)
        else:
            assert float(row.gbest_fit) == float(ref.gbest_fit), nm


def test_kernel_async_hetero_batch_parity():
    """Kernel 4h at its validated shape (d10/n128): fully bitwise."""
    cfg = _cfg(10, 128)
    rows, table = problem_rows(ALL_BUILTINS, 10, cfg.dtype)
    batch = init_batch(cfg, SEEDS, rows=rows, table=table)
    out = ops.run_queue_lock_fused_async_batch(cfg, batch, iters=8,
                                               sync_every=4,
                                               fids=rows.fid, table=table)
    for s, (nm, sd) in enumerate(zip(ALL_BUILTINS, SEEDS)):
        ck = _cfg(10, 128, nm)
        ref = ops.run_queue_lock_fused_async(ck, init_swarm(ck, sd),
                                             iters=8, sync_every=4)
        row = batch_row(out, s)
        for f in ("pos", "vel", "pbest_pos", "pbest_fit", "gbest_pos",
                  "gbest_fit"):
            np.testing.assert_array_equal(np.asarray(getattr(row, f)),
                                          np.asarray(getattr(ref, f)),
                                          err_msg=f"row {s} ({nm}): {f}")


def test_core_solve_many_problems_validation():
    with pytest.raises(ValueError, match="bounds"):
        solve_many(PSOConfig(dim=2, min_pos=-1.0, max_pos=1.0), [0, 1],
                   problems=["sphere", "cubic"])
    with pytest.raises(ValueError, match="problems for"):
        solve_many(PSOConfig(dim=2), [0, 1, 2],
                   problems=["sphere", "cubic"])


def test_facade_solve_many_problems():
    res = repro.solve_many(problems=ALL_BUILTINS, seeds=SEEDS, dim=10,
                           particles=128, iters=10, variant="queue")
    assert [r.problem.name for r in res] == ALL_BUILTINS
    for r, nm, sd in zip(res, ALL_BUILTINS, SEEDS):
        ref = repro.solve(nm, dim=10, particles=128, iters=10, seed=sd,
                          variant="queue")
        assert float(r.state.gbest_fit) == float(ref.state.gbest_fit)
        np.testing.assert_array_equal(np.asarray(r.state.gbest_pos),
                                      np.asarray(ref.state.gbest_pos))
        # per-row Result accessors report in the row problem's own sense
        assert r.best_fit == ref.best_fit
        assert r.config.fitness == resolve_problem(nm)


def test_facade_solve_many_problems_validation():
    with pytest.raises(ValueError, match="exactly one"):
        repro.solve_many("sphere", [0, 1], problems=["sphere", "cubic"])
    with pytest.raises(ValueError, match="exactly one"):
        repro.solve_many(seeds=[0, 1])
    with pytest.raises(ValueError, match="problems for"):
        repro.solve_many(problems=["sphere"], seeds=[0, 1])
    with pytest.raises(ValueError, match="bounds"):
        repro.solve_many(problems=["sphere", "cubic"], seeds=[0, 1],
                         min_pos=-1.0)


def test_facade_solve_many_problems_kernel_backend():
    res = repro.solve_many(problems=["sphere", "rastrigin", "ackley"],
                           seeds=[0, 1, 2], dim=2, particles=128, iters=6,
                           backend="kernel", variant="queue_lock")
    for r, nm, sd in zip(res, ["sphere", "rastrigin", "ackley"], [0, 1, 2]):
        ck = _cfg(2, 128, nm)
        ref = ops.run_queue_lock_fused(ck, init_swarm(ck, sd), iters=6)
        assert float(r.state.gbest_fit) == float(ref.gbest_fit)
        np.testing.assert_array_equal(np.asarray(r.state.gbest_pos),
                                      np.asarray(ref.gbest_pos))


# --------------------------------------------------------------------------
# Serving: registry coalescing
# --------------------------------------------------------------------------

def test_serve_mixed_builtin_trace_coalesces_to_one_dispatch():
    from repro.launch.serve import SolveRequest, SolveServer
    reqs = [SolveRequest(dim=10, particle_cnt=128, fitness=nm, seed=i,
                         iters=20, variant="queue")
            for i, nm in enumerate(ALL_BUILTINS)]
    srv = SolveServer()
    res = srv.solve_all(reqs)
    assert srv.stats.dispatches == 1
    assert srv.stats.hetero_dispatches == 1
    assert srv.stats.batch_fill == len(reqs)
    for r in res:
        ref = solve(_cfg(10, 128, r.request.fitness), seed=r.request.seed,
                    iters=20, variant="queue")
        assert r.gbest_fit == float(ref.gbest_fit)
        np.testing.assert_array_equal(r.gbest_pos,
                                      np.asarray(ref.gbest_pos))


def test_serve_coalesce_off_restores_content_hash_grouping():
    from repro.launch.serve import SolveRequest, SolveServer
    reqs = [SolveRequest(dim=3, particle_cnt=64, fitness=nm, seed=i,
                         iters=10, variant="queue")
            for i, nm in enumerate(["sphere", "cubic", "rastrigin"])]
    srv = SolveServer(coalesce_registry=False)
    srv.solve_all(reqs)
    assert srv.stats.dispatches == 3       # one per problem (legacy keys)
    assert srv.stats.hetero_dispatches == 0
    srv2 = SolveServer()
    srv2.solve_all(reqs)
    assert srv2.stats.dispatches == 1
    assert srv2.stats.batch_fill >= 2 * srv.stats.batch_fill


def test_serve_custom_problem_keeps_content_hash_isolation():
    from repro.launch.serve import SolveRequest, SolveServer
    custom = Problem(name="mine", fn=lambda x: -(x * x).sum(-1),
                     lo=-1.0, hi=1.0)
    reqs = [SolveRequest(dim=3, particle_cnt=64, fitness="sphere", seed=0,
                         iters=10, variant="queue"),
            SolveRequest(dim=3, particle_cnt=64, fitness=custom, seed=1,
                         iters=10, variant="queue")]
    assert reqs[0].hetero_eligible and not reqs[1].hetero_eligible
    srv = SolveServer()
    res = srv.solve_all(reqs)
    assert srv.stats.dispatches == 2       # custom cannot join the mix
    assert srv.stats.hetero_dispatches == 1
    ref = solve(PSOConfig(dim=3, particle_cnt=64, fitness=custom).resolved(),
                seed=1, iters=10, variant="queue")
    assert res[1].gbest_fit == float(ref.gbest_fit)


def test_serve_kernel_backend_hetero_dispatch():
    from repro.launch.serve import SolveRequest, SolveServer
    names = ["sphere", "rastrigin", "ackley"]
    for variant in ("queue_lock", "async"):
        reqs = [SolveRequest(dim=2, particle_cnt=128, fitness=nm, seed=i,
                             iters=6, variant=variant)
                for i, nm in enumerate(names)]
        srv = SolveServer(backend="kernel")
        res = srv.solve_all(reqs)
        assert srv.stats.dispatches == 1
        for r in res:
            ck = _cfg(2, 128, r.request.fitness)
            st = init_swarm(ck, r.request.seed)
            if variant == "queue_lock":
                ref = ops.run_queue_lock_fused(ck, st, iters=6)
            else:
                ref = ops.run_queue_lock_fused_async(ck, st, iters=6)
            np.testing.assert_array_equal(r.gbest_pos,
                                          np.asarray(ref.gbest_pos))
            assert r.gbest_fit == float(ref.gbest_fit)
