"""Custom flash VJP vs autodiff-through-scan reference: values and all
three gradients, across causal/window/GQA/offset configurations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention
from repro.models.flash_vjp import flash_attention_vjp

_slow = pytest.mark.slow
CASES = [
    # (B, Sq, Sk, H, KH, hd, hdv, causal, window, qb, kb) — the plain causal
    # case stays in tier-1; the config sweep rides behind --runslow.
    (2, 64, 64, 4, 4, 16, 16, True, 0, 32, 32),
    pytest.param((1, 128, 128, 8, 2, 16, 16, True, 0, 64, 32),
                 marks=_slow),                       # GQA
    pytest.param((2, 96, 96, 4, 4, 16, 16, True, 32, 32, 32),
                 marks=_slow),                       # sliding window
    pytest.param((1, 64, 64, 4, 2, 16, 8, True, 0, 32, 32),
                 marks=_slow),                       # hd_qk != hd_v
    pytest.param((2, 64, 64, 4, 4, 16, 16, False, 0, 32, 32),
                 marks=_slow),                       # non-causal
]


def _mk(case, seed=0):
    b, sq, sk, h, kh, hd, hdv, causal, window, qb, kb = case
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kh, hdv), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
def test_forward_matches(case):
    b, sq, sk, h, kh, hd, hdv, causal, window, qb, kb = case
    q, k, v = _mk(case)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=qb, kv_block=kb)
    got = flash_attention_vjp(q, k, v, causal, window, 0, qb, kb, None, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("case", CASES)
def test_grads_match(case):
    b, sq, sk, h, kh, hd, hdv, causal, window, qb, kb = case
    q, k, v = _mk(case)

    def loss_ref(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            q_block=qb, kv_block=kb)
        return jnp.sum(jnp.sin(o))          # nontrivial cotangents

    def loss_vjp(q, k, v):
        o = flash_attention_vjp(q, k, v, causal, window, 0, qb, kb, None, 0)
        return jnp.sum(jnp.sin(o))

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_got = jax.grad(loss_vjp, argnums=(0, 1, 2))(q, k, v)
    for name, a, bb in zip("qkv", g_got, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name} mismatch")


@pytest.mark.slow
def test_model_trains_with_custom_vjp():
    """End-to-end: smoke arch with flash_custom_vjp=True trains one step
    and matches the default path's loss."""
    import dataclasses
    from repro.configs import get_arch
    from repro.models import zoo
    cfg0 = get_arch("stablelm-3b").smoke()
    cfg1 = dataclasses.replace(cfg0, flash_custom_vjp=True)
    params = zoo.init_params(cfg0, jax.random.key(0))
    batch = zoo.make_batch(cfg0, "train_4k", 2, 64, jax.random.key(1))
    l0 = float(jax.jit(lambda p: zoo.loss_fn(cfg0, p, batch))(params))
    l1 = float(jax.jit(lambda p: zoo.loss_fn(cfg1, p, batch))(params))
    assert l0 == pytest.approx(l1, rel=1e-4)
    g = jax.jit(jax.grad(lambda p: zoo.loss_fn(cfg1, p, batch)))(params)
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in jax.tree.leaves(g))
