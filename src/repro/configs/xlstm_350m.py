"""xlstm-350m — attention-free: mLSTM blocks with one sLSTM block per group
of 6 (20 mLSTM + 4 sLSTM over 24 layers); d_ff=0 — gating/up-projections
live inside the blocks. O(1) recurrent decode state ⇒ runs long_500k.
[arXiv:2405.04517; unverified]"""
from .base import ArchConfig, register

XLSTM_350M = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    xlstm=True, slstm_group=6,
    source="arXiv:2405.04517",
))
