"""Swarm topologies beyond the paper's global-best (gbest) PSO.

The paper uses the star topology (every particle sees the swarm-wide best
— the aggregation its queue/queue-lock algorithms accelerate). Two classic
variants are provided as composable alternatives:

  * ``step_ring`` — lbest PSO with a ring neighborhood of radius r: each
    particle is attracted to the best pbest among its 2r+1 neighbors.
    There is NO global reduction at all — the aggregation the paper
    optimizes disappears, at the cost of slower information propagation
    (O(N/r) iterations to cross the swarm). On TPU the neighborhood max
    is 2r+1 vectorized rolls — no collective needed even when sharded
    (halo exchange is a collective-permute of r rows).
  * ``multi_swarm`` — vmap over independent swarms (restart/portfolio
    strategies; also the natural "meta-PSO" evaluation harness).

Both reuse SwarmState; ring keeps ``gbest_*`` fields updated (monitoring
only — they do not influence the dynamics).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from . import rng
from .pso import (PSOConfig, STREAM_R1, STREAM_R2, SwarmState, init_swarm)

Array = jnp.ndarray


def _neighborhood_best(pbest_fit: Array, pbest_pos: Array, radius: int
                       ) -> Tuple[Array, Array]:
    """Best (fit, pos) among each particle's ring neighborhood."""
    n = pbest_fit.shape[0]
    best_fit = pbest_fit
    best_pos = pbest_pos
    for off in range(1, radius + 1):
        for sign in (off, -off):
            f = jnp.roll(pbest_fit, sign, axis=0)
            p = jnp.roll(pbest_pos, sign, axis=0)
            take = f > best_fit
            best_fit = jnp.where(take, f, best_fit)
            best_pos = jnp.where(take[:, None], p, best_pos)
    return best_fit, best_pos


def step_ring(cfg: PSOConfig, s: SwarmState, radius: int = 1) -> SwarmState:
    """One lbest iteration (ring of ``radius``)."""
    n, d = s.pos.shape
    dt = s.pos.dtype
    it = s.iteration + 1
    idx = jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
    r1 = rng.uniform(s.seed, it, STREAM_R1, idx, dtype=dt)
    r2 = rng.uniform(s.seed, it, STREAM_R2, idx, dtype=dt)
    _, lbest_pos = _neighborhood_best(s.pbest_fit, s.pbest_pos, radius)
    vel = (cfg.w * s.vel
           + cfg.c1 * r1 * (s.pbest_pos - s.pos)
           + cfg.c2 * r2 * (lbest_pos - s.pos))
    vel = jnp.clip(vel, -cfg.max_v, cfg.max_v)
    pos = jnp.clip(s.pos + vel, cfg.min_pos, cfg.max_pos)
    fit = cfg.fitness_fn(pos)
    improved = fit > s.pbest_fit
    pbest_fit = jnp.where(improved, fit, s.pbest_fit)
    pbest_pos = jnp.where(improved[:, None], pos, s.pbest_pos)
    # gbest tracked for monitoring only (queue predicate still applies)
    def publish(op):
        f, p, _, _ = op
        b = jnp.argmax(f)
        return f[b], p[b]

    def skip(op):
        return op[2], op[3]

    gbest_fit, gbest_pos = jax.lax.cond(
        jnp.any(pbest_fit > s.gbest_fit), publish, skip,
        (pbest_fit, pbest_pos, s.gbest_fit, s.gbest_pos))
    return s._replace(pos=pos, vel=vel, fit=fit, pbest_pos=pbest_pos,
                      pbest_fit=pbest_fit, gbest_fit=gbest_fit,
                      gbest_pos=gbest_pos, iteration=it)


@partial(jax.jit, static_argnames=("cfg", "iters", "radius"))
def run_ring(cfg: PSOConfig, s: SwarmState, iters: int,
             radius: int = 1) -> SwarmState:
    cfg = cfg.resolved()
    return jax.lax.fori_loop(0, iters,
                             lambda _, t: step_ring(cfg, t, radius), s)


def init_multi_swarm(cfg: PSOConfig, seeds) -> SwarmState:
    """Stack of independent swarms (leading axis = swarm)."""
    cfg = cfg.resolved()
    return jax.vmap(lambda sd: init_swarm(cfg, sd))(jnp.asarray(seeds))


@partial(jax.jit, static_argnames=("cfg", "iters", "variant"))
def run_multi_swarm(cfg: PSOConfig, states: SwarmState, iters: int,
                    variant: str = "queue") -> SwarmState:
    """Portfolio of swarms advancing in lockstep (vmapped)."""
    from .pso import STEP_FNS
    cfg = cfg.resolved()
    step = STEP_FNS[variant]

    def one(s):
        return jax.lax.fori_loop(0, iters, lambda _, t: step(cfg, t), s)

    return jax.vmap(one)(states)


def best_of_swarms(states: SwarmState) -> Tuple[Array, Array]:
    b = jnp.argmax(states.gbest_fit)
    return states.gbest_fit[b], states.gbest_pos[b]
