"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the synthetic pipeline with checkpoint/restart, and show the loss
decreasing. (The production entry point for full configs on a pod is
``python -m repro.launch.train``.)

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import os
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import zoo
from repro import checkpoint as ckpt


def hundred_m_config():
    """~100M-param dense transformer (stablelm family, shrunk)."""
    return dataclasses.replace(
        get_arch("stablelm-3b"),
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab=50304, head_dim=64, loss_chunk=256, attn_q_block=256,
        attn_kv_block=256, param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = hundred_m_config()
    params = zoo.init_params(cfg, jax.random.key(0))
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    step_fn, opt_init = make_train_step(cfg, base_lr=args.lr,
                                        warmup=20, total_steps=args.steps)
    jstep = jax.jit(step_fn)
    opt_state = opt_init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))

    start = 0
    if args.resume:
        got = ckpt.restore_latest(args.ckpt_dir, (params, opt_state))
        if got[0] is not None:
            start, (params, opt_state) = got
            print(f"resumed from step {start}")
    elif os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)
    os.makedirs(args.ckpt_dir, exist_ok=True)

    first = last = None
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, m = jstep(params, opt_state, batch)
        loss = float(m["loss"])
        first = loss if first is None else first
        last = loss
        if step % 20 == 0:
            toks = args.batch * args.seq
            dt = time.time() - t0
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({toks*(step-start+1)/max(dt,1e-9):.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
            ckpt.prune(args.ckpt_dir, keep=2)
    ckpt.save(args.ckpt_dir, args.steps, (params, opt_state))
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
