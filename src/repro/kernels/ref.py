"""Pure-jnp oracles for the Pallas kernels (no pallas_call anywhere).

These reproduce, eagerly and without any Pallas machinery, exactly what the
kernels are *supposed* to compute — including the sequential-block
publication order of the fused queue-lock kernel (block b of iteration t
sees the gbest already updated by blocks 0..b-1 of iteration t). They share
the tile math helpers with the kernel module so interpret-mode comparisons
isolate the pallas orchestration; the math itself is independently checked
against ``repro.core.pso`` in tests/test_kernels.py.

All oracles work on the packed D-major layout (see ops.py for pack/unpack).
``fitness`` accepts a registered name or a ``repro.core.problem.Problem``;
it resolves through the SAME ``pso_step.kernel_fitness`` (hand-tuned fast
path or generic d-major adapter) as the kernels, so custom-objective runs
compare bit-for-bit too.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

import functools

import jax

from repro.core.topology import block_neighbor_best, kernel_neighbor_ids
from repro.core.update_rules import resolve_rule

from .pso_step import (_advance_block, _pbest_improved, _pin, is_converted,
                       kernel_fitness, kernel_projection, kernel_violation,
                       pad_dim)


def run_islands_ring_oracle(cfg, seed: int, n_shards: int, iters: int,
                            exchange_interval: int,
                            sync_every: int = 8, n_blocks=None):
    """Eager oracle for the async island ring in ``repro.core.distributed``.

    Simulates ``n_shards`` islands as explicit per-island SwarmStates (the
    ``init_swarm(index_offset)`` sharding convention) and runs, per exchange
    round, the island-local ``run_async`` loop followed by one Python-level
    ring hop with the exact ``ring_exchange`` fold semantics (strict-
    improvement predicate, lowest-owner tie-break, NaN-as--inf) and the
    local-best pull, then the ``n_shards - 1`` drain hops. With one shard
    the whole thing reduces to ``run_async`` on the monolithic swarm —
    bit-identically, since the self-hop fold and pull are exact no-ops.

    Returns ``(islands, history)``: the final per-island states, and one
    ``[(gbest_fit, owner), ...]`` snapshot per exchange round (taken after
    the hop), from which tests assert the staleness bound — any island's
    round-r best is visible on island ``(i + d) % n_shards`` by round
    ``r + d``, i.e. everywhere within ``n_shards`` rounds — and the
    final-flush invariant (every island's gbest equals the max over all
    pbests after the drain).
    """
    from repro.core.blocking import default_block_count
    from repro.core.pso import init_swarm, run_async

    cfg = cfg.resolved()
    if cfg.particle_cnt % n_shards:
        raise ValueError("particle_cnt not divisible by n_shards")
    local_n = cfg.particle_cnt // n_shards
    nb = n_blocks or default_block_count(local_n)
    sync_eff = min(sync_every, exchange_interval)
    if exchange_interval % sync_eff:
        raise ValueError("sync_every must divide exchange_interval")

    islands = [init_swarm(cfg, seed, n=local_n, index_offset=i * local_n)
               for i in range(n_shards)]
    # init-time reconcile (init_sharded_swarm's _pmax_best): lowest-index
    # winner of the max init fit owns the shared starting gbest.
    fits = [float(s.gbest_fit) for s in islands]
    best = max(f for f in fits if not np.isnan(f)) if any(
        not np.isnan(f) for f in fits) else -np.inf
    win = min(i for i, f in enumerate(fits)
              if (not np.isnan(f)) and f >= best) if best > -np.inf else 0
    islands = [s._replace(gbest_fit=islands[win].gbest_fit,
                          gbest_pos=islands[win].gbest_pos)
               for s in islands]
    owners = list(range(n_shards))

    def hop(islands, owners):
        snap = [(jnp.where(jnp.isnan(s.gbest_fit), -jnp.inf, s.gbest_fit),
                 s.gbest_pos, owners[i]) for i, s in enumerate(islands)]
        out, own_out = [], []
        for i, s in enumerate(islands):
            rf, rp, ro = snap[(i - 1) % n_shards]
            gf, gp, own = snap[i][0], s.gbest_pos, owners[i]
            better = bool(rf > gf) or (bool(rf == gf) and ro < own)
            if better:
                gf, gp, own = rf, rp, ro
            lbf, lbp = s.lbest_fit, s.lbest_pos
            if lbf is not None:
                take = gf > lbf
                lbf = jnp.where(take, gf, lbf)
                lbp = jnp.where(take[:, None], gp[None, :], lbp)
            out.append(s._replace(gbest_fit=jnp.asarray(gf), gbest_pos=gp,
                                  lbest_fit=lbf, lbest_pos=lbp))
            own_out.append(own)
        return out, own_out

    rounds, rem = divmod(iters, exchange_interval)
    spans = [exchange_interval] * rounds + ([rem] if rem else [])
    history = []
    for k in spans:
        nxt = []
        for i, s in enumerate(islands):
            prev = float(s.gbest_fit)
            s = run_async(cfg, s, k, sync_every=sync_eff, n_blocks=nb,
                          phase=0, index_offset=i * local_n)
            if float(s.gbest_fit) > prev:
                owners[i] = i
            nxt.append(s)
        islands, owners = hop(nxt, owners)
        history.append([(float(s.gbest_fit), owners[i])
                        for i, s in enumerate(islands)])
    for _ in range(n_shards - 1):
        islands, owners = hop(islands, owners)
        history.append([(float(s.gbest_fit), owners[i])
                        for i, s in enumerate(islands)])
    return islands, history


def run_constrained_oracle(cfg, seed: int, iters: int,
                           variant: str = "queue_lock",
                           sync_every: int = 8,
                           n_blocks: int = None):
    """Eager oracle for CONSTRAINED solves through the jnp engines.

    An independent re-implementation of ``repro.core.pso``'s synchronous
    queue-lock (and, for ``variant="async"``, the relaxed block-local
    schedule) in the library's particle-major [N, D] layout: init (with
    the projection / repair-resample constrained init), the
    velocity/position/clip advance, the post-advance projection hook, the
    penalized canonical fitness, pbest folds, and the variant's gbest
    publication rule — a Python iteration loop with Python-level
    publication conditionals, no ``cond``, ``fori_loop`` or ``pallas_call``
    anywhere. Only the advance+fitness subgraph runs under ``jit`` (the
    ``_advance_fn`` precedent: XLA:CPU FMA-contracts the velocity chain
    inside a compiled program one ulp differently from op-by-op eager
    execution, so the oracle compiles the SAME subgraph; the pbest/gbest
    select folds are rounding-free and stay eager).

    Bit-exactness granularity: the jnp engine dispatched one iteration per
    call (``run(cfg, s, 1)`` / ``run_async(cfg, s, 1)`` — phase-aligned)
    matches this oracle BIT-EXACTLY, constraints and all
    (tests/test_constraints.py). A multi-iteration ``fori_loop`` program
    additionally FMA-fuses ACROSS iterations (the pre-existing XLA:CPU
    caveat documented in ``repro.core.multi_swarm`` — it applies to
    unconstrained built-ins equally), so full-loop runs are validated
    exact on the gbest trajectory and ulp-tight on positions. The kernel
    backends validate bit-exact against
    ``run_fused_oracle``/``run_fused_async_oracle``, which thread the same
    projection/penalty through the d-major tile machinery.

    Returns a ``repro.core.pso.SwarmState``.
    """
    from repro.core import rng as _rng
    from repro.core.blocking import default_block_count
    from repro.core.constraints import deb_improved, repair_init_positions
    from repro.core.pso import (STREAM_INIT_POS, STREAM_INIT_VEL, STREAM_R1,
                                STREAM_R2, SwarmState)

    if variant not in ("queue_lock", "async"):
        raise ValueError(f"unsupported oracle variant {variant!r}")
    cfg = cfg.resolved()
    prob = cfg.problem
    fit_fn = prob.max_fn                       # penalty rides the wrapper
    proj = prob.projection_fn
    # Deb-rule pbest selection for projection/repair modes (penalty mode
    # stays on raw canonical fitness) — the engine's deb_selection_fn gate,
    # mirrored here so the bit-exact comparison stays like-for-like.
    deb_vf = (prob.violation_fn
              if prob.constrained and prob.constraints.mode != "penalty"
              else None)
    n, d = cfg.particle_cnt, cfg.dim
    dt = jnp.dtype(cfg.dtype)

    def op(v):
        return jnp.asarray(v, dt) if isinstance(v, tuple) else v

    lo, hi, mv = op(cfg.min_pos), op(cfg.max_pos), op(cfg.max_v)
    idx = jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
    pos = lo + (hi - lo) * _rng.uniform(seed, 0, STREAM_INIT_POS, idx, dt)
    vel = -mv + 2.0 * mv * _rng.uniform(seed, 0, STREAM_INIT_VEL, idx, dt)
    if proj is not None:
        pos = proj(pos)
    elif prob.constrained and prob.constraints.mode == "repair":
        pos = repair_init_positions(prob.constraints, prob.violation_fn,
                                    pos, lo, hi - lo, seed,
                                    STREAM_INIT_POS, idx, dt)
    fit = fit_fn(pos)
    pbp, pbf = pos, fit
    b = jnp.argmax(fit)
    gp, gf = pos[b], fit[b]

    nb = n_blocks or default_block_count(n)
    bn = n // nb
    if variant == "async":
        lbp = jnp.broadcast_to(gp[None, :], (nb, d))
        lbf = jnp.broadcast_to(gf, (nb,))

    orule = resolve_rule(cfg.update_rule)

    @jax.jit
    def advance(vel, pos, pbp, attractor, r1, r2):
        p, v = orule.advance(r1, r2, pos, vel, pbp, attractor,
                             w=cfg.w, c1=cfg.c1, c2=cfg.c2,
                             mv=mv, lo=lo, hi=hi)
        if proj is not None:
            p = proj(p)
        return p, v, fit_fn(p)

    for t in range(1, iters + 1):
        r1 = _rng.uniform(seed, t, STREAM_R1, idx, dt)
        r2 = _rng.uniform(seed, t, STREAM_R2, idx, dt)
        attractor = (gp[None, :] if variant != "async"
                     else jnp.repeat(lbp, bn, axis=0))
        pos, vel, fit = advance(vel, pos, pbp, attractor, r1, r2)
        imp = (fit > pbf if deb_vf is None
               else deb_improved(fit, deb_vf(pos), pbf, deb_vf(pbp)))
        pbf = jnp.where(imp, fit, pbf)
        pbp = jnp.where(imp[:, None], pos, pbp)
        if variant == "async":
            fb = fit.reshape(nb, bn)
            bi = jnp.argmax(fb, axis=1)
            bfit = jnp.take_along_axis(fb, bi[:, None], axis=1)[:, 0]
            bpos = pos.reshape(nb, bn, d)[jnp.arange(nb), bi]
            take = bfit > lbf
            lbf = jnp.where(take, bfit, lbf)
            lbp = jnp.where(take[:, None], bpos, lbp)
            sched = t % max(1, sync_every) == 0
            if sched or t == iters:
                wb = jnp.argmax(lbf)
                if float(lbf[wb]) > float(gf):
                    gf, gp = lbf[wb], lbp[wb]
                if sched:    # scheduled sync point: publish AND pull; an
                    # unscheduled final boundary flushes publish-only
                    # (mirrors run_async's flush_async_locals tail)
                    if cfg.topology == "gbest":
                        lbf = jnp.broadcast_to(gf, lbf.shape)
                        lbp = jnp.broadcast_to(gp[None, :], lbp.shape)
                    else:  # lbest pull: neighborhood fold of block-locals
                        lbp, lbf = block_neighbor_best(lbf, lbp,
                                                       cfg.topology)
        else:
            if bool(jnp.any(imp)):           # queue-lock publication rule
                wb = jnp.argmax(pbf)
                if float(pbf[wb]) > float(gf):
                    gf, gp = pbf[wb], pbp[wb]

    state = SwarmState(pos=pos, vel=vel, fit=fit, pbest_pos=pbp,
                       pbest_fit=pbf, gbest_pos=gp, gbest_fit=gf,
                       iteration=jnp.asarray(iters, jnp.int32),
                       seed=jnp.asarray(seed, jnp.uint32))
    if variant == "async":
        state = state._replace(lbest_pos=lbp, lbest_fit=lbf)
    return state


def _advance_fn(fitness, **kw):
    """The oracles' advance step.

    Hand-tuned (built-in) objectives: the plain eager ``_advance_block`` —
    the seed oracle, bit-for-bit. Converted objectives (d-major adapter /
    user kernel_fn / constrained problems): the kernels pin their advance
    outputs with an optimization barrier (see
    ``pso_step._resolve_statics``), and XLA:CPU rounds that pinned advance
    cluster differently from op-by-op eager execution — so the oracle runs
    the SAME pinned subgraph under jit, keeping custom-objective validation
    bit-exact too. A projection-mode constraint set rides the same hook as
    in the kernels: the d-major ``kernel_projection`` form applied after
    the box clip inside ``_advance_block``.
    """
    lifted = kernel_projection(fitness)
    if lifted is not None:
        d_real = kw["d_real"]
        kw = dict(kw, project=lambda p: lifted(p, d_real))
    adv = functools.partial(_advance_block, **kw)
    if not (is_converted(fitness) or lifted is not None):
        return adv

    @jax.jit
    def stepped(seed, it, pos, vel, pbp, gp, base):
        p, v, dmask, lane = adv(seed, it, pos, vel, pbp, gp, base)
        p, v = _pin(True, p, v)
        return p, v, dmask, lane

    return stepped

_BIG = np.int32(2 ** 30)


def _block_views(arrs, b, bn):
    return [a[..., b * bn:(b + 1) * bn] for a in arrs]


def queue_step_oracle(seed, iteration, pos, vel, pbp, pbf, gp, gf,
                      block_n: int, *, w, c1, c2, min_pos, max_pos, max_v,
                      d_real: int, fitness, rule="pso"):
    """One queue-algorithm iteration (kernel 1 + the jnp 2nd stage).

    Inputs in D-major layout: pos/vel/pbp [Dpad, N], pbf [1, N],
    gp [Dpad, 1], gf scalar. Returns the updated six arrays.
    """
    dpad, n = pos.shape
    nb = n // block_n
    fitfn = kernel_fitness(fitness)
    vf = kernel_violation(fitness)
    viol = None if vf is None else (lambda p: vf(p, d_real))
    adv = _advance_fn(fitness, w=w, c1=c1, c2=c2, min_pos=min_pos,
                      max_pos=max_pos, max_v=max_v, d_real=d_real,
                      rule=resolve_rule(rule))
    pos, vel, pbp, pbf = map(jnp.asarray, (pos, vel, pbp, pbf))
    aux_fit = []
    aux_idx = []
    new = {k: [] for k in ("pos", "vel", "pbp", "pbf")}
    for b in range(nb):
        p, v, bp, bf_ = _block_views((pos, vel, pbp, pbf), b, block_n)
        p, v, dmask, lane = adv(seed, iteration + 1, p, v, bp, gp,
                                b * block_n)
        fit = fitfn(p, dmask, d_real)
        imp = _pbest_improved(fit, p, bf_, bp, viol)
        bf_ = jnp.where(imp, fit, bf_)
        bp = jnp.where(imp, p, bp)
        new["pos"].append(p); new["vel"].append(v)
        new["pbp"].append(bp); new["pbf"].append(bf_)
        q = jnp.where(fit > gf, fit, -jnp.inf)
        best = jnp.max(q)
        lane_row = jnp.broadcast_to(jnp.arange(block_n)[None, :], q.shape)
        bidx = jnp.min(jnp.where(q >= best, lane_row, _BIG))
        aux_fit.append(best)
        aux_idx.append(b * block_n + bidx)
    pos = jnp.concatenate(new["pos"], axis=-1)
    vel = jnp.concatenate(new["vel"], axis=-1)
    pbp = jnp.concatenate(new["pbp"], axis=-1)
    pbf = jnp.concatenate(new["pbf"], axis=-1)
    aux_fit = jnp.stack(aux_fit)
    aux_idx = jnp.stack(aux_idx).astype(jnp.int32)
    # 2nd stage (cross-block): conditional global-best update.
    wb = int(jnp.argmax(aux_fit))
    if float(aux_fit[wb]) > float(gf):
        gf = aux_fit[wb]
        gp = pos[:, int(aux_idx[wb]):int(aux_idx[wb]) + 1]
    return pos, vel, pbp, pbf, gp, gf, aux_fit, aux_idx


def run_fused_oracle(seed, base_iter, pos, vel, pbp, pbf, gp, gf,
                     iters: int, block_n: int, *, w, c1, c2, min_pos,
                     max_pos, max_v, d_real: int, fitness, rule="pso",
                     counters=None):
    """The fused queue-lock kernel's exact semantics, eagerly.

    Sequential (t, b) loop; gbest is updated in place so later blocks of the
    same iteration see it — mirroring TPU sequential grid execution.

    ``counters``: an optional dict whose ``queue_updates`` /
    ``publications`` / ``block_improvements`` keys are incremented at the
    same program points the telemetry kernels count — the validation
    oracle for ``repro.telemetry`` (one conditional guards both the queue
    fold and the publication here, so the first two move together).
    """
    dpad, n = pos.shape
    nb = n // block_n
    fitfn = kernel_fitness(fitness)
    vf = kernel_violation(fitness)
    viol = None if vf is None else (lambda p: vf(p, d_real))
    adv = _advance_fn(fitness, w=w, c1=c1, c2=c2, min_pos=min_pos,
                      max_pos=max_pos, max_v=max_v, d_real=d_real,
                      rule=resolve_rule(rule))
    pos, vel, pbp, pbf, gp = map(jnp.asarray, (pos, vel, pbp, pbf, gp))
    gf = jnp.asarray(gf)
    pos, vel, pbp, pbf = (np.array(pos), np.array(vel), np.array(pbp),
                          np.array(pbf))
    for t in range(iters):
        for b in range(nb):
            sl = slice(b * block_n, (b + 1) * block_n)
            p, v, dmask, lane = adv(
                seed, base_iter + t + 1,
                jnp.asarray(pos[:, sl]), jnp.asarray(vel[:, sl]),
                jnp.asarray(pbp[:, sl]), gp, b * block_n)
            fit = fitfn(p, dmask, d_real)
            bf_ = jnp.asarray(pbf[:, sl])
            bp = jnp.asarray(pbp[:, sl])
            imp = _pbest_improved(fit, p, bf_, bp, viol)
            pbf[:, sl] = np.array(jnp.where(imp, fit, bf_))
            pbp[:, sl] = np.array(jnp.where(imp, p, bp))
            pos[:, sl] = np.array(p)
            vel[:, sl] = np.array(v)
            if counters is not None and bool(jnp.any(imp)):
                counters["block_improvements"] = (
                    counters.get("block_improvements", 0) + 1)
            q_mask = fit > gf
            if bool(jnp.any(q_mask)):                 # rare publication
                if counters is not None:
                    counters["queue_updates"] = (
                        counters.get("queue_updates", 0) + 1)
                    counters["publications"] = (
                        counters.get("publications", 0) + 1)
                q = jnp.where(q_mask, fit, -jnp.inf)
                bf = jnp.max(q)
                lane_row = jnp.broadcast_to(
                    jnp.arange(block_n)[None, :], q.shape)
                bidx = int(jnp.min(jnp.where(q >= bf, lane_row, _BIG)))
                gf = bf
                sel = (lane == bidx) & dmask
                gp = jnp.sum(jnp.where(sel, p, jnp.zeros_like(p)),
                             axis=1, keepdims=True)
    return (jnp.asarray(pos), jnp.asarray(vel), jnp.asarray(pbp),
            jnp.asarray(pbf), gp, gf)


def run_fused_async_oracle(seed, base_iter, pos, vel, pbp, pbf, gp, gf,
                           iters: int, block_n: int, sync_every: int, *,
                           w, c1, c2, min_pos, max_pos, max_v, d_real: int,
                           fitness, rule="pso", topology="gbest",
                           counters=None):
    """The async queue-lock kernel's exact semantics, eagerly.

    Block-major: block b runs its ENTIRE iteration span (all chunks of
    ``sync_every`` iterations) before block b+1 starts, maintaining a
    block-local best; the shared gbest is pulled at chunk entry and
    conditionally published at chunk exit — mirroring the kernel's
    (blocks, chunks) grid order bit-for-bit, including the ops-wrapper
    behaviour of running a trailing ``iters % sync_every`` remainder as a
    second block-major phase over all blocks.

    With an lbest ``topology`` (``"ring"`` / ``"vonneumann"``) the chunk
    entry folds the NEIGHBOR blocks' local slots (same stencil and fold
    order as the kernel's ``kernel_neighbor_ids`` loop) instead of pulling
    the shared gbest, which remains a chunk-exit flush target only —
    mirroring the kernel's block-major diffusion schedule bit-for-bit.

    ``counters`` mirrors ``run_fused_oracle``: ``queue_updates`` counts
    inner iterations with a non-empty block-local queue, ``publications``
    counts chunk-exit shared-gbest writes, ``block_improvements`` counts
    (iteration, block) pbest-fold events — the async telemetry kernels'
    validation oracle.
    """
    dpad, n = pos.shape
    nb = n // block_n
    fitfn = kernel_fitness(fitness)
    vf = kernel_violation(fitness)
    viol = None if vf is None else (lambda p: vf(p, d_real))
    adv = _advance_fn(fitness, w=w, c1=c1, c2=c2, min_pos=min_pos,
                      max_pos=max_pos, max_v=max_v, d_real=d_real,
                      rule=resolve_rule(rule))
    pos, vel, pbp, pbf, gp = map(jnp.asarray, (pos, vel, pbp, pbf, gp))
    gf = jnp.asarray(gf)
    pos, vel, pbp, pbf = (np.array(pos), np.array(vel), np.array(pbp),
                          np.array(pbf))
    # Local bests seeded from the shared gbest, one slot per block — exactly
    # what ops.run_queue_lock_fused_async hands the kernel. The phase split
    # (and its degenerate-input clamps) is the wrapper's own, not a copy.
    from .ops import _async_spans
    lp = [jnp.array(gp) for _ in range(nb)]      # each [Dpad, 1]
    lf = [jnp.asarray(gf) for _ in range(nb)]
    for it_off, span, k in _async_spans(iters, sync_every):
        for b in range(nb):
            sl = slice(b * block_n, (b + 1) * block_n)
            for c in range(span // k):
                if topology == "gbest":
                    # chunk entry: pull shared into local
                    if float(gf) > float(lf[b]):
                        lf[b] = gf
                        lp[b] = gp
                else:
                    # lbest: fold neighbor block-locals, same running-max
                    # order as the kernel's kernel_neighbor_ids loop
                    for nbr in kernel_neighbor_ids(b, nb, topology):
                        if float(lf[nbr]) > float(lf[b]):
                            lf[b] = lf[nbr]
                            lp[b] = lp[nbr]
                for tl in range(k):
                    it = base_iter + it_off + c * k + tl + 1
                    p, v, dmask, lane = adv(
                        seed, it,
                        jnp.asarray(pos[:, sl]), jnp.asarray(vel[:, sl]),
                        jnp.asarray(pbp[:, sl]), lp[b], b * block_n)
                    fit = fitfn(p, dmask, d_real)
                    bf_ = jnp.asarray(pbf[:, sl])
                    bp = jnp.asarray(pbp[:, sl])
                    imp = _pbest_improved(fit, p, bf_, bp, viol)
                    pbf[:, sl] = np.array(jnp.where(imp, fit, bf_))
                    pbp[:, sl] = np.array(jnp.where(imp, p, bp))
                    pos[:, sl] = np.array(p)
                    vel[:, sl] = np.array(v)
                    if counters is not None and bool(jnp.any(imp)):
                        counters["block_improvements"] = (
                            counters.get("block_improvements", 0) + 1)
                    q_mask = fit > lf[b]
                    if bool(jnp.any(q_mask)):    # local publication
                        if counters is not None:
                            counters["queue_updates"] = (
                                counters.get("queue_updates", 0) + 1)
                        q = jnp.where(q_mask, fit, -jnp.inf)
                        best = jnp.max(q)
                        lane_row = jnp.broadcast_to(
                            jnp.arange(block_n)[None, :], q.shape)
                        bidx = int(jnp.min(jnp.where(q >= best, lane_row,
                                                     _BIG)))
                        lf[b] = best
                        sel = (lane == bidx) & dmask
                        lp[b] = jnp.sum(jnp.where(sel, p, jnp.zeros_like(p)),
                                        axis=1, keepdims=True)
                # chunk exit: rare cross-block publication
                if float(lf[b]) > float(gf):
                    if counters is not None:
                        counters["publications"] = (
                            counters.get("publications", 0) + 1)
                    gf = lf[b]
                    gp = lp[b]
    lp_arr = jnp.concatenate(lp, axis=1)
    lf_arr = jnp.stack([jnp.asarray(x).reshape(()) for x in lf])
    return (jnp.asarray(pos), jnp.asarray(vel), jnp.asarray(pbp),
            jnp.asarray(pbf), gp, gf, lp_arr, lf_arr)
