"""Per-dimension bounds: degenerate and mixed-sign boxes.

The ``[Dpad, 1]`` bound columns (kernels) and ``[D]`` bound arrays (jnp
engine) must handle the edges the Problem API now allows: ``lo == hi`` on
some dimensions (the coordinate is frozen: zero span at init, zero
velocity budget — ``max_v = 0.5 * (hi - lo) = 0`` — so the clip chain pins
it forever) and boxes that do not straddle zero (all-negative,
all-positive, mixed per dimension) through init, advance, the serial
mirror and the Pallas kernels.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import PSOConfig, init_swarm, run, solve
from repro.core.problem import Problem
from repro.core.serial import run_serial_fast
from repro.kernels import ops

FROZEN_LO = (0.5, -2.0, 1.0)     # dims 0 and 2 frozen (lo == hi)
FROZEN_HI = (0.5, 2.0, 1.0)


def _frozen_cfg(n=64, fitness="sphere"):
    return PSOConfig(dim=3, particle_cnt=n, fitness=fitness,
                     min_pos=FROZEN_LO, max_pos=FROZEN_HI).resolved()


def test_frozen_dims_init():
    cfg = _frozen_cfg()
    s0 = init_swarm(cfg, 0)
    pos, vel = np.asarray(s0.pos), np.asarray(s0.vel)
    assert np.all(pos[:, 0] == 0.5) and np.all(pos[:, 2] == 1.0)
    assert np.all(vel[:, 0] == 0.0) and np.all(vel[:, 2] == 0.0)
    assert pos[:, 1].min() >= -2.0 and pos[:, 1].max() <= 2.0
    assert np.all(np.isfinite(np.asarray(s0.fit)))


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock",
                                     "async"])
def test_frozen_dims_stay_frozen_through_advance(variant):
    cfg = _frozen_cfg()
    s = solve(cfg, seed=1, iters=40, variant=variant)
    pos, vel = np.asarray(s.pos), np.asarray(s.vel)
    assert np.all(pos[:, 0] == 0.5) and np.all(pos[:, 2] == 1.0)
    assert np.all(vel[:, 0] == 0.0) and np.all(vel[:, 2] == 0.0)
    # the free dim still optimizes: sphere's best is at x_1 = 0, so the
    # optimum of the frozen problem is -(0.25 + 0 + 1)
    assert float(s.gbest_fit) == pytest.approx(-1.25, abs=1e-3)


def test_frozen_dims_through_kernels():
    cfg = _frozen_cfg()
    s0 = init_swarm(cfg, 0)
    for out in (ops.run_queue_lock_fused(cfg, s0, iters=10, block_n=32),
                ops.run_queue_lock_fused_async(cfg, s0, iters=10,
                                               sync_every=4, block_n=32)):
        pos, vel = np.asarray(out.pos), np.asarray(out.vel)
        assert np.all(pos[:, 0] == 0.5) and np.all(pos[:, 2] == 1.0)
        assert np.all(vel[:, 0] == 0.0) and np.all(vel[:, 2] == 0.0)
        assert float(out.gbest_fit) >= float(s0.gbest_fit)


def test_frozen_dims_kernel_matches_jnp_init_exactly():
    """The frozen columns are bound consts: the kernel and library inits
    must agree on them bit-for-bit (both compute lo + 0 * u)."""
    cfg = _frozen_cfg()
    s0 = init_swarm(cfg, 3)
    out = ops.queue_step(cfg, s0, block_n=32)
    pos = np.asarray(out.pos)
    assert np.all(pos[:, 0] == 0.5) and np.all(pos[:, 2] == 1.0)


def test_frozen_dims_serial_mirror():
    cfg = _frozen_cfg(n=32)
    gf, gp = run_serial_fast(cfg, 0, 20)
    assert gp[0] == 0.5 and gp[2] == 1.0
    assert np.isfinite(gf)


@pytest.mark.parametrize("lo,hi", [
    ((-5.0, -3.0), (-1.0, -0.5)),     # all-negative box
    ((2.0, 0.25), (6.0, 8.0)),        # all-positive box
    ((-4.0, 1.0), (-1.0, 3.0)),       # mixed-sign per dimension
])
def test_mixed_sign_bounds_respected(lo, hi):
    prob = Problem(name="box", fn=lambda x: -jnp.sum(x * x, -1),
                   lo=lo, hi=hi)
    cfg = PSOConfig(dim=2, particle_cnt=64, fitness=prob).resolved()
    lo_a, hi_a = np.asarray(lo), np.asarray(hi)
    for variant in ("queue", "async"):
        s = solve(cfg, seed=0, iters=30, variant=variant)
        pos = np.asarray(s.pos)
        assert np.all(pos >= lo_a - 1e-6) and np.all(pos <= hi_a + 1e-6)
        vel = np.abs(np.asarray(s.vel))
        assert np.all(vel <= 0.5 * (hi_a - lo_a) * (1 + 1e-6))
    k = ops.run_queue_lock_fused(cfg, init_swarm(cfg, 0), iters=10,
                                 block_n=32)
    pos = np.asarray(k.pos)
    assert np.all(pos >= lo_a - 1e-6) and np.all(pos <= hi_a + 1e-6)
    # the clamped optimum is the box corner closest to the origin
    want = -np.sum(np.where(lo_a > 0, lo_a, np.where(hi_a < 0, hi_a, 0.0))
                   ** 2)
    s = solve(cfg, seed=0, iters=200, variant="queue")
    assert float(s.gbest_fit) == pytest.approx(want, abs=1e-2)


def test_frozen_dims_batched_engine_row_identity():
    cfg = _frozen_cfg()
    rs = repro.solve_many(cfg.fitness, [0, 1], dim=3, particles=64,
                          iters=20, min_pos=FROZEN_LO, max_pos=FROZEN_HI,
                          variant="queue")
    lone = repro.solve(cfg.fitness, dim=3, particles=64, iters=20, seed=1,
                       min_pos=FROZEN_LO, max_pos=FROZEN_HI,
                       variant="queue")
    assert np.array_equal(np.asarray(rs[1].state.pos),
                          np.asarray(lone.state.pos))
    for r in rs:
        pos = np.asarray(r.state.pos)
        assert np.all(pos[:, 0] == 0.5) and np.all(pos[:, 2] == 1.0)


def test_fully_degenerate_scalar_box():
    """lo == hi on EVERY dim: the swarm is pinned at one point — legal,
    if useless (the engine must not NaN out on the zero span)."""
    prob = Problem(name="pin", fn=lambda x: -jnp.sum(x * x, -1),
                   lo=2.0, hi=2.0)
    cfg = PSOConfig(dim=2, particle_cnt=16, fitness=prob).resolved()
    s = solve(cfg, seed=0, iters=5, variant="queue")
    assert np.all(np.asarray(s.pos) == 2.0)
    assert float(s.gbest_fit) == -8.0
