"""Kernel contention counters: layout + host-side view.

The fused Pallas kernels (``repro.kernels.pso_step``) optionally carry an
extra aliased int32 SMEM buffer of ``SLOTS_PER_SWARM`` slots per swarm:

    [3*s + 0]  queue_updates       — iterations (sync) / inner iterations
                                     x blocks (async) where at least one
                                     particle beat the working best and a
                                     queue fold ran
    [3*s + 1]  publications        — writes that actually landed in the
                                     shared gbest slot: the sync kernels'
                                     ``pl.when(any(fit > gbest))`` body,
                                     the async kernels' chunk-exit
                                     ``pl.when(local_best > gbest)``
    [3*s + 2]  block_improvements  — (iteration, block) pairs where at
                                     least one particle improved its own
                                     pbest (the Alg.2 fold did real work)

For the sync queue-lock kernel one conditional guards both the queue fold
and the publication, so ``queue_updates == publications`` by construction;
the async kernel splits them (block-local updates are frequent, shared
publications happen at most once per ``sync_every`` chunk per block) —
their ratio is the paper's contention-avoidance story as a measured
number. The eager oracles in ``repro.kernels.ref`` count the same events
at the same program points; tests/test_telemetry.py asserts equality.

Counts accumulate across a whole fused call (all iterations, all blocks)
and, because the buffer is donated/aliased like the state operands, across
chunked calls when the caller threads the array back in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

#: Slot names, in buffer order.
COUNTER_NAMES = ("queue_updates", "publications", "block_improvements")

#: int32 slots per swarm in the kernel counter buffer.
SLOTS_PER_SWARM = len(COUNTER_NAMES)


def zero_counts(swarms: int = 1):
    """Fresh kernel counter buffer: ``[SLOTS_PER_SWARM * swarms]`` int32.

    Lazy jax import so the dataclass side of this module stays usable in
    pure-host contexts (exporters, docs tooling).
    """
    import jax.numpy as jnp
    return jnp.zeros((SLOTS_PER_SWARM * swarms,), jnp.int32)


@dataclass(frozen=True)
class KernelCounters:
    """Host-side view of one swarm's kernel counter slots."""

    queue_updates: int
    publications: int
    block_improvements: int

    @classmethod
    def from_array(cls, arr) -> "KernelCounters":
        """[SLOTS_PER_SWARM] buffer -> one swarm's counters."""
        a = np.asarray(arr).reshape(-1)
        if a.shape[0] != SLOTS_PER_SWARM:
            raise ValueError(
                f"expected {SLOTS_PER_SWARM} counter slots, got {a.shape}")
        return cls(*(int(v) for v in a))

    @classmethod
    def rows(cls, arr) -> List["KernelCounters"]:
        """[S * SLOTS_PER_SWARM] or [S, SLOTS_PER_SWARM] -> per-swarm."""
        a = np.asarray(arr).reshape(-1, SLOTS_PER_SWARM)
        return [cls(*(int(v) for v in row)) for row in a]

    def as_dict(self) -> Dict[str, int]:
        return {n: getattr(self, n) for n in COUNTER_NAMES}

    def __add__(self, other: "KernelCounters") -> "KernelCounters":
        return KernelCounters(
            self.queue_updates + other.queue_updates,
            self.publications + other.publications,
            self.block_improvements + other.block_improvements)
