"""Roofline machinery unit tests (no production-mesh compiles):
HLO collective parsing, param counting, model-FLOPs accounting, report."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import zoo
from repro.roofline import analysis as ra


HLO_SAMPLE = """
ENTRY %main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %p0), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), to_apply=%sum
  %rs = bf16[8,128]{1,0} reduce-scatter(bf16[128,128]{1,0} %y), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %z), source_target_pairs={{0,1}}
  %ata = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %c, f32[64,128]{1,0} %d)
}
"""


def test_collective_bytes_parsing():
    out = ra.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 256 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["reduce-scatter"] == 8 * 128 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["all-to-all"] == 2 * (2 * 2 * 4)
    assert out["count"] == 5
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "collective-permute",
        "all-to-all"))


def test_shape_bytes_tuple_and_scalar():
    assert ra._shape_bytes("f32[10,10]") == 400
    assert ra._shape_bytes("(bf16[4], f32[2,2])") == 8 + 16
    assert ra._shape_bytes("pred[8]") == 8
    assert ra._shape_bytes("u32[]") == 4          # scalar


def test_active_params_moe():
    cfg = get_arch("phi3.5-moe-42b-a6.6b").smoke()
    shapes = zoo.abstract_params(cfg)
    total = ra.count_params(shapes)
    active = ra.count_active_params(cfg, shapes)
    assert 0 < active < total                      # experts discounted
    # dense arch: active == total
    dcfg = get_arch("stablelm-3b").smoke()
    dshapes = zoo.abstract_params(dcfg)
    assert ra.count_active_params(dcfg, dshapes) == ra.count_params(dshapes)


def test_model_flops_train_vs_prefill():
    cfg = get_arch("stablelm-3b").smoke()
    shapes = zoo.abstract_params(cfg)
    t = ra.model_flops(cfg, shapes, "train", 1000)
    p = ra.model_flops(cfg, shapes, "prefill", 1000)
    assert t == 3 * p                              # 6ND vs 2ND


def test_roofline_terms_and_bottleneck():
    r = ra.Roofline(arch="x", shape="y", mesh="16x16", chips=256,
                    flops_total=256 * ra.PEAK_FLOPS,     # 1 s compute
                    bytes_total=256 * ra.HBM_BW * 2.0,   # 2 s memory
                    coll_bytes_per_chip=ra.ICI_BW * 0.5,  # 0.5 s
                    coll_count=10, model_flops=128 * ra.PEAK_FLOPS)
    assert r.t_compute == 1.0
    assert r.t_memory == 2.0
    assert r.t_collective == 0.5
    assert r.bottleneck == "memory"
    assert r.useful_ratio == 0.5
    assert r.roofline_fraction == (0.5 / 2.0)
    d = r.to_dict()
    assert d["bottleneck"] == "memory"


def test_scan_body_counted_once_documented():
    """Regression guard for the piecewise-analysis premise."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scan10(a):
        return jax.lax.scan(lambda c, _: (c @ c, None), a, None,
                            length=10)[0]

    f1 = ra.cost_analysis_dict(jax.jit(lambda a: a @ a).lower(x).compile())["flops"]
    fs = ra.cost_analysis_dict(jax.jit(scan10).lower(x).compile())["flops"]
    # body counted once (+ O(1) loop bookkeeping), NOT 10x:
    assert fs < 1.5 * f1   # piecewise analysis must correct for trips


# ==========================================================================
# pso_cost: the analytic schedule cost model behind the autotuner.
# Golden files pin the per-iteration flop/byte arithmetic for fixed
# shapes (a refactor that silently changes a term must fail loudly —
# the tuner's ranking depends on these numbers); property tests pin the
# orderings the tuner exploits.
# ==========================================================================
import dataclasses

import pytest

from repro.roofline import pso_cost
from repro.roofline.pso_cost import (DEFAULT_CALIBRATION, FITNESS_MIX,
                                     OpMix, estimate_us_per_iter,
                                     fit_calibration, fitness_op_mix,
                                     iteration_cost)


def test_golden_fitness_mix_table():
    """The op-mix table matches the fitness source expressions."""
    assert FITNESS_MIX["cubic"] == OpMix(9.0, 0.0)
    assert FITNESS_MIX["sphere"] == OpMix(2.0, 1.0)
    assert FITNESS_MIX["rosenbrock"] == OpMix(8.0, 1.0)
    assert FITNESS_MIX["griewank"] == OpMix(4.0, 4.0, 1.0)
    assert FITNESS_MIX["rastrigin"] == OpMix(5.0, 3.0, 1.0)
    assert FITNESS_MIX["ackley"] == OpMix(4.0, 7.0, 1.0, 3.0)
    # sphere at d=4, n=256: 256 * (4*2 + 1) flops, no transcendentals
    mix = fitness_op_mix("sphere", 4)
    assert mix.flops(4, 256) == 256 * 9
    assert mix.transcendentals(4, 256) == 0


def test_golden_reduction_jnp_sphere():
    """reduction/jnp, sphere, d=4, n=256, f32 — every term by hand."""
    c = iteration_cost("reduction", "sphere", 4, 256)
    d, n = 4, 256
    fit = n * (d * 2 + 1)                       # sphere mix
    adv = n * d * (9 + 5 + 1)                   # vel + pos + pbest select
    pbest = n * 2
    agg = n + d + 1                             # unconditional argmax+gather
    assert c.flops == fit + adv + pbest + agg
    assert c.transcendentals == 0
    assert c.bytes_hbm == 4 * (8 * n * d + 4 * n) + 4 * (d + 1) * 2
    assert c.gbest_bytes == 4 * (d + 1) * 2
    assert c.const_bytes == 0 and c.grid_steps == 0 and c.dispatches == 0


def test_golden_queue_rare_improvement_term():
    """queue/jnp aggregation: 2n compare+any every iter, argmax+gather
    only on the RARE_IMPROVE fraction of iterations."""
    d, n = 4, 256
    cq = iteration_cost("queue", "sphere", d, n)
    cr = iteration_cost("reduction", "sphere", d, n)
    rare = pso_cost.RARE_IMPROVE
    agg_q = 2 * n + rare * (2 * n + d)
    agg_r = n + d + 1
    assert cq.flops - cr.flops == pytest.approx(agg_q - agg_r)
    # gbest traffic: (d+1) scalars, written only on the rare improvements
    assert cq.gbest_bytes == pytest.approx(4 * (d + 1) * (1 + rare))


def test_golden_async_jnp_sphere():
    """async/jnp, d=4, n=256, block_n=64 (4 blocks), sync_every=8."""
    d, n, bn, k = 4, 256, 64, 8
    nb = n // bn
    c = iteration_cost("async", "sphere", d, n, block_n=bn, sync_every=k)
    base = iteration_cost("reduction", "sphere", d, n)
    agg = n + nb * (1 + d) + (nb + d) / k
    agg_r = n + d + 1
    assert c.flops - (base.flops - agg_r) == pytest.approx(agg)
    # publication traffic: pull+publish /k, plus per-iter block-local upkeep
    assert c.gbest_bytes == pytest.approx(
        4 * 2 * (d + 1) * nb / k + 4 * 2 * (d + 1) * nb)
    assert c.grid_steps == 0        # jnp engine: no Pallas grid


def test_golden_async_kernel_state_amortization():
    """The block-resident async kernel reads/writes swarm state once per
    chunk, not per iteration: state bytes divide by sync_every."""
    d, n, bn, k = 4, 256, 128, 8
    cj = iteration_cost("async", "sphere", d, n, block_n=bn, sync_every=k,
                        backend="jnp")
    ck = iteration_cost("async", "sphere", d, n, block_n=bn, sync_every=k,
                        backend="kernel")
    state = 4 * (8 * n * d + 4 * n)
    assert (cj.bytes_hbm - cj.gbest_bytes) == pytest.approx(state)
    assert (ck.bytes_hbm - ck.gbest_bytes - ck.const_bytes) == \
        pytest.approx(state / k)
    assert ck.grid_steps == pytest.approx((n // bn) / k)


def test_golden_queue_kernel_dispatch():
    """The queue kernel launches once per iteration (nb grid steps + one
    host dispatch); the fused queue_lock kernel folds iters into the
    grid so it dispatches once per RUN, not per iteration."""
    d, n, bn = 2, 256, 128
    cq = iteration_cost("queue", "sphere", d, n, block_n=bn,
                        backend="kernel")
    cf = iteration_cost("queue_lock", "sphere", d, n, block_n=bn,
                        backend="kernel")
    assert cq.grid_steps == n // bn and cq.dispatches == 1.0
    assert cf.grid_steps == n // bn and cf.dispatches == 0.0


def test_golden_batch_scaling():
    a = iteration_cost("queue", "rastrigin", 8, 512, batch=1)
    b = iteration_cost("queue", "rastrigin", 8, 512, batch=16)
    for f in ("flops", "transcendentals", "bytes_hbm", "gbest_bytes"):
        assert getattr(b, f) == pytest.approx(16 * getattr(a, f))


def test_golden_hetero_table_pricing():
    """jnp lax.switch lowers to select_n — every branch evaluated, so
    fitness flops scale with the table size; kernels run a real
    conditional and pay only per-grid-step switch bookkeeping."""
    d, n, t = 4, 256, 6
    base = iteration_cost("queue", "sphere", d, n)
    het = iteration_cost("queue", "sphere", d, n, hetero_table=t)
    mix = fitness_op_mix("sphere", d)
    assert het.flops - base.flops == pytest.approx((t - 1) * mix.flops(d, n))
    kb = iteration_cost("queue_lock", "sphere", d, n, block_n=128,
                        backend="kernel")
    kh = iteration_cost("queue_lock", "sphere", d, n, block_n=128,
                        backend="kernel", hetero_table=t)
    assert kh.flops - kb.flops == pytest.approx(
        pso_cost.HETERO_SWITCH_FLOPS * (n // 128))


def test_constrained_problem_doubles_mix():
    """A constrained variant of a TABLED problem prices at ~2x the raw
    mix (objective + violation evaluated together)."""
    import dataclasses as dc
    from repro.core.constraints import (ConstraintSet, project_simplex,
                                        simplex_constraints)
    from repro.core.problem import resolve_problem
    plain_prob = resolve_problem("sphere")
    con_prob = dc.replace(plain_prob, constraints=ConstraintSet(
        constraints=simplex_constraints(), mode="projection",
        projection=project_simplex))
    plain = fitness_op_mix(plain_prob, 4)
    con = fitness_op_mix(con_prob, 4)
    assert con.flops_per_dim == 2 * plain.flops_per_dim
    assert con.flops_per_particle == 2 * plain.flops_per_particle + 4
    # the registered constrained built-ins (custom fn, not in the table)
    # fall through to measured accounting without error
    assert fitness_op_mix("sphere_simplex", 4).flops_per_dim > 0


def test_builtin_lowering_is_const_free():
    assert pso_cost.const_operand_bytes("sphere", 4, 128) == 0.0
    assert pso_cost.const_operand_bytes("rastrigin", 8, 128) == 0.0


@pytest.mark.parametrize("variant", ["reduction", "queue", "queue_lock",
                                     "async"])
def test_cost_monotone_in_n(variant):
    """More particles never cost less — in flops, bytes, and estimated
    microseconds (for the default calibration)."""
    prev = None
    for n in (64, 128, 256, 512, 1024, 2048):
        c = iteration_cost(variant, "rastrigin", 8, n, sync_every=8)
        us = estimate_us_per_iter(variant, "rastrigin", 8, n, sync_every=8)
        if prev is not None:
            assert c.flops > prev[0].flops
            assert c.bytes_hbm > prev[0].bytes_hbm
            assert us > prev[1]
        prev = (c, us)


@pytest.mark.parametrize("backend", ["jnp", "kernel"])
def test_async_gbest_traffic_decreasing_in_sync_every(backend):
    """The paper's knob: sparser publication must strictly shrink the
    gbest term (and never increase total traffic)."""
    d, n, bn = 8, 512, 128
    prev = None
    for k in (1, 2, 4, 8, 16, 32, 64):
        c = iteration_cost("async", "sphere", d, n, block_n=bn,
                           sync_every=k, backend=backend)
        if prev is not None:
            assert c.gbest_bytes < prev.gbest_bytes
            assert c.bytes_hbm <= prev.bytes_hbm
        prev = c


def test_async_estimate_decreasing_in_sync_every_kernel():
    """On the kernel backend sync_every also amortizes grid steps and
    state traffic, so the full microsecond estimate must decrease too."""
    prev = None
    for k in (1, 4, 16, 64):
        us = estimate_us_per_iter("async", "sphere", 8, 512, block_n=128,
                                  sync_every=k, backend="kernel")
        if prev is not None:
            assert us < prev
        prev = us


def test_transcendental_problems_cost_more():
    """ackley (cos+exp+sqrt) must price above sphere at equal shape."""
    assert (estimate_us_per_iter("queue", "ackley", 8, 512)
            > estimate_us_per_iter("queue", "sphere", 8, 512))


def test_cost_model_invalid_inputs_raise():
    with pytest.raises(ValueError, match="variant"):
        iteration_cost("bogus", "sphere", 4, 64)
    with pytest.raises(ValueError, match="backend"):
        iteration_cost("queue", "sphere", 4, 64, backend="gpu")
    with pytest.raises(ValueError, match="reduction kernel"):
        iteration_cost("reduction", "sphere", 4, 64, backend="kernel")


def _synthetic_bench(meta=None):
    """A BENCH doc generated FROM a known calibration — the fit must
    recover its constants."""
    true = dataclasses.replace(DEFAULT_CALIBRATION, flops_per_us=2000.0,
                               iter_overhead_us=1.0, grid_step_us=30.0)
    recs = []
    for n in (64, 256, 1024):
        for v in ("reduction", "queue", "queue_lock"):
            cost = iteration_cost(v, "cubic", 1, n)
            us = true.us_per_iter(cost, rng_elems=n * pso_cost.RNG_DRAWS)
            recs.append({"name": f"table3/p{n}/{v}", "us_per_call": us})
    for k in (1, 4, 16, 64):
        nb = 1024 // 256
        recs.append({"name": f"async_sweep/d1_n1024_b256/sync_every_{k}",
                     "us_per_call": 100.0 + true.grid_step_us * nb / k})
    return {"meta": meta or {}, "benchmarks": recs}, true


def test_fit_calibration_recovers_synthetic_constants():
    doc, true = _synthetic_bench()
    fit = fit_calibration(doc)
    assert fit.source.startswith("bench-fit")
    assert fit.flops_per_us == pytest.approx(true.flops_per_us, rel=0.25)
    assert fit.grid_step_us == pytest.approx(true.grid_step_us, rel=0.05)


def test_fit_calibration_refuses_host_mismatch():
    doc, _ = _synthetic_bench(meta={"host": "other-box", "cpu_count": 9999})
    fit = fit_calibration(doc)
    assert "host-mismatch" in fit.source
    assert fit.flops_per_us == DEFAULT_CALIBRATION.flops_per_us
    assert fit.grid_step_us == DEFAULT_CALIBRATION.grid_step_us


def test_fit_calibration_missing_artifact_is_default():
    assert fit_calibration(None) == DEFAULT_CALIBRATION
    assert fit_calibration("/nonexistent/BENCH.json") == DEFAULT_CALIBRATION


def test_fit_calibration_committed_baseline():
    """The committed baseline must always yield a usable calibration
    (fitted when host-comparable, default otherwise — never a crash)."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_pso.json")
    fit = fit_calibration(path)
    assert fit.flops_per_us > 0 and fit.grid_step_us > 0
