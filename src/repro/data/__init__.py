from .pipeline import DataConfig, MemmapCorpus, SyntheticLM, write_corpus

__all__ = ["DataConfig", "MemmapCorpus", "SyntheticLM", "write_corpus"]
