"""Pallas TPU kernel for chunked Gated Linear Attention — the shared
compute hot-spot of the hymba SSD branch and the xLSTM mLSTM blocks
(EXPERIMENTS.md §Perf identified its autodiff residuals as the dominant
memory term of hybrid/ssm training; the jnp-level fix is chunk-remat, the
kernel-level fix is this: intra-chunk tiles never leave VMEM).

Recurrence (repro.models.ssm.gla_chunked semantics):

    H_t = exp(ld_t) · H_{t-1} + exp(li_t) · k_t ⊗ v_t
    y_t = q_t · H_t

Grid: (batch·heads, n_chunks) — sequential "arbitrary" order. The running
state H [N, P] lives in a VMEM scratch buffer, carried across the chunk
dimension exactly like the PSO fused kernel carries gbest (DESIGN.md §2:
TPU sequential-grid semantics replace cross-block synchronization). Per
step the kernel computes the intra-chunk masked matmul in registers/VMEM
and writes only the [L, P] output tile — the [L, L] weight tile is never
materialized to HBM.

Forward only (training backward uses the chunk-remat path; a custom
backward kernel is symmetric future work). Validated in interpret mode
against the pure-jnp engine in tests/test_gla_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

_CLAMP = 20.0


def _gla_kernel(q_ref, k_ref, v_ref, ld_ref, li_ref, y_ref, h_scratch,
                *, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():                       # new (batch, head): zero the state
        h_scratch[...] = jnp.zeros_like(h_scratch)

    q = q_ref[0]                        # [L, N]
    k = k_ref[0]
    v = v_ref[0]                        # [L, P]
    ld = ld_ref[0].astype(jnp.float32)  # [L]
    li = li_ref[0].astype(jnp.float32)
    cum = jnp.cumsum(ld)                # [L]
    idx = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = idx >= jdx
    logw = cum[:, None] - cum[None, :] + li[None, :]
    logw = jnp.where(tri, logw, -jnp.inf)
    w = jnp.exp(jnp.clip(logw, -_CLAMP * 4, _CLAMP))        # [L, L]
    qk = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    y_intra = jnp.dot((qk * w).astype(v.dtype), v,
                      preferred_element_type=jnp.float32)   # [L, P]
    ei = jnp.exp(jnp.clip(cum, -_CLAMP * 4, _CLAMP))        # [L]
    h = h_scratch[...]
    y_inter = jnp.dot((q * ei[:, None]).astype(jnp.float32),
                      h, preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update: H <- e_tot·H + Σ_j e(tot-cum_j+li_j) k_j ⊗ v_j
    tot = cum[-1]
    wj = jnp.exp(jnp.clip(tot - cum + li, -_CLAMP * 4, _CLAMP))
    dstate = jnp.dot((k * wj[:, None]).T.astype(jnp.float32),
                     v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)    # [N, P]
    e_tot = jnp.exp(jnp.clip(tot, -_CLAMP * 4, _CLAMP))
    h_scratch[...] = h * e_tot + dstate


def gla_forward_call(bh: int, s: int, n: int, p: int, chunk: int, dtype,
                     interpret: bool = True):
    """Build the pallas_call. Inputs: q,k [BH,S,N]; v [BH,S,P]; ld,li
    [BH,S]. Returns y [BH,S,P]. S must be a multiple of chunk."""
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    kern = functools.partial(_gla_kernel, chunk=chunk)
    mat = lambda width: pl.BlockSpec((1, chunk, width),
                                     lambda b, c: (b, c, 0))
    vec = pl.BlockSpec((1, chunk), lambda b, c: (b, c))
    return pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[mat(n), mat(n), mat(p), vec, vec],
        out_specs=mat(p),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY, pltpu.ARBITRARY)),
        interpret=interpret,
        name="gla_chunked_fwd",
    )


def gla_forward(q, k, v, log_decay, log_inc, chunk: int = 128,
                interpret: bool = True):
    """Drop-in (forward-only) replacement for models.ssm.gla_chunked.

    q,k: [B,S,H,N]; v: [B,S,H,P]; gates [B,S,H]. Returns y [B,S,H,P].
    """
    b, s, h, n = q.shape
    p = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        log_decay = jnp.pad(log_decay, [(0, 0), (0, pad), (0, 0)])
        log_inc = jnp.pad(log_inc, [(0, 0), (0, pad), (0, 0)],
                          constant_values=-_CLAMP * 2)
    sp = s + pad
    fold = lambda a: a.transpose(0, 2, 1, *range(3, a.ndim)).reshape(
        b * h, sp, *a.shape[3:])
    qf, kf, vf = fold(q), fold(k), fold(v)
    ldf = log_decay.transpose(0, 2, 1).reshape(b * h, sp)
    lif = log_inc.transpose(0, 2, 1).reshape(b * h, sp)
    call = gla_forward_call(b * h, sp, n, p, min(chunk, sp), v.dtype,
                            interpret=interpret)
    y = call(qf, kf, vf, ldf, lif)
    y = y.reshape(b, h, sp, p).transpose(0, 2, 1, 3)
    return y[:, :s]
