"""Roofline machinery unit tests (no production-mesh compiles):
HLO collective parsing, param counting, model-FLOPs accounting, report."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import zoo
from repro.roofline import analysis as ra


HLO_SAMPLE = """
ENTRY %main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %p0), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), to_apply=%sum
  %rs = bf16[8,128]{1,0} reduce-scatter(bf16[128,128]{1,0} %y), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %z), source_target_pairs={{0,1}}
  %ata = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %c, f32[64,128]{1,0} %d)
}
"""


def test_collective_bytes_parsing():
    out = ra.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 256 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["reduce-scatter"] == 8 * 128 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["all-to-all"] == 2 * (2 * 2 * 4)
    assert out["count"] == 5
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "collective-permute",
        "all-to-all"))


def test_shape_bytes_tuple_and_scalar():
    assert ra._shape_bytes("f32[10,10]") == 400
    assert ra._shape_bytes("(bf16[4], f32[2,2])") == 8 + 16
    assert ra._shape_bytes("pred[8]") == 8
    assert ra._shape_bytes("u32[]") == 4          # scalar


def test_active_params_moe():
    cfg = get_arch("phi3.5-moe-42b-a6.6b").smoke()
    shapes = zoo.abstract_params(cfg)
    total = ra.count_params(shapes)
    active = ra.count_active_params(cfg, shapes)
    assert 0 < active < total                      # experts discounted
    # dense arch: active == total
    dcfg = get_arch("stablelm-3b").smoke()
    dshapes = zoo.abstract_params(dcfg)
    assert ra.count_active_params(dcfg, dshapes) == ra.count_params(dshapes)


def test_model_flops_train_vs_prefill():
    cfg = get_arch("stablelm-3b").smoke()
    shapes = zoo.abstract_params(cfg)
    t = ra.model_flops(cfg, shapes, "train", 1000)
    p = ra.model_flops(cfg, shapes, "prefill", 1000)
    assert t == 3 * p                              # 6ND vs 2ND


def test_roofline_terms_and_bottleneck():
    r = ra.Roofline(arch="x", shape="y", mesh="16x16", chips=256,
                    flops_total=256 * ra.PEAK_FLOPS,     # 1 s compute
                    bytes_total=256 * ra.HBM_BW * 2.0,   # 2 s memory
                    coll_bytes_per_chip=ra.ICI_BW * 0.5,  # 0.5 s
                    coll_count=10, model_flops=128 * ra.PEAK_FLOPS)
    assert r.t_compute == 1.0
    assert r.t_memory == 2.0
    assert r.t_collective == 0.5
    assert r.bottleneck == "memory"
    assert r.useful_ratio == 0.5
    assert r.roofline_fraction == (0.5 / 2.0)
    d = r.to_dict()
    assert d["bottleneck"] == "memory"


def test_scan_body_counted_once_documented():
    """Regression guard for the piecewise-analysis premise."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scan10(a):
        return jax.lax.scan(lambda c, _: (c @ c, None), a, None,
                            length=10)[0]

    f1 = ra.cost_analysis_dict(jax.jit(lambda a: a @ a).lower(x).compile())["flops"]
    fs = ra.cost_analysis_dict(jax.jit(scan10).lower(x).compile())["flops"]
    # body counted once (+ O(1) loop bookkeeping), NOT 10x:
    assert fs < 1.5 * f1   # piecewise analysis must correct for trips
