"""Attention: GQA (+bias, RoPE, sliding window), MLA (latent KV), and a
memory-efficient blockwise "flash" attention in pure JAX.

The flash path never materializes [S, S] scores: a static python loop over
query blocks wraps a ``lax.scan`` over exactly the key/value blocks inside
the causal/window horizon, carrying online-softmax statistics. This keeps
HLO FLOPs at ~S²/2 for causal (not S²) and ~S·W for sliding-window — the
compiled cost_analysis reflects only useful work, which matters for the
roofline's MODEL_FLOPS/HLO_FLOPs ratio (EXPERIMENTS.md §Roofline).

Decode paths take a cache dict and a scalar ``cache_len``; MLA decode uses
the absorbed-weight formulation so attention runs entirely in the latent
space (cache = [S, kv_rank + rope] per token, the technique's memory win).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init

Params = Dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise flash attention (training / prefill)
# ---------------------------------------------------------------------------

def _pad_to(x, mult: int, axis: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg), s


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, q_block: int = 1024,
                    kv_block: int = 1024, scale: Optional[float] = None,
                    prefix_len: int = 0):
    """q: [B, Sq, H, hd_qk]; k: [B, Sk, K, hd_qk]; v: [B, Sk, K, hd_v].

    GQA via grouped einsum (H = K * G). ``q_offset``: absolute position of
    q[0] (prefill continuation). ``window``: 0 = unlimited; else each query
    attends to keys in (q_pos - window, q_pos]. ``prefix_len``: the first
    `prefix_len` keys (meta tokens / vision prefix) are always visible.
    Returns [B, Sq, H, hd_v].
    """
    b, sq, h, hdq = q.shape
    _, sk, kh, hdv = v.shape
    g = h // kh
    scale = scale or (hdq ** -0.5)
    q_block = min(q_block, max(sq, 16))
    kv_block = min(kv_block, max(sk, 16))

    q, sq_real = _pad_to(q, q_block, axis=1)
    k, sk_real = _pad_to(k, kv_block, axis=1)
    v, _ = _pad_to(v, kv_block, axis=1)
    sqp, skp = q.shape[1], k.shape[1]
    nq, nk = sqp // q_block, skp // kv_block

    qg = q.reshape(b, sqp, kh, g, hdq)
    outs = []
    for i in range(nq):                     # static loop: per-block bounds
        q_i = qg[:, i * q_block:(i + 1) * q_block]          # [B,qb,K,G,hd]
        q_i = (q_i * scale).astype(q.dtype)
        qpos = q_offset + i * q_block + jnp.arange(q_block)  # [qb]
        # causal horizon for this block (static ints → scan length is exact)
        if causal:
            hi_pos = q_offset + (i + 1) * q_block           # exclusive
            k_hi = min(nk, -(-min(hi_pos, sk_real) // kv_block))
        else:
            k_hi = nk
        if window and causal:
            lo_pos = q_offset + i * q_block - window
            k_lo = max(0, lo_pos // kv_block)
        else:
            k_lo = 0
        n_steps = max(k_hi - k_lo, 1)

        def kv_step(carry, blk):
            m, l, acc = carry
            k_j = jax.lax.dynamic_slice_in_dim(k, blk * kv_block, kv_block, 1)
            v_j = jax.lax.dynamic_slice_in_dim(v, blk * kv_block, kv_block, 1)
            kpos = blk * kv_block + jnp.arange(kv_block)     # [kb]
            s_ij = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j,
                              preferred_element_type=jnp.float32)
            mask = kpos[None, :] < sk_real                  # valid keys
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
                if window:
                    win = (qpos[:, None] - kpos[None, :] < window)
                    if prefix_len:
                        win = win | (kpos[None, :] < prefix_len)
                    mask = mask & win
            s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), ()

        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, hdv), jnp.float32)
        from .unroll import maybe_scan
        (m, l, acc), _ = maybe_scan(
            kv_step, (m0, l0, a0), jnp.arange(k_lo, k_lo + n_steps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # [B,K,G,qb,hdv]
        outs.append(out.transpose(0, 3, 1, 2, 4))           # [B,qb,K,G,hdv]
    out = jnp.concatenate(outs, axis=1)[:, :sq_real]
    return out.reshape(b, sq_real, h, hdv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: Optional[float] = None, prefix_len: int = 0):
    """Single-token attention. q: [B, 1, H, hd]; caches: [B, S, K, hd]."""
    b, _, h, hdq = q.shape
    _, s, kh, hdv = v_cache.shape
    g = h // kh
    scale = scale or (hdq ** -0.5)
    qg = (q.reshape(b, kh, g, hdq) * scale).astype(q.dtype)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)
    kpos = jnp.arange(s)
    mask = kpos[None, :] < cache_len
    if window:
        win = (cache_len - 1 - kpos[None, :]) < window
        if prefix_len:
            win = win | (kpos[None, :] < prefix_len)
        mask = mask & win
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def init_gqa(key, d: int, h: int, kh: int, hd: int, bias: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, kh * hd, dtype),
         "wv": dense_init(ks[2], d, kh * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype)}
    if bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    return p


def gqa_project(p: Params, x, h: int, kh: int, hd: int):
    from .policy import constrain
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (constrain(q.reshape(b, s, h, hd), ("dp", None, "tp", None)),
            constrain(k.reshape(b, s, kh, hd), ("dp", None, "tp", None)),
            constrain(v.reshape(b, s, kh, hd), ("dp", None, "tp", None)))


def gqa_forward(p: Params, x, positions, *, h, kh, hd, theta, window=0,
                prefix_len=0, q_block=1024, kv_block=1024,
                use_custom_vjp: bool = False,
                return_kv: bool = False):
    """Training / prefill self-attention. x: [B, S, d]."""
    q, k, v = gqa_project(p, x, h, kh, hd)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    if use_custom_vjp:
        from .flash_vjp import flash_attention_vjp
        out = flash_attention_vjp(q, k, v, True, window, 0, q_block,
                                  kv_block, None, prefix_len)
    else:
        out = flash_attention(q, k, v, causal=True, window=window,
                              prefix_len=prefix_len,
                              q_block=q_block, kv_block=kv_block)
    from .policy import constrain
    out = constrain(out, ("dp", None, "tp", None))
    out = constrain(out.reshape(*x.shape[:2], h * hd) @ p["wo"],
                    ("dp", None, None))
    return (out, (k, v)) if return_kv else out


def gqa_decode(p: Params, x, cache: Params, cache_len, *, h, kh, hd, theta,
               window=0, prefix_len=0, window_only_reads: bool = False):
    """x: [B, 1, d]; cache: {"k","v": [B, Smax, K, hd]} updated in place
    (functionally) at ``cache_len``. Returns (out, new_cache).

    window_only_reads (§Perf): for sliding-window layers, gather only the
    ``prefix_len`` always-visible rows plus the last ``window`` rows of
    the cache instead of streaming all Smax rows through the masked
    attention — decode reads drop from O(Smax) to O(window+prefix)
    (hymba decode_32k: 32768 → 1152 rows per layer).
    """
    q, k, v = gqa_project(p, x, h, kh, hd)
    pos = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cache_len, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cache_len, 1)
    smax = k_cache.shape[1]
    if window_only_reads and window and window + prefix_len < smax:
        start = jnp.clip(cache_len + 1 - window, prefix_len, smax - window)
        k_win = jax.lax.dynamic_slice_in_dim(k_cache, start, window, 1)
        v_win = jax.lax.dynamic_slice_in_dim(v_cache, start, window, 1)
        if prefix_len:
            k_r = jnp.concatenate([k_cache[:, :prefix_len], k_win], axis=1)
            v_r = jnp.concatenate([v_cache[:, :prefix_len], v_win], axis=1)
        else:
            k_r, v_r = k_win, v_win
        # positions within the gathered view: rows [prefix, prefix+window)
        # hold absolute positions [start, start+window); valid rows are
        # those with absolute position <= cache_len.
        kpos_abs = jnp.concatenate(
            [jnp.arange(prefix_len),
             start + jnp.arange(window)]) if prefix_len else (
            start + jnp.arange(window))
        b = x.shape[0]
        g = h // kh
        scale = hd ** -0.5
        qg = (q.reshape(b, kh, g, hd) * scale).astype(q.dtype)
        scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_r,
                            preferred_element_type=jnp.float32)
        mask = kpos_abs[None, :] <= cache_len
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
        pattn = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskh->bkgh", pattn.astype(v_r.dtype), v_r,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, 1, h * hd).astype(q.dtype)
    else:
        out = decode_attention(q, k_cache, v_cache, cache_len + 1,
                               window=window, prefix_len=prefix_len)
        out = out.reshape(x.shape[0], 1, h * hd)
    out = out @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — minicpm3
# ---------------------------------------------------------------------------

def init_mla(key, d: int, h: int, *, q_rank, kv_rank, rope_hd, nope_hd,
             v_hd, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, q_rank, dtype),
        "q_norm": jnp.ones((q_rank,), dtype),
        "wq_b": dense_init(ks[1], q_rank, h * (nope_hd + rope_hd), dtype),
        "wkv_a": dense_init(ks[2], d, kv_rank + rope_hd, dtype),
        "kv_norm": jnp.ones((kv_rank,), dtype),
        "w_uk": dense_init(ks[3], kv_rank, h * nope_hd, dtype),
        "w_uv": dense_init(ks[4], kv_rank, h * v_hd, dtype),
        "wo": dense_init(ks[5], h * v_hd, d, dtype),
    }


def _mla_q(p, x, positions, h, nope_hd, rope_hd, theta, eps):
    from .layers import rmsnorm
    b, s, _ = x.shape
    ql = rmsnorm(p["q_norm"], x @ p["wq_a"], eps)
    q = (ql @ p["wq_b"]).reshape(b, s, h, nope_hd + rope_hd)
    q_nope, q_rope = q[..., :nope_hd], q[..., nope_hd:]
    q_rope = apply_rope(q_rope, positions, theta)
    return q_nope, q_rope


def _mla_latent(p, x, positions, kv_rank, rope_hd, theta, eps):
    from .layers import rmsnorm
    kv = x @ p["wkv_a"]                                   # [B,S,kvr+rope]
    c_kv = rmsnorm(p["kv_norm"], kv[..., :kv_rank], eps)
    k_rope = apply_rope(kv[..., None, kv_rank:], positions, theta)  # [B,S,1,r]
    return c_kv, k_rope[..., 0, :]


def mla_forward(p: Params, x, positions, *, h, q_rank, kv_rank, rope_hd,
                nope_hd, v_hd, theta, eps, q_block=1024, kv_block=1024):
    """Training / prefill: expand latent to per-head K/V, run flash."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, positions, h, nope_hd, rope_hd, theta, eps)
    c_kv, k_rope = _mla_latent(p, x, positions, kv_rank, rope_hd, theta, eps)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nope_hd)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, v_hd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, rope_hd))],
        axis=-1)
    scale = (nope_hd + rope_hd) ** -0.5
    out = flash_attention(q, k, v, causal=True, scale=scale,
                          q_block=q_block, kv_block=kv_block)
    return out.reshape(b, s, h * v_hd) @ p["wo"]


def mla_decode(p: Params, x, cache: Params, cache_len, *, h, q_rank, kv_rank,
               rope_hd, nope_hd, v_hd, theta, eps):
    """Absorbed-weight decode over the latent cache.

    cache: {"c_kv": [B, Smax, kv_rank], "k_rope": [B, Smax, rope_hd]} —
    the MLA memory win: kv_rank+rope floats/token instead of 2·H·hd.
    """
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, pos, h, nope_hd, rope_hd, theta, eps)
    c_new, r_new = _mla_latent(p, x, pos, kv_rank, rope_hd, theta, eps)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), cache_len, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], r_new.astype(cache["k_rope"].dtype), cache_len, 1)
    # Absorb W_uk into q: score in latent space.
    w_uk = p["w_uk"].reshape(kv_rank, h, nope_hd)
    q_lat = jnp.einsum("bqhn,khn->bhk", q_nope, w_uk)     # [B,H,kv_rank]
    s_lat = jnp.einsum("bhk,bsk->bhs", q_lat, c_kv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhr,bsr->bhs", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scale = (nope_hd + rope_hd) ** -0.5
    scores = (s_lat + s_rope) * scale
    smax = c_kv.shape[1]
    mask = jnp.arange(smax)[None, :] < (cache_len + 1)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    pattn = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsk->bhk", pattn.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)  # [B,H,kvr]
    w_uv = p["w_uv"].reshape(kv_rank, h, v_hd)
    out = jnp.einsum("bhk,khv->bhv", ctx_lat.astype(x.dtype), w_uv)
    out = out.reshape(b, 1, h * v_hd) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
