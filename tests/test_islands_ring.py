"""The async multi-device island ring (core.distributed) + the exchange
primitives' contracts.

Collective property tests run in-process under ``jax.vmap(axis_name=...)``
(vmap implements pmax/pmin/psum/ppermute over the named axis without
needing devices); the true >= 4-device mesh runs in a subprocess with
``--xla_force_host_platform_device_count`` (the main process deliberately
keeps the real single CPU device — see conftest).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PSOConfig, init_swarm, run, run_async
from repro.core.distributed import (_pmax_best, init_sharded_swarm,
                                    make_distributed_run, ring_exchange)
from repro.kernels.ref import run_islands_ring_oracle

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh():
    return jax.make_mesh((1,), ("data",))


def _spmd(fn, *args):
    """Run a collective-using fn over a vmap named axis 's'."""
    return jax.vmap(fn, axis_name="s")(*args)


# --------------------------------------------------------------------------
# _pmax_best properties (ties, ±inf, NaN) — the barrier primitive.
# --------------------------------------------------------------------------

def _pm(fit, pos):
    return _spmd(lambda f, p: _pmax_best(f, p, ("s",)),
                 jnp.asarray(fit, jnp.float32),
                 jnp.asarray(pos, jnp.float32))


def test_pmax_best_matches_dense_argmax_oracle():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 9))
        fit = rng.normal(size=n).astype(np.float32)
        pos = rng.normal(size=(n, 3)).astype(np.float32)
        gf, gp = _pm(fit, pos)
        w = int(np.argmax(fit))                   # dense oracle
        np.testing.assert_array_equal(np.asarray(gf), np.full(n, fit[w]))
        for s in range(n):
            np.testing.assert_array_equal(np.asarray(gp)[s], pos[w])


def test_pmax_best_tie_lowest_index_owns_broadcast():
    fit = [2.0, 5.0, 5.0, 5.0]
    pos = [[0.0], [10.0], [20.0], [30.0]]
    gf, gp = _pm(fit, pos)
    np.testing.assert_array_equal(np.asarray(gf), np.full(4, 5.0))
    # every shard gets shard 1's position — the lowest tied index
    np.testing.assert_array_equal(np.asarray(gp), np.full((4, 1), 10.0))


def test_pmax_best_inf_fits():
    gf, gp = _pm([-np.inf, 1.0, np.inf, np.inf], [[0.], [1.], [2.], [3.]])
    np.testing.assert_array_equal(np.asarray(gf), np.full(4, np.inf))
    np.testing.assert_array_equal(np.asarray(gp), np.full((4, 1), 2.0))
    # an all -inf swarm elects shard 0 deterministically
    gf, gp = _pm([-np.inf] * 4, [[0.], [1.], [2.], [3.]])
    np.testing.assert_array_equal(np.asarray(gf), np.full(4, -np.inf))
    np.testing.assert_array_equal(np.asarray(gp), np.zeros((4, 1)))


def test_pmax_best_nan_guard():
    # NaN never owns the broadcast (treated as -inf)...
    gf, gp = _pm([np.nan, 3.0, np.nan, 1.0], [[9.], [1.], [9.], [3.]])
    np.testing.assert_array_equal(np.asarray(gf), np.full(4, 3.0))
    np.testing.assert_array_equal(np.asarray(gp), np.full((4, 1), 1.0))
    # ...and an all-NaN swarm degrades to -inf + shard 0's pos, never a
    # garbage zero-sum position
    gf, gp = _pm([np.nan] * 4, [[7.], [1.], [2.], [3.]])
    np.testing.assert_array_equal(np.asarray(gf), np.full(4, -np.inf))
    np.testing.assert_array_equal(np.asarray(gp), np.full((4, 1), 7.0))


# --------------------------------------------------------------------------
# ring_exchange properties — the async primitive.
# --------------------------------------------------------------------------

def _hop(f, p, o, n):
    return _spmd(lambda a, b, c: ring_exchange(a, b, c, "s", n), f, p, o)


def test_ring_propagates_one_hop_per_round():
    n = 5
    f = jnp.asarray([9.0, 1.0, 2.0, 3.0, 4.0])
    p = jnp.arange(n, dtype=jnp.float32)[:, None]
    o = jnp.arange(n, dtype=jnp.int32)
    for hop in range(1, n):
        f, p, o = _hop(f, p, o, n)
        know = np.asarray(f) == 9.0
        # after h hops, shards 0..h know the best (one ring step per hop)
        np.testing.assert_array_equal(know, np.arange(n) <= hop)
    # n-1 hops: everyone knows, and owns the winner's pos + owner id
    np.testing.assert_array_equal(np.asarray(p), np.zeros((n, 1)))
    np.testing.assert_array_equal(np.asarray(o), np.zeros(n, np.int32))


def test_ring_tie_break_converges_to_lowest_owner():
    n = 4
    f = jnp.full(n, 5.0)                          # a pure fit tie
    p = jnp.arange(n, dtype=jnp.float32)[:, None]
    o = jnp.asarray([2, 1, 3, 0], jnp.int32)      # distinct originators
    for _ in range(n - 1):
        f, p, o = _hop(f, p, o, n)
    np.testing.assert_array_equal(np.asarray(o), np.zeros(n, np.int32))
    # every shard converged to the lowest-owner candidate's position
    # (owner 0's payload started on shard 3)
    np.testing.assert_array_equal(np.asarray(p), np.full((n, 1), 3.0))


def test_ring_nan_never_propagates():
    n = 4
    f = jnp.asarray([np.nan, 1.0, np.nan, 2.0])
    p = jnp.arange(n, dtype=jnp.float32)[:, None]
    o = jnp.arange(n, dtype=jnp.int32)
    for _ in range(n - 1):
        f, p, o = _hop(f, p, o, n)
    np.testing.assert_array_equal(np.asarray(f), np.full(n, 2.0))
    np.testing.assert_array_equal(np.asarray(p), np.full((n, 1), 3.0))


# --------------------------------------------------------------------------
# One-shard ring == single-chip run_async, bit for bit.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("iters,exchange,sync", [(24, 8, 4), (20, 5, 5),
                                                 (23, 8, 4)])
def test_one_shard_ring_bit_identical_to_run_async(iters, exchange, sync):
    """The acceptance identity: with one shard the ring path (shard_map,
    ppermute self-hop, fold, drain) reproduces run_async exactly —
    including remainder-tail iteration counts (23 % 8 != 0)."""
    cfg = PSOConfig(dim=4, particle_cnt=128, fitness="rastrigin").resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 7, mesh)
    runner = make_distributed_run(cfg, mesh, iters=iters, variant="async",
                                  exchange_interval=exchange,
                                  sync_every=sync)
    out = runner(st)
    ref = run_async(cfg, init_swarm(cfg, 7), iters, sync_every=sync,
                    n_blocks=out.lbest_fit.shape[0])
    # lbest differs only when a non-scheduled tail flush ran (the ring pulls
    # the published best back into the blocks, plain run_async does not)
    skip = ("lbest_pos", "lbest_fit") if iters % exchange else ()
    for f in out._fields:
        if f in skip:
            continue
        np.testing.assert_array_equal(np.asarray(getattr(out, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)
    assert float(out.gbest_fit) == float(np.max(np.asarray(out.pbest_fit)))


def test_async_ring_rejects_bad_sync_exchange_combo():
    cfg = PSOConfig(dim=2, particle_cnt=64, fitness="cubic").resolved()
    with pytest.raises(ValueError, match="divide"):
        make_distributed_run(cfg, _mesh(), iters=12, variant="async",
                             exchange_interval=6, sync_every=4)


# --------------------------------------------------------------------------
# Remainder-tail rounds (satellite: iters % exchange_interval != 0).
# --------------------------------------------------------------------------

def test_sync_variant_remainder_tail_vs_divisible():
    """iters no longer must divide exchange_interval: on one shard (where
    the exchange collective is semantically a no-op) the non-divisible
    schedule must produce the same trajectory as the divisible one, both
    equal to the plain single-chip run."""
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="sphere").resolved()
    mesh = _mesh()
    st = init_sharded_swarm(cfg, 1, mesh)
    div = make_distributed_run(cfg, mesh, iters=24, variant="queue",
                               exchange_interval=8)(st)
    ndiv = make_distributed_run(cfg, mesh, iters=24, variant="queue",
                                exchange_interval=7)(st)
    assert int(div.iteration) == int(ndiv.iteration) == 24
    np.testing.assert_allclose(np.asarray(div.pos), np.asarray(ndiv.pos),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(div.gbest_fit), float(ndiv.gbest_fit),
                               rtol=1e-4)


# --------------------------------------------------------------------------
# Eager multi-island oracle (kernels/ref.py).
# --------------------------------------------------------------------------

def test_oracle_one_island_reduces_to_run_async():
    cfg = PSOConfig(dim=4, particle_cnt=128, fitness="rastrigin").resolved()
    isl, _ = run_islands_ring_oracle(cfg, 7, 1, 24, 8, sync_every=4)
    ref = run_async(cfg, init_swarm(cfg, 7), 24, sync_every=4,
                    n_blocks=isl[0].lbest_fit.shape[0])
    for f in ref._fields:
        np.testing.assert_array_equal(np.asarray(getattr(isl[0], f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)


def test_oracle_staleness_and_final_flush_invariants():
    """Four eager islands: (a) any island's round-r best is visible on the
    island d hops downstream by round r+d — so everywhere within n_shards
    rounds; (b) after the drain every island's gbest equals the max over
    ALL pbests (final-flush invariant)."""
    n_shards = 4
    cfg = PSOConfig(dim=3, particle_cnt=256, fitness="rastrigin").resolved()
    isl, hist = run_islands_ring_oracle(cfg, 0, n_shards, 24, 8,
                                        sync_every=4)
    all_pbest = np.concatenate([np.asarray(s.pbest_fit) for s in isl])
    for s in isl:
        assert float(s.gbest_fit) == float(all_pbest.max())
    for r in range(len(hist)):
        for i in range(n_shards):
            v = hist[r][i][0]
            for d in range(1, n_shards):
                if r + d < len(hist):
                    assert hist[r + d][(i + d) % n_shards][0] >= v, (
                        f"round {r} island {i} best lost after {d} hops")


# --------------------------------------------------------------------------
# The real >= 4-device mesh (subprocess: forced virtual CPU devices).
# --------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import json
import jax, numpy as np
from repro.core import PSOConfig
from repro.core.distributed import init_sharded_swarm, make_distributed_run
from repro.kernels.ref import run_islands_ring_oracle

n_shards = 4
cfg = PSOConfig(dim=3, particle_cnt=256, fitness="rastrigin").resolved()
mesh = jax.make_mesh((n_shards,), ("data",))
st = init_sharded_swarm(cfg, 0, mesh)
runner = make_distributed_run(cfg, mesh, iters=24, variant="async",
                              exchange_interval=8, sync_every=4)
out = runner(st)
gf = float(out.gbest_fit)
pb = np.asarray(out.pbest_fit)
shard_vals = [float(np.asarray(s.data))
              for s in out.gbest_fit.addressable_shards]
isl, hist = run_islands_ring_oracle(cfg, 0, n_shards, 24, 8, sync_every=4)
per_island = [bool(np.allclose(pb[i*64:(i+1)*64],
                               np.asarray(isl[i].pbest_fit),
                               rtol=1e-3, atol=1e-3))
              for i in range(n_shards)]
print(json.dumps({
    "devices": len(jax.devices()),
    "gbest": gf,
    "max_pbest": float(pb.max()),
    "replicated": all(v == gf for v in shard_vals),
    "oracle_gbest": float(isl[0].gbest_fit),
    "per_island_pbest_close": per_island,
    "iteration": int(out.iteration),
}))
"""


def test_ring_on_four_device_mesh():
    """End-to-end on a 4-device CPU mesh (subprocess so the forced device
    count cannot leak into the in-process backend): final-flush invariant,
    gbest replication across shards, and agreement with the eager oracle."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], cwd=REPO,
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    got = json.loads(r.stdout.strip().splitlines()[-1])
    assert got["devices"] == 4
    assert got["iteration"] == 24
    assert got["replicated"], got
    assert got["gbest"] == got["max_pbest"], got
    # shard_map vs eager oracle compile differently (1-ulp amplification
    # over chaotic iterations) — compare with tolerance
    assert abs(got["gbest"] - got["oracle_gbest"]) <= 1e-3 * max(
        1.0, abs(got["oracle_gbest"])), got
    assert all(got["per_island_pbest_close"]), got


def test_pso_run_cli_islands_async_four_devices():
    """The previously-forbidden CLI spelling runs end to end on a 4-device
    mesh: `pso_run --islands 4 --variant async`."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.pso_run", "--dim", "3",
         "--particles", "256", "--iters", "30", "--variant", "async",
         "--islands", "4", "--exchange", "10", "--sync-every", "5"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "gbest_fit=" in r.stdout


def test_pso_run_cli_islands_async_single_device():
    """...and on the plain 1-device box (no forced devices)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.pso_run", "--dim", "2",
         "--particles", "128", "--iters", "20", "--variant", "async",
         "--islands", "1", "--exchange", "5"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "gbest_fit=" in r.stdout


def test_method_facade_islands():
    """Method(islands=...) routes solve() through the distributed runner."""
    import repro
    res = repro.solve("rastrigin", dim=3, particles=128, iters=16, seed=0,
                      method=repro.Method(variant="async", islands=1,
                                          exchange_interval=8,
                                          sync_every=4))
    ref = repro.solve("rastrigin", dim=3, particles=128, iters=16, seed=0,
                      method=repro.Method(variant="async", sync_every=4))
    assert res.gbest_fit == ref.gbest_fit      # 1-island ring == single chip
    assert res.method.islands == 1
    with pytest.raises(ValueError, match="solve_many"):
        repro.solve_many("cubic", seeds=[0, 1],
                         method=repro.Method(islands=2))
    with pytest.raises(ValueError, match="ring local loop"):
        repro.Method(variant="async", backend="kernel", islands=2)
    # sync variants still run the barrier path under the facade
    res_q = repro.solve("rastrigin", dim=3, particles=128, iters=16, seed=0,
                        method=repro.Method(variant="queue", islands=1,
                                            exchange_interval=4))
    assert np.isfinite(res_q.gbest_fit)
