"""LM training launcher (example end-to-end driver at reduced scale runs in
examples/train_lm.py; this module is the production entry point).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --steps 200 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/ckpt

--smoke uses the reduced config (CPU-viable). On a real pod, omit --smoke
and launch one process per host (jax.distributed.initialize is called when
the usual cluster env vars are present).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models import zoo
from repro.runtime import RunnerConfig, StepRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-interval", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if "COORDINATOR_ADDRESS" in os.environ:      # multi-host fleet
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = zoo.init_params(cfg, jax.random.key(args.seed))
    train_step, opt_init = make_train_step(cfg, base_lr=args.lr,
                                           warmup=max(args.steps // 10, 1),
                                           total_steps=args.steps)
    opt_state = opt_init(params)
    jstep = jax.jit(train_step)

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, num_shards=jax.process_count(),
        shard_id=jax.process_index()))

    losses = []

    def step_fn(state, step):
        params, opt_state = state
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, metrics = jstep(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_interval == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return params, opt_state

    state = (params, opt_state)
    if args.ckpt_dir:
        runner = StepRunner(
            RunnerConfig(args.ckpt_dir, ckpt_interval=args.ckpt_interval),
            step_fn)
        start, state = runner.resume_or(state)
        state = runner.run(state, start, args.steps - start)
    else:
        for step in range(args.steps):
            state = step_fn(state, step)
    print(f"final loss: {losses[-1]:.4f} (first: {losses[0]:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
