"""Shared model building blocks (pure JAX, no flax): norms, embeddings,
RoPE, MLPs, parameter initializers.

Params are plain dict pytrees. ``init_*`` functions take a key and return
the param tree; ``apply`` logic is free functions so everything composes
under jit / scan / shard_map and can be abstractly initialized with
``jax.eval_shape`` for the dry-run (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(w, x, eps: float = 1e-5):
    # Norm statistics in fp32 regardless of activation dtype.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                    # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / plain)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_in": dense_init(k1, d, ff, dtype),
         "w_out": dense_init(k2, ff, d, dtype)}
    if act == "silu":                                    # gated (SwiGLU)
        p["w_gate"] = dense_init(k3, d, ff, dtype)
    return p


def mlp(p: Params, x, act: str):
    from .policy import constrain
    h = constrain(x @ p["w_in"], ("dp", None, "tp"))
    if "w_gate" in p:
        h = act_fn(act)(constrain(x @ p["w_gate"], ("dp", None, "tp"))) * h
    else:
        h = act_fn(act)(h)
    return constrain(h @ p["w_out"], ("dp", None, None))


# ---------------------------------------------------------------------------
# Cross-entropy with sequence chunking (vocab can be 152k: never materialize
# the full [B, S, V] logits — scan over S chunks and reduce).
# ---------------------------------------------------------------------------

def chunked_xent(h, w_unembed, labels, chunk: int, pad_vocab: bool = False):
    """h: [B, S, d] final hidden; w_unembed: [d, V]; labels: [B, S] int32.
    Returns mean NLL (fp32). Positions with label < 0 are masked out.

    pad_vocab: pad V up to a multiple of 128 so the logits can shard over
    the model axis even for awkward vocab sizes (32001, 51865, 73448);
    padded columns are masked to -inf before the logsumexp. Without this,
    an indivisible vocab silently REPLICATES the whole unembed matmul on
    every model rank (measured 11x head-flops inflation on hymba-1.5b).
    """
    b, s, d = h.shape
    v_real = w_unembed.shape[-1]
    if pad_vocab and v_real % 128:
        w_unembed = jnp.pad(w_unembed, ((0, 0), (0, (-v_real) % 128)))
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def piece(hc, lc):
        from .policy import constrain
        logits = constrain((hc @ w_unembed).astype(jnp.float32),
                           ("dp", None, "tp"))               # [B, c, V]
        if logits.shape[-1] != v_real:
            col = jnp.arange(logits.shape[-1])
            logits = jnp.where(col[None, None, :] < v_real, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return ((lse - tgt) * mask).sum(), mask.sum()

    def body(carry, xs):
        hc, lc = xs
        nll, cnt = piece(hc, lc)
        return (carry[0] + nll, carry[1] + cnt), ()

    hs = h[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    ls = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    from .unroll import maybe_scan
    (nll, cnt), _ = maybe_scan(body, (jnp.float32(0), jnp.float32(0)),
                               (hs, ls))
    if rem:
        nll_r, cnt_r = piece(h[:, n * chunk:], labels[:, n * chunk:])
        nll, cnt = nll + nll_r, cnt + cnt_r
    return nll / jnp.maximum(cnt, 1.0)
