"""Production meshes. A FUNCTION (not module-level constant) so importing
never touches jax device state — the 512-device fake platform is set only
by dryrun.py before any jax import."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever devices exist, as (data, model) — for tests/examples."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch/particles."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
