"""Piecewise roofline accounting — correct FLOP/byte/collective totals for
scan-over-layers models.

``compiled.cost_analysis()`` counts a ``while`` (scan) body ONCE, not
× trip-count (verified empirically; see EXPERIMENTS.md §Dry-run notes), so
whole-model numbers from the scanned step function under-report by ~L×.
Instead we lower each *piece* in unrolled-inner mode with the same
shardings on the same production mesh, and combine:

    total = Σ_piece  trip_count(piece) × cost(piece)  +  top-level piece

Pieces per arch: one per distinct layer kind (dense/moe/hybrid-swa/
hybrid-global/mlstm/slstm/enc/dec), the embed+loss head, and for decode the
per-layer cache-update step. Training pieces are wrapped in the SAME remat
policy as the real model, so recompute FLOPs are included. sLSTM's
sequential time-scan is lowered at a short window and scaled linearly
(per-step cost is constant in sequence position).

The engine-level knob ``repro.models.unroll.UNROLL`` flips the inner
lax.scans (flash-attention KV loop, GLA chunk loop, xent chunk loop) into
python loops for these piece lowerings only.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import unroll as unroll_mod
from repro.models import zoo
from . import analysis as ra


@dataclasses.dataclass
class PieceCost:
    name: str
    trips: float
    flops: float            # per trip, per device
    bytes_: float
    coll_bytes: float
    coll_count: int


def _measure(fn, in_shardings, args, name: str, trips: float) -> PieceCost:
    lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
    compiled = lowered.compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost(name=name, trips=trips,
                     flops=float(cost.get("flops", 0.0)),
                     bytes_=float(cost.get("bytes accessed", 0.0)),
                     coll_bytes=float(coll["total"]),
                     coll_count=int(coll["count"]))


def combine(pieces: List[PieceCost]) -> Dict[str, float]:
    return {
        "flops_dev": sum(p.flops * p.trips for p in pieces),
        "bytes_dev": sum(p.bytes_ * p.trips for p in pieces),
        "coll_bytes_dev": sum(p.coll_bytes * p.trips for p in pieces),
        "coll_count": int(sum(p.coll_count * p.trips for p in pieces)),
        "pieces": {p.name: {"trips": p.trips, "flops": p.flops,
                            "bytes": p.bytes_, "coll": p.coll_bytes}
                   for p in pieces},
    }


# ---------------------------------------------------------------------------
# Piece construction
# ---------------------------------------------------------------------------

def _dp(mesh):
    from repro.launch.mesh import data_axes
    dp = data_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


def _named(mesh, spec_tree):
    from repro.launch import sharding as shp
    return shp.to_named(spec_tree, mesh)


def _single_layer_shapes(cfg: ArchConfig, kind: str):
    from repro.models.transformer import _init_layer
    return jax.eval_shape(lambda: _init_layer(cfg, jax.random.key(0), kind))


def _layer_specs(cfg: ArchConfig, lp_shape, mesh):
    from repro.launch import sharding as shp
    return shp.param_pspecs(cfg, lp_shape, mesh)


def _x_sds(cfg: ArchConfig, b: int, s: int):
    return jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                jnp.dtype(cfg.param_dtype))


def layer_plan_pieces(cfg: ArchConfig, s_total: int):
    """[(name, kind, window, trips, s_piece, scale)] — scale multiplies the
    measured cost (linear-in-S pieces lowered at a shorter window)."""
    LIN_CAP = 4352                        # lower linear pieces at ≤ this S
    out = []
    if cfg.xlstm:
        g = cfg.slstm_group
        ng = cfg.n_layers // g
        sp = min(s_total, 2048)
        out.append(("mlstm", "mlstm", 0, ng * (g - 1), sp, s_total / sp))
        sp_s = min(s_total, 64)
        out.append(("slstm", "slstm", 0, ng, sp_s, s_total / sp_s))
        return out
    if cfg.hybrid_ssm:
        n_glob = len(cfg.global_attn_layers)
        sp = min(s_total, LIN_CAP)
        out.append(("hybrid_swa", "hybrid", cfg.swa_window,
                    cfg.n_layers - n_glob, sp, s_total / sp))
        out.append(("hybrid_global", "hybrid", 0, n_glob, s_total, 1.0))
        return out
    kind = "moe" if cfg.moe else "dense"
    out.append((kind, kind, 0, cfg.n_layers, s_total, 1.0))
    return out


ANALYSIS_BLOCK = 4096   # attention tiling for piece lowerings: FLOPs are
                        # tiling-invariant; fewer/larger inner bodies keep
                        # single-core compile times tractable.


def _analysis_cfg(cfg: ArchConfig) -> ArchConfig:
    # SWA archs: tiles must not exceed the window, or the blockwise loop
    # loses its ability to skip out-of-window KV blocks and the analysis
    # over-counts FLOPs that the real kernel never does.
    blk = ANALYSIS_BLOCK
    if cfg.swa_window:
        blk = min(1024, max(cfg.swa_window, 128))
    return dataclasses.replace(cfg, attn_q_block=blk, attn_kv_block=blk)


def _train_layer_piece(cfg: ArchConfig, mesh, kind: str, window: int,
                       b: int, s: int, name: str, trips: float,
                       scale: float, fwd_only: bool = False) -> PieceCost:
    from repro.models.transformer import _apply_layer, _remat
    cfg = _analysis_cfg(cfg)
    lp_shape = _single_layer_shapes(cfg, kind)
    lp_spec = _named(mesh, _layer_specs(cfg, lp_shape, mesh))
    x = _x_sds(cfg, b, s)
    from jax.sharding import NamedSharding, PartitionSpec as P
    x_spec = NamedSharding(mesh, P(_dp(mesh), None, None))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    body = functools.partial(_apply_layer, cfg, positions=positions,
                             kind=kind, window=window)

    if fwd_only:
        def fn(lp, xx):
            y, aux = body(lp, xx)
            return jnp.sum(y).astype(jnp.float32) + aux
        jfn = jax.jit(fn, in_shardings=(lp_spec, x_spec))
    else:
        rb = _remat(lambda lp, xx: body(lp, xx), cfg.remat)

        def fn(lp, xx):
            def lf(lp_, x_):
                y, aux = rb(lp_, x_)
                return jnp.sum(y).astype(jnp.float32) + aux
            return jax.value_and_grad(lf, argnums=(0, 1))(lp, xx)

        jfn = jax.jit(fn, in_shardings=(lp_spec, x_spec),
                      out_shardings=(None, (lp_spec, x_spec)))
    with unroll_mod.unrolled():
        lowered = jfn.lower(lp_shape, x)
    compiled = lowered.compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost(name=name, trips=trips,
                     flops=float(cost.get("flops", 0.0)) * scale,
                     bytes_=float(cost.get("bytes accessed", 0.0)) * scale,
                     coll_bytes=float(coll["total"]) * scale,
                     coll_count=int(coll["count"]))


def _encdec_layer_piece(cfg: ArchConfig, mesh, which: str, b: int, s: int,
                        trips: float, fwd_only: bool) -> PieceCost:
    from repro.models import encdec as ed
    from repro.models.transformer import _remat
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = _analysis_cfg(cfg)
    init = (ed._init_enc_layer if which == "enc" else ed._init_dec_layer)
    lp_shape = jax.eval_shape(lambda: init(cfg, jax.random.key(0)))
    lp_spec = _named(mesh, _layer_specs(cfg, lp_shape, mesh))
    x = _x_sds(cfg, b, s)
    x_spec = NamedSharding(mesh, P(_dp(mesh), None, None))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if which == "enc":
        def body(lp, xx):
            import jax.numpy as jn
            from repro.models import attention as at
            from repro.models.layers import apply_rope, mlp, rmsnorm
            h = rmsnorm(lp["ln1"], xx, cfg.norm_eps)
            q, k, v = at.gqa_project(lp["attn"], h, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.resolved_head_dim)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            a = at.flash_attention(q, k, v, causal=False,
                                   q_block=cfg.attn_q_block,
                                   kv_block=cfg.attn_kv_block)
            a = a.reshape(b, s, -1) @ lp["attn"]["wo"]
            xx = xx + a
            h2 = rmsnorm(lp["ln2"], xx, cfg.norm_eps)
            return xx + mlp(lp["mlp"], h2, cfg.act)

        def fn_fwd(lp, xx):
            return jnp.sum(body(lp, xx)).astype(jnp.float32)
        args = (lp_shape, x)
        in_sh = (lp_spec, x_spec)
        out_sh = (None, (lp_spec, x_spec))
    else:
        def body(lp, xx, enc):
            from repro.models import attention as at
            from repro.models.layers import mlp, rmsnorm
            a = at.gqa_forward(lp["self_attn"],
                               rmsnorm(lp["ln1"], xx, cfg.norm_eps),
                               positions, **ed._kw(cfg))
            xx = xx + a
            kv = ed._enc_kv(cfg, lp, enc)
            xx = xx + ed._cross_attend(cfg, lp, xx, kv)
            h2 = rmsnorm(lp["ln2"], xx, cfg.norm_eps)
            return xx + mlp(lp["mlp"], h2, cfg.act)

        def fn_fwd(lp, xx, enc):
            return jnp.sum(body(lp, xx, enc)).astype(jnp.float32)
        args = (lp_shape, x, x)
        in_sh = (lp_spec, x_spec, x_spec)
        out_sh = (None, (lp_spec, x_spec, x_spec))

    if fwd_only:
        jfn = jax.jit(fn_fwd, in_shardings=in_sh)
    else:
        rb = _remat(fn_fwd, cfg.remat)
        nargs = len(args)

        def fn(*a):
            return jax.value_and_grad(rb, argnums=tuple(range(nargs)))(*a)

        jfn = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=(None, tuple(in_sh)))
    with unroll_mod.unrolled():
        compiled = jfn.lower(*args).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost(name=f"{which}_layer", trips=trips,
                     flops=float(cost.get("flops", 0.0)),
                     bytes_=float(cost.get("bytes accessed", 0.0)),
                     coll_bytes=float(coll["total"]),
                     coll_count=int(coll["count"]))


def _head_piece(cfg: ArchConfig, mesh, b: int, s_text: int,
                fwd_only: bool) -> PieceCost:
    """final norm + unembed + chunked xent (+ grads)."""
    from repro.models.layers import chunked_xent, rmsnorm
    from jax.sharding import NamedSharding, PartitionSpec as P
    dt = jnp.dtype(cfg.param_dtype)
    dp = _dp(mesh)
    x = jax.ShapeDtypeStruct((b, s_text, cfg.d_model), dt)
    labels = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    w = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt)
    norm = jax.ShapeDtypeStruct((cfg.d_model,), dt)
    x_spec = NamedSharding(mesh, P(dp, None, None))
    l_spec = NamedSharding(mesh, P(dp, None))
    w_spec = NamedSharding(
        mesh, P("data" if cfg.d_model % mesh.shape["data"] == 0 else None,
                "model" if cfg.vocab % mesh.shape["model"] == 0 else None))
    n_spec = NamedSharding(mesh, P(None))

    def fn(norm_w, w_un, xx, ll):
        h = rmsnorm(norm_w, xx, cfg.norm_eps)
        return chunked_xent(h, w_un, ll, cfg.loss_chunk,
                            pad_vocab=cfg.pad_vocab)

    if fwd_only:
        jfn = jax.jit(fn, in_shardings=(n_spec, w_spec, x_spec, l_spec))
    else:
        def gfn(norm_w, w_un, xx, ll):
            return jax.value_and_grad(fn, argnums=(0, 1, 2))(
                norm_w, w_un, xx, ll)
        jfn = jax.jit(gfn, in_shardings=(n_spec, w_spec, x_spec, l_spec),
                      out_shardings=(None, (n_spec, w_spec, x_spec)))
    with unroll_mod.unrolled():
        compiled = jfn.lower(norm, w, x, labels).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost("head", 1.0, float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(coll["total"]), int(coll["count"]))


def _embed_piece(cfg: ArchConfig, mesh, b: int, s_text: int,
                 fwd_only: bool) -> PieceCost:
    from jax.sharding import NamedSharding, PartitionSpec as P
    dt = jnp.dtype(cfg.param_dtype)
    dp = _dp(mesh)
    emb = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt)
    toks = jax.ShapeDtypeStruct((b, s_text), jnp.int32)
    e_spec = NamedSharding(
        mesh, P("model" if cfg.vocab % mesh.shape["model"] == 0 else None,
                "data" if cfg.d_model % mesh.shape["data"] == 0 else None))
    t_spec = NamedSharding(mesh, P(dp, None))

    def fn(e, t):
        return jnp.sum(jnp.take(e, t, axis=0).astype(jnp.float32))

    if fwd_only:
        jfn = jax.jit(fn, in_shardings=(e_spec, t_spec))
    else:
        jfn = jax.jit(lambda e, t: jax.value_and_grad(fn)(e, t),
                      in_shardings=(e_spec, t_spec),
                      out_shardings=(None, e_spec))
    compiled = jfn.lower(emb, toks).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost("embed", 1.0, float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(coll["total"]), int(coll["count"]))


def _optimizer_piece(cfg: ArchConfig, mesh) -> PieceCost:
    from repro.launch import sharding as shp
    from repro.launch.steps import make_train_step
    from repro.models import zoo
    from repro.optim import get_optimizer
    params_shape = zoo.abstract_params(cfg)
    pspecs_p = shp.param_pspecs(cfg, params_shape, mesh)
    pspecs = _named(mesh, pspecs_p)
    opt_init, opt_update = get_optimizer(cfg.optimizer)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    ospecs = _named(mesh, shp.opt_pspecs(cfg, opt_shape, mesh, pspecs_p))

    def fn(p, g, s):
        return opt_update(p, g, s, 1e-4)

    jfn = jax.jit(fn, in_shardings=(pspecs, pspecs, ospecs),
                  out_shardings=(pspecs, ospecs))
    compiled = jfn.lower(params_shape, params_shape, opt_shape).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost("optimizer", 1.0, float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(coll["total"]), int(coll["count"]))


# ---------------------------------------------------------------------------
# Decode pieces
# ---------------------------------------------------------------------------

def _decode_layer_piece(cfg: ArchConfig, mesh, shape_name: str, kind: str,
                        window: int, name: str, trips: float) -> PieceCost:
    from repro.launch import sharding as shp
    from repro.models import zoo
    from repro.models.transformer import _decode_layer
    from jax.sharding import NamedSharding, PartitionSpec as P
    cell = SHAPES[shape_name]
    b, s = cell.global_batch, cell.seq_len
    if cfg.encdec:
        from repro.models import encdec as ed
        lp_shape = jax.eval_shape(
            lambda: ed._init_dec_layer(cfg, jax.random.key(0)))
    else:
        lp_shape = _single_layer_shapes(cfg, kind)
    lp_spec = _named(mesh, _layer_specs(cfg, lp_shape, mesh))
    cache_full = zoo.abstract_cache(cfg, shape_name)
    cspec_full = shp.cache_pspecs(cfg, cache_full, shape_name, mesh)

    def strip(tree_sds, tree_spec, n_lead: int):
        sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[n_lead:], a.dtype),
            tree_sds)
        spec = jax.tree.map(lambda p: P(*p[n_lead:]), tree_spec,
                            is_leaf=lambda x: isinstance(x, P))
        return sds, spec

    if cfg.xlstm:
        if kind == "mlstm":
            sub, subspec = strip(cache_full["m"], cspec_full["m"], 2)
        else:
            sub = [jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
                   for a in cache_full["s"]]
            subspec = [P(*p[1:]) for p in cspec_full["s"]]
    elif cfg.hybrid_ssm:
        sub, subspec = strip(cache_full["swa"], cspec_full["swa"], 1)
    else:
        sub, subspec = strip(cache_full, cspec_full, 1)
    c_spec = _named(mesh, subspec)
    x = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.dtype(cfg.param_dtype))
    dp = _dp(mesh)
    # batch=1 (long_500k): replicate x over the batch axes
    x_ax = dp if (b % _axes_size(mesh, dp) == 0) else None
    bspec = NamedSharding(mesh, P(x_ax, None, None))
    n = jax.ShapeDtypeStruct((), jnp.int32)

    if cfg.encdec:
        from repro.models import encdec as ed
        from repro.models import attention as at
        from repro.models.layers import mlp, rmsnorm

        def fn(lp, cl, xx, cache_len):
            h = rmsnorm(lp["ln1"], xx, cfg.norm_eps)
            kw = ed._kw(cfg)
            kw.pop("q_block"), kw.pop("kv_block")
            a, new_kv = at.gqa_decode(lp["self_attn"], h,
                                      {"k": cl["k"], "v": cl["v"]},
                                      cache_len, **kw)
            xx = xx + a
            hx = rmsnorm(lp["ln_x"], xx, cfg.norm_eps)
            q = (hx @ lp["cross_attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.resolved_head_dim)
            xa = at.decode_attention(q, cl["xk"], cl["xv"],
                                     cl["xk"].shape[1])
            xx = xx + xa.reshape(b, 1, -1) @ lp["cross_attn"]["wo"]
            h2 = rmsnorm(lp["ln2"], xx, cfg.norm_eps)
            xx = xx + mlp(lp["mlp"], h2, cfg.act)
            return xx, dict(cl, k=new_kv["k"], v=new_kv["v"])
    else:
        def fn(lp, cl, xx, cache_len):
            return _decode_layer(cfg, lp, cl, xx, cache_len, kind, window)

    jfn = jax.jit(fn, in_shardings=(lp_spec, c_spec, bspec, None),
                  out_shardings=(bspec, c_spec))
    compiled = jfn.lower(lp_shape, sub, x, n).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost(name, trips, float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(coll["total"]), int(coll["count"]))


def _axes_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _decode_top_piece(cfg: ArchConfig, mesh, b: int) -> PieceCost:
    """embed gather (1 tok) + final norm + unembed matmul."""
    from repro.models.layers import rmsnorm
    from jax.sharding import NamedSharding, PartitionSpec as P
    dt = jnp.dtype(cfg.param_dtype)
    emb = jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt)
    w = jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt)
    norm = jax.ShapeDtypeStruct((cfg.d_model,), dt)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    e_spec = NamedSharding(
        mesh, P("model" if cfg.vocab % mesh.shape["model"] == 0 else None,
                None))
    w_spec = NamedSharding(
        mesh, P(None,
                "model" if cfg.vocab % mesh.shape["model"] == 0 else None))

    def fn(e, wn, wu, t):
        x = jnp.take(e, t, axis=0)
        x = rmsnorm(wn, x, cfg.norm_eps)
        return (x[:, 0] @ wu).astype(jnp.float32)

    jfn = jax.jit(fn, in_shardings=(e_spec, None, w_spec, None))
    compiled = jfn.lower(emb, norm, w, tok).compile()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(compiled.as_text())
    return PieceCost("decode_top", 1.0, float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     float(coll["total"]), int(coll["count"]))


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def analyze_cell_piecewise(cfg: ArchConfig, shape_name: str, mesh,
                           ) -> Dict[str, Any]:
    cell = SHAPES[shape_name]
    b, s = cell.global_batch, cell.seq_len
    pieces: List[PieceCost] = []
    if cell.kind in ("train", "prefill"):
        fwd = cell.kind == "prefill"
        s_total = s
        s_text = s
        if cfg.vision_prefix:
            s_text = s - cfg.vision_prefix
        if cfg.meta_tokens:
            s_total = s + cfg.meta_tokens
        if cfg.encdec:
            pieces.append(_encdec_layer_piece(cfg, mesh, "enc", b, s,
                                              cfg.enc_layers, fwd))
            pieces.append(_encdec_layer_piece(cfg, mesh, "dec", b, s,
                                              cfg.n_layers, fwd))
        else:
            for (name, kind, window, trips, sp, scale) in \
                    layer_plan_pieces(cfg, s_total):
                pieces.append(_train_layer_piece(
                    cfg, mesh, kind, window, b, sp, name, trips, scale,
                    fwd_only=fwd))
        pieces.append(_head_piece(cfg, mesh, b, s_text, fwd))
        pieces.append(_embed_piece(cfg, mesh, b, s_text, fwd))
        if cell.kind == "train":
            pieces.append(_optimizer_piece(cfg, mesh))
    else:
        if cfg.encdec:
            pieces.append(_decode_layer_piece(
                cfg, mesh, shape_name, "dense", 0, "dec_layer",
                cfg.n_layers))
        elif cfg.xlstm:
            g = cfg.slstm_group
            ng = cfg.n_layers // g
            pieces.append(_decode_layer_piece(cfg, mesh, shape_name,
                                              "mlstm", 0, "mlstm",
                                              ng * (g - 1)))
            pieces.append(_decode_layer_piece(cfg, mesh, shape_name,
                                              "slstm", 0, "slstm", ng))
        elif cfg.hybrid_ssm:
            pieces.append(_decode_layer_piece(
                cfg, mesh, shape_name, "hybrid", cfg.swa_window, "hybrid",
                cfg.n_layers))
        else:
            kind = "moe" if cfg.moe else "dense"
            pieces.append(_decode_layer_piece(cfg, mesh, shape_name, kind,
                                              0, kind, cfg.n_layers))
        pieces.append(_decode_top_piece(cfg, mesh, b))
    return combine(pieces)
