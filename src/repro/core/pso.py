"""Parallel PSO (PPSO) in JAX: state, config, and the three aggregation
variants from the paper, expressed TPU-natively.

Variants (paper §3.2, §4):
  * ``step_reduction``  — state of the art the paper compares against: an
    unconditional full argmax reduction over all particles every iteration.
  * ``step_queue``      — the paper's queue algorithm, adapted: the swarm-wide
    reduction is *predicated* on ``any(fit > gbest_fit)``. Because improvement
    is rare (<0.1 % of iterations at steady state, §4.1), the expensive
    argmax + D-dim position gather is skipped almost always; only a cheap
    vectorized compare + ``any`` runs unconditionally.
  * ``step_queue_lock`` — the fused variant. At the library level the fusion
    (removing the second kernel) is realized by the Pallas kernel in
    ``repro.kernels``; the jnp fallback here additionally fuses the pbest and
    gbest conditionals into a single predicated block so that XLA emits one
    conditional region instead of two.
  * ``step_async``/``run_async`` — the paper's *enhanced* queue-lock:
    particle blocks run asynchronously against block-local bests and the
    shared gbest is published/pulled only every ``sync_every`` iterations
    (relaxed consistency: a block's view is at most ``sync_every``
    iterations stale). The Pallas counterpart is
    ``repro.kernels.ops.run_queue_lock_fused_async``.

Semantics note: all parallel variants are *synchronous* PPSO — every particle
sees the gbest of the previous iteration (the paper's Fig. 1 workflow). The
sequential SPSO (Alg. 1), where gbest updates mid-iteration, lives in
``repro.core.serial`` and is used as the CPU baseline and semantic oracle.

Scaling note: all three step functions are written to vmap cleanly over a
leading swarm axis — ``repro.core.multi_swarm.solve_many`` batches many
independent solves (heterogeneous seeds, optionally per-swarm ``coeffs``
overriding (w, c1, c2)) into one device program with per-row bit-identity
to the standalone path. Keep step-function ``lax.cond`` branch outputs
small (scalars / [D]); see ``step_queue_lock`` for why.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from . import rng
from .blocking import default_block_count
from .constraints import deb_improved, repair_init_positions
from .fitness import DEFAULT_BOUNDS, FITNESS_FNS  # noqa: F401 (legacy API)
from .problem import Bound, Problem, broadcast_bounds, resolve_problem
from .update_rules import TOPOLOGIES, resolve_rule, rule_names

Array = jnp.ndarray


def _bound_operand(v, dt):
    """Bound -> jnp operand: scalars stay Python floats (weak-typed, the
    seed arithmetic, bit-for-bit); per-dimension tuples become [D] arrays
    that broadcast against [N, D] / [S, N, D] state."""
    return v if not isinstance(v, tuple) else jnp.asarray(v, dt)


@dataclasses.dataclass(frozen=True)
class PSOConfig:
    """Static PSO problem configuration (paper Table 1).

    ``fitness`` is a registered problem name (the legacy string path, e.g.
    ``"cubic"``) or a first-class ``repro.core.problem.Problem`` carrying a
    user-defined pure-jnp objective, bounds and sense. ``min_pos``/``max_pos``
    /``max_v`` override the problem's domain; each is a scalar or a
    length-``dim`` tuple (per-dimension boxes). The config stays hashable —
    it is a jit static argument everywhere.

    ``update_rule`` names the per-particle update rule
    (``repro.core.update_rules``: ``"pso"``/``"sso"``/``"lowcost"``);
    ``topology`` names the async variant's block-neighborhood pull
    (``"gbest"`` star, ``"ring"``, ``"vonneumann"`` —
    ``repro.core.topology``). Both default to the paper's algorithm and
    are Python-gated so default configs trace the exact pre-portfolio
    jaxprs.
    """

    dim: int = 1
    particle_cnt: int = 1024
    w: float = 1.0          # inertia (paper §6.1: w = 1)
    c1: float = 2.0         # cognitive coefficient
    c2: float = 2.0         # social coefficient
    fitness: Union[str, Problem] = "cubic"
    min_pos: Optional[Bound] = None   # default: fitness-specific domain
    max_pos: Optional[Bound] = None
    max_v: Optional[Bound] = None     # default: half the position range
    dtype: str = "float32"
    update_rule: str = "pso"
    topology: str = "gbest"

    def __post_init__(self):
        # Normalize any sequence bound to a tuple so the config stays
        # hashable (lists/arrays would break jit static hashing).
        for f in ("min_pos", "max_pos", "max_v"):
            v = getattr(self, f)
            if v is not None and not isinstance(v, (int, float, tuple)):
                object.__setattr__(self, f, tuple(float(x) for x in v))
        resolve_rule(self.update_rule)   # raises with the enumeration
        if self.topology not in TOPOLOGIES:
            raise ValueError(
                f"unknown topology {self.topology!r}; one of {TOPOLOGIES}")

    @property
    def problem(self) -> Problem:
        return resolve_problem(self.fitness)

    def resolved(self) -> "PSOConfig":
        prob = self.problem
        lo, hi = prob.lo, prob.hi
        min_pos = lo if self.min_pos is None else self.min_pos
        max_pos = hi if self.max_pos is None else self.max_pos
        min_pos, max_pos = broadcast_bounds(min_pos, max_pos)
        for name, v in (("min_pos", min_pos), ("max_pos", max_pos)):
            if isinstance(v, tuple) and len(v) != self.dim:
                raise ValueError(
                    f"{name} has {len(v)} entries but dim={self.dim}")
        if self.max_v is None:
            if isinstance(min_pos, tuple):
                max_v: Bound = tuple(0.5 * (h - l)
                                     for l, h in zip(min_pos, max_pos))
            else:
                max_v = 0.5 * (max_pos - min_pos)
        else:
            max_v = self.max_v
            if isinstance(max_v, tuple) and len(max_v) != self.dim:
                raise ValueError(
                    f"max_v has {len(max_v)} entries but dim={self.dim}")
        return dataclasses.replace(self, min_pos=min_pos, max_pos=max_pos, max_v=max_v)

    @property
    def fitness_fn(self) -> Callable[[Array], Array]:
        """The objective in canonical (maximization) form. For legacy string
        configs this is the exact ``FITNESS_FNS`` function object."""
        return self.problem.max_fn

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


class SwarmState(NamedTuple):
    """Full swarm state — everything needed to checkpoint/resume/reshard.

    ``lbest_pos``/``lbest_fit`` are the async variant's block-local bests
    (one slot per particle block; the Pallas async kernel's side buffers,
    surfaced at the library level). They default to ``None`` — synchronous
    variants never materialize them — and ride the pytree when present, so
    a checkpoint taken mid-async-run carries the blocks' local knowledge
    and resume does not restart the staleness window (see ``run_async``).
    """

    pos: Array        # [N, D]
    vel: Array        # [N, D]
    fit: Array        # [N]
    pbest_pos: Array  # [N, D]
    pbest_fit: Array  # [N]
    gbest_pos: Array  # [D]
    gbest_fit: Array  # []
    iteration: Array  # [] int32 — RNG counter component
    seed: Array       # [] uint32
    lbest_pos: Optional[Array] = None  # [nb, D] async block-local bests
    lbest_fit: Optional[Array] = None  # [nb]


# RNG stream ids (keep in sync with kernels/pso_step.py).
STREAM_INIT_POS = 0
STREAM_INIT_VEL = 1
STREAM_R1 = 2
STREAM_R2 = 3


# Heterogeneous dispatch convention: ``hetero=(table, row)`` threads through
# ``init_swarm``/``_advance``/the step functions, where ``table`` is a static
# tuple of ``Problem``s (a trace-time Python constant; the jit entry points
# in ``multi_swarm`` key their cache on it) and ``row`` is a ``HeteroRow`` of
# traced per-swarm operands: the [] int32 index into the table plus the
# row's [D] bound columns. ``hetero=None`` everywhere keeps the exact
# pre-hetero jaxprs (Python-gated). The dispatch is deliberately as narrow
# as possible: the RNG draw and raw velocity/position update chain stay
# OUTSIDE the switch — byte-identical ops to the homogeneous trace, with the
# bounds as runtime [D] operands instead of inlined constants — and only the
# objective evaluation goes through ``lax.switch`` over ``Problem.max_fn``
# branches. Under vmap the batched switch lowers to compute-all-branches +
# ``select_n``, i.e. a hetero batch costs ``len(table)`` objective
# evaluations per step (bounded: the table is the built-in registry).
#
# Exactness envelope (asserted in tests/test_hetero.py): every trajectory
# field of a hetero row — pos, vel, pbest_pos, gbest_pos — is bit-identical
# to the standalone ``solve`` of that row's problem. The carried fitness
# values (fit / pbest_fit / gbest_fit) are bit-identical for most
# (objective, dim) combos but can differ by 1-2 ulp on a few (observed:
# griewank at d=10/d=3, rastrigin at d=1): XLA:CPU fuses all table branches
# into one loop-body cluster and re-vectorizes the objective's sum/prod
# reduction tail, the same per-shape codegen hazard MIN_VALIDATED_SWARMS
# documents. This is the best achievable form on this backend — both wider
# dispatches were tried and are strictly worse: wrapping the whole advance
# in per-problem branches lets cross-branch CSE perturb the shared velocity
# chain (real trajectory divergence), and scalar-index conditional dispatch
# changes loop-body fusion even for a content-identical single branch.


class HeteroRow(NamedTuple):
    """Per-swarm dispatch operands for a heterogeneous batch row.

    ``fid`` indexes the static problem table; ``lo``/``hi``/``mv`` are the
    row's [D] position/velocity bound columns, precomputed host-side by
    ``multi_swarm.problem_rows`` with the exact arithmetic
    ``PSOConfig.resolved()`` uses (float64 then weak-f32 cast), so a row's
    runtime bounds are bitwise the constants its standalone solve inlines.
    """
    fid: Array   # [] int32
    lo: Array    # [D]
    hi: Array    # [D]
    mv: Array    # [D]


def _hetero_fitness(table, fid: Array, pos: Array) -> Array:
    """Canonical (maximization, penalty-baked) fitness of row ``fid``."""
    return jax.lax.switch(fid, [p.max_fn for p in table], pos)


def hetero_member_config(cfg: PSOConfig, prob: Problem) -> PSOConfig:
    """``cfg`` re-pointed at one dispatch-table member, bounds re-derived.

    Exactly the config a standalone ``solve`` of ``prob`` at this
    dim/particle_cnt/w/c1/c2/dtype would resolve — the per-branch static
    config the kernel-path heterogeneous dispatch closes each branch over.
    """
    return dataclasses.replace(cfg, fitness=prob, min_pos=None,
                               max_pos=None, max_v=None).resolved()


def init_swarm(cfg: PSOConfig, seed: int, n: Optional[int] = None,
               index_offset: int = 0, hetero=None) -> SwarmState:
    """Initialize a swarm (paper Alg. 1 step 1).

    ``n``/``index_offset`` support sharded construction: a shard owning
    particles [off, off+n) builds exactly the same particles as the
    corresponding slice of a monolithic swarm (elastic resharding invariant,
    tested in tests/test_distributed.py).

    ``hetero=(table, row)`` draws from the same streams but takes the box
    from the row's runtime bound columns and the objective from the table
    dispatch (the heterogeneous batch engine, ``multi_swarm.solve_many``
    with per-row problems); ``None`` keeps the exact homogeneous trace.
    """
    cfg = cfg.resolved()
    n = cfg.particle_cnt if n is None else n
    d = cfg.dim
    dt = cfg.jnp_dtype
    idx = (jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
           + jnp.uint32(index_offset * d))
    u_pos = rng.uniform(seed, 0, STREAM_INIT_POS, idx, dtype=dt)
    u_vel = rng.uniform(seed, 0, STREAM_INIT_VEL, idx, dtype=dt)
    if hetero is not None:
        lo, hi, mv = hetero[1].lo, hetero[1].hi, hetero[1].mv
    else:
        lo = _bound_operand(cfg.min_pos, dt)
        hi = _bound_operand(cfg.max_pos, dt)
        mv = _bound_operand(cfg.max_v, dt)
    span = hi - lo
    pos = lo + span * u_pos
    vel = -mv + 2.0 * mv * u_vel
    prob = cfg.problem
    proj = prob.projection_fn if hetero is None else None
    if proj is not None:
        # projection mode: start feasible (box draw projected in-place)
        pos = proj(pos)
    elif hetero is None and prob.constrained and \
            prob.constraints.mode == "repair":
        # repair mode: resample infeasible draws (attempt-indexed RNG on
        # the init stream; see constraints.repair_init_positions)
        pos = repair_init_positions(
            prob.constraints, prob.violation_fn, pos, lo, span, seed,
            STREAM_INIT_POS, idx, dt)
    fit = (cfg.fitness_fn(pos) if hetero is None
           else _hetero_fitness(hetero[0], hetero[1].fid, pos))
    best = jnp.argmax(fit)
    return SwarmState(
        pos=pos, vel=vel, fit=fit,
        pbest_pos=pos, pbest_fit=fit,
        gbest_pos=pos[best], gbest_fit=fit[best],
        iteration=jnp.zeros((), jnp.int32),
        seed=jnp.asarray(seed, jnp.uint32),
    )


def _advance(cfg: PSOConfig, s: SwarmState, index_offset: int = 0,
             coeffs: Optional[Tuple[Array, Array, Array]] = None,
             gbest_pos: Optional[Array] = None, hetero=None
             ) -> Tuple[Array, Array, Array]:
    """Steps 2–3 of Alg. 1: velocity/position update + fitness, vectorized.

    Returns (pos, vel, fit) for iteration ``s.iteration + 1``.

    ``coeffs`` optionally overrides ``(w, c1, c2)`` with traced scalars —
    the hook ``repro.core.multi_swarm.solve_many`` uses to vmap one compiled
    program over *per-swarm* hyper-parameters (meta-tuning). When ``None``
    the config's Python floats are used, producing the exact same jaxpr as
    before the hook existed. ``gbest_pos`` optionally overrides the social
    attractor (any shape broadcastable to [N, D]) — the hook ``step_async``
    uses to steer each particle toward its *block's* local best instead of
    the shared swarm best. ``hetero=(table, row)`` swaps the inlined bound
    constants for the row's runtime columns and the objective for the table
    dispatch — the heterogeneous batch hook; also Python-gated (see the
    convention note above ``HeteroRow``).
    """
    n, d = s.pos.shape
    dt = s.pos.dtype
    it = s.iteration + 1
    w, c1, c2 = coeffs if coeffs is not None else (cfg.w, cfg.c1, cfg.c2)
    gbp = s.gbest_pos[None, :] if gbest_pos is None else gbest_pos
    idx = (jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
           + jnp.uint32(index_offset * d))
    r1 = rng.uniform(s.seed, it, STREAM_R1, idx, dtype=dt)
    r2 = rng.uniform(s.seed, it, STREAM_R2, idx, dtype=dt)
    rule = resolve_rule(cfg.update_rule)
    if hetero is not None:
        table, hr = hetero
        pos, vel = rule.advance(r1, r2, s.pos, s.vel, s.pbest_pos, gbp,
                                w=w, c1=c1, c2=c2, mv=hr.mv, lo=hr.lo,
                                hi=hr.hi)
        return pos, vel, _hetero_fitness(table, hr.fid, pos)
    pos, vel = rule.advance(r1, r2, s.pos, s.vel, s.pbest_pos, gbp,
                            w=w, c1=c1, c2=c2,
                            mv=_bound_operand(cfg.max_v, dt),
                            lo=_bound_operand(cfg.min_pos, dt),
                            hi=_bound_operand(cfg.max_pos, dt))
    proj = cfg.problem.projection_fn
    if proj is not None:
        # the constrained post-advance hook (mode="projection"): clip to
        # the box first, then project onto the feasible set. Python-gated,
        # so unconstrained jaxprs are untouched bit-for-bit.
        pos = proj(pos)
    fit = cfg.fitness_fn(pos)
    return pos, vel, fit


def deb_selection_fn(cfg: PSOConfig):
    """The engine-level constrained pbest comparator, or None.

    Deb-rule selection (``constraints.deb_improved``) applies to the
    ``projection`` and ``repair`` constraint modes only: ``penalty`` mode
    keeps the raw canonical-fitness fold (the penalty already rides
    ``Problem.max_fn``), and unconstrained problems are Python-gated out so
    their jaxprs stay bit-identical to the pre-Deb engine. The returned
    callable computes ``improved(fit_new, pos_new, fit_old, pos_old) ->
    bool [N]``.
    """
    prob = cfg.problem
    if not prob.constrained or prob.constraints.mode == "penalty":
        return None
    vf = prob.violation_fn

    def better(fit_new, pos_new, fit_old, pos_old):
        return deb_improved(fit_new, vf(pos_new), fit_old, vf(pos_old))

    return better


def _update_pbest(s: SwarmState, pos: Array, fit: Array,
                  better=None) -> Tuple[Array, Array]:
    improved = (fit > s.pbest_fit if better is None
                else better(fit, pos, s.pbest_fit, s.pbest_pos))
    pbest_fit = jnp.where(improved, fit, s.pbest_fit)
    pbest_pos = jnp.where(improved[:, None], pos, s.pbest_pos)
    return pbest_pos, pbest_fit


def step_reduction(cfg: PSOConfig, s: SwarmState,
                   coeffs: Optional[Tuple[Array, Array, Array]] = None,
                   hetero=None) -> SwarmState:
    """Baseline: unconditional full argmax reduction (paper §3.2)."""
    pos, vel, fit = _advance(cfg, s, coeffs=coeffs, hetero=hetero)
    pbest_pos, pbest_fit = _update_pbest(
        s, pos, fit, deb_selection_fn(cfg) if hetero is None else None)
    best = jnp.argmax(pbest_fit)                      # O(N) reduction, always
    cand_fit = pbest_fit[best]
    cand_pos = pbest_pos[best]                        # O(D) gather, always
    take = cand_fit > s.gbest_fit
    gbest_fit = jnp.where(take, cand_fit, s.gbest_fit)
    gbest_pos = jnp.where(take, cand_pos, s.gbest_pos)
    return s._replace(pos=pos, vel=vel, fit=fit, pbest_pos=pbest_pos,
                      pbest_fit=pbest_fit, gbest_pos=gbest_pos,
                      gbest_fit=gbest_fit, iteration=s.iteration + 1)


def step_queue(cfg: PSOConfig, s: SwarmState,
               coeffs: Optional[Tuple[Array, Array, Array]] = None,
               hetero=None) -> SwarmState:
    """Queue algorithm (paper §4.1), TPU adaptation.

    The shared-memory queue + atomicAdd degenerates on a SIMD core into a
    *mask*: ``improved = fit > gbest_fit`` is the queue membership, and the
    argmax over improved lanes is thread-0's scan. The paper's win — skipping
    memory traffic when the queue is empty — maps to predicating the argmax +
    gather on the cheap scalar ``any(improved)``.
    """
    pos, vel, fit = _advance(cfg, s, coeffs=coeffs, hetero=hetero)
    pbest_pos, pbest_fit = _update_pbest(
        s, pos, fit, deb_selection_fn(cfg) if hetero is None else None)
    improved = fit > s.gbest_fit                      # cheap vector compare
    any_improved = jnp.any(improved)                  # scalar "queue non-empty"

    def publish(operand):
        fit_, pos_, gf, gp = operand
        best = jnp.argmax(jnp.where(improved, fit_, -jnp.inf))
        return fit_[best], pos_[best]

    def skip(operand):
        _, _, gf, gp = operand
        return gf, gp

    gbest_fit, gbest_pos = jax.lax.cond(
        any_improved, publish, skip, (fit, pos, s.gbest_fit, s.gbest_pos))
    return s._replace(pos=pos, vel=vel, fit=fit, pbest_pos=pbest_pos,
                      pbest_fit=pbest_fit, gbest_pos=gbest_pos,
                      gbest_fit=gbest_fit, iteration=s.iteration + 1)


def step_queue_lock(cfg: PSOConfig, s: SwarmState,
                    coeffs: Optional[Tuple[Array, Array, Array]] = None,
                    hetero=None) -> SwarmState:
    """Queue-lock (paper §4.2) jnp fallback: predicated gbest publication.

    The real fusion win (one pallas_call spanning all iterations with gbest
    carried in SMEM — the TPU analogue of removing the 2nd kernel and the
    spin-lock, including folding the rare O(N·D) pbest-position write under
    the improvement predicate) is ``repro.kernels.ops.run_queue_lock_fused``;
    this function keeps identical semantics for non-kernel paths with the
    argmax + D-dim gather predicated on the rare ``any(improved)``.

    The cond deliberately carries only the small gbest pair ([], [D]) —
    never an [N, D] operand. A matrix-valued branch output changes how XLA
    clusters the surrounding element-wise graph, and (on CPU) the float
    contraction it picks, breaking the multi-swarm engine's row-bit-identity
    invariant (vmapped select vs single-swarm cond); see
    tests/test_multi_swarm.py.
    """
    pos, vel, fit = _advance(cfg, s, coeffs=coeffs, hetero=hetero)
    better = deb_selection_fn(cfg) if hetero is None else None
    p_improved = (fit > s.pbest_fit if better is None
                  else better(fit, pos, s.pbest_fit, s.pbest_pos))
    pbest_fit = jnp.where(p_improved, fit, s.pbest_fit)
    pbest_pos = jnp.where(p_improved[:, None], pos, s.pbest_pos)
    any_p = jnp.any(p_improved)

    def publish(operand):
        gf, gp = operand
        best = jnp.argmax(pbest_fit)                  # rare O(N) + O(D) gather
        take = pbest_fit[best] > gf
        return (jnp.where(take, pbest_fit[best], gf),
                jnp.where(take, pbest_pos[best], gp))

    def skip(operand):
        return operand

    gbest_fit, gbest_pos = jax.lax.cond(
        any_p, publish, skip, (s.gbest_fit, s.gbest_pos))
    return s._replace(pos=pos, vel=vel, fit=fit, pbest_pos=pbest_pos,
                      pbest_fit=pbest_fit, gbest_pos=gbest_pos,
                      gbest_fit=gbest_fit, iteration=s.iteration + 1)


STEP_FNS = {
    "reduction": step_reduction,
    "queue": step_queue,
    "queue_lock": step_queue_lock,
}

# All aggregation variants accepted by run/solve/solve_many/serve. "async"
# is not in STEP_FNS because it carries extra block-local state between
# iterations (see run_async); run()/run_many() dispatch it explicitly.
VARIANTS = ("reduction", "queue", "queue_lock", "async")

# Default publication interval for the async variant (iterations between
# cross-block gbest syncs). 8 keeps the staleness window small while
# amortizing the reduction ~an order of magnitude.
ASYNC_SYNC_EVERY = 8


def init_async_locals(state: SwarmState, n_blocks: int
                      ) -> Tuple[Array, Array]:
    """Block-local bests seeded from the shared gbest: ([nb, D], [nb])."""
    lbp = jnp.broadcast_to(state.gbest_pos[None, :],
                           (n_blocks,) + state.gbest_pos.shape)
    lbf = jnp.broadcast_to(state.gbest_fit, (n_blocks,))
    return jnp.asarray(lbp), jnp.asarray(lbf)


def init_swarm_async(cfg: PSOConfig, seed: int,
                     n_blocks: Optional[int] = None,
                     hetero=None) -> SwarmState:
    """``init_swarm`` with the async block-local bests already attached.

    The serving scheduler's admission seam: a freshly admitted request's
    row must splice into an in-flight batch whose pytree structure carries
    ``lbest_pos``/``lbest_fit`` (the batch was built for the async
    variant), so the fresh row needs the buffers too. Seeding them with
    ``init_async_locals`` at iteration 0 is exactly what ``run_async``
    would have done on its first call for a bare ``init_swarm`` state —
    the carried-locals resume path and the fresh-seed path coincide at
    phase 0 — so an admitted row is bit-identical to the standalone
    solve of its request (tests/test_serving.py).
    """
    cfg = cfg.resolved()
    s = init_swarm(cfg, seed, hetero=hetero)
    nb = n_blocks or _default_async_blocks(s.pos.shape[0])
    lbp, lbf = init_async_locals(s, nb)
    return s._replace(lbest_pos=lbp, lbest_fit=lbf)


def step_async(cfg: PSOConfig, s: SwarmState,
               local: Tuple[Array, Array],
               coeffs: Optional[Tuple[Array, Array, Array]] = None,
               index_offset=None, hetero=None
               ) -> Tuple[SwarmState, Tuple[Array, Array]]:
    """One ASYNC queue-lock iteration (paper's enhanced variant, §4.2).

    Every block of ``n // nb`` particles advances against its *block-local*
    best ``local = (lbp [nb, D], lbf [nb])`` — zero cross-block
    communication. The iteration's per-block winner is folded into the local
    best; the shared ``s.gbest_*`` fields are left untouched (stale) until
    ``publish_async_locals`` syncs them, which ``run_async`` does every
    ``sync_every`` iterations. Deliberately cond-free (pure where/argmax)
    so it vmaps over a swarm axis without changing semantics.

    ``index_offset`` (optional, may be traced — e.g. ``axis_index * local_n``
    under shard_map) shifts the particle RNG indices so a shard owning
    particles [off, off+n) draws exactly the slice of the monolithic swarm's
    random stream (the ``init_swarm`` sharding convention). ``None`` keeps
    the exact pre-existing single-chip trace.
    """
    lbp, lbf = local
    n, d = s.pos.shape
    nb = lbf.shape[0]
    bn = n // nb
    gb = jnp.repeat(lbp, bn, axis=0)              # particle -> its block best
    pos, vel, fit = _advance(cfg, s, coeffs=coeffs, gbest_pos=gb,
                             index_offset=(0 if index_offset is None
                                           else index_offset),
                             hetero=hetero)
    pbest_pos, pbest_fit = _update_pbest(
        s, pos, fit, deb_selection_fn(cfg) if hetero is None else None)
    fb = fit.reshape(nb, bn)
    bi = jnp.argmax(fb, axis=1)                   # per-block iteration winner
    bfit = jnp.take_along_axis(fb, bi[:, None], axis=1)[:, 0]
    bpos = pos.reshape(nb, bn, d)[jnp.arange(nb), bi]
    take = bfit > lbf
    lbf = jnp.where(take, bfit, lbf)
    lbp = jnp.where(take[:, None], bpos, lbp)
    s = s._replace(pos=pos, vel=vel, fit=fit, pbest_pos=pbest_pos,
                   pbest_fit=pbest_fit, iteration=s.iteration + 1)
    return s, (lbp, lbf)


def publish_async_locals(s: SwarmState, local: Tuple[Array, Array]
                         ) -> Tuple[SwarmState, Tuple[Array, Array]]:
    """The sync point: publish the best local into the shared gbest, then
    pull the (new) shared gbest back into every block's local. After this,
    every block sees the true swarm-wide best — staleness resets to zero."""
    s, (lbp, lbf) = flush_async_locals(s, local)
    lbf = jnp.broadcast_to(s.gbest_fit, lbf.shape)
    lbp = jnp.broadcast_to(s.gbest_pos[None, :], lbp.shape)
    return s, (lbp, lbf)


def flush_async_locals(s: SwarmState, local: Tuple[Array, Array]
                       ) -> Tuple[SwarmState, Tuple[Array, Array]]:
    """Publish-only half of a sync: fold the best block-local into the
    shared gbest WITHOUT pulling it back into the blocks. Used for the
    forced end-of-call flush at a non-scheduled boundary: the returned
    state satisfies ``gbest_fit == max(pbest_fit)``, while the untouched
    locals let a resumed run continue each block exactly where it left off
    instead of restarting the staleness window."""
    lbp, lbf = local
    b = jnp.argmax(lbf)
    take = lbf[b] > s.gbest_fit
    gf = jnp.where(take, lbf[b], s.gbest_fit)
    gp = jnp.where(take, lbp[b], s.gbest_pos)
    return s._replace(gbest_pos=gp, gbest_fit=gf), (lbp, lbf)


def _default_async_blocks(n: int, target: int = 512) -> int:
    """Block count giving the largest block size ≤ target that divides n.

    Shares ``repro.core.blocking.pick_block_n`` with the Pallas kernels
    (``lane=1``: the jnp fallback has no tile-alignment constraint, which
    keeps its pre-unification block choices bit-for-bit)."""
    return default_block_count(n, target)


def run_async(cfg: PSOConfig, state: SwarmState, iters: int,
              sync_every: int = ASYNC_SYNC_EVERY,
              n_blocks: Optional[int] = None,
              coeffs: Optional[Tuple[Array, Array, Array]] = None,
              phase: Optional[int] = None, index_offset=None,
              hetero_row: Optional["HeteroRow"] = None,
              table=None) -> SwarmState:
    """``iters`` iterations of relaxed-consistency async PSO (jnp fallback).

    The library-level mirror of the Pallas async queue-lock: particle
    blocks run against block-local bests and the shared gbest is
    published/pulled only every ``sync_every`` iterations, so any block's
    view of the swarm best is at most ``sync_every`` iterations stale. A
    final sync always runs before returning: the result's ``gbest_fit``
    equals ``max(pbest_fit)`` exactly. With ``sync_every=1`` every
    iteration syncs — the synchronous queue-lock semantics as a special
    case. vmap-clean (no lax.cond anywhere) for ``multi_swarm.solve_many``.

    Checkpoint/resume: the returned state carries the block-local bests
    (``lbest_pos``/``lbest_fit``); a new call whose state carries them (with
    a matching block count) resumes from them instead of re-seeding from
    the shared gbest, and the end-of-call flush at a non-sync-aligned
    boundary publishes WITHOUT resetting them (``flush_async_locals``), so
    splitting a run across calls at sync points is bit-identical to the
    uninterrupted run (tests/test_checkpoint.py). ``phase`` is the resume
    point's offset into the staleness window (``iteration % sync_every``,
    static): sync points stay aligned to absolute iteration numbers, so
    even a mid-window split keeps the uninterrupted publication schedule.

    ``index_offset`` (optional, traced) shifts particle RNG indices for
    sharded swarms — see ``step_async``.
    """
    if phase is None:
        # Auto-align to the absolute iteration count when it is concrete
        # (the host-side resume path); under a trace (vmap'd batch engine)
        # fall back to 0 — the historical relative-window behavior.
        try:
            phase = int(state.iteration) % max(1, sync_every)
        except (TypeError, jax.errors.ConcretizationTypeError,
                jax.errors.TracerIntegerConversionError):
            phase = 0
    return _run_async(cfg, state, iters, sync_every, n_blocks, coeffs,
                      phase, index_offset, hetero_row, table)


@partial(jax.jit,
         static_argnames=("cfg", "iters", "sync_every", "n_blocks", "phase",
                          "table"))
def _run_async(cfg: PSOConfig, state: SwarmState, iters: int,
               sync_every: int, n_blocks: Optional[int],
               coeffs, phase: int, index_offset,
               hetero_row=None, table=None) -> SwarmState:
    cfg = cfg.resolved()
    hetero = None if hetero_row is None else (table, hetero_row)
    n, _ = state.pos.shape
    nb = n_blocks or _default_async_blocks(n)
    if n % nb:
        raise ValueError(f"n_blocks={nb} does not divide particle_cnt={n}")
    if iters <= 0:
        return state
    sync_every = max(1, sync_every)
    phase = phase % sync_every
    carried = (state.lbest_fit is not None
               and state.lbest_fit.shape == (nb,))
    local = ((state.lbest_pos, state.lbest_fit) if carried
             else init_async_locals(state, nb))
    state = state._replace(lbest_pos=None, lbest_fit=None)

    # The scheduled sync: star topology publishes + pulls the shared gbest
    # into every block; lbest topologies flush to the shared gbest (for
    # monitoring and the final answer) but each block pulls only from its
    # NEIGHBORHOOD of block-locals, so information diffuses hop by hop
    # (repro.core.topology). Python-gated: the default "gbest" traces the
    # exact pre-topology jaxpr.
    if cfg.topology == "gbest":
        scheduled_publish = publish_async_locals
    else:
        from .topology import block_neighbor_best

        def scheduled_publish(s, local):
            s, (lbp, lbf) = flush_async_locals(s, local)
            lbp, lbf = block_neighbor_best(lbf, lbp, cfg.topology)
            return s, (lbp, lbf)

    def one(carry):
        s, local = carry
        return step_async(cfg, s, local, coeffs=coeffs,
                          index_offset=index_offset, hetero=hetero)

    def chunk(span, publish=None):
        publish = scheduled_publish if publish is None else publish
        def body(_, carry):
            s, local = carry
            s, local = jax.lax.fori_loop(
                0, span, lambda _, c: one(c), (s, local))
            return publish(s, local)
        return body

    # Segment the run so publish points land on absolute iteration numbers
    # ≡ 0 (mod sync_every): an optional head chunk completes the window the
    # resume point interrupted, full chunks follow, and a trailing remainder
    # flushes publish-only (no pull — see flush_async_locals).
    if phase:
        head = min(iters, sync_every - phase)
        chunks, rem = divmod(iters - head, sync_every)
    else:
        head, (chunks, rem) = 0, divmod(iters, sync_every)
    carry = (state, local)
    if head:
        scheduled = head == sync_every - phase
        carry = chunk(head, scheduled_publish if scheduled
                      else flush_async_locals)(0, carry)
    if chunks:
        carry = jax.lax.fori_loop(0, chunks, chunk(sync_every), carry)
    if rem:
        carry = chunk(rem, flush_async_locals)(0, carry)
    s, (lbp, lbf) = carry
    return s._replace(lbest_pos=lbp, lbest_fit=lbf)


@partial(jax.jit, static_argnames=("cfg", "iters", "variant"))
def _run_stepped(cfg: PSOConfig, state: SwarmState, iters: int,
                 variant: str) -> SwarmState:
    step = STEP_FNS[variant]
    return jax.lax.fori_loop(0, iters, lambda _, s: step(cfg, s), state)


def run(cfg: PSOConfig, state: SwarmState, iters: int,
        variant: str = "queue",
        sync_every: int = ASYNC_SYNC_EVERY,
        n_blocks: Optional[int] = None) -> SwarmState:
    """Run ``iters`` PSO iterations with the chosen aggregation variant.

    ``sync_every`` and ``n_blocks`` only affect ``variant="async"``
    (publication interval and particle-block count — the schedule knobs
    the autotuner picks; ``n_blocks=None`` keeps the heuristic default).
    A thin dispatcher over the jitted implementations, so synchronous
    variants never key their jit cache on the (irrelevant) ``sync_every``.
    """
    cfg = cfg.resolved()
    if variant == "async":
        return run_async(cfg, state, iters, sync_every=sync_every,
                         n_blocks=n_blocks)
    if state.lbest_fit is not None:
        # Sync variants advance gbest without maintaining the async
        # block-local cache; drop it so a later async run re-seeds fresh.
        state = state._replace(lbest_pos=None, lbest_fit=None)
    return _run_stepped(cfg, state, iters, variant)


def solve(cfg: PSOConfig, seed: int = 0, iters: int = 1000,
          variant: str = "queue",
          sync_every: int = ASYNC_SYNC_EVERY) -> SwarmState:
    """Convenience one-shot: init + run."""
    cfg = cfg.resolved()
    return run(cfg, init_swarm(cfg, seed), iters, variant, sync_every)


# --------------------------------------------------------------------------
# Convergence history (ROADMAP follow-on (c)): gbest per sync point.
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "iters", "variant"))
def _run_stepped_history(cfg: PSOConfig, state: SwarmState, iters: int,
                         variant: str):
    step = STEP_FNS[variant]
    vf = cfg.problem.violation_fn

    def body(s, _):
        s = step(cfg, s)
        v = (vf(s.gbest_pos) if vf is not None
             else jnp.zeros((), s.gbest_fit.dtype))
        return s, (s.gbest_fit, v)

    state, (fits, viols) = jax.lax.scan(body, state, xs=None, length=iters)
    return state, fits, viols


def run_with_history(cfg: PSOConfig, state: SwarmState, iters: int,
                     variant: str = "queue",
                     sync_every: int = ASYNC_SYNC_EVERY):
    """Like ``run`` but also records the gbest trajectory.

    Returns ``(state, (iterations, gbest_fits, violations))`` where the
    arrays hold one entry per sync point — every iteration for the
    synchronous variants (a ``lax.scan`` over the same step functions, so
    one device program), every publication boundary for ``async`` (the run
    is segmented at sync points, which the checkpoint/resume machinery
    makes bit-identical to the uninterrupted run — tests/test_checkpoint).
    ``violations`` is the aggregate constraint violation of the recorded
    gbest position (None for unconstrained problems): constrained runs use
    it to report the first-feasible iteration (``repro.Result``).
    """
    cfg = cfg.resolved()
    constrained = cfg.problem.constrained
    if iters <= 0:
        empty = jnp.zeros((0,), state.gbest_fit.dtype)
        return state, ((), empty, empty if constrained else None)
    if variant != "async":
        if state.lbest_fit is not None:
            state = state._replace(lbest_pos=None, lbest_fit=None)
        start = int(state.iteration)
        state, fits, viols = _run_stepped_history(cfg, state, iters, variant)
        its = tuple(range(start + 1, start + iters + 1))
        return state, (its, fits, viols if constrained else None)
    vf = cfg.problem.violation_fn
    its, fits, viols = [], [], []
    done = 0
    while done < iters:
        k = min(max(1, sync_every), iters - done)
        state = run_async(cfg, state, k, sync_every=sync_every)
        done += k
        its.append(int(state.iteration))
        fits.append(state.gbest_fit)
        viols.append(vf(state.gbest_pos) if vf is not None
                     else jnp.zeros((), state.gbest_fit.dtype))
    fits = jnp.stack(fits)
    viols = jnp.stack(viols)
    return state, (tuple(its), fits, viols if constrained else None)
