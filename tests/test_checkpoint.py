"""Checkpoint/restart: atomicity, resume, pruning, crash simulation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import PSOConfig, init_swarm, run


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step_count": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ckpt.save(d, 3, tree)
    assert ckpt.latest_step(d) == 3
    out = ckpt.restore(d, 3, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_prune(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree)
    assert ckpt.latest_step(d) == 5
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 5
    assert ckpt.restore_latest(d, tree)[0] == 5
    with pytest.raises(FileNotFoundError):
        ckpt.restore(d, 1, tree)


def test_incomplete_checkpoint_ignored(tmp_path):
    """A dir without manifest (simulated crash mid-write) is not 'latest'."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000009"))  # torn write, no manifest
    assert ckpt.latest_step(d) == 1


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    bad = dict(_tree(), w=jnp.zeros((2, 2)))
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(d, 1, bad)


def test_pso_crash_restart_bit_exact(tmp_path):
    """Run 30 iters; 'crash'; resume from step-10 checkpoint and re-run —
    trajectory must be bit-exact vs uninterrupted (counter RNG contract)."""
    d = str(tmp_path)
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness="rastrigin").resolved()
    s = init_swarm(cfg, 3)
    s10 = run(cfg, s, 10, "queue")
    ckpt.save(d, 10, s10)
    full = run(cfg, s10, 20, "queue")          # uninterrupted continuation
    # --- crash happens here; new process restores:
    step, restored = ckpt.restore_latest(d, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s10))
    assert step == 10
    from repro.core.pso import SwarmState
    restored = SwarmState(*restored) if not isinstance(
        restored, SwarmState) else restored
    resumed = run(cfg, restored, 20, "queue")
    np.testing.assert_array_equal(np.asarray(full.pos),
                                  np.asarray(resumed.pos))
    assert float(full.gbest_fit) == float(resumed.gbest_fit)


def test_async_checkpoint_resume_bit_exact_at_chunk_boundary(tmp_path):
    """Async resume must not restart the staleness window: the checkpoint
    carries the block-local bests (SwarmState.lbest_*), so resuming at a
    chunk boundary reproduces the uninterrupted run bit for bit —
    trajectory AND the relaxed-consistency bookkeeping."""
    from repro.core import run_async
    d = str(tmp_path)
    cfg = PSOConfig(dim=3, particle_cnt=128, fitness="rastrigin").resolved()
    s0 = init_swarm(cfg, 9)
    full = run_async(cfg, s0, 32, sync_every=4, n_blocks=4)
    s16 = run_async(cfg, s0, 16, sync_every=4, n_blocks=4)
    assert s16.lbest_fit is not None and s16.lbest_fit.shape == (4,)
    ckpt.save(d, 16, s16)
    # --- crash; new process restores (locals ride the checkpoint pytree):
    step, restored = ckpt.restore_latest(d, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s16))
    assert step == 16
    assert restored.lbest_fit is not None         # locals survived the disk
    resumed = run_async(cfg, restored, 16, sync_every=4, n_blocks=4)
    for f in full._fields:
        np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                      np.asarray(getattr(resumed, f)),
                                      err_msg=f)


def test_async_resume_mid_window_keeps_publication_schedule():
    """Resuming OFF the sync grid (e.g. --ckpt-every not a multiple of
    sync_every) stays bit-exact too: the carried locals plus the static
    ``phase`` (auto-derived from state.iteration) keep publish points on
    absolute iteration numbers, and the tail flush publishes without
    resetting the blocks."""
    from repro.core import run, run_async
    # particle_cnt=1024 → the default block picker yields 2 blocks, so the
    # run() path (no explicit n_blocks) exercises real multi-block locals
    cfg = PSOConfig(dim=2, particle_cnt=1024, fitness="cubic").resolved()
    s0 = init_swarm(cfg, 4)
    full = run_async(cfg, s0, 20, sync_every=8)
    # 20 = 6 + 14: both splits are off the sync_every=8 grid
    part = run_async(cfg, s0, 6, sync_every=8)
    assert float(part.gbest_fit) == float(np.max(np.asarray(part.pbest_fit)))
    resumed = run_async(cfg, part, 14, sync_every=8)
    for f in full._fields:
        np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                      np.asarray(getattr(resumed, f)),
                                      err_msg=f)
    # the run() dispatcher path (what the CLI chunked loop uses) resumes
    # identically
    resumed2 = run(cfg, part, 14, "async", sync_every=8)
    np.testing.assert_array_equal(np.asarray(full.pos),
                                  np.asarray(resumed2.pos))


def test_batched_async_resume_bit_exact_any_boundary():
    """Regression (PR 5 known bug): the batched engine vmaps run_async, so
    the per-swarm phase auto-derivation hit a tracer and silently restarted
    every swarm's publication window at 0 on resume. run_many now reads the
    phases off the concrete batch before jit entry, so a batched async solve
    split at ANY boundary — chunk-aligned or mid-window — is bit-exact vs
    the uninterrupted batched run AND per-row vs the single-swarm path."""
    from repro.core import batch_row, init_batch, run_async, run_many
    # particle_cnt=1024 -> the default block picker yields 2 blocks, so the
    # publication schedule is observable (single-block async degenerates)
    cfg = PSOConfig(dim=2, particle_cnt=1024, fitness="cubic").resolved()
    seeds = list(range(8))
    b0 = init_batch(cfg, seeds)
    for split in (8, 6):                  # chunk boundary AND mid-window
        full = run_many(cfg, b0, 20, "async", sync_every=8)
        part = run_many(cfg, b0, split, "async", sync_every=8)
        assert part.lbest_fit is not None and part.lbest_fit.shape == (8, 2)
        resumed = run_many(cfg, part, 20 - split, "async", sync_every=8)
        for f in full._fields:
            np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                          np.asarray(getattr(resumed, f)),
                                          err_msg=f"{f} (split={split})")
        # row identity against the standalone resume (engine contract)
        single = run_async(cfg, batch_row(part, 3), 20 - split, sync_every=8)
        np.testing.assert_array_equal(np.asarray(resumed.pos[3]),
                                      np.asarray(single.pos))


def test_batched_async_resume_mixed_phases():
    """Rows checkpointed at different iterations resume correctly: run_many
    splits the batch into per-phase dispatch groups, and each row still
    matches its own standalone run_async continuation bit for bit."""
    from repro.core import batch_row, run_async, run_many, stack_states
    from repro.core.pso import init_swarm
    cfg = PSOConfig(dim=2, particle_cnt=1024, fitness="cubic").resolved()
    states = []
    for sd, pre in zip(range(6), (3, 6, 11, 3, 6, 11)):
        # 11 % 8 == 3: same phase as pre=3 but a different iteration count,
        # so the grouping is genuinely by phase, not by iteration
        states.append(run_async(cfg, init_swarm(cfg, sd), pre, sync_every=8))
    batch = stack_states(states)
    out = run_many(cfg, batch, 9, "async", sync_every=8)
    for i in range(6):
        single = run_async(cfg, batch_row(batch, i), 9, sync_every=8)
        for f in ("pos", "pbest_fit", "gbest_fit", "lbest_fit"):
            np.testing.assert_array_equal(np.asarray(getattr(out, f)[i]),
                                          np.asarray(getattr(single, f)),
                                          err_msg=f"row {i} {f}")


def test_step_runner_retry_and_resume(tmp_path):
    """StepRunner recovers from a transient failure via its checkpoint."""
    from repro.runtime import RunnerConfig, StepRunner
    calls = {"n": 0}

    def flaky_step(state, step):
        calls["n"] += 1
        if calls["n"] == 7:                       # one transient device loss
            raise RuntimeError("simulated device failure")
        return jax.tree.map(lambda x: x + 1, state)

    runner = StepRunner(RunnerConfig(str(tmp_path), ckpt_interval=2,
                                     backoff_s=0.0), flaky_step)
    out = runner.run({"x": jnp.zeros(())}, 0, 10)
    assert float(out["x"]) == 10.0                # all 10 steps applied
    assert ckpt.latest_step(str(tmp_path)) == 10
