"""Quickstart: the unified solve facade.

One entry point — ``repro.solve(problem, ...)`` — covers everything that
used to be scattered across ``core.pso.solve``, ``core.multi_swarm.
solve_many`` and the ``repro.kernels.ops`` wrappers: pick a problem (a
registered benchmark name or your own ``repro.Problem``), a ``Method``
(aggregation variant + jnp/kernel backend), and go.

Here: the paper's two benchmark workloads (1D and 120D cubic) through all
four aggregation variants, the fused/async Pallas kernels (interpret mode
off-TPU), and a batched multi-seed solve — verifying the paper's §4.1 claim
that queueing is an optimization, not an approximation.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import repro
from repro import Method


def solve_and_report(dim: int, particles: int, iters: int):
    print(f"\n=== cubic, dim={dim}, particles={particles}, iters={iters} ===")
    print(f"{'method':32s} {'best_fit':>14s} {'wall_s':>8s}")
    for variant in ("reduction", "queue", "queue_lock", "async"):
        t0 = time.time()
        res = repro.solve("cubic", dim=dim, particles=particles, iters=iters,
                          seed=0, variant=variant)
        print(f"{variant + ' (jnp)':32s} {res.best_fit:14.4f} "
              f"{time.time() - t0:8.3f}")
    # Fused Pallas kernels (TPU target; interpret mode here => slow, so few
    # iters). backend="kernel" exists for the queue_lock and async variants.
    k_iters = min(iters, 100)
    for variant, extra in (("queue_lock", {}), ("async", {"sync_every": 10})):
        t0 = time.time()
        res = repro.solve("cubic", dim=dim, particles=particles,
                          iters=k_iters, seed=0,
                          method=Method(variant=variant, backend="kernel",
                                        **extra))
        print(f"{variant + ' (pallas interp)':32s} {res.best_fit:14.4f} "
              f"{time.time() - t0:8.3f}  ({k_iters} iters)")
    ideal = dim * 900000.0
    print(f"{'analytic optimum f(100)*d':32s} {ideal:14.4f}")


def batched_demo():
    """Many independent solves in ONE device program (the serving primitive)."""
    t0 = time.time()
    results = repro.solve_many("rastrigin", seeds=range(8), dim=10,
                               particles=256, iters=200, variant="queue")
    best = repro.best(results)
    print(f"\n=== batched: 8 seeds of 10D rastrigin in one dispatch ===")
    print(f"best seed result {best.best_fit:.4f}  "
          f"(8 solves, wall={time.time() - t0:.3f}s)")


def islands_demo():
    """One swarm sharded into islands with the ASYNC ring exchange.

    Islands iterate against a stale view and push their best around a
    neighbor ring every ``exchange_interval`` iterations — no global
    barrier collective anywhere. Staleness is bounded by ``sync_every``
    iterations within an island plus ``islands`` exchange rounds across
    them; the run still ends fully synchronized (drain hops), so the
    reported best equals the true max over all particles. On this machine
    it uses as many devices as are available (1 is fine — the ring then
    degenerates, bit-identically, to the single-chip async variant).
    """
    import jax
    n_islands = max(1, len(jax.devices()))
    t0 = time.time()
    res = repro.solve("rastrigin", dim=10, particles=1024, iters=200, seed=0,
                      method=repro.Method(variant="async",
                                          islands=n_islands,
                                          exchange_interval=20,
                                          sync_every=5))
    print(f"\n=== islands: async ring over {n_islands} device(s) ===")
    print(f"best {res.best_fit:.4f}  (wall={time.time() - t0:.3f}s)")


def main():
    solve_and_report(dim=1, particles=1024, iters=1000)
    solve_and_report(dim=120, particles=2048, iters=500)
    batched_demo()
    islands_demo()


if __name__ == "__main__":
    main()
