"""Constrained optimization, end to end: penalty vs projection.

Real PSO workloads are rarely pure boxes. ``repro.ConstraintSet`` attaches
feasibility constraints to any Problem and composes with every backend
(jnp engines, the fused/async Pallas kernels, serving, the tuner). Here:
minimize ``||x||^2`` on the probability simplex ``{x >= 0, sum(x) = 1}``
(optimum ``x_i = 1/D``, ``f = 1/D``) with the same landscape handled two
ways:

* ``penalty`` — fitness becomes ``f(x) - weight * violation(x)``; the swarm
  roams the box and is *pushed* toward feasibility (optionally harder over
  time via the ``ramp`` schedule).
* ``projection`` — every advance is projected back onto the simplex
  (Duchi et al. sort-based projection); the swarm *never leaves* the
  feasible set.

``Method(record_history=True)`` records the gbest per sync point, from
which constrained runs report their first-feasible iteration
(``Result.first_feasible_iter``); ``repro.best`` ranks results by the Deb
rule (feasible beats infeasible, then fitness, then violation).

    PYTHONPATH=src python examples/constrained.py
"""
import numpy as np

import repro
from repro import Constraint, ConstraintSet, Method

DIM = 8


def report(label: str, res: repro.Result) -> None:
    print(f"{label:24s} f={res.best_fit:.6f}  feasible={res.feasible}  "
          f"violation={res.violation:.3g}  "
          f"first_feasible_iter={res.first_feasible_iter}")


def main():
    print(f"=== sphere on the {DIM}-simplex (optimum f = 1/{DIM} "
          f"= {1.0 / DIM:.6f}) ===")

    # The two built-in spellings of the same constrained landscape.
    pen = repro.solve("sphere_simplex_pen", dim=DIM, particles=256,
                      iters=300, seed=0, w=0.7, variant="queue_lock",
                      record_history=True)
    report("penalty (w=50)", pen)

    proj = repro.solve("sphere_simplex", dim=DIM, particles=256,
                       iters=300, seed=0, w=0.7, variant="queue_lock",
                       record_history=True)
    report("projection", proj)

    # The async variant and the Pallas kernels take constrained problems
    # unchanged (the penalty rides the objective; the projection lowers
    # into the kernels' d-major layout).
    k = repro.solve("sphere_simplex_pen", dim=DIM, particles=256, iters=60,
                    seed=0, w=0.7,
                    method=Method(variant="async", backend="kernel",
                                  sync_every=10))
    report("penalty (pallas async)", k)

    # An adaptive ramp: start gentle (weight 1), quadruple every 75
    # iterations — the facade segments the run and re-weights the carried
    # bests at each boundary, so the ramp works on every backend.
    import jax.numpy as jnp
    ramped = repro.Problem(
        name="sphere_simplex_ramp",
        fn=lambda x: jnp.sum(x * x, axis=-1), lo=0.0, hi=1.0, sense="min",
        constraints=ConstraintSet(
            constraints=(
                Constraint(fn=lambda x: jnp.sum(x, -1) - 1.0, kind="eq",
                           tol=1e-5, name="sum=1"),
                Constraint(fn=lambda x: jnp.max(-x, -1), name="x>=0"),
            ),
            mode="penalty", weight=1.0, ramp=4.0, ramp_every=75))
    r = repro.solve(ramped, dim=DIM, particles=256, iters=300, seed=0,
                    w=0.7, variant="queue_lock", record_history=True)
    report("penalty (ramp 1->4^k)", r)

    # Deb-rule selection over a batch of seeds.
    rs = repro.solve_many("sphere_simplex_pen", seeds=range(6), dim=DIM,
                          particles=128, iters=200, w=0.7,
                          variant="queue_lock")
    b = repro.best(rs)
    print(f"{'deb best of 6 seeds':24s} f={b.best_fit:.6f}  "
          f"feasible={b.feasible}  "
          f"({sum(r.feasible for r in rs)}/6 feasible)")

    assert proj.feasible and abs(proj.best_fit - 1.0 / DIM) < 1e-3
    assert proj.first_feasible_iter is not None
    assert np.all(np.diff(np.asarray(proj.history.gbest_fit)) >= 0)


if __name__ == "__main__":
    main()
