"""Update-rule layer: every (rule, variant) pair vs its ref.py eager
oracle, plus the digest regression pinning the refactored scaffold
bit-identical to the pre-refactor kernel bodies for the default rule."""
import hashlib

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import Method
from repro.core import PSOConfig, init_swarm
from repro.core.pso import run, run_async
from repro.core.update_rules import (TOPOLOGIES, UPDATE_RULES, PSORule,
                                     UpdateRule, resolve_rule, rule_names)
from repro.kernels import ops, ref

RULES = tuple(sorted(UPDATE_RULES))


def _digest(state) -> str:
    h = hashlib.sha1()
    for a in (state.pos, state.vel, state.pbest_fit, state.gbest_pos,
              state.gbest_fit):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()[:16]


def _oracle_inputs(cfg, seed):
    s0 = init_swarm(cfg, seed)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s0, cfg.dim)
    kw = ops._cfg_kwargs(cfg)          # carries rule=cfg.update_rule
    kw["d_real"] = cfg.dim
    fitness = kw.pop("fitness")
    return s0, (pos, vel, pbp, pbf, gp, float(gf[0])), fitness, kw


# --------------------------------------------------------------------------
# Registry semantics
# --------------------------------------------------------------------------

def test_registry_and_resolve():
    assert rule_names() == RULES
    assert {"pso", "sso", "lowcost"} <= set(RULES)
    for name in RULES:
        r = UPDATE_RULES[name]
        assert resolve_rule(name) is r
        assert resolve_rule(r) is r            # instances pass through
        # all shipped rules draw both streams: swapping the rule changes
        # no RNG bookkeeping anywhere in the stack
        assert r.rng_draws == 2
        assert r.kernel_eligible
    with pytest.raises(ValueError) as ei:
        resolve_rule("warp_speed")
    # the error enumerates every valid name
    assert all(n in str(ei.value) for n in RULES)


def test_rule_advance_semantics():
    """Hand-checkable elementwise semantics on a 1x4 tile."""
    r1 = jnp.asarray([[0.1, 0.5, 0.8, 0.95]])
    r2 = jnp.asarray([[0.25, 0.25, 0.75, 0.25]])
    pos = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
    vel = jnp.asarray([[0.5, 0.5, 0.5, 0.5]])
    pbp = jnp.asarray([[2.0, 2.0, 2.0, 2.0]])
    gp = jnp.asarray([[3.0, 3.0, 3.0, 3.0]])
    kw = dict(w=0.5, c1=1.0, c2=1.0, mv=10.0, lo=-10.0, hi=10.0)
    # sso: thresholds 0.4 / 0.7 / 0.9 -> gbest, pbest, keep, resample
    p, v = UPDATE_RULES["sso"].advance(r1, r2, pos, vel, pbp, gp, **kw)
    np.testing.assert_allclose(np.asarray(p)[0],
                               [3.0, 2.0, 1.0, -10.0 + 20.0 * 0.25])
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vel))  # untouched
    # lowcost: Bernoulli(1/2) selection of the difference terms
    p, v = UPDATE_RULES["lowcost"].advance(r1, r2, pos, vel, pbp, gp, **kw)
    np.testing.assert_allclose(np.asarray(v)[0],
                               [0.5 + 1.0 + 2.0,   # both selected
                                0.5 + 0.0 + 2.0,   # r1 >= .5? no: r1=.5 -> off
                                0.5 + 0.0 + 0.0,   # both off
                                0.5 + 0.0 + 2.0])
    np.testing.assert_allclose(np.asarray(p), np.asarray(pos + v))
    # pso: the canonical chain
    p, v = UPDATE_RULES["pso"].advance(r1, r2, pos, vel, pbp, gp, **kw)
    want_v = 0.5 * 0.5 + np.asarray(r1)[0] * 1.0 + np.asarray(r2)[0] * 2.0
    np.testing.assert_allclose(np.asarray(v)[0], want_v, rtol=1e-6)


# --------------------------------------------------------------------------
# Kernels vs eager oracles, per rule
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rule", RULES)
def test_queue_kernel_vs_oracle_per_rule(rule):
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="rastrigin",
                    update_rule=rule).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 11)
    out = ops.queue_step(cfg, s0, block_n=32)
    o = ref.queue_step_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                              32, fitness=fitness, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 3)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-6)


@pytest.mark.parametrize("rule", RULES)
@pytest.mark.parametrize("block_n", [64, 32])
def test_fused_kernel_vs_oracle_per_rule(rule, block_n):
    """Single- and multi-block, ulp-tight (compiled-vs-eager FMA
    contraction is the repo's documented caveat; the bit-exact surface is
    kernel-vs-kernel, below)."""
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="sphere",
                    update_rule=rule).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=block_n)
    o = ref.run_fused_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp, gf,
                             8, block_n, fitness=fitness, **kw)
    got = np.asarray(ops.pack_dmajor(out.pos, 3))
    np.testing.assert_allclose(got, np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o[3])[0], rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("rule", RULES)
@pytest.mark.parametrize("block_n", [64, 32])
def test_async_kernel_vs_oracle_per_rule(rule, block_n):
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="sphere",
                    update_rule=rule).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 1)
    out = ops.run_queue_lock_fused_async(cfg, s0, iters=8, sync_every=4,
                                         block_n=block_n)
    o = ref.run_fused_async_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp,
                                   gf, 8, block_n, 4,
                                   fitness=fitness, **kw)
    got = np.asarray(ops.pack_dmajor(out.pos, 3))
    np.testing.assert_allclose(got, np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-5)


@pytest.mark.parametrize("rule", RULES)
def test_async_single_block_equals_fused_per_rule(rule):
    """With one block the async kernel IS the fused kernel — the scaffold
    invariant survives every rule, not just the default."""
    cfg = PSOConfig(dim=2, particle_cnt=64, fitness="cubic",
                    update_rule=rule).resolved()
    s0 = init_swarm(cfg, 5)
    f = ops.run_queue_lock_fused(cfg, s0, iters=8, block_n=64)
    a = ops.run_queue_lock_fused_async(cfg, s0, iters=8, sync_every=2,
                                       block_n=64)
    assert np.array_equal(np.asarray(f.pos), np.asarray(a.pos))
    assert float(f.gbest_fit) == float(a.gbest_fit)


# --------------------------------------------------------------------------
# jnp engine vs the constrained-run oracle, per rule
# --------------------------------------------------------------------------

@pytest.mark.parametrize("rule", RULES)
@pytest.mark.parametrize("variant", ["queue_lock", "async"])
def test_jnp_engine_vs_oracle_per_rule(rule, variant):
    """Per-iteration dispatch matches the eager oracle bit-exactly for
    every rule (the _advance_fn jit-subgraph precedent)."""
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness="sphere",
                    update_rule=rule).resolved()
    iters = 10
    o = ref.run_constrained_oracle(cfg, 3, iters, variant=variant,
                                   sync_every=4, n_blocks=4)
    s = init_swarm(cfg, 3)
    for _ in range(iters):
        if variant == "async":
            s = run_async(cfg, s, 1, sync_every=4, n_blocks=4)
        else:
            s = run(cfg, s, 1, "queue_lock")
    assert np.array_equal(np.asarray(s.pos), np.asarray(o.pos))
    assert np.array_equal(np.asarray(s.pbest_fit), np.asarray(o.pbest_fit))
    assert float(s.gbest_fit) == float(o.gbest_fit)


# --------------------------------------------------------------------------
# Digest regression: the scaffold refactor is bit-identical for the
# default rule (same params as tests/test_problem.py's seed pins)
# --------------------------------------------------------------------------

def test_scaffold_default_rule_digests_unchanged():
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="cubic").resolved()
    assert isinstance(resolve_rule(cfg.update_rule), PSORule)
    s0 = init_swarm(cfg, 5)
    k = ops.run_queue_lock_fused(cfg, s0, iters=12, block_n=64)
    assert _digest(k) == "e738dfc1df826106"
    a = ops.run_queue_lock_fused_async(cfg, s0, iters=12, sync_every=4,
                                       block_n=64)
    assert _digest(a) == "919036ad04111333"
    # and spelling the default rule explicitly traces the same program
    cfg2 = PSOConfig(dim=2, particle_cnt=128, fitness="cubic",
                     update_rule="pso").resolved()
    k2 = ops.run_queue_lock_fused(cfg2, init_swarm(cfg2, 5), iters=12,
                                  block_n=64)
    assert _digest(k2) == "e738dfc1df826106"


# --------------------------------------------------------------------------
# Method facade + config plumbing
# --------------------------------------------------------------------------

def test_config_validates_rule_and_topology():
    with pytest.raises(ValueError, match="unknown update rule"):
        PSOConfig(dim=2, particle_cnt=64, fitness="cubic",
                  update_rule="warp_speed")
    with pytest.raises(ValueError, match="topology"):
        PSOConfig(dim=2, particle_cnt=64, fitness="cubic",
                  topology="hypercube")
    # resolved() preserves both fields
    cfg = PSOConfig(dim=2, particle_cnt=64, fitness="cubic",
                    update_rule="sso", topology="ring").resolved()
    assert cfg.update_rule == "sso" and cfg.topology == "ring"


def test_method_validates_rule_and_topology():
    with pytest.raises(ValueError) as ei:
        Method(rule="warp_speed")
    assert all(n in str(ei.value) for n in RULES)
    with pytest.raises(ValueError, match="async"):
        Method(variant="queue", topology="ring")
    for t in TOPOLOGIES:
        Method(variant="async", topology=t)     # all valid on async
    # a non-kernel-eligible custom rule is rejected on the kernel backend
    class HostRule(UpdateRule):
        pass
    host = HostRule("hostonly", kernel_eligible=False)
    UPDATE_RULES["hostonly"] = host
    try:
        Method(variant="queue_lock", backend="jnp", rule="hostonly")
        with pytest.raises(ValueError, match="kernel"):
            Method(variant="queue_lock", backend="kernel", rule="hostonly")
    finally:
        del UPDATE_RULES["hostonly"]


@pytest.mark.parametrize("rule", ["sso", "lowcost"])
@pytest.mark.parametrize("backend,variant", [("jnp", "queue_lock"),
                                             ("jnp", "async"),
                                             ("kernel", "queue_lock"),
                                             ("kernel", "async")])
def test_solve_end_to_end_per_rule(rule, backend, variant):
    """The non-default rules run end-to-end through the facade on both
    backends, improve on the init and respect the box."""
    res = repro.solve("sphere", dim=3, particles=128, iters=60, seed=0,
                      method=Method(variant=variant, backend=backend,
                                    rule=rule))
    s0 = init_swarm(res.config, 0)
    assert float(res.state.gbest_fit) >= float(s0.gbest_fit)
    pos = np.asarray(res.state.pos)
    assert np.all(pos >= res.config.min_pos - 1e-5)
    assert np.all(pos <= res.config.max_pos + 1e-5)
    assert not np.any(np.isnan(pos))
