"""minicpm3-4b — dense with Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]

MLA ranks follow the model card: q_lora_rank=768, kv_lora_rank=256,
qk_rope_head_dim=32, qk_nope_head_dim=64, v_head_dim=64 (40 heads).
"""
from .base import ArchConfig, register

MINICPM3_4B = register(ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    mla=True, q_rank=768, kv_rank=256,
    rope_head_dim=32, nope_head_dim=64, v_head_dim=64,
    source="hf:openbmb/MiniCPM3-4B",
))
