"""Multi-chip / multi-pod PSO: the paper's "future work: multi-GPU" built out
to datacenter scale with shard_map.

Design (DESIGN.md §3):
  * Particles are sharded over the ("pod", "data") mesh axes. Each shard runs
    the full per-particle pipeline (advance + fitness + pbest) locally using
    the single-chip step variants — including the Pallas fused kernel when
    enabled.
  * The swarm-global best is the only cross-chip state. Synchronous mode
    (``exchange_interval=1``) all-reduces a scalar ``(fit, argmax-owner)``
    pair every iteration — the collective analogue of the paper's reduction
    kernel, but already minimized to O(1) bytes (8 B) per chip per iteration.
  * Island mode (``exchange_interval=K>1``) is the datacenter analogue of the
    queue-lock idea: shards iterate against a stale global best and publish
    occasionally. One collective per K iterations instead of per iteration.
  * ``variant="async"`` extends the async queue-lock's relaxed-consistency
    contract ACROSS devices — the **island ring**. There is no global
    barrier collective at all: each exchange is a single neighbor push of
    the island's current best ``(gbest_fit, owner, gbest_pos)`` around a
    ring (``lax.ppermute`` — the shard_map spelling of a
    ``make_async_remote_copy`` neighbor DMA), folded into the receiver
    under a rare-improvement predicate (the O(D) position select only
    applies when the received fit actually beats the local view, with
    lowest-owner-index tie-breaking so every shard converges to the same
    winner). Gossip-style forwarding — each shard pushes the best it
    *knows*, not just its own — gives the documented staleness bound:

        an island's published best reaches ALL shards within
        ``n_shards`` exchange rounds (one hop per round),

    on top of the intra-island bound of ``sync_every`` iterations from
    ``run_async``. A final drain of ``n_shards - 1`` exchange-only hops
    makes the run end fully synchronized: every shard's ``gbest`` equals
    the max over all pbests everywhere (the final-flush invariant, mirrored
    eagerly by ``repro.kernels.ref.run_islands_ring_oracle``).
  * gbest_pos (O(D) bytes) is broadcast from the winning shard only — via a
    pmax-weighted select in sync mode, via the predicated ring fold in async
    mode — so no gather of positions ever crosses the network unless an
    improvement actually happened (the paper's §5.3 index trick at cluster
    scale).

Remainder handling: ``iters`` need not divide ``exchange_interval`` — a
trailing short round (same RNG-counter chaining as
``ops.run_queue_lock_fused_async``'s tail phase) runs the leftover
iterations and still exchanges afterwards.

Elasticity: ``init_sharded_swarm`` builds shard-local particles from global
indices, so a checkpoint taken on 256 chips restores bit-identically on 64 or
1024 (tests/test_distributed.py::test_elastic_reshard_equivalence). The async
ring keeps the same convention by threading ``index_offset`` into the
shard-local ``run_async`` loop.

Problems: ``cfg.fitness`` may be a registered name or a first-class
``repro.core.problem.Problem`` — the shard-local step functions evaluate
``cfg.fitness_fn`` (canonical-max form, per-dimension bounds included)
inside shard_map unchanged, so user objectives distribute for free
(tests/test_problem.py::test_distributed_custom_problem).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .blocking import default_block_count
from .pso import (ASYNC_SYNC_EVERY, PSOConfig, STEP_FNS, SwarmState,
                  init_async_locals, init_swarm, run_async)

Array = jnp.ndarray

# jax moved shard_map to the top level and renamed check_rep -> check_vma in
# newer releases — and not necessarily in the same release, so resolve the
# function and the kwarg spelling independently.
if hasattr(jax, "shard_map"):
    _shard_map_fn = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_fn

import inspect as _inspect

_SM_CHECK_KW = ("check_vma" if "check_vma"
                in _inspect.signature(_shard_map_fn).parameters
                else "check_rep")


def _shard_map(f, mesh, in_specs, out_specs):
    return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **{_SM_CHECK_KW: False})


def swarm_pspec(particle_axes, with_locals: bool = False) -> SwarmState:
    """PartitionSpecs for a SwarmState sharded over ``particle_axes``.

    ``with_locals`` adds specs for the async block-local best buffers
    (``lbest_*``), which are shard-private and therefore sharded on the
    block axis like the particles.
    """
    pa = particle_axes
    return SwarmState(
        pos=P(pa, None), vel=P(pa, None), fit=P(pa),
        pbest_pos=P(pa, None), pbest_fit=P(pa),
        gbest_pos=P(None), gbest_fit=P(), iteration=P(), seed=P(),
        lbest_pos=P(pa, None) if with_locals else None,
        lbest_fit=P(pa) if with_locals else None,
    )


def _axes_tuple(particle_axes):
    return ((particle_axes,) if isinstance(particle_axes, str)
            else tuple(particle_axes))


def _n_shards(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def init_sharded_swarm(cfg: PSOConfig, seed: int, mesh: Mesh,
                       particle_axes=("data",)) -> SwarmState:
    """Initialize a swarm laid out over ``mesh`` without materializing it
    densely on one host: each shard constructs only its own slice via the
    counter RNG (index_offset), then the arrays are device_put with the
    swarm sharding."""
    cfg = cfg.resolved()
    axes = _axes_tuple(particle_axes)
    n_shards = _n_shards(mesh, axes)
    if cfg.particle_cnt % n_shards:
        raise ValueError(
            f"particle_cnt={cfg.particle_cnt} not divisible by {n_shards} shards")
    if n_shards == 1:
        # One shard owns everything: build the monolithic swarm directly so
        # the state is bit-identical to init_swarm (the shard_map-compiled
        # init fuses 1 ulp differently on XLA:CPU), then lay it out.
        state = init_swarm(cfg, seed)
        specs = swarm_pspec(axes if len(axes) > 1 else axes[0])
        return jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            state, specs)

    def per_shard():
        # Runs under shard_map: build the local slice from global indices.
        shard_id = jax.lax.axis_index(axes)
        local_n = cfg.particle_cnt // n_shards
        local = init_swarm(cfg, seed, n=local_n,
                           index_offset=shard_id * local_n)
        # Reconcile the global best across shards.
        gfit, gpos = _pmax_best(local.gbest_fit, local.gbest_pos, axes)
        return local._replace(gbest_fit=gfit, gbest_pos=gpos)

    specs = swarm_pspec(axes if len(axes) > 1 else axes[0])
    fn = _shard_map(per_shard, mesh, (), specs)
    return jax.jit(fn)()


_INT_MAX = jnp.iinfo(jnp.int32).max


def _pmax_best(fit: Array, pos: Array, axes) -> Tuple[Array, Array]:
    """All-reduce a (scalar fit, D-dim pos) pair to the global argmax.

    Communicates the scalar twice (max + masked-min for tie-broken
    ownership) and the position once, only from the winner — O(D) total,
    not O(N·D). Contract (tests/test_islands_ring.py):

      * the LOWEST shard index achieving the max fit owns the broadcast —
        ties are deterministic and every shard returns that owner's pos;
      * ``±inf`` fits participate normally (an all ``-inf`` swarm elects
        shard 0);
      * NaN guard: a NaN fit is treated as ``-inf`` and can never own the
        broadcast (an all-NaN swarm returns ``-inf`` and shard 0's pos)
        rather than poisoning ``gbest_pos`` with a zero sum.
    """
    fit = jnp.where(jnp.isnan(fit), -jnp.inf, fit)
    gfit = jax.lax.pmax(fit, axes)
    me = jax.lax.axis_index(axes)
    # Tie-break: lowest shard index that achieves the max owns the broadcast.
    winner = jax.lax.pmin(jnp.where(fit >= gfit, me, _INT_MAX), axes)
    contrib = jnp.where(me == winner, pos, jnp.zeros_like(pos))
    gpos = jax.lax.psum(contrib, axes)
    return gfit, gpos


def _ring_perm(n_shards: int):
    return [(i, (i + 1) % n_shards) for i in range(n_shards)]


def ring_exchange(gf: Array, gp: Array, owner: Array, axis, n_shards: int
                  ) -> Tuple[Array, Array, Array]:
    """One ring hop of the async island exchange.

    Pushes the shard's current known-best ``(fit, pos, owner)`` to its
    ring successor and folds the received candidate into the local view
    under the improvement predicate

        ``(recv_fit > fit) | (recv_fit == fit & recv_owner < owner)``

    so ties converge to the lowest originating shard everywhere, NaN never
    propagates (NaN compares false), and the O(D) position select applies
    only on actual improvement. Because each shard forwards the best it
    KNOWS (gossip), a value published anywhere reaches all ``n_shards``
    shards in at most ``n_shards - 1`` hops.
    """
    gf = jnp.where(jnp.isnan(gf), -jnp.inf, gf)
    perm = _ring_perm(n_shards)
    rf = jax.lax.ppermute(gf, axis, perm)
    rp = jax.lax.ppermute(gp, axis, perm)
    ro = jax.lax.ppermute(owner, axis, perm)
    better = (rf > gf) | ((rf == gf) & (ro < owner))
    return (jnp.where(better, rf, gf),
            jnp.where(better, rp, gp),
            jnp.where(better, ro, owner))


def make_distributed_run(cfg: PSOConfig, mesh: Mesh, iters: int,
                         variant: str = "queue",
                         exchange_interval: int = 1,
                         particle_axes=("data",),
                         local_step_fn=None,
                         sync_every: int = ASYNC_SYNC_EVERY,
                         n_blocks: Optional[int] = None):
    """Build a jitted ``run(state) -> state`` over the mesh.

    exchange_interval=1  → synchronous PPSO (reduction-equivalent semantics).
    exchange_interval=K  → island mode: K local iterations per global
                           exchange (queue-lock analogue at scale).
    ``iters % exchange_interval`` may be nonzero: the leftover iterations
    run as a shorter trailing round (RNG counters chain through unchanged)
    followed by a final exchange.

    ``variant="async"`` runs the RING path (module docstring): the
    shard-local loop is ``run_async`` (block-local bests carried in
    ``SwarmState.lbest_*``, publication every ``sync_every`` iterations)
    and the cross-shard exchange is a neighbor-only ``ring_exchange``
    instead of the ``_pmax_best`` barrier collective. ``sync_every`` must
    divide ``exchange_interval`` (it is clamped down to it when larger) so
    every exchange round keeps the same publication schedule as the
    uninterrupted single-chip run — with ONE shard the ring path is
    bit-identical to ``run_async`` (tests/test_islands_ring.py).

    ``local_step_fn(cfg, state) -> state`` overrides the shard-local step
    of the synchronous variants (e.g. the Pallas fused kernel from
    repro.kernels.ops); the async ring has its own chunked local loop.
    """
    cfg = cfg.resolved()
    axes = _axes_tuple(particle_axes)
    n_shards = _n_shards(mesh, axes)
    rounds, rem = divmod(iters, exchange_interval)
    specs = swarm_pspec(axes if len(axes) > 1 else axes[0])

    if variant == "async":
        if local_step_fn is not None:
            raise NotImplementedError(
                "variant='async' islands run the built-in jnp run_async "
                "local loop; local_step_fn only overrides sync variants")
        if len(axes) != 1:
            raise NotImplementedError(
                "the async island ring exchanges over a single mesh axis; "
                f"got particle_axes={axes}")
        return _make_async_ring_run(cfg, mesh, iters, exchange_interval,
                                    axes, sync_every, n_blocks, specs,
                                    n_shards)

    step = local_step_fn if local_step_fn is not None else STEP_FNS[variant]

    def shard_body(state: SwarmState) -> SwarmState:
        def local_span(s, k):
            return jax.lax.fori_loop(0, k, lambda _, t: step(cfg, t), s)

        def one_round(k):
            def body(_, s):
                # K purely-local iterations against the (possibly stale)
                # gbest, then the serialized publication collective.
                s = local_span(s, k)
                gfit, gpos = _pmax_best(s.gbest_fit, s.gbest_pos, axes)
                return s._replace(gbest_fit=gfit, gbest_pos=gpos)
            return body

        state = jax.lax.fori_loop(0, rounds, one_round(exchange_interval),
                                  state)
        if rem:
            state = one_round(rem)(0, state)
        return state

    fn = _shard_map(shard_body, mesh, (specs,), specs)
    return jax.jit(fn)


def _make_async_ring_run(cfg: PSOConfig, mesh: Mesh, iters: int,
                         exchange_interval: int, axes,
                         sync_every: int, n_blocks: Optional[int],
                         specs, n_shards: int):
    """The async island ring runner (see make_distributed_run)."""
    axis = axes[0]
    local_n = cfg.particle_cnt // n_shards
    nb = n_blocks or default_block_count(local_n)
    rounds, rem = divmod(iters, exchange_interval)
    # Keep every round's intra-island publication schedule aligned with the
    # uninterrupted run: sync points must land on round boundaries.
    sync_eff = min(sync_every, exchange_interval)
    if exchange_interval % sync_eff:
        raise ValueError(
            f"sync_every={sync_every} must divide "
            f"exchange_interval={exchange_interval} for async islands")
    out_specs = swarm_pspec(axes if len(axes) > 1 else axes[0],
                            with_locals=True)

    def shard_body(state: SwarmState) -> SwarmState:
        me = jax.lax.axis_index(axes).astype(jnp.int32)
        # One shard owns the whole swarm: a static None keeps the exact
        # single-chip run_async jaxpr (index arithmetic constant-folded),
        # which the bit-identity contract with run_async depends on.
        offset = None if n_shards == 1 else me * local_n
        lbp, lbf = init_async_locals(state, nb)
        state = state._replace(lbest_pos=lbp, lbest_fit=lbf)
        owner = me

        def exchange(s: SwarmState, owner):
            gf, gp, owner = ring_exchange(s.gbest_fit, s.gbest_pos, owner,
                                          axis, n_shards)
            # Pull the (possibly fresher) ring best into the block locals
            # so the next round's blocks steer toward it immediately.
            take = gf > s.lbest_fit
            lbf = jnp.where(take, gf, s.lbest_fit)
            lbp = jnp.where(take[:, None], gp[None, :], s.lbest_pos)
            return s._replace(gbest_fit=gf, gbest_pos=gp,
                              lbest_fit=lbf, lbest_pos=lbp), owner

        def one_round(k):
            def body(_, carry):
                # Barrier at round entry/exit: each round's local loop then
                # compiles exactly like a standalone run_async dispatch
                # (XLA cannot re-fuse across the exchange), which keeps the
                # one-shard ring bit-identical to single-chip run_async.
                s, owner = jax.lax.optimization_barrier(carry)
                prev = s.gbest_fit
                s = run_async(cfg, s, k, sync_every=sync_eff, n_blocks=nb,
                              phase=0, index_offset=offset)
                # A gbest raised during the local span is our discovery.
                owner = jnp.where(s.gbest_fit > prev, me, owner)
                return jax.lax.optimization_barrier(exchange(s, owner))
            return body

        carry = (state, owner)
        if rounds:
            carry = jax.lax.fori_loop(
                0, rounds, one_round(exchange_interval), carry)
        if rem:
            carry = one_round(rem)(0, carry)
        state, owner = carry
        # Drain: n_shards - 1 exchange-only hops complete the propagation of
        # every island's final best — afterwards gbest is identical on all
        # shards and equals max over all pbests (final-flush invariant).
        for _ in range(n_shards - 1):
            state, owner = exchange(state, owner)
        return state

    fn = _shard_map(shard_body, mesh, (specs,), out_specs)
    return jax.jit(fn)


def gather_swarm(state: SwarmState) -> SwarmState:
    """Fetch a fully-replicated host copy (for checkpointing / inspection)."""
    return jax.tree.map(lambda x: jax.device_get(x), state)
