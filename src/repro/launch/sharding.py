"""Sharding policy: PartitionSpecs for params, optimizer states, batches and
decode caches on the production meshes (DESIGN.md §6).

Baseline policy (uniform, divisibility-guarded):
  * weight matrices — last dim over "model" (TP), previous dim over "data"
    (FSDP); leading stack dims (layer/group/expert) unsharded; vectors
    replicated. The "pod" axis is pure DP: params replicated across pods,
    gradients all-reduced (XLA inserts the collective because the batch is
    sharded over pod while params are not).
  * batch-like arrays — first dim over ("pod","data").
  * decode KV caches — batch over "data" when divisible, cache sequence
    over "model" (context parallelism); long_500k (batch=1) re-shards the
    sequence over ("data","model").
An axis is applied only when the dim divides the mesh extent — the policy
is total over every (arch × shape × mesh) cell by construction.

Per-arch overrides (the §Perf hillclimb levers) are expressed via
``rules``-dict entries keyed by path substring.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig
from .mesh import data_axes


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= mesh.shape[a]
        return dim % total == 0
    return dim % mesh.shape[axis] == 0


def _matrix_spec(shape, mesh: Mesh, n_stack: int,
                 model_axis="model", data_axis="data") -> P:
    """Generic weight rule: trailing dim → model, the one before → data."""
    ndim = len(shape)
    spec = [None] * ndim
    if ndim - n_stack >= 1:
        last = ndim - 1
        if _fits(shape[last], mesh, model_axis):
            spec[last] = model_axis
    if ndim - n_stack >= 2:
        prev = ndim - 2
        if _fits(shape[prev], mesh, data_axis):
            spec[prev] = data_axis
    return P(*spec)


def _count_stack_dims(path_str: str, cfg: ArchConfig) -> int:
    """Leading non-matmul dims: layer stacks, xlstm groups, moe experts."""
    n = 0
    if "layers" in path_str or "enc_layers" in path_str or "dec_layers" in path_str:
        n += 1
        if "['m']" in path_str and cfg.xlstm:
            n += 1                              # [G, g-1, ...]
    if "moe" in path_str and ("w_in" in path_str or "w_out" in path_str
                              or "w_gate" in path_str):
        n += 1                                  # expert dim
    return n


def param_pspecs(cfg: ArchConfig, params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching an (abstract) param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        shape = leaf.shape
        if len(shape) <= 1 + _count_stack_dims(ps, cfg):
            # vectors (norms, biases) possibly stacked: replicate
            specs.append(P())
            continue
        if cfg.moe and "moe" in ps and any(
                w in ps for w in ("w_in", "w_out", "w_gate")) \
                and cfg.moe_expert_sharding == "ep":
            # expert parallelism: E over model; FSDP the wider matmul dim
            nstack = _count_stack_dims(ps, cfg) - 1   # E handled explicitly
            spec = [None] * len(shape)
            e_dim = nstack                            # [..stack.., E, a, b]
            if _fits(shape[e_dim], mesh, "model"):
                spec[e_dim] = "model"
            if _fits(shape[e_dim + 1], mesh, "data"):
                spec[e_dim + 1] = "data"
            specs.append(P(*spec))
            continue
        if "embed" in ps or "unembed" in ps:
            # [V, d] / [d, V]: vocab→model, d→data
            big = 0 if shape[0] >= shape[1] else 1
            spec = [None, None]
            if _fits(shape[big], mesh, "model"):
                spec[big] = "model"
            if _fits(shape[1 - big], mesh, "data"):
                spec[1 - big] = "data"
            specs.append(P(*spec))
            continue
        if cfg.row_parallel_out and any(w in ps for w in ("wo", "w_out")):
            # Megatron row-parallel: contraction dim (ff / H*hd) over model
            # so it matches the TP layout of the incoming activations;
            # output dim FSDP over data. Avoids activation reshards at
            # every down-projection (§Perf iteration on qwen1.5-110b).
            ns = _count_stack_dims(ps, cfg)
            nd = len(shape)
            spec = [None] * nd
            if _fits(shape[nd - 2], mesh, "model"):
                spec[nd - 2] = "model"
            if _fits(shape[nd - 1], mesh, "data"):
                spec[nd - 1] = "data"
            specs.append(P(*spec))
            continue
        specs.append(_matrix_spec(shape, mesh, _count_stack_dims(ps, cfg)))
    return jax.tree.unflatten(treedef, specs)


def opt_pspecs(cfg: ArchConfig, opt_shape: Any, mesh: Mesh,
               param_specs: Any) -> Any:
    """Optimizer state specs: mirror the param spec where shapes match;
    adafactor's factored vectors inherit the surviving dims."""
    # Build a path→spec map from params for lookup by suffix.
    pflat, _ = jax.tree_util.tree_flatten_with_path(param_specs)
    by_path = {jax.tree_util.keystr(p): s for p, s in pflat}

    oflat, otreedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = []
    for path, leaf in oflat:
        ps = jax.tree_util.keystr(path)
        # strip the optimizer wrapper levels: [...]['inner']['m']<param path>
        match = None
        for ppath, spec in by_path.items():
            if ps.endswith(ppath) or ppath in ps:
                match = (ppath, spec)
                break
        if leaf.ndim == 0:
            out.append(P())
        elif match and len(match[1]) == leaf.ndim:
            out.append(match[1])
        elif match and len(match[1]) == leaf.ndim + 1:
            # factored row/col: drop the missing trailing/leading entry
            spec = list(match[1])
            if ps.endswith("['vr']") or "vr" in ps.rsplit("[", 1)[-1]:
                out.append(P(*spec[:-1]))
            else:                                 # vc: drops dim -2
                out.append(P(*(spec[:-2] + spec[-1:])))
        else:
            out.append(P())
    return jax.tree.unflatten(otreedef, out)


def batch_pspecs(cfg: ArchConfig, shape_name: str, mesh: Mesh) -> Any:
    cell = SHAPES[shape_name]
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b = cell.global_batch
    bdp = dp if _fits(b, mesh, dp) else None
    if cell.kind in ("train", "prefill"):
        spec: Dict[str, P] = {"tokens": P(bdp, None), "labels": P(bdp, None)}
        if cfg.encdec:
            spec["frames"] = P(bdp, None, None)
        if cfg.vision_prefix:
            spec["vision_embeds"] = P(bdp, None, None)
        return spec
    return {"token": P(bdp, None), "cache_len": P()}


def cache_pspecs(cfg: ArchConfig, cache_shape: Any, shape_name: str,
                 mesh: Mesh) -> Any:
    """Decode caches: [L, B, S, ...] → B over data, S over model (context
    parallelism); batch=1 (long_500k) shards S over (data, model)."""
    cell = SHAPES[shape_name]

    def spec_for(path, leaf):
        shape = leaf.shape
        ps = jax.tree_util.keystr(path)
        if cfg.xlstm or "ssm" in ps or "['s']" in ps:
            # recurrent states: shard batch dim if possible, else replicate
            spec = [None] * len(shape)
            for i, d in enumerate(shape):
                if d == cell.global_batch and _fits(d, mesh, "data"):
                    spec[i] = "data"
                    break
            return P(*spec)
        # KV-like: [L, B, S, K, hd] or [L, B, S, r]
        spec = [None] * len(shape)
        b_dim, s_dim = 1, 2
        if cfg.swa_window_decode and cfg.swa_window:
            # windowed decode reads are dynamic slices along S — keep the
            # cache unsharded on S (batch-sharded only) so the slice stays
            # shard-local (§Perf hymba decode iteration).
            if _fits(shape[b_dim], mesh, "data"):
                spec[b_dim] = "data"
            return P(*spec)
        seq_axis: Any = "model"
        if cell.global_batch == 1:
            seq_axis = tuple(a for a in mesh.axis_names)  # all axes
            if not _fits(shape[s_dim], mesh, seq_axis):
                seq_axis = ("data", "model")
        elif _fits(shape[b_dim], mesh, "data"):
            spec[b_dim] = "data"
        if _fits(shape[s_dim], mesh, seq_axis):
            spec[s_dim] = seq_axis
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree.unflatten(treedef, [spec_for(p, l) for p, l in flat])


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
