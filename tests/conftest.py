import numpy as np
import pytest

# NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
# set here — smoke tests and benchmarks must see the real single CPU device.
# Only launch/dryrun.py fakes 512 devices (and only in its own process).


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked slow (long-iteration PSO "
                          "runs, LM-substrate smoke compiles)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test, excluded from tier-1 unless --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: run with --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture(scope="session")
def rng_np():
    return np.random.default_rng(0)
