"""cuPSO core: the paper's contribution as a composable JAX module."""
from .fitness import FITNESS_FNS, FITNESS_IDS, DEFAULT_BOUNDS
from .pso import (PSOConfig, SwarmState, STEP_FNS, init_swarm, run, solve,
                  step_queue, step_queue_lock, step_reduction)
from .serial import SerialSwarm, run_serial_fast
from .topology import (best_of_swarms, init_multi_swarm, run_multi_swarm,
                       run_ring, step_ring)
from .tuner import PSOTuner, SearchDim, TunerResult

__all__ = [
    "FITNESS_FNS", "FITNESS_IDS", "DEFAULT_BOUNDS",
    "PSOConfig", "SwarmState", "STEP_FNS", "init_swarm", "run", "solve",
    "step_queue", "step_queue_lock", "step_reduction",
    "SerialSwarm", "run_serial_fast",
    "run_ring", "step_ring", "init_multi_swarm", "run_multi_swarm",
    "best_of_swarms",
    "PSOTuner", "SearchDim", "TunerResult",
]
