"""Batched multi-swarm engine: many independent PSO solves in ONE device
program (DESIGN: the scaling layer on top of the paper's single-swarm
queue/queue-lock algorithms).

The paper (arXiv 2205.01313) amortizes aggregation cost *within* one swarm;
serving-scale workloads (tuning sweeps, per-request optimizations) need to
amortize across *many* swarms — different seeds, and optionally different
(w, c1, c2) hyper-parameters — without paying one dispatch + compile per
swarm. This module vmaps the three step variants from ``repro.core.pso``
over a leading swarm axis, so a batch of S solves costs one compile and one
dispatch per ``run_many`` call. PSO-PS (arXiv 2009.03816) makes the same
move to keep distributed populations device-resident.

RNG stream convention
---------------------
Each swarm carries its own ``seed`` and the counter RNG is keyed by
``(seed, iteration, stream, element_index)`` with element indices local to
the swarm (particle * D + dim, exactly the single-swarm ``index_offset=0``
convention of ``init_swarm``/``_advance``). Because vmap changes neither the
counters nor the arithmetic, row ``s`` of a batch is **bit-identical** to a
standalone ``solve(cfg, seeds[s])`` — batching is a pure scheduling
transform, never a semantic one. This is asserted exactly (``==`` on
float bits) in tests/test_multi_swarm.py.

Caveat (CPU backend): XLA:CPU chooses loop-body fusion + FMA contraction
per compiled shape, and for a few tiny batch shapes the batched program
rounds the velocity chain one ulp differently from the standalone program,
which chaotic PSO dynamics then amplify. Root cause (isolated at S=4,
dim=3, n=64, sphere): ``vel`` diverges on the SECOND iteration inside one
``fori_loop`` program while separate per-iteration dispatches stay
bit-identical — i.e. the in-loop fusion, not the vmapped step, makes the
shape-dependent contraction choice; and pinning the loop carry with
``optimization_barrier`` merely moves the anomaly to other shapes (S=3).
The pin therefore lives at the dispatch level: ``run_many`` pads batches
smaller than ``MIN_VALIDATED_SWARMS`` (= 8) with dead rows and slices the
result back, so every dispatch runs a validated program shape and the
serving layer buckets at 4 again. This also constrains step-function
design: a ``lax.cond`` carrying an [N, D] branch output changes XLA's
fusion clustering enough to break the identity at *every* batch size (see
``step_queue_lock``).

Per-swarm hyper-parameters
--------------------------
``coeffs=(w, c1, c2)`` (each shape ``[S]``) rides the same vmap, which is
what lets ``repro.core.tuner.make_solve_many_fitness`` evaluate a whole
population of PSO hyper-parameter candidates as one batched solve.

The Pallas counterpart (one ``pallas_call`` advancing S swarms x iters with
per-swarm gbest buffers) is ``repro.kernels.ops.run_queue_lock_fused_batch``.

Problems: ``cfg.fitness`` may be a registered benchmark name or a
first-class ``repro.core.problem.Problem`` (user objective, per-dimension
bounds, min/max sense) — the vmapped step functions and the batched Pallas
kernels both resolve it through the same registry/adapter machinery, so a
batch of custom-objective solves is one device program too. The serving
front end (``repro.launch.serve``) relies on this plus content-hashed
compile keys to batch identical custom objectives safely.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .problem import Problem, resolve_problem
from .pso import (ASYNC_SYNC_EVERY, HeteroRow, PSOConfig, STEP_FNS,
                  SwarmState, init_swarm, run_async)

Array = jnp.ndarray


class ProblemRows(NamedTuple):
    """Per-row problem descriptors for a heterogeneous batch.

    Built by ``problem_rows`` against a *static* dispatch table (a tuple of
    registered ``Problem``s, by default the built-in benchmark suite):
    ``fid[s]`` indexes the table, and the bound columns replicate exactly
    the arithmetic ``PSOConfig.resolved()`` would have produced for row
    ``s``'s problem (Python-float64 ``0.5 * (hi - lo)`` then a cast), so a
    heterogeneous row is bit-identical to the standalone solve of its
    problem. ``sense``/``cmode``/``pweight`` are descriptor metadata —
    constant for the built-in table (max-sense, unconstrained, weight 0) —
    reserved for the two-tier custom-objective follow-on where penalty-mode
    registry entries join the table.
    """

    fid: Array      # [S] int32 — index into the static dispatch table
    lo: Array       # [S, D] lower box bound per row
    hi: Array       # [S, D] upper box bound per row
    mv: Array       # [S, D] velocity clamp per row
    sense: Array    # [S] int32: +1 max / -1 min (baked into the branch)
    cmode: Array    # [S] int32: 0 unconstrained / 1 penalty
    pweight: Array  # [S] penalty weight (0 when unconstrained)

    @property
    def swarm_cnt(self) -> int:
        return self.fid.shape[0]



def hetero_fid(fitness) -> Optional[int]:
    """Index of ``fitness`` in the built-in dispatch table, else None.

    The coalescing eligibility test: a request whose problem IS one of the
    registered built-ins (dataclass equality — ``fn`` by identity, so
    registry-resolved instances match and a user's re-built lookalike does
    not) can ride a shared heterogeneous batch; everything else keeps
    content-hash isolation.
    """
    from .fitness import BUILTIN_PROBLEMS
    try:
        prob = resolve_problem(fitness)
    except (KeyError, TypeError):
        return None
    for i, p in enumerate(BUILTIN_PROBLEMS):
        if prob == p:
            return i
    return None


def _row_bound(v, d: int, dt) -> np.ndarray:
    """Resolved Bound (scalar or per-dim tuple) -> [D] host array."""
    if isinstance(v, tuple):
        return np.asarray(v, dt)
    return np.full((d,), v, dt)


def problem_rows(problems: Sequence, dim: int, dtype: str = "float32",
                 table: Optional[Tuple[Problem, ...]] = None
                 ) -> Tuple[ProblemRows, Tuple[Problem, ...]]:
    """Build the per-row descriptors for a heterogeneous batch.

    ``problems`` are names or ``Problem``s, each of which must appear in
    ``table`` (default: the built-in benchmark suite) — the static branch
    tuple the engines ``lax.switch`` over. Table entries must be
    unconstrained or penalty-mode (the penalty rides ``max_fn``):
    projection/repair entries would need per-row init/advance hooks and are
    rejected. Returns ``(rows, table)``.
    """
    from .fitness import BUILTIN_PROBLEMS
    table = BUILTIN_PROBLEMS if table is None else tuple(table)
    for p in table:
        if p.projection_fn is not None or (
                p.constrained and p.constraints.mode == "repair"):
            raise ValueError(
                f"problem {p.name!r}: projection/repair constraint modes "
                "cannot join a heterogeneous dispatch table (per-row "
                "init/advance hooks); solve it in its own batch")
    dt = np.dtype(dtype)
    fid, lo, hi, mv, sense, cmode, pw = [], [], [], [], [], [], []
    for f in problems:
        prob = resolve_problem(f)
        try:
            i = table.index(prob)
        except ValueError:
            raise ValueError(
                f"problem {prob.name!r} is not in the heterogeneous "
                "dispatch table; solve it in its own (content-keyed) batch"
            ) from None
        # Exactly the standalone bound resolution (max_v = 0.5 * (hi - lo)
        # in Python float64, then one cast) — the row-bit-identity contract.
        r = PSOConfig(dim=dim, fitness=prob, dtype=dtype).resolved()
        fid.append(i)
        lo.append(_row_bound(r.min_pos, dim, dt))
        hi.append(_row_bound(r.max_pos, dim, dt))
        mv.append(_row_bound(r.max_v, dim, dt))
        sense.append(1 if prob.sense == "max" else -1)
        cset = prob.constraints
        penalized = cset is not None and cset.mode == "penalty"
        cmode.append(1 if penalized else 0)
        pw.append(cset.weight if penalized else 0.0)
    return ProblemRows(
        fid=jnp.asarray(fid, jnp.int32),
        lo=jnp.asarray(np.stack(lo)), hi=jnp.asarray(np.stack(hi)),
        mv=jnp.asarray(np.stack(mv)),
        sense=jnp.asarray(sense, jnp.int32),
        cmode=jnp.asarray(cmode, jnp.int32),
        pweight=jnp.asarray(np.asarray(pw, dt)),
    ), table


def _hetero_rows(rows: ProblemRows) -> HeteroRow:
    """The engine-facing slice of the descriptors (vmaps to per-row)."""
    return HeteroRow(fid=rows.fid, lo=rows.lo, hi=rows.hi, mv=rows.mv)


class SwarmBatch(NamedTuple):
    """S independent swarms, stacked on a leading axis.

    Field order matches ``SwarmState`` exactly so the two convert by
    positional splat (``SwarmBatch(*state_pytree)``) and vmapped SwarmState
    functions apply directly.
    """

    pos: Array        # [S, N, D]
    vel: Array        # [S, N, D]
    fit: Array        # [S, N]
    pbest_pos: Array  # [S, N, D]
    pbest_fit: Array  # [S, N]
    gbest_pos: Array  # [S, D]
    gbest_fit: Array  # [S]
    iteration: Array  # [S] int32
    seed: Array       # [S] uint32
    lbest_pos: Optional[Array] = None  # [S, nb, D] async block-local bests
    lbest_fit: Optional[Array] = None  # [S, nb]

    @property
    def swarm_cnt(self) -> int:
        return self.gbest_fit.shape[0]


def init_batch(cfg: PSOConfig, seeds, rows: Optional[ProblemRows] = None,
               table: Optional[Tuple[Problem, ...]] = None) -> SwarmBatch:
    """Initialize S swarms, one per entry of ``seeds``.

    Row ``s`` is bit-identical to ``init_swarm(cfg, seeds[s])`` (see module
    docstring: the RNG counters are untouched by the vmap). With
    ``rows``/``table`` (heterogeneous batch) each row instead initializes
    against its own problem's bounds and objective.
    """
    cfg = cfg.resolved()
    seeds = jnp.asarray(seeds)
    if rows is None:
        return SwarmBatch(*jax.vmap(lambda sd: init_swarm(cfg, sd))(seeds))
    fn = jax.vmap(lambda sd, f: init_swarm(cfg, sd, hetero=(table, f)))
    return SwarmBatch(*fn(seeds, _hetero_rows(rows)))


def batch_row(batch: SwarmBatch, s: int) -> SwarmState:
    """Extract swarm ``s`` as a standalone SwarmState."""
    return SwarmState(*(jax.tree_util.tree_map(lambda a: a[s], tuple(batch))))


def stack_states(states: Sequence[SwarmState]) -> SwarmBatch:
    """Stack standalone swarms into a batch (inverse of ``batch_row``)."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)
    return SwarmBatch(*stacked)


def set_batch_row(batch: SwarmBatch, s: int, state: SwarmState) -> SwarmBatch:
    """Splice a standalone swarm into row ``s`` (the scheduler's admission
    primitive: a continuous-batching lane swaps a finished row for a fresh
    request without restarting the program).

    The batch's pytree structure is fixed by the in-flight compiled
    program, so ``state`` must match it field-for-field — in particular an
    async batch carries ``lbest_*`` and the admitted row must too (build
    it with ``repro.core.pso.init_swarm_async``).
    """
    if (batch.lbest_fit is None) != (state.lbest_fit is None):
        raise ValueError(
            "row/batch lbest structure mismatch: splice rows built with "
            "init_swarm_async into async batches (and bare init_swarm "
            "rows into synchronous ones)")
    return SwarmBatch(*jax.tree_util.tree_map(
        lambda a, v: a.at[s].set(v), tuple(batch), tuple(state)))


def set_problem_row(rows: ProblemRows, s: int, one: ProblemRows
                    ) -> ProblemRows:
    """Splice row 0 of a 1-row descriptor set into row ``s`` of ``rows``.

    The hetero half of lane admission: descriptors are TRACED operands of
    the batched program (only the table is static), so retargeting a lane
    slot at a different registered problem recompiles nothing.
    """
    return ProblemRows(*jax.tree_util.tree_map(
        lambda a, v: a.at[s].set(v[0]), tuple(rows), tuple(one)))


@partial(jax.jit,
         static_argnames=("cfg", "iters", "sync_every", "phase", "table",
                          "n_blocks"))
def _run_many_async(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                    sync_every: int,
                    coeffs: Optional[Tuple[Array, Array, Array]] = None,
                    phase: int = 0, rows: Optional[ProblemRows] = None,
                    table=None,
                    n_blocks: Optional[int] = None) -> SwarmBatch:
    hr = None if rows is None else _hetero_rows(rows)
    if coeffs is None and hr is None:
        fn = jax.vmap(lambda s: run_async(
            cfg, s, iters, sync_every=sync_every, phase=phase,
            n_blocks=n_blocks))
        return SwarmBatch(*fn(SwarmState(*batch)))
    if coeffs is None:
        fn = jax.vmap(lambda s, f: run_async(
            cfg, s, iters, sync_every=sync_every, phase=phase,
            hetero_row=f, table=table, n_blocks=n_blocks))
        return SwarmBatch(*fn(SwarmState(*batch), hr))
    w, c1, c2 = (jnp.asarray(c) for c in coeffs)
    if hr is None:
        fn = jax.vmap(lambda s, w_, c1_, c2_: run_async(
            cfg, s, iters, sync_every=sync_every, coeffs=(w_, c1_, c2_),
            phase=phase, n_blocks=n_blocks))
        return SwarmBatch(*fn(SwarmState(*batch), w, c1, c2))
    fn = jax.vmap(lambda s, w_, c1_, c2_, f: run_async(
        cfg, s, iters, sync_every=sync_every, coeffs=(w_, c1_, c2_),
        phase=phase, hetero_row=f, table=table, n_blocks=n_blocks))
    return SwarmBatch(*fn(SwarmState(*batch), w, c1, c2, hr))


def _batch_phases(batch: SwarmBatch, sync_every: int) -> Tuple[int, ...]:
    """Per-swarm resume phases (``iteration % sync_every``), host-side.

    ``run_async``'s publication schedule aligns to absolute iteration
    numbers via a *static* ``phase``; under vmap the per-row iteration is a
    tracer, so the single-swarm auto-derivation silently fell back to 0 and
    a resumed batched async solve restarted every swarm's staleness window
    (PR 5 known bug). The phases are read off the concrete batch before jit
    entry instead. Under a trace (run_many called inside jit) the counters
    are unreadable — fall back to 0, the historical relative behavior.
    """
    se = max(1, sync_every)
    try:
        return tuple(int(i) % se for i in batch.iteration)
    except (TypeError, jax.errors.ConcretizationTypeError,
            jax.errors.TracerIntegerConversionError):
        return (0,) * batch.swarm_cnt


def _batched_step(cfg: PSOConfig, variant: str, coeffs, hr, table):
    """One vmapped step over the batch, closed over the static extras
    (per-swarm coeffs and/or hetero rows) — shared by the fori_loop runner
    and the history-recording scan so both trace the same computation."""
    step = STEP_FNS[variant]
    if coeffs is None and hr is None:
        step_b = jax.vmap(lambda s: step(cfg, s))
        return lambda b: SwarmBatch(*step_b(SwarmState(*b)))
    if hr is None:
        w, c1, c2 = (jnp.asarray(c) for c in coeffs)
        step_b = jax.vmap(
            lambda s, w_, c1_, c2_: step(cfg, s, coeffs=(w_, c1_, c2_)))
        return lambda b: SwarmBatch(*step_b(SwarmState(*b), w, c1, c2))
    if coeffs is None:
        step_b = jax.vmap(lambda s, h: step(cfg, s, hetero=(table, h)))
        return lambda b: SwarmBatch(*step_b(SwarmState(*b), hr))
    w, c1, c2 = (jnp.asarray(c) for c in coeffs)
    step_b = jax.vmap(
        lambda s, w_, c1_, c2_, h: step(cfg, s, coeffs=(w_, c1_, c2_),
                                        hetero=(table, h)))
    return lambda b: SwarmBatch(*step_b(SwarmState(*b), w, c1, c2, hr))


@partial(jax.jit, static_argnames=("cfg", "iters", "variant", "table"))
def _run_many_stepped(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                      variant: str,
                      coeffs: Optional[Tuple[Array, Array, Array]] = None,
                      rows: Optional[ProblemRows] = None, table=None
                      ) -> SwarmBatch:
    hr = None if rows is None else _hetero_rows(rows)
    step_b = _batched_step(cfg, variant, coeffs, hr, table)
    return jax.lax.fori_loop(0, iters, lambda _, b: step_b(b), batch)


@partial(jax.jit, static_argnames=("cfg", "iters", "variant", "table"))
def _run_many_stepped_history(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                              variant: str,
                              coeffs=None, rows: Optional[ProblemRows] = None,
                              table=None):
    """``_run_many_stepped`` that also stacks the per-iteration gbest
    trajectory: one scan over the same vmapped step, collecting
    ``gbest_fit`` [iters, S] (and the recorded gbest's aggregate constraint
    violation for constrained homogeneous batches — hetero rows are
    built-in table entries, so their violations are identically zero)."""
    hr = None if rows is None else _hetero_rows(rows)
    step_b = _batched_step(cfg, variant, coeffs, hr, table)
    vf = None if rows is not None else cfg.problem.violation_fn

    def body(b, _):
        b = step_b(b)
        v = (jax.vmap(vf)(b.gbest_pos) if vf is not None
             else jnp.zeros_like(b.gbest_fit))
        return b, (b.gbest_fit, v)

    batch, (fits, viols) = jax.lax.scan(body, batch, xs=None, length=iters)
    return batch, fits, viols


# Smallest batch row count whose compiled program is covered by the
# row-bit-identity validation. XLA:CPU picks loop-body fusion (and with it
# FMA contraction of the velocity chain) per compiled batch shape; for a few
# tiny batches the choice rounds 1 ulp differently from the standalone
# program (root-caused at S=4, dim=3, n=64, sphere: `vel` diverges on the
# second in-loop iteration while separate per-iteration dispatches match).
# Rather than chase codegen across every tiny shape, sub-validated batches
# ride the smallest validated shape with dead rows (sliced off afterwards),
# which also keeps the jit cache to one program for all S < 8.
MIN_VALIDATED_SWARMS = 8


def _pad_rows(batch: SwarmBatch, target: int) -> SwarmBatch:
    """Pad a batch to ``target`` rows by replicating row 0 (dead rows)."""
    k = target - batch.swarm_cnt
    return SwarmBatch(*jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (k,) + a.shape[1:])]),
        tuple(batch)))


def _pad_batch_inputs(batch: SwarmBatch, coeffs, rows, target: int):
    """Pad the batch AND its per-row companions (coeffs, hetero rows) to
    ``target`` rows (replicating row 0), for the MIN_VALIDATED_SWARMS
    dead-row dispatch."""
    s_cnt = batch.swarm_cnt
    batch = _pad_rows(batch, target)
    if coeffs is not None:
        coeffs = tuple(
            jnp.concatenate([jnp.asarray(c),
                             jnp.broadcast_to(jnp.asarray(c)[:1],
                                              (target - s_cnt,))])
            for c in coeffs)
    if rows is not None:
        rows = ProblemRows(*jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.broadcast_to(a[:1],
                                     (target - s_cnt,) + a.shape[1:])]),
            tuple(rows)))
    return batch, coeffs, rows


def run_many(cfg: PSOConfig, batch: SwarmBatch, iters: int,
             variant: str = "queue",
             coeffs: Optional[Tuple[Array, Array, Array]] = None,
             sync_every: int = ASYNC_SYNC_EVERY,
             rows: Optional[ProblemRows] = None,
             table: Optional[Tuple[Problem, ...]] = None,
             n_blocks: Optional[int] = None) -> SwarmBatch:
    """Advance every swarm of the batch ``iters`` iterations in lockstep.

    One fori_loop over one vmapped step: a single compiled program, a single
    dispatch, no host round-trips between iterations or between swarms.
    ``variant="async"`` vmaps the whole ``run_async`` loop nest instead (it
    carries block-local bests across iterations, so it cannot ride the
    per-step registry); ``run_async`` is cond-free, so the vmap is a pure
    scheduling transform and per-row bit-identity holds like the others.
    A thin dispatcher over the jitted implementations, so synchronous
    variants never key their jit cache on the (irrelevant) ``sync_every``.

    Batches smaller than ``MIN_VALIDATED_SWARMS`` are padded to it with
    dead rows and sliced back, so every dispatch runs a program shape whose
    row-bit-identity is validated (see the constant's comment — the S=4
    XLA:CPU contraction anomaly), and the serving layer can bucket at 4
    again.
    """
    cfg = cfg.resolved()
    s_cnt = batch.swarm_cnt
    if s_cnt < MIN_VALIDATED_SWARMS:
        batch, coeffs, rows = _pad_batch_inputs(batch, coeffs, rows,
                                                MIN_VALIDATED_SWARMS)
        out = run_many(cfg, batch, iters, variant, coeffs, sync_every,
                       rows, table, n_blocks)
        return SwarmBatch(*jax.tree_util.tree_map(lambda a: a[:s_cnt],
                                                  tuple(out)))
    if variant == "async":
        phases = _batch_phases(batch, sync_every)
        uniq = sorted(set(phases))
        if len(uniq) == 1:
            return _run_many_async(cfg, batch, iters, sync_every, coeffs,
                                   uniq[0], rows, table, n_blocks)
        # Mixed resume points (rows checkpointed at different iterations):
        # phase is static per compiled program, so dispatch one padded
        # program per phase group and scatter the rows back in place.
        out_rows = [None] * s_cnt
        for ph in uniq:
            idx = [i for i, p in enumerate(phases) if p == ph]
            take = jnp.asarray(idx)
            sub = SwarmBatch(*jax.tree_util.tree_map(
                lambda a: a[take], tuple(batch)))
            sub_coeffs = (tuple(jnp.asarray(c)[take] for c in coeffs)
                          if coeffs is not None else None)
            sub_rows = (ProblemRows(*jax.tree_util.tree_map(
                lambda a: a[take], tuple(rows)))
                if rows is not None else None)
            out = run_many(cfg, sub, iters, variant, sub_coeffs, sync_every,
                           sub_rows, table, n_blocks)
            for j, i in enumerate(idx):
                out_rows[i] = jax.tree_util.tree_map(lambda a: a[j],
                                                     tuple(out))
        return SwarmBatch(*jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *out_rows))
    if batch.lbest_fit is not None:
        # mirror run(): sync variants advance gbest without maintaining the
        # async block-local cache — drop it so a later async run re-seeds
        batch = batch._replace(lbest_pos=None, lbest_fit=None)
    return _run_many_stepped(cfg, batch, iters, variant, coeffs, rows, table)


def run_many_with_history(cfg: PSOConfig, batch: SwarmBatch, iters: int,
                          variant: str = "queue",
                          coeffs: Optional[Tuple[Array, Array, Array]] = None,
                          sync_every: int = ASYNC_SYNC_EVERY,
                          rows: Optional[ProblemRows] = None,
                          table: Optional[Tuple[Problem, ...]] = None,
                          n_blocks: Optional[int] = None):
    """``run_many`` that also records every row's gbest trajectory.

    Returns ``(batch, (iterations, gbest_fits, violations))`` with
    ``iterations`` a length-K tuple of absolute iteration numbers and
    ``gbest_fits`` a ``[K, S]`` array — one sample per sync point per row,
    mirroring the single-swarm ``run_with_history`` semantics: every
    iteration for the synchronous variants (one scanned program), every
    publication boundary for ``async`` (the vmapped loop nest is segmented
    at sync points, which the checkpoint/resume machinery makes
    bit-identical to the uninterrupted run). ``violations`` is ``[K, S]``
    for constrained homogeneous batches, else None (hetero rows are
    built-in table entries — unconstrained or static-penalty). Assumes the
    lockstep batches the facades build (all rows at one iteration count).
    """
    cfg = cfg.resolved()
    constrained = rows is None and cfg.problem.constrained
    if iters <= 0:
        empty = jnp.zeros((0, batch.swarm_cnt), batch.gbest_fit.dtype)
        return batch, ((), empty, empty if constrained else None)
    if variant == "async":
        vf = None if rows is not None else cfg.problem.violation_fn
        its, fits, viols = [], [], []
        done = 0
        while done < iters:
            k = min(max(1, sync_every), iters - done)
            batch = run_many(cfg, batch, k, variant, coeffs, sync_every,
                             rows, table, n_blocks)
            done += k
            its.append(int(batch.iteration[0]))
            fits.append(batch.gbest_fit)
            if vf is not None:
                viols.append(jax.vmap(vf)(batch.gbest_pos))
        return batch, (tuple(its), jnp.stack(fits),
                       jnp.stack(viols) if constrained else None)
    if batch.lbest_fit is not None:
        batch = batch._replace(lbest_pos=None, lbest_fit=None)
    s_cnt = batch.swarm_cnt
    if s_cnt < MIN_VALIDATED_SWARMS:
        batch, coeffs, rows = _pad_batch_inputs(batch, coeffs, rows,
                                                MIN_VALIDATED_SWARMS)
        out, (its, fits, viols) = run_many_with_history(
            cfg, batch, iters, variant, coeffs, sync_every, rows, table,
            n_blocks)
        out = SwarmBatch(*jax.tree_util.tree_map(lambda a: a[:s_cnt],
                                                 tuple(out)))
        return out, (its, fits[:, :s_cnt],
                     None if viols is None else viols[:, :s_cnt])
    start = int(batch.iteration[0])
    batch, fits, viols = _run_many_stepped_history(cfg, batch, iters,
                                                   variant, coeffs, rows,
                                                   table)
    its = tuple(range(start + 1, start + iters + 1))
    return batch, (its, fits, viols if constrained else None)


def solve_many(cfg: PSOConfig, seeds, iters: int = 1000,
               variant: str = "queue",
               coeffs: Optional[Tuple[Array, Array, Array]] = None,
               sync_every: int = ASYNC_SYNC_EVERY,
               problems: Optional[Sequence] = None,
               n_blocks: Optional[int] = None) -> SwarmBatch:
    """Batched one-shot: init + run for S independent solves.

    ``seeds`` is any int sequence/array of length S; ``variant`` is one of
    ``reduction | queue | queue_lock | async``; ``coeffs`` optionally
    supplies per-swarm ``(w, c1, c2)`` arrays; ``sync_every`` is the async
    variant's publication interval. Row ``s`` of the result is
    bit-identical to ``solve(cfg, seeds[s], iters, variant)`` when
    ``coeffs`` is None.

    ``problems`` (length S, names or registered built-in ``Problem``s)
    makes the batch *heterogeneous*: row ``s`` solves ``problems[s]`` —
    its own objective (dispatched by ``lax.switch`` inside one compiled
    program) and its own box bounds — and is bit-identical to
    ``solve(cfg_s, seeds[s], iters, variant)`` with ``cfg_s`` the same
    config pointed at ``problems[s]``. ``cfg.fitness`` is ignored for the
    rows (it only keys the compile cache — the serving layer pins it to a
    canonical value so every mix shares one program) and explicit
    ``min_pos``/``max_pos``/``max_v`` overrides are rejected: bounds come
    from each row's problem.
    """
    if problems is not None:
        if (cfg.min_pos is not None or cfg.max_pos is not None
                or cfg.max_v is not None):
            raise ValueError(
                "heterogeneous batches take bounds from each row's "
                "problem; pass a config without min_pos/max_pos/max_v "
                "overrides (and not already resolved())")
        seeds = jnp.asarray(seeds)
        if len(problems) != seeds.shape[0]:
            raise ValueError(
                f"{len(problems)} problems for {seeds.shape[0]} seeds")
        rows, table = problem_rows(problems, cfg.dim, cfg.dtype)
        cfg = cfg.resolved()
        batch = init_batch(cfg, seeds, rows=rows, table=table)
        return run_many(cfg, batch, iters, variant, coeffs, sync_every,
                        rows, table, n_blocks)
    cfg = cfg.resolved()
    return run_many(cfg, init_batch(cfg, seeds), iters, variant, coeffs,
                    sync_every, n_blocks=n_blocks)


def best_of_batch(batch: SwarmBatch) -> Tuple[Array, Array, Array]:
    """(best gbest_fit, its gbest_pos, winning swarm index) over the batch."""
    b = jnp.argmax(batch.gbest_fit)
    return batch.gbest_fit[b], batch.gbest_pos[b], b
