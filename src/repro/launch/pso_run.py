"""PSO launcher — the paper's workload as the framework's serving-style
entry point.

    PYTHONPATH=src python -m repro.launch.pso_run --dim 120 \
        --particles 32768 --iters 1000 --variant queue --kernel \
        --islands 8 --exchange 50 --ckpt-dir /tmp/pso_ckpt

--kernel uses the fused Pallas queue-lock kernel (interpret mode on CPU);
--islands N runs N shard_map islands over the first N available devices
(on a pod, particles shard over the data axis; see DESIGN.md §3).
``--islands N --variant async`` runs the asynchronous island ring: no
barrier collective at all — islands exchange their best over a neighbor
ring every --exchange iterations, with the documented staleness bound of
--sync-every iterations within an island plus N exchange rounds across
islands (core/distributed.py).

``--fitness`` accepts any problem registered with
``repro.register_problem`` (the six paper benchmarks ship registered, plus
the constrained ``sphere_simplex``/``sphere_simplex_pen``); for one-off
user objectives use the library facade ``repro.solve`` instead — see
examples/custom_objective.py.

``--constraint`` attaches constraints to the chosen fitness: expression
presets like ``"sum(x)<=1"``/``"norm(x)<=2"``/``"min(x)>=0"``/
``"sum(x)==1"`` (repeatable), or the named preset ``simplex``.
``--constraint-mode`` picks penalty (default; ``--penalty-weight``),
repair, or projection (projection needs the ``simplex`` preset — general
expressions have no automatic projection operator). The run then reports
``violation=``/``feasible=`` next to the usual gbest line. See
``repro.core.constraints`` for the mode semantics and the Deb rule.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import ASYNC_SYNC_EVERY, PSOConfig, init_swarm, run
from repro.core.constraints import constrain_problem, constraint_set_from_cli
from repro.core.problem import list_problems, resolve_problem
from repro.core.distributed import (gather_swarm, init_sharded_swarm,
                                    make_distributed_run)
from repro.runtime import RunnerConfig, StepRunner
from repro import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=120)
    ap.add_argument("--particles", type=int, default=32768)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--fitness", default="cubic",
                    help="registered problem name (see repro.list_problems)")
    ap.add_argument("--variant", default="queue",
                    choices=["reduction", "queue", "queue_lock", "async"])
    ap.add_argument("--sync-every", type=int, default=ASYNC_SYNC_EVERY,
                    help="async variant: iterations between gbest syncs")
    ap.add_argument("--rule", default="pso",
                    help="per-particle update rule (pso|sso|lowcost or a "
                         "custom repro.core.update_rules registration)")
    ap.add_argument("--topology", default="gbest",
                    choices=["gbest", "ring", "vonneumann"],
                    help="async variant: block-neighborhood best pull "
                         "(lbest topologies need --variant async)")
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas kernel for local steps")
    ap.add_argument("--islands", type=int, default=0,
                    help="shard over devices with this exchange group")
    ap.add_argument("--exchange", type=int, default=1,
                    help="island gbest exchange interval")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N iterations (0=off)")
    ap.add_argument("--constraint", action="append", default=[],
                    metavar="SPEC",
                    help="constraint preset: 'sum(x)<=1'-style expressions "
                         "(sum|norm|norm2|min|max, <=|>=|==; repeatable) "
                         "or the named preset 'simplex'")
    ap.add_argument("--constraint-mode", default="penalty",
                    choices=["penalty", "projection", "repair"],
                    help="how constraints are enforced (core.constraints)")
    ap.add_argument("--penalty-weight", type=float, default=1000.0,
                    help="penalty mode: weight per unit violation")
    ap.add_argument("--telemetry", action="store_true",
                    help="thread the in-kernel contention counters "
                         "through the run (requires --kernel; "
                         "docs/observability.md)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Perfetto-loadable trace.json of the "
                         "run's solve chunks here")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write a Prometheus text exposition (chunk "
                         "latency + kernel counters) here")
    ap.add_argument("--profile-dir", default="", metavar="DIR",
                    help="also capture a jax.profiler trace into DIR "
                         "(no-op when the profiler is unavailable)")
    args = ap.parse_args()

    if args.fitness not in list_problems():
        ap.error(f"unknown fitness {args.fitness!r}; registered problems: "
                 f"{', '.join(list_problems())}")
    fitness = args.fitness
    if args.constraint:
        try:
            cset = constraint_set_from_cli(args.constraint,
                                           mode=args.constraint_mode,
                                           weight=args.penalty_weight)
            fitness = constrain_problem(args.fitness, cset)
        except ValueError as e:
            ap.error(str(e))
    from repro.core.update_rules import rule_names
    if args.rule not in rule_names():
        ap.error(f"unknown update rule {args.rule!r}; "
                 f"one of {', '.join(rule_names())}")
    if args.topology != "gbest" and args.variant != "async":
        ap.error(f"--topology {args.topology} generalizes the async "
                 f"variant's block-local pull; use --variant async")
    if args.topology != "gbest" and args.islands:
        ap.error("--topology applies within one device's block grid; "
                 "drop --islands (the island ring is its own topology)")
    cfg = PSOConfig(dim=args.dim, particle_cnt=args.particles,
                    fitness=fitness, update_rule=args.rule,
                    topology=args.topology).resolved()
    if args.kernel and not args.islands and args.variant not in (
            "queue_lock", "async"):
        # only the fused queue-lock kernels exist; don't silently run
        # queue_lock semantics under a reduction/queue label
        ap.error(f"--kernel implements queue_lock/async, not "
                 f"{args.variant!r}")
    if args.kernel and args.islands and args.variant == "async":
        # the async island ring runs the jnp local loop (ROADMAP: Pallas
        # async kernel + ring composition is a TPU-hardware follow-on)
        ap.error("--kernel --islands does not support --variant async; "
                 "drop --kernel (the ring uses the jnp async local loop)")
    if args.telemetry and not args.kernel:
        ap.error("--telemetry counts inside the fused Pallas kernels; "
                 "add --kernel (with --variant queue_lock or async)")
    if args.telemetry and args.islands:
        ap.error("--telemetry is single-device; drop --islands")
    trace = metrics = tel = None
    if args.trace_out:
        from repro.telemetry import TraceWriter
        trace = TraceWriter()
    if args.metrics_out:
        from repro.serving import ServingMetrics
        metrics = ServingMetrics()

    def note_chunk(done, n, t_start):
        """Record one solve chunk on the trace / metrics sinks."""
        if trace is None and metrics is None:
            return
        jax.block_until_ready(state.gbest_fit)
        dur_us = (time.perf_counter() - t_start) * 1e6
        if trace is not None:
            trace.complete(f"chunk @{done}", t_start * 1e6, dur_us,
                           process="solver", thread="chunks", cat="solve",
                           args={"iters": n, "variant": args.variant})
        if metrics is not None:
            metrics.observe("chunk_us", dur_us)
            metrics.inc("chunks")

    import contextlib
    prof = contextlib.ExitStack()
    if args.profile_dir:
        from repro.telemetry import profiler_session
        prof.enter_context(profiler_session(args.profile_dir))
    t0 = time.time()
    if args.islands:
        devs = jax.devices()
        if args.islands > len(devs):
            ap.error(f"--islands {args.islands} exceeds the {len(devs)} "
                     f"available device(s)")
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(devs[:args.islands]), ("data",))
        state = init_sharded_swarm(cfg, args.seed, mesh)
        local_step = None
        if args.kernel:
            from repro.kernels.ops import make_fused_local_step
            local_step = make_fused_local_step(iters_per_call=1)
        runner = make_distributed_run(
            cfg, mesh, iters=args.iters, variant=args.variant,
            exchange_interval=args.exchange, local_step_fn=local_step,
            sync_every=args.sync_every)
        state = runner(state)
    else:
        state = init_swarm(cfg, args.seed)
        if args.kernel:
            from repro.kernels.ops import (run_queue_lock_fused,
                                           run_queue_lock_fused_async)
            if args.variant == "async":
                step_chunk = lambda st, k: run_queue_lock_fused_async(
                    cfg, st, iters=k, sync_every=args.sync_every,
                    telemetry=args.telemetry)
            else:
                step_chunk = lambda st, k: run_queue_lock_fused(
                    cfg, st, iters=k, telemetry=args.telemetry)
            chunk = args.ckpt_every or args.iters
            done = 0
            while done < args.iters:
                n = min(chunk, args.iters - done)
                tc = time.perf_counter()
                if args.telemetry:
                    from repro.telemetry import KernelCounters
                    state, cnt = step_chunk(state, n)
                    c = KernelCounters.from_array(cnt)
                    tel = c if tel is None else tel + c
                else:
                    state = step_chunk(state, n)
                done += n
                note_chunk(done, n, tc)
                if args.ckpt_dir:
                    ckpt.save(args.ckpt_dir, done, gather_swarm(state))
        else:
            chunk = args.ckpt_every or args.iters
            done = 0
            while done < args.iters:
                n = min(chunk, args.iters - done)
                tc = time.perf_counter()
                state = run(cfg, state, n, args.variant,
                            sync_every=args.sync_every)
                done += n
                note_chunk(done, n, tc)
                if args.ckpt_dir:
                    ckpt.save(args.ckpt_dir, done, gather_swarm(state))
    prof.close()
    gf = float(state.gbest_fit)
    dt = time.time() - t0
    extra = ""
    prob = resolve_problem(fitness)
    if prob.constrained:
        viol = prob.violation_at(state.gbest_pos)
        extra = f"violation={viol:.3g}  feasible={viol <= 0.0}  "
    print(f"gbest_fit={gf:.6g}  {extra}iters={args.iters}  "
          f"particles={args.particles}  dim={args.dim}  "
          f"wall={dt:.3f}s  ({1e6*dt/args.iters:.1f} us/iter)")
    if tel is not None:
        d = tel.as_dict()
        print("telemetry: " + "  ".join(f"{k}={v}" for k, v in d.items()))
    if trace is not None:
        trace.write(args.trace_out)
        print(f"trace: {args.trace_out}")
    if metrics is not None:
        with open(args.metrics_out, "w") as f:
            f.write(metrics.prometheus(
                kernel_counters=None if tel is None else tel.as_dict()))
        print(f"metrics: {args.metrics_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
