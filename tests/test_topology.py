"""Ring (lbest) topology + multi-swarm portfolio tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PSOConfig, init_swarm
from repro.core.topology import (best_of_swarms, init_multi_swarm,
                                 run_multi_swarm, run_ring, step_ring,
                                 _neighborhood_best)


def test_neighborhood_best_semantics():
    fit = jnp.asarray([1.0, 5.0, 2.0, 0.0])
    pos = jnp.arange(4, dtype=jnp.float32)[:, None]
    bf, bp = _neighborhood_best(fit, pos, radius=1)
    # ring: each particle sees (i-1, i, i+1) mod n
    # neighborhoods (mod 4): 0:{3,0,1} 1:{0,1,2} 2:{1,2,3} 3:{2,3,0}
    np.testing.assert_array_equal(np.asarray(bf), [5.0, 5.0, 5.0, 2.0])
    np.testing.assert_array_equal(np.asarray(bp)[:, 0], [1.0, 1.0, 1.0, 2.0])


def test_ring_converges():
    cfg = PSOConfig(dim=1, particle_cnt=128, fitness="cubic").resolved()
    s = init_swarm(cfg, 0)
    out = run_ring(cfg, s, 300, radius=2)
    assert float(out.gbest_fit) == pytest.approx(900000.0, rel=1e-5)


def test_ring_invariants():
    cfg = PSOConfig(dim=6, particle_cnt=64, fitness="rastrigin").resolved()
    s = init_swarm(cfg, 7)
    prev = float(s.gbest_fit)
    for _ in range(20):
        s = step_ring(cfg, s, radius=1)
        assert float(s.gbest_fit) >= prev
        prev = float(s.gbest_fit)
        assert np.asarray(s.pos).max() <= cfg.max_pos + 1e-5
        assert not np.any(np.isnan(np.asarray(s.pos)))


def test_ring_propagates_slower_than_star():
    """Information travels O(N/r): after few iters, a star swarm's worst
    particle has seen the global best, a ring swarm's hasn't necessarily —
    but given enough iterations the ring catches up on an easy landscape."""
    cfg = PSOConfig(dim=2, particle_cnt=256, fitness="sphere",
                    w=0.7).resolved()
    s0 = init_swarm(cfg, 3)
    from repro.core.pso import run
    star = run(cfg, s0, 150, "queue")
    ring = run_ring(cfg, s0, 150, radius=1)
    assert float(star.gbest_fit) > -1e-2
    assert float(ring.gbest_fit) > -1.0      # converging, more slowly


def test_multi_swarm_portfolio():
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="ackley").resolved()
    states = init_multi_swarm(cfg, [0, 1, 2, 3])
    out = run_multi_swarm(cfg, states, 100, "queue")
    assert out.pos.shape == (4, 64, 3)
    bf, bp = best_of_swarms(out)
    assert float(bf) >= float(jnp.max(out.gbest_fit)) - 1e-6
    # portfolio best must beat (or tie) every individual swarm
    assert all(float(bf) >= float(f) for f in out.gbest_fit)
