"""Block-neighborhood (lbest) topologies: neighbor-definition unit tests
shared by both engines, kernel-vs-oracle parity, and end-to-end facade
runs (the multi-swarm portfolio lives in ``repro.solve_many`` now — the
legacy ``run_ring``/``run_multi_swarm`` paths were folded into the
topology + batching layers)."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import Method
from repro.core import PSOConfig, init_swarm
from repro.core.pso import run_async
from repro.core.topology import (_neighborhood_best, block_neighbor_best,
                                 grid_dims, kernel_neighbor_ids)
from repro.kernels import ops, ref

TOPOS = ("ring", "vonneumann")


def test_neighborhood_best_semantics():
    fit = jnp.asarray([1.0, 5.0, 2.0, 0.0])
    pos = jnp.arange(4, dtype=jnp.float32)[:, None]
    bf, bp = _neighborhood_best(fit, pos, radius=1)
    # ring: each particle sees (i-1, i, i+1) mod n
    # neighborhoods (mod 4): 0:{3,0,1} 1:{0,1,2} 2:{1,2,3} 3:{2,3,0}
    np.testing.assert_array_equal(np.asarray(bf), [5.0, 5.0, 5.0, 2.0])
    np.testing.assert_array_equal(np.asarray(bp)[:, 0], [1.0, 1.0, 1.0, 2.0])


@pytest.mark.parametrize("nb,want", [(1, (1, 1)), (4, (2, 2)), (6, (2, 3)),
                                     (8, (2, 4)), (12, (3, 4)), (16, (4, 4)),
                                     (7, (1, 7)), (36, (6, 6))])
def test_grid_dims(nb, want):
    assert grid_dims(nb) == want
    r, c = grid_dims(nb)
    assert r * c == nb and r <= c


def _brute_neighbor_best(lbf, lbp, topology):
    """O(nb²) reference: fold each block's neighborhood explicitly."""
    nb = lbf.shape[0]
    out_f, out_p = lbf.copy(), lbp.copy()
    for b in range(nb):
        for nbr in kernel_neighbor_ids(b, nb, topology):
            if lbf[int(nbr)] > out_f[b]:
                out_f[b] = lbf[int(nbr)]
                out_p[b] = lbp[int(nbr)]
    return out_f, out_p


@pytest.mark.parametrize("topology", TOPOS)
@pytest.mark.parametrize("nb", [4, 6, 8, 12])
def test_block_neighbor_best_matches_kernel_neighbor_ids(topology, nb):
    """The jnp roll-fold and the kernels' explicit neighbor-id fold
    implement the SAME neighbor definition."""
    rng = np.random.default_rng(nb)
    lbf = rng.standard_normal(nb).astype(np.float32)
    lbp = rng.standard_normal((nb, 3)).astype(np.float32)
    lbp2, lbf2 = block_neighbor_best(jnp.asarray(lbf), jnp.asarray(lbp),
                                     topology)
    want_f, want_p = _brute_neighbor_best(lbf, lbp, topology)
    np.testing.assert_array_equal(np.asarray(lbf2), want_f)
    np.testing.assert_array_equal(np.asarray(lbp2), want_p)
    # self is always in the neighborhood: locals never regress
    assert np.all(np.asarray(lbf2) >= lbf)


@pytest.mark.parametrize("topology", TOPOS)
def test_kernel_neighbor_ids_shape(topology):
    nb = 8
    for b in range(nb):
        ids = tuple(int(i) for i in kernel_neighbor_ids(b, nb, topology))
        assert all(0 <= i < nb for i in ids)
        assert b not in ids                    # excludes self
    assert len(kernel_neighbor_ids(0, nb, "ring")) == 2
    assert len(kernel_neighbor_ids(0, nb, "vonneumann")) == 4
    with pytest.raises(ValueError, match="topology"):
        kernel_neighbor_ids(0, nb, "hypercube")
    with pytest.raises(ValueError, match="topology"):
        block_neighbor_best(jnp.zeros(4), jnp.zeros((4, 2)), "hypercube")


# --------------------------------------------------------------------------
# lbest async: kernel vs eager oracle, jnp engine vs eager oracle
# --------------------------------------------------------------------------

def _oracle_inputs(cfg, seed):
    s0 = init_swarm(cfg, seed)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s0, cfg.dim)
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = cfg.dim
    fitness = kw.pop("fitness")
    return s0, (pos, vel, pbp, pbf, gp, float(gf[0])), fitness, kw


@pytest.mark.parametrize("topology", TOPOS)
@pytest.mark.parametrize("rule", ["pso", "sso"])
def test_lbest_async_kernel_vs_oracle(topology, rule):
    """4-block async kernel with a neighborhood pull, ulp-tight vs the
    eager oracle that folds the same kernel_neighbor_ids order (the
    compiled-vs-eager FMA-contraction caveat bounds the tolerance)."""
    cfg = PSOConfig(dim=3, particle_cnt=128, fitness="rastrigin",
                    update_rule=rule, topology=topology).resolved()
    s0, (pos, vel, pbp, pbf, gp, gf), fitness, kw = _oracle_inputs(cfg, 2)
    out = ops.run_queue_lock_fused_async(cfg, s0, iters=8, sync_every=4,
                                         block_n=32)
    o = ref.run_fused_async_oracle(int(s0.seed), 0, pos, vel, pbp, pbf, gp,
                                   gf, 8, 32, 4, fitness=fitness,
                                   topology=topology, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, 3)),
                               np.asarray(o[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o[3])[0], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-5)


@pytest.mark.parametrize("topology", TOPOS)
def test_lbest_async_jnp_vs_oracle(topology):
    """The jnp engine's lbest pull (publish-then-neighborhood-fold),
    dispatched per iteration, matches the eager oracle bit-exactly."""
    cfg = PSOConfig(dim=5, particle_cnt=64, fitness="sphere",
                    topology=topology).resolved()
    iters = 12
    o = ref.run_constrained_oracle(cfg, 3, iters, variant="async",
                                   sync_every=4, n_blocks=4)
    s = init_swarm(cfg, 3)
    for _ in range(iters):
        s = run_async(cfg, s, 1, sync_every=4, n_blocks=4)
    assert np.array_equal(np.asarray(s.pos), np.asarray(o.pos))
    assert np.array_equal(np.asarray(s.lbest_fit), np.asarray(o.lbest_fit))
    assert float(s.gbest_fit) == float(o.gbest_fit)


def test_lbest_gbest_flush_monotone_and_diffusive():
    """The shared gbest is still flushed every sync under lbest pulls:
    monotone trajectory, and the ring eventually converges on an easy
    landscape (knowledge diffuses hop by hop)."""
    cfg = PSOConfig(dim=2, particle_cnt=256, fitness="sphere",
                    w=0.7, topology="ring").resolved()
    s = init_swarm(cfg, 3)
    prev = float(s.gbest_fit)
    for _ in range(30):
        s = run_async(cfg, s, 4, sync_every=4, n_blocks=8)
        assert float(s.gbest_fit) >= prev - 1e-7
        prev = float(s.gbest_fit)
        assert not np.any(np.isnan(np.asarray(s.pos)))
    assert float(s.gbest_fit) > -1.0           # converging


@pytest.mark.parametrize("backend", ["jnp", "kernel"])
@pytest.mark.parametrize("topology", TOPOS)
def test_lbest_end_to_end_facade(backend, topology):
    res = repro.solve("cubic", dim=2, particles=128, iters=40, seed=0,
                      method=Method(variant="async", backend=backend,
                                    topology=topology))
    assert res.config.topology == topology
    s0 = init_swarm(res.config, 0)
    assert float(res.state.gbest_fit) >= float(s0.gbest_fit)
    pos = np.asarray(res.state.pos)
    assert np.all(pos >= res.config.min_pos - 1e-5)
    assert np.all(pos <= res.config.max_pos + 1e-5)


def test_portfolio_via_solve_many():
    """The old multi-swarm portfolio (same problem, independent seeds,
    best-of) is now spelled with the batched facade."""
    seeds = [0, 1, 2, 3]
    rows = repro.solve_many("ackley", dim=3, particles=64, iters=100,
                            seeds=seeds, variant="queue")
    fits = [float(r.state.gbest_fit) for r in rows]
    best = max(fits)
    # portfolio best must beat (or tie) every individual swarm, and match
    # an independent single solve of the winning seed
    assert all(best >= f for f in fits)
    win = seeds[int(np.argmax(fits))]
    solo = repro.solve("ackley", dim=3, particles=64, iters=100, seed=win,
                       variant="queue")
    np.testing.assert_allclose(best, float(solo.state.gbest_fit), rtol=1e-5)
