import numpy as np
import pytest

# NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
# set here — smoke tests and benchmarks must see the real single CPU device.
# Only launch/dryrun.py fakes 512 devices (and only in its own process).


@pytest.fixture(scope="session")
def rng_np():
    return np.random.default_rng(0)
