"""Continuous-batching scheduler: admit requests into in-flight batched
async solves at chunk boundaries.

The flush server (``repro.launch.serve``) batches one queue generation at
a time: every request in a flush rides a padded ``solve_many`` keyed on
its FULL shape *including* ``iters``, and nothing new can join until the
whole batch returns. A serving tier sees a *stream* — arrivals are
staggered and iteration budgets differ — and flush batching pays twice:
mixed-``iters`` traffic fragments into many small padded groups, and a
late arrival waits a whole batch.

This scheduler keeps a small number of persistent **lanes** running
instead. A lane is a ``SwarmBatch`` of ``width`` independent rows that
advances ``sync_every`` iterations per dispatch (one chunk) through ONE
compiled program, reused for the lane's whole lifetime. The paper's
enhanced queue-lock semantics make the chunk boundary a natural
preemption point: blocks only touch shared state at publication points,
so between chunks every row is at a publication boundary and its state is
a complete, bit-exact checkpoint (PR-4/PR-6 machinery: ``SwarmState``
carries the block-local ``lbest_*`` buffers, and splitting an async run
at sync points is bit-identical to the uninterrupted run —
tests/test_checkpoint.py).

Admission invariants (the whole correctness argument):

1. **Rows are admitted and removed only between dispatches** — i.e. at
   chunk boundaries. A fresh row enters via
   ``pso.init_swarm_async`` (init + seeded locals — exactly what
   ``run_async`` would do on its first call) spliced in with
   ``multi_swarm.set_batch_row``; the program never restarts.
2. **Every row in a lane is always at phase 0** (``iteration`` a
   multiple of the lane's ``sync_every``): rows start at 0 and advance in
   whole chunks, so the vmapped program's static ``phase=0`` is exact for
   every row at every dispatch — no phase-group splitting, ever.
3. **Iteration budgets are honored per row.** A request for ``T``
   iterations rides ``T // sync_every`` chunks; a non-zero remainder
   ejects the row at the last chunk boundary and finishes standalone via
   ``run_async`` (the proven resume path — publication schedule
   unchanged). Requests shorter than one chunk never enter a lane.

Consequence: every per-request result is bit-identical to the standalone
``core.pso.solve(cfg, seed, T, "async", sync_every)`` of that request
(asserted in tests/test_serving.py), while steady-state throughput beats
flush batching on mixed traffic — lane compile keys DROP ``iters``
(accounting is per-row), so traffic that fragments the flush server's
groups rides one full lane here (benchmarks/loadgen.py).

Heterogeneous lanes: registry built-ins coalesce into one lane per solve
shape (``lax.switch`` row dispatch, exactly the flush server's two-tier
grouping). Per-row problem descriptors are TRACED operands, so admitting
a *different* built-in into a freed slot recompiles nothing
(``multi_swarm.set_problem_row``).

Cold start: with a ``CompileCache`` attached, each lane program is traced
once ever — a restarted replica deserializes the exported program and
serves its first request with zero re-traces (``trace_events == 0``).

Synchronous variants have no publication boundaries to preempt at; those
requests (and sub-chunk ones) run standalone, counted in
``standalone_solves``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.blocking import default_block_count
from repro.core.multi_swarm import (MIN_VALIDATED_SWARMS, ProblemRows,
                                    batch_row, hetero_fid, problem_rows,
                                    run_many, set_batch_row, set_problem_row,
                                    stack_states)
from repro.core.pso import (HeteroRow, PSOConfig, init_swarm_async,
                            run_async, solve)
from repro.launch.serve import (_HETERO, _HETERO_CANONICAL_FITNESS,
                                SolveRequest, SolveResult, request_error)

from .compile_cache import CompileCache
from .metrics import ServingMetrics


def _now_us() -> float:
    return time.perf_counter() * 1e6


@dataclasses.dataclass
class _Active:
    """One admitted request occupying a lane slot."""
    ticket: int
    request: SolveRequest
    done: int = 0            # iterations applied so far
    submitted_us: float = 0.0
    admitted_us: float = 0.0
    history: Optional[list] = None   # [(iteration, gbest_fit), ...] samples


class _Lane:
    """One persistent batched program: ``width`` slots advancing in chunks."""

    def __init__(self, key: Tuple, cfg: PSOConfig, width: int,
                 sync_every: int, hetero: bool, table=None):
        self.key = key
        self.uid = 0                           # display id (trace rows)
        self.cfg = cfg.resolved()
        self.width = width
        self.sync_every = sync_every
        self.hetero = hetero
        self.table = table
        self.nb = default_block_count(self.cfg.particle_cnt)
        self.batch = None                      # SwarmBatch [width, ...]
        self.rows: Optional[ProblemRows] = None
        self.slots: List[Optional[_Active]] = [None] * width
        self.chunks_dispatched = 0
        self.program = None

    @property
    def active_count(self) -> int:
        return sum(1 for a in self.slots if a is not None)

    def free_slot(self) -> Optional[int]:
        for i, a in enumerate(self.slots):
            if a is None:
                return i
        return None

    def program_key(self) -> str:
        c = self.cfg
        # Stable across processes (Python's tuple hash is salted): content
        # lanes key on a digest of the problem's content-hash tuple.
        content = (_HETERO if self.hetero
                   else "content:" + hashlib.sha1(
                       repr(self.key).encode()).hexdigest()[:16])
        return (f"lane|d{c.dim}|n{c.particle_cnt}|{c.dtype}"
                f"|se{self.sync_every}|nb{self.nb}|w{self.width}"
                f"|r{c.update_rule}|t{c.topology}|{content}")


class ContinuousScheduler:
    """Streaming solve front end over persistent batched async lanes.

    ``lane_width`` rows per lane (floored at the engine's
    ``MIN_VALIDATED_SWARMS`` so every dispatch runs a validated program
    shape); ``coalesce_registry`` merges registry built-ins at one solve
    shape into heterogeneous lanes; ``compile_cache`` (a
    ``serving.CompileCache``) makes lane programs restart-persistent;
    ``autotune=True`` rewrites async requests' ``sync_every`` to the
    model-tuned value and caps lane width at the autotuner's bucket
    ladder's last rung — the point where the cost model prices per-row
    gains as flattened, so admission never grows a lane past what pays.

    Telemetry (``repro.telemetry``): ``trace`` (a ``TraceWriter``) records
    the serving timeline — one Perfetto row per lane with a span per
    dispatched chunk, admit/eject instants, a per-request span, and a
    lane-fill counter track. ``record_history=True`` samples every lane
    row's gbest at its chunk boundaries onto ``SolveResult.history``
    (lane-riding async requests only; standalone fallbacks report None).

    Single-threaded and synchronous like ``SolveServer``: ``submit`` +
    ``step``/``drain`` (or one-shot ``run``).
    """

    def __init__(self, lane_width: int = 8,
                 coalesce_registry: bool = True,
                 compile_cache: Optional[CompileCache] = None,
                 autotune: bool = False,
                 metrics: Optional[ServingMetrics] = None,
                 trace=None, record_history: bool = False):
        self.lane_width = max(MIN_VALIDATED_SWARMS, lane_width)
        self.coalesce_registry = coalesce_registry
        self.autotune = autotune
        self.metrics = metrics or ServingMetrics()
        self.trace = trace
        self.record_history = record_history
        self.compile_cache = compile_cache
        if compile_cache is not None and compile_cache.metrics is None:
            compile_cache.metrics = self.metrics
        self._lanes: "OrderedDict[Tuple, _Lane]" = OrderedDict()
        self._pending: List[_Active] = []
        self._results: Dict[int, SolveResult] = {}
        self._ticket = 0
        self._ladder_width: Dict[Tuple, int] = {}

    # -- submission --------------------------------------------------------
    def submit(self, req: SolveRequest) -> int:
        t = self._ticket
        self._ticket += 1
        self.metrics.inc("submitted")
        self._pending.append(_Active(ticket=t, request=req,
                                     submitted_us=_now_us()))
        return t

    def _tuned(self, r: SolveRequest) -> SolveRequest:
        if not self.autotune or r.variant != "async":
            return r
        from repro.core.autotune import tuned_sync_every
        k = tuned_sync_every(r.fitness, r.dim, r.particle_cnt, r.iters,
                             r.dtype)
        return dataclasses.replace(r, sync_every=k)

    # -- lane keying -------------------------------------------------------
    def _lane_key(self, r: SolveRequest) -> Tuple:
        """Like ``SolveRequest.group_key`` but WITHOUT ``iters`` — per-row
        accounting means mixed budgets share a lane."""
        hetero = self.coalesce_registry and hetero_fid(r.fitness) is not None
        from repro.core.problem import resolve_problem
        content = _HETERO if hetero else resolve_problem(
            r.fitness).cache_key()
        return (r.dim, r.particle_cnt, r.dtype, r.sync_every,
                r.rule, r._topology_key(), content)

    def _lane_for(self, r: SolveRequest) -> _Lane:
        key = self._lane_key(r)
        lane = self._lanes.get(key)
        if lane is not None:
            return lane
        hetero = key[-1] == _HETERO
        if hetero:
            cfg = PSOConfig(dim=r.dim, particle_cnt=r.particle_cnt,
                            fitness=_HETERO_CANONICAL_FITNESS,
                            dtype=r.dtype, update_rule=r.rule,
                            topology=r._topology_key())
        else:
            cfg = PSOConfig(dim=r.dim, particle_cnt=r.particle_cnt,
                            fitness=r.fitness, dtype=r.dtype,
                            update_rule=r.rule,
                            topology=r._topology_key())
        lane = _Lane(key, cfg, self._width_for(r), r.sync_every, hetero)
        lane.uid = len(self._lanes)
        self._lanes[key] = lane
        return lane

    def _width_for(self, r: SolveRequest) -> int:
        if not self.autotune:
            return self.lane_width
        key = (r.dim, r.particle_cnt, r.variant, r.dtype)
        if key not in self._ladder_width:
            from repro.core.autotune import bucket_ladder
            ladder = bucket_ladder(
                r.fitness, r.dim, r.particle_cnt, r.iters,
                max_batch=self.lane_width, variant=r.variant,
                dtype=r.dtype, min_bucket=MIN_VALIDATED_SWARMS)
            self._ladder_width[key] = max(MIN_VALIDATED_SWARMS, ladder[-1])
        return self._ladder_width[key]

    # -- admission ---------------------------------------------------------
    def _admit(self) -> None:
        still: List[_Active] = []
        for a in self._pending:
            err = request_error(a.request)
            if err is not None:
                # mirror the flush server's admission rejection: a bad
                # variant/rule/topology gets its own error result and
                # never reaches a lane or a standalone solve
                self.metrics.inc("failed")
                self._results[a.ticket] = SolveResult(
                    request=a.request, gbest_fit=float("nan"),
                    gbest_pos=np.full((a.request.dim,), np.nan),
                    batch_size=0, error=err)
                continue
            r = self._tuned(a.request)
            if r.variant != "async" or r.iters < max(1, r.sync_every):
                self._solve_standalone(a, r)
                continue
            lane = self._lane_for(r)
            slot = lane.free_slot()
            if slot is None:
                still.append(a)     # lane full: wait for a chunk boundary
                continue
            self._splice(lane, slot, a, r)
        self._pending = still

    def _fresh_state(self, lane: _Lane, r: SolveRequest):
        """A fresh row for the lane, locals seeded (phase 0, iteration 0)."""
        if not lane.hetero:
            return init_swarm_async(lane.cfg, r.seed, n_blocks=lane.nb), None
        one, table = problem_rows([r.fitness], lane.cfg.dim, lane.cfg.dtype)
        if lane.table is None:
            lane.table = table
        hr = HeteroRow(fid=one.fid[0], lo=one.lo[0], hi=one.hi[0],
                       mv=one.mv[0])
        return init_swarm_async(lane.cfg, r.seed, n_blocks=lane.nb,
                                hetero=(table, hr)), one

    def _splice(self, lane: _Lane, slot: int, a: _Active,
                r: SolveRequest) -> None:
        state, one = self._fresh_state(lane, r)
        if lane.batch is None:
            # First admission bootstraps the lane: dead slots replicate the
            # first row (well-defined bounds, never read back).
            lane.batch = stack_states([state] * lane.width)
            if lane.hetero:
                lane.rows = ProblemRows(*jax_broadcast_rows(one, lane.width))
        else:
            lane.batch = set_batch_row(lane.batch, slot, state)
            if lane.hetero:
                lane.rows = set_problem_row(lane.rows, slot, one)
        a.request = r
        a.admitted_us = _now_us()
        self.metrics.observe("queue_us", a.admitted_us - a.submitted_us)
        self.metrics.inc("admitted")
        if lane.chunks_dispatched:
            self.metrics.inc("row_swaps")
        if self.record_history:
            a.history = []
        if self.trace is not None:
            self.trace.instant(
                f"admit t{a.ticket}", a.admitted_us, process="serving",
                thread=f"lane {lane.uid}", cat="admission",
                args={"slot": slot, "fitness": str(r.fitness),
                      "iters": r.iters})
        lane.slots[slot] = a

    # -- standalone fallbacks ---------------------------------------------
    def _solve_standalone(self, a: _Active, r: SolveRequest) -> None:
        a.admitted_us = _now_us()
        self.metrics.observe("queue_us", a.admitted_us - a.submitted_us)
        cfg = PSOConfig(dim=r.dim, particle_cnt=r.particle_cnt,
                        fitness=r.fitness, dtype=r.dtype,
                        update_rule=r.rule, topology=r._topology_key())
        t0 = _now_us()
        st = solve(cfg, r.seed, r.iters, r.variant, r.sync_every)
        if self.trace is not None:
            self.trace.complete(
                f"standalone t{a.ticket}", t0, _now_us() - t0,
                process="serving", thread="standalone", cat="solve",
                args={"fitness": str(r.fitness), "variant": r.variant,
                      "iters": r.iters})
        self.metrics.inc("standalone_solves")
        self._finish(a, float(st.gbest_fit), np.asarray(st.gbest_pos),
                     batch_size=1)

    def _eject(self, lane: _Lane, slot: int, rem: int) -> None:
        """Finish a row's sub-chunk remainder standalone at a boundary."""
        a = lane.slots[slot]
        state = batch_row(lane.batch, slot)
        if lane.hetero:
            hr = HeteroRow(fid=lane.rows.fid[slot], lo=lane.rows.lo[slot],
                           hi=lane.rows.hi[slot], mv=lane.rows.mv[slot])
            st = run_async(lane.cfg, state, rem,
                           sync_every=lane.sync_every, n_blocks=lane.nb,
                           hetero_row=hr, table=lane.table)
        else:
            st = run_async(lane.cfg, state, rem,
                           sync_every=lane.sync_every, n_blocks=lane.nb)
        lane.slots[slot] = None
        self.metrics.inc("tail_ejections")
        if a.history is not None:
            a.history.append((a.request.iters, float(st.gbest_fit)))
        if self.trace is not None:
            self.trace.instant(
                f"eject t{a.ticket}", _now_us(), process="serving",
                thread=f"lane {lane.uid}", cat="admission",
                args={"slot": slot, "remainder": rem})
        self._finish(a, float(st.gbest_fit), np.asarray(st.gbest_pos),
                     batch_size=lane.width)

    def _finish(self, a: _Active, gf: float, gp: np.ndarray,
                batch_size: int) -> None:
        now = _now_us()
        self.metrics.observe("solve_us", now - a.admitted_us)
        self.metrics.observe("e2e_us", now - a.submitted_us)
        self.metrics.inc("completed")
        hist = None
        if a.history:
            from repro.api import History
            its, fits = zip(*a.history)
            hist = History(iteration=np.asarray(its, dtype=np.int64),
                           gbest_fit=np.asarray(fits), violation=None)
        if self.trace is not None:
            self.trace.complete(
                f"request t{a.ticket}", a.submitted_us,
                now - a.submitted_us, process="requests",
                thread=f"ticket {a.ticket}", cat="request",
                args={"fitness": str(a.request.fitness),
                      "iters": a.request.iters,
                      "batch_size": batch_size, "gbest_fit": gf})
        self._results[a.ticket] = SolveResult(
            request=a.request, gbest_fit=gf, gbest_pos=gp,
            batch_size=batch_size, history=hist)

    # -- dispatch ----------------------------------------------------------
    def _lane_program(self, lane: _Lane):
        if lane.program is not None:
            return lane.program
        cfg, chunk, se, nb, table = (lane.cfg, lane.sync_every,
                                     lane.sync_every, lane.nb, lane.table)
        if lane.hetero:
            def build(batch, rows):
                return run_many(cfg, batch, chunk, "async", sync_every=se,
                                rows=rows, table=table, n_blocks=nb)
            args = (lane.batch, lane.rows)
        else:
            def build(batch):
                return run_many(cfg, batch, chunk, "async", sync_every=se,
                                n_blocks=nb)
            args = (lane.batch,)
        if self.compile_cache is None:
            lane.program = build
        else:
            t0 = _now_us()
            lane.program = self.compile_cache.get(
                lane.program_key(), build, *args)
            self.metrics.observe("compile_us", _now_us() - t0)
        return lane.program

    def _dispatch(self, lane: _Lane) -> None:
        program = self._lane_program(lane)
        t0 = _now_us()
        if lane.hetero:
            out = program(lane.batch, lane.rows)
        else:
            out = program(lane.batch)
        out.gbest_fit.block_until_ready()
        dur = _now_us() - t0
        self.metrics.observe("dispatch_us", dur)
        lane.batch = out
        lane.chunks_dispatched += 1
        self.metrics.inc("dispatches")
        self.metrics.inc("lane_slots", lane.width)
        self.metrics.inc("lane_active_slots", lane.active_count)
        if self.trace is not None:
            self.trace.complete(
                f"chunk {lane.chunks_dispatched}", t0, dur,
                process="serving", thread=f"lane {lane.uid}",
                cat="dispatch",
                args={"active": lane.active_count, "width": lane.width,
                      "sync_every": lane.sync_every})
            self.trace.counter(f"lane {lane.uid} fill", t0,
                               {"active": lane.active_count,
                                "idle": lane.width - lane.active_count})
        for i, a in enumerate(lane.slots):
            if a is not None:
                a.done += lane.sync_every
                if a.history is not None:
                    a.history.append((a.done,
                                      float(lane.batch.gbest_fit[i])))

    # -- the loop ----------------------------------------------------------
    def step(self) -> Dict[int, SolveResult]:
        """One scheduling round: admit at the boundary, advance every
        active lane one chunk, harvest completions. Returns the results
        that completed this round (also retained for ``drain``/``run``)."""
        before = set(self._results)
        self._admit()
        for lane in list(self._lanes.values()):
            # Boundary bookkeeping first: rows whose remainder is shorter
            # than a chunk leave now (standalone finish, proven resume).
            for i, a in enumerate(lane.slots):
                if a is None:
                    continue
                rem = a.request.iters - a.done
                if 0 < rem < lane.sync_every:
                    self._eject(lane, i, rem)
            if lane.active_count == 0:
                continue
            self._dispatch(lane)
            for i, a in enumerate(lane.slots):
                if a is not None and a.done >= a.request.iters:
                    gf = float(lane.batch.gbest_fit[i])
                    gp = np.asarray(lane.batch.gbest_pos[i])
                    lane.slots[i] = None
                    self._finish(a, gf, gp, batch_size=lane.width)
        return {t: r for t, r in self._results.items() if t not in before}

    @property
    def busy(self) -> bool:
        return bool(self._pending) or any(
            lane.active_count for lane in self._lanes.values())

    def drain(self) -> Dict[int, SolveResult]:
        """Step until every submitted request has a result."""
        while self.busy:
            self.step()
        return dict(self._results)

    def run(self, requests) -> List[SolveResult]:
        """Convenience one-shot: submit all + drain, results in order."""
        tickets = [self.submit(r) for r in requests]
        resolved = self.drain()
        return [resolved[t] for t in tickets]

    def snapshot(self) -> dict:
        """Serving state: metrics + lane occupancy + compile-cache stats."""
        doc = self.metrics.snapshot()
        doc["lanes"] = [
            {"key": repr(lane.key), "width": lane.width,
             "active": lane.active_count,
             "chunks": lane.chunks_dispatched}
            for lane in self._lanes.values()]
        if self.compile_cache is not None:
            doc["compile_cache"] = self.compile_cache.snapshot()
        return doc


def jax_broadcast_rows(one: ProblemRows, width: int) -> tuple:
    """Replicate a 1-row descriptor set to ``width`` rows (lane bootstrap)."""
    import jax
    import jax.numpy as jnp
    return tuple(jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[:1], (width,) + a.shape[1:]),
        tuple(one)))
