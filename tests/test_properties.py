"""Hypothesis property-based tests on system invariants.

``hypothesis`` is an optional dev dependency (requirements-test.txt): the
whole module is skipped, not errored, when it is absent so tier-1
collection stays green on minimal installs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import PSOConfig, init_swarm
from repro.core.pso import (SwarmState, step_queue, step_queue_lock,
                            step_reduction)

FITNESS = st.sampled_from(["cubic", "sphere", "rastrigin", "ackley"])


def _mk_state(cfg, seed):
    return init_swarm(cfg.resolved(), seed)


@settings(max_examples=25, deadline=None)
@given(dim=st.integers(1, 40), n_exp=st.integers(3, 7),
       seed=st.integers(0, 2 ** 31 - 1), fitness=FITNESS)
def test_step_invariants(dim, n_exp, seed, fitness):
    """For any problem: clamping, pbest domination, gbest monotonicity."""
    cfg = PSOConfig(dim=dim, particle_cnt=2 ** n_exp, fitness=fitness).resolved()
    s = _mk_state(cfg, seed)
    g0 = float(s.gbest_fit)
    s = step_queue(cfg, s)
    assert float(s.gbest_fit) >= g0
    pos, vel = np.asarray(s.pos), np.asarray(s.vel)
    assert pos.min() >= cfg.min_pos - 1e-5
    assert pos.max() <= cfg.max_pos + 1e-5
    assert np.abs(vel).max() <= cfg.max_v * (1 + 1e-6)
    assert np.all(np.asarray(s.pbest_fit) >= np.asarray(s.fit) - 1e-4)
    assert not np.any(np.isnan(pos))


@settings(max_examples=15, deadline=None)
@given(dim=st.integers(1, 16), seed=st.integers(0, 2 ** 31 - 1),
       fitness=FITNESS, steps=st.integers(1, 8))
def test_queue_reduction_equivalence(dim, seed, fitness, steps):
    """§4.1 claim: queue is semantically identical to reduction — for ANY
    landscape/seed, not just the paper's cubic."""
    cfg = PSOConfig(dim=dim, particle_cnt=64, fitness=fitness).resolved()
    a = _mk_state(cfg, seed)
    b = _mk_state(cfg, seed)
    for _ in range(steps):
        a = step_queue(cfg, a)
        b = step_reduction(cfg, b)
    np.testing.assert_allclose(float(a.gbest_fit), float(b.gbest_fit),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), fitness=FITNESS)
def test_queue_lock_equivalence(seed, fitness):
    cfg = PSOConfig(dim=5, particle_cnt=32, fitness=fitness).resolved()
    a = _mk_state(cfg, seed)
    b = _mk_state(cfg, seed)
    for _ in range(5):
        a = step_queue(cfg, a)
        b = step_queue_lock(cfg, b)
    np.testing.assert_allclose(float(a.gbest_fit), float(b.gbest_fit),
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_particle_permutation_invariance_of_gbest(seed):
    """Relabeling particles must not change the gbest value sequence —
    the reduction is a symmetric function."""
    cfg = PSOConfig(dim=3, particle_cnt=64, fitness="rastrigin").resolved()
    s = _mk_state(cfg, seed)
    perm = np.random.default_rng(seed).permutation(64)
    # A permuted swarm evolves differently particle-for-particle (RNG is tied
    # to the particle index), so permute *after* stepping and verify the
    # aggregation alone. gbest(perm(state)) == gbest(state).
    s = step_queue(cfg, s)
    permuted = s._replace(
        pos=s.pos[perm], vel=s.vel[perm], fit=s.fit[perm],
        pbest_pos=s.pbest_pos[perm], pbest_fit=s.pbest_fit[perm])
    gp = jnp.max(permuted.pbest_fit)
    go = jnp.max(s.pbest_fit)
    assert float(gp) == float(go)
    assert float(s.gbest_fit) >= float(go) - 1e-4 * abs(float(go))


@settings(max_examples=10, deadline=None)
@given(dim=st.integers(1, 64), n=st.sampled_from([128, 256, 384]),
       seed=st.integers(0, 1000))
def test_kernel_property_sweep(dim, n, seed):
    """Hypothesis-driven shape sweep of the fused kernel vs the library:
    gbest after k iterations must dominate the library's pbest max (same
    particles, fresher gbest can only help or tie)."""
    from repro.kernels import ops
    cfg = PSOConfig(dim=dim, particle_cnt=n, fitness="sphere").resolved()
    s = init_swarm(cfg, seed)
    out = ops.run_queue_lock_fused(cfg, s, iters=3)
    assert not np.any(np.isnan(np.asarray(out.pos)))
    assert float(out.gbest_fit) >= float(s.gbest_fit)
    assert np.asarray(out.pos).shape == (n, dim)
