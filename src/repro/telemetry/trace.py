"""Chrome trace-event (Perfetto-loadable) writer for solver timelines.

Emits the JSON object format of the Trace Event spec — a ``traceEvents``
list of phase-coded events — which both ``chrome://tracing`` and
https://ui.perfetto.dev load directly. The writer keeps its own
process/thread registries so callers name rows semantically ("serving" /
"lane 0") instead of juggling pid/tid integers:

- ``complete(name, ts_us, dur_us, ...)`` — a span (ph "X"): serving
  requests, lane dispatches, solve chunks.
- ``instant(name, ts_us, ...)`` — a point event (ph "i"): admissions,
  ejections, publications.
- ``counter(name, ts_us, values)`` — a counter track (ph "C"): lane fill,
  per-chunk gbest.
- ``span(name, ...)`` — context manager wrapping a host-side region with
  ``time.perf_counter`` stamps.

Timestamps are microseconds on any monotonic base; ``to_dict()`` rebases
them to zero so the timeline starts at t=0 regardless of the clock.

``profiler_session(logdir)`` optionally brackets a region with a
``jax.profiler`` trace (XLA-level events alongside ours); it degrades to
a no-op when the profiler backend is unavailable, so callers never gate
on it.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, List, Optional


def _now_us() -> float:
    return time.perf_counter() * 1e6


class TraceWriter:
    """Accumulates trace events; one instance per exported timeline."""

    def __init__(self) -> None:
        self._events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}
        self._tids: Dict[tuple, int] = {}

    def _pid(self, process: str) -> int:
        pid = self._pids.get(process)
        if pid is None:
            pid = len(self._pids) + 1
            self._pids[process] = pid
            self._events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": process},
            })
        return pid

    def _tid(self, process: str, thread: str) -> int:
        key = (process, thread)
        tid = self._tids.get(key)
        if tid is None:
            pid = self._pid(process)
            tid = sum(1 for p, _ in self._tids if p == process) + 1
            self._tids[key] = tid
            self._events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": thread},
            })
        return tid

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 process: str = "solver", thread: str = "main",
                 cat: str = "solve",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A finished span: ``[ts_us, ts_us + dur_us]`` on a named row."""
        self._events.append({
            "name": name, "ph": "X", "cat": cat,
            "ts": float(ts_us), "dur": max(0.0, float(dur_us)),
            "pid": self._pid(process), "tid": self._tid(process, thread),
            "args": dict(args or {}),
        })

    def instant(self, name: str, ts_us: float, *,
                process: str = "solver", thread: str = "main",
                cat: str = "solve",
                args: Optional[Dict[str, Any]] = None) -> None:
        """A point event (thread-scoped tick mark)."""
        self._events.append({
            "name": name, "ph": "i", "s": "t", "cat": cat,
            "ts": float(ts_us),
            "pid": self._pid(process), "tid": self._tid(process, thread),
            "args": dict(args or {}),
        })

    def counter(self, name: str, ts_us: float,
                values: Dict[str, float], *,
                process: str = "solver", cat: str = "solve") -> None:
        """A sample on a counter track (rendered as a stacked area)."""
        self._events.append({
            "name": name, "ph": "C", "cat": cat, "ts": float(ts_us),
            "pid": self._pid(process), "tid": 0,
            "args": {k: float(v) for k, v in values.items()},
        })

    @contextlib.contextmanager
    def span(self, name: str, *, process: str = "solver",
             thread: str = "main", cat: str = "solve",
             args: Optional[Dict[str, Any]] = None):
        """Wrap a host-side region as a complete event."""
        t0 = _now_us()
        try:
            yield self
        finally:
            self.complete(name, t0, _now_us() - t0, process=process,
                          thread=thread, cat=cat, args=args)

    @property
    def event_count(self) -> int:
        return len(self._events)

    def to_dict(self) -> Dict[str, Any]:
        """The trace document, timestamps rebased to start at 0."""
        stamped = [e["ts"] for e in self._events if "ts" in e]
        base = min(stamped) if stamped else 0.0
        events = []
        for e in self._events:
            e = dict(e)
            if "ts" in e:
                e["ts"] = e["ts"] - base
            events.append(e)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        """Serialize to a Perfetto-loadable ``trace.json``."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


@contextlib.contextmanager
def profiler_session(logdir: Optional[str]):
    """Optionally bracket a region with a ``jax.profiler`` trace.

    Yields True when a profiler session actually started (logdir given and
    the backend cooperated), else False. Never raises: on CPU test rigs
    and in environments without the profiler plugin this must stay a
    no-op so telemetry code paths are portable.
    """
    if not logdir:
        yield False
        return
    started = False
    try:
        from jax import profiler
        profiler.start_trace(logdir)
        started = True
    except Exception:
        yield False
        return
    try:
        yield True
    finally:
        if started:
            try:
                profiler.stop_trace()
            except Exception:
                pass
