"""Analytic per-iteration FLOP/byte cost model for the PSO engines.

The cuPSO result is a *schedule* result: the enhanced async variant wins
by trading gbest memory traffic against synchronization frequency, and the
crossover depends on (problem, d, n, block_n, sync_every) — not just on the
algorithm. This module prices one PSO iteration for every engine the repo
ships, so ``repro.core.autotune`` can rank candidate schedules analytically
before (optionally) measuring the top few:

  * jnp engines   — ``reduction | queue | queue_lock | async`` from
    ``repro.core.pso`` (vmap-batched by ``batch=S``).
  * Pallas kernels — ``queue`` (per-iteration ``queue_step_call``),
    ``queue_lock`` (fused, grid ``(iters, blocks)``) and ``async``
    (block-resident, grid ``(blocks, iters/sync_every)``), plus their
    swarm-major batched forms — the five pallas_calls in
    ``repro.kernels.pso_step``.

Three ingredient families, all inspectable (golden-filed in
tests/test_roofline.py):

1. **Fitness op mix** — ``FITNESS_MIX`` counts the adds/muls and
   transcendentals each built-in objective (``repro.core.fitness``) spends
   per particle-dimension, as written in its jnp source (one reduction add
   per dimension is folded in). Custom ``Problem`` objectives fall back to
   XLA's own accounting (``cost_analysis`` of the jitted ``max_fn`` at a
   reference shape, cached per content hash).

2. **Traffic** — per-iteration HBM bytes per engine, with the gbest
   *publication* traffic split out (``IterCost.gbest_bytes``): the async
   variants' pull+publish per block per chunk divides by ``sync_every`` —
   the paper's knob — while the synchronous variants pay every iteration.
   Adapter-lowered custom objectives additionally stream their hoisted
   const operands (``lower_statics``) once per grid step
   (``IterCost.const_bytes``).

3. **Scheduling overhead** — Pallas grid steps and host dispatches per
   iteration. Interpret-mode grid steps cost ~tens of microseconds (the
   committed ``async_sweep`` history fits ~27us/step on CPU), which is why
   the model sends this container to the jnp engines; a TPU fit shrinks
   the constant and flips the choice.

``Calibration`` turns counts into microseconds. ``fit_calibration`` fits
the machine constants from a committed ``benchmarks/BENCH_pso.json``
(table3 records calibrate the jnp throughput terms, async_sweep the
per-grid-step constant), refusing to mix hosts when the artifact records
``cpu_count``/``device_kind`` metadata that disagrees with this process.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Dict, Optional, Tuple, Union

import numpy as np

# Keep this module import-light: jax only loads for custom-objective
# accounting and bound lowering, so the tuner can price schedules without
# touching the device.

DTYPE_BYTES = {"float32": 4, "float64": 8, "bfloat16": 2, "float16": 2}

#: Default fraction of iterations on which the swarm best improves at
#: steady state — the paper's queue-algorithm premise (§4.1: <0.1%; we use
#: a conservative 2% so early-run behavior is not underpriced).
RARE_IMPROVE = 0.02

# --- advance (velocity/position update) op counts, per particle-dim -----
#: w*vel + c1*r1*(pbest-pos) + c2*r2*(gbest-pos): 5 mul + 4 add/sub.
VEL_FLOPS = 9
#: clip(vel) (2) + pos += vel (1) + clip(pos) (2).
POS_FLOPS = 5
#: pbest_pos where-select per element.
PBEST_SELECT_FLOPS = 1
#: per-particle pbest compare + fit select.
PBEST_FLOPS_PER_PARTICLE = 2
#: uniform draws per particle-dim per iteration (r1, r2).
RNG_DRAWS = 2
#: lax.switch bookkeeping per kernel grid step for hetero dispatch.
HETERO_SWITCH_FLOPS = 16.0


@dataclasses.dataclass(frozen=True)
class RuleMix:
    """Advance-op mix of one update rule, per particle-dim.

    The aggregation scaffold (queues, local bests, publication) is
    rule-independent; only the velocity/position chain and the RNG draw
    count change with ``PSOConfig(update_rule=...)``. Counted from the
    ``repro.core.update_rules`` source expressions the same way
    ``FITNESS_MIX`` counts the objectives."""

    vel_flops: float
    pos_flops: float
    rng_draws: int = RNG_DRAWS


#:   pso      w v + c1 r1 (p-x) + c2 r2 (g-x); clip; x+v; clip  -> 9 + 5
#:   sso      fresh = lo+(hi-lo)r2 (3); 3 cmp+select (6); clip (2); no vel
#:   lowcost  2 sub + 2 cmp + 2 select + 2 add (8); clips as pso (5)
RULE_MIX: Dict[str, RuleMix] = {
    "pso": RuleMix(VEL_FLOPS, POS_FLOPS),
    "sso": RuleMix(0.0, 11.0),
    "lowcost": RuleMix(8.0, POS_FLOPS),
}


def rule_op_mix(rule) -> RuleMix:
    """Mix for a rule name/instance; unlisted custom rules price as the
    canonical chain with their own declared ``rng_draws``."""
    from repro.core.update_rules import resolve_rule

    r = resolve_rule(rule)
    mix = RULE_MIX.get(r.name)
    if mix is None:
        mix = RuleMix(VEL_FLOPS, POS_FLOPS, r.rng_draws)
    return mix


@dataclasses.dataclass(frozen=True)
class OpMix:
    """Arithmetic mix of one objective evaluation.

    ``flops_per_dim`` counts adds/muls per particle per dimension (the sum
    reduction's add is folded in); ``transc_per_dim`` counts cos/exp/sqrt
    the same way; the ``*_per_particle`` fields hold the reduction tail
    (negation, scalar combines) paid once per particle.
    """

    flops_per_dim: float
    flops_per_particle: float = 0.0
    transc_per_dim: float = 0.0
    transc_per_particle: float = 0.0

    def flops(self, d: int, n: int) -> float:
        return n * (d * self.flops_per_dim + self.flops_per_particle)

    def transcendentals(self, d: int, n: int) -> float:
        return n * (d * self.transc_per_dim + self.transc_per_particle)


#: Op mix of the six built-ins, counted from their ``repro.core.fitness``
#: source expressions (golden-filed in tests/test_roofline.py):
#:   cubic      x³-0.8x²-1000x+8000 : 5 mul + 3 add + sum  -> 9/dim
#:   sphere     -Σx²                : 1 mul + sum          -> 2/dim + negate
#:   rosenbrock Σ100(b-a²)²+(1-a)²  : 4 mul + 4 add        -> 8/dim + negate
#:   griewank   Σx²/4000 - Πcos(x/√i) + 1 : 3 flops + div + cos per dim
#:   rastrigin  10d + Σ(x²-10cos2πx): 4 flops + cos-scale per dim
#:   ackley     -20e^(-.2√(Σx²/d)) - e^(Σcos2πx/d) + 20 + e
FITNESS_MIX: Dict[str, OpMix] = {
    "cubic": OpMix(9.0, 0.0),
    "sphere": OpMix(2.0, 1.0),
    "rosenbrock": OpMix(8.0, 1.0),
    "griewank": OpMix(4.0, 4.0, 1.0),
    "rastrigin": OpMix(5.0, 3.0, 1.0),
    "ackley": OpMix(4.0, 7.0, 1.0, 3.0),
}

_MEASURE_N = 64  # reference particle count for custom-objective accounting


@functools.lru_cache(maxsize=256)
def _measured_mix(cache_key, fn_id, d: int, dtype: str) -> OpMix:
    # fn_id keeps the lru entry alive only while the Problem object is;
    # cache_key (content hash) is the real identity.
    del fn_id
    prob = _MIX_PROBES.pop(cache_key)
    import jax

    compiled = jax.jit(prob.max_fn).lower(
        jax.ShapeDtypeStruct((_MEASURE_N, d), np.dtype(dtype))).compile()
    from .analysis import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    transc = float(cost.get("transcendentals", 0.0))
    per_elem = flops / (_MEASURE_N * d)
    return OpMix(flops_per_dim=per_elem,
                 transc_per_dim=transc / (_MEASURE_N * d))


_MIX_PROBES: Dict[Tuple, object] = {}


def fitness_op_mix(problem, d: int, dtype: str = "float32") -> OpMix:
    """Op mix for a registered name or ``Problem`` (measured fallback)."""
    from repro.core.problem import resolve_problem

    prob = resolve_problem(problem)
    mix = FITNESS_MIX.get(prob.name)
    if mix is not None and not prob.constrained:
        return mix
    if mix is not None and prob.constrained:
        # penalty mode evaluates the violation alongside the objective;
        # approximate the combined cost as 2x the raw mix.
        return OpMix(2 * mix.flops_per_dim, 2 * mix.flops_per_particle + 4,
                     2 * mix.transc_per_dim, 2 * mix.transc_per_particle)
    key = prob.cache_key()
    _MIX_PROBES.setdefault((key, d, dtype), prob)
    probe = _MIX_PROBES  # keep name for clarity
    try:
        return _measured_mix((key, d, dtype), id(prob), d, dtype)
    finally:
        probe.pop((key, d, dtype), None)


def const_operand_bytes(problem, d: int, block_n: int,
                        dtype: str = "float32") -> float:
    """Bytes of hoisted const operands an adapter-lowered kernel streams
    per grid step (``repro.kernels.pso_step.lower_statics``): the custom
    objective's captured arrays plus any per-dimension bound columns.
    Hand-tuned built-ins lower const-free and return 0."""
    from repro.core.problem import resolve_problem
    from repro.core.pso import PSOConfig

    prob = resolve_problem(problem)
    cfg = PSOConfig(dim=d, fitness=prob, dtype=dtype).resolved()
    from repro.kernels.pso_step import lower_statics, pad_dim

    _, consts = lower_statics(
        cfg.fitness, d=d, dpad=pad_dim(d), bn=block_n, dtype=cfg.jnp_dtype,
        min_pos=cfg.min_pos, max_pos=cfg.max_pos, max_v=cfg.max_v)
    return float(sum(np.asarray(c).nbytes for c in consts))


@dataclasses.dataclass(frozen=True)
class IterCost:
    """Priced work of ONE PSO iteration (whole batch, all swarms).

    ``gbest_bytes`` (publication traffic) and ``const_bytes`` (adapter
    const streaming) are *subsets* of ``bytes_hbm``, split out because they
    are the schedule-sensitive terms: publication divides by ``sync_every``
    on the async engines, const streaming scales with grid steps."""

    flops: float
    transcendentals: float
    bytes_hbm: float
    gbest_bytes: float
    const_bytes: float
    grid_steps: float      # Pallas grid steps per iteration (0 for jnp)
    dispatches: float      # host dispatches per iteration


def _blocks(n: int, block_n: Optional[int], backend: str) -> Tuple[int, int]:
    from repro.core.blocking import pick_block_n

    bn = block_n or pick_block_n(n, lane=(128 if backend == "kernel" else 1))
    return bn, max(1, n // bn)


def iteration_cost(variant: str, problem, d: int, n: int, *,
                   dtype: str = "float32", backend: str = "jnp",
                   block_n: Optional[int] = None, sync_every: int = 8,
                   batch: int = 1, hetero_table: int = 0,
                   rule: str = "pso",
                   rare: float = RARE_IMPROVE) -> IterCost:
    """Price one iteration of ``variant`` on ``backend``.

    ``hetero_table > 0`` marks a heterogeneous multi-problem batch with
    that many dispatch-table members: the vmapped jnp ``lax.switch``
    lowers to select_n (every branch evaluated -> fitness cost times the
    table size), while the kernels run a real conditional (one branch per
    grid step, plus small switch bookkeeping). ``sync_every`` only shapes
    the async terms. All counts scale linearly with ``batch``.
    """
    if variant not in ("reduction", "queue", "queue_lock", "async"):
        raise ValueError(f"unknown variant {variant!r}")
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "kernel" and variant == "reduction":
        raise ValueError("no reduction kernel exists")
    b = DTYPE_BYTES[dtype]
    sync_every = max(1, sync_every)
    mix = fitness_op_mix(problem, d, dtype)
    bn, nb = _blocks(n, block_n, backend)

    # --- flops ------------------------------------------------------------
    fit_mult = max(1, hetero_table) if backend == "jnp" else 1
    fit_flops = fit_mult * mix.flops(d, n)
    transc = fit_mult * mix.transcendentals(d, n)
    rmix = rule_op_mix(rule)
    adv = n * d * (rmix.vel_flops + rmix.pos_flops + PBEST_SELECT_FLOPS)
    pbest = n * PBEST_FLOPS_PER_PARTICLE
    rng = n * d * rmix.rng_draws  # scaled by Calibration.rng_flops later
    if variant == "reduction":
        agg = n + d + 1                      # unconditional argmax + gather
    elif variant in ("queue", "queue_lock"):
        agg = 2 * n + rare * (2 * n + d)     # cmp + any; rare argmax+gather
    else:  # async: per-block argmax every iter, publish every sync_every
        agg = n + nb * (1 + d) + (nb + d) / sync_every
    flops = fit_flops + adv + pbest + agg
    if backend == "kernel" and hetero_table:
        flops += HETERO_SWITCH_FLOPS * nb

    # --- bytes ------------------------------------------------------------
    # pos/vel/pbest_pos read+write (6 n d) + materialized r1/r2 (2 n d);
    # fit/pbest_fit read+write (4 n).
    state = b * (8 * n * d + 4 * n)
    consts = (const_operand_bytes(problem, d, bn, dtype)
              if backend == "kernel" else 0.0)
    if variant == "reduction":
        gbest = b * (d + 1) * 2
    elif variant in ("queue", "queue_lock"):
        gbest = b * (d + 1) * (1 + rare)
    else:
        # pull + predicated publish per block per chunk, plus the per-
        # iteration block-local best maintenance (read+select per block).
        gbest = (b * 2 * (d + 1) * nb / sync_every
                 + b * 2 * (d + 1) * nb)
    if backend == "kernel" and variant == "async":
        # block-resident chunks: state traffic amortizes over the chunk.
        state = state / sync_every
        const_traffic = consts * nb / sync_every
    elif backend == "kernel":
        const_traffic = consts * nb
    else:
        const_traffic = 0.0
    bytes_hbm = state + gbest + const_traffic

    # --- scheduling -------------------------------------------------------
    if backend == "jnp":
        grid_steps, dispatches = 0.0, 0.0    # one dispatch per RUN, not iter
    elif variant == "queue":
        grid_steps, dispatches = float(nb), 1.0   # per-iteration kernel
    elif variant == "queue_lock":
        grid_steps, dispatches = float(nb), 0.0
    else:
        grid_steps, dispatches = nb / sync_every, 0.0

    s = max(1, batch)
    return IterCost(flops=s * flops, transcendentals=s * transc,
                    bytes_hbm=s * bytes_hbm, gbest_bytes=s * gbest,
                    const_bytes=s * const_traffic,
                    grid_steps=s * grid_steps, dispatches=s * dispatches)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Machine constants that turn an ``IterCost`` into microseconds.

    Defaults describe a mid-range CPU running jit-compiled XLA with Pallas
    in interpret mode (this container); ``fit_calibration`` replaces them
    with constants fitted from benchmark history."""

    flops_per_us: float = 1500.0      # effective element-op throughput
    bytes_per_us: float = 6000.0      # effective stream bandwidth
    iter_overhead_us: float = 0.35    # fori_loop/bookkeeping per iteration
    dispatch_us: float = 50.0         # host -> device dispatch
    grid_step_us: float = 25.0        # per Pallas grid step (interpret!)
    transcendental_flops: float = 8.0  # one cos/exp ~ this many flops
    rng_flops: float = 12.0           # one counter-RNG draw, per element
    source: str = "default"

    def us_per_iter(self, cost: IterCost, rng_elems: float = 0.0) -> float:
        """Roofline estimate: max(compute, memory) + scheduling terms."""
        flops = (cost.flops
                 + cost.transcendentals * self.transcendental_flops
                 + rng_elems * self.rng_flops)
        work = max(flops / self.flops_per_us,
                   cost.bytes_hbm / self.bytes_per_us)
        return (self.iter_overhead_us + work
                + cost.grid_steps * self.grid_step_us
                + cost.dispatches * self.dispatch_us)


DEFAULT_CALIBRATION = Calibration()


def estimate_us_per_iter(variant: str, problem, d: int, n: int, *,
                         dtype: str = "float32", backend: str = "jnp",
                         block_n: Optional[int] = None, sync_every: int = 8,
                         batch: int = 1, hetero_table: int = 0,
                         rule: str = "pso",
                         calib: Calibration = DEFAULT_CALIBRATION) -> float:
    """One-call convenience: ``iteration_cost`` -> microseconds."""
    cost = iteration_cost(variant, problem, d, n, dtype=dtype,
                          backend=backend, block_n=block_n,
                          sync_every=sync_every, batch=batch,
                          hetero_table=hetero_table, rule=rule)
    return calib.us_per_iter(
        cost, rng_elems=batch * n * d * rule_op_mix(rule).rng_draws)


# --------------------------------------------------------------------------
# Calibration fitting from benchmark history (BENCH_pso.json).
# --------------------------------------------------------------------------

def _host_fingerprint() -> Dict[str, object]:
    import platform

    fp = {"host": os.environ.get("BENCH_HOST_ID") or platform.node(),
          "cpu_count": os.cpu_count()}
    try:
        import jax
        fp["device_kind"] = jax.devices()[0].device_kind
    except Exception:
        fp["device_kind"] = None
    return fp


def hosts_comparable(meta: Dict) -> bool:
    """True unless the artifact records host metadata that disagrees with
    this process. Artifacts predating the cpu_count/device_kind fields are
    treated as unknown-but-usable (the fit is marked unverified)."""
    fp = _host_fingerprint()
    for key in ("cpu_count", "device_kind", "host"):
        if meta.get(key) is not None and fp.get(key) is not None \
                and meta[key] != fp[key]:
            return False
    return True


def _fit_jnp_terms(records: Dict[str, Dict]) -> Optional[Tuple[float, float]]:
    """(flops_per_us, iter_overhead_us) from table3 jnp records (d=1 cubic,
    flop-bound: the memory term is not separately identifiable there)."""
    rows = []
    for name, rec in records.items():
        parts = name.split("/")
        if (len(parts) != 3 or parts[0] != "table3"
                or parts[2] not in ("reduction", "queue", "queue_lock")):
            continue
        n = int(parts[1].lstrip("p"))
        cost = iteration_cost(parts[2], "cubic", 1, n)
        flops = (cost.flops + RNG_DRAWS * n * 1 *
                 DEFAULT_CALIBRATION.rng_flops)
        rows.append((flops, rec["us_per_call"]))
    if len(rows) < 3:
        return None
    a = np.array([[f, 1.0] for f, _ in rows])
    y = np.array([t for _, t in rows])
    (inv_f, c), *_ = np.linalg.lstsq(a, y, rcond=None)
    if inv_f <= 0:
        return None
    return 1.0 / inv_f, max(float(c), 0.0)


def _fit_grid_step(records: Dict[str, Dict]) -> Optional[float]:
    """Per-grid-step microseconds from the async_sweep kernel records:
    us/iter = base + grid_step_us * blocks / sync_every."""
    rows = []
    for name, rec in records.items():
        parts = name.split("/")
        if (len(parts) != 3 or parts[0] != "async_sweep"
                or "_b" not in parts[1]):
            continue
        try:
            nb = (int(parts[1].split("_n")[1].split("_b")[0])
                  // int(parts[1].split("_b")[1]))
        except (IndexError, ValueError):
            continue
        if parts[2] == "sync_kernel":
            rows.append((float(nb), rec["us_per_call"]))
        elif parts[2].startswith("sync_every_"):
            k = int(parts[2].rsplit("_", 1)[1])
            rows.append((nb / k, rec["us_per_call"]))
    if len(rows) < 2:
        return None
    a = np.array([[g, 1.0] for g, _ in rows])
    y = np.array([t for _, t in rows])
    (g, _base), *_ = np.linalg.lstsq(a, y, rcond=None)
    return float(g) if g > 0 else None


def fit_calibration(bench: Union[str, Dict, None]) -> Calibration:
    """Fit machine constants from a ``BENCH_pso.json`` document or path.

    Returns ``DEFAULT_CALIBRATION`` (source ``"default"``) when the
    artifact is missing/unreadable, and a host-mismatch default (source
    names the reason) when the artifact's recorded host fingerprint —
    ``host``/``cpu_count``/``device_kind`` in the meta — disagrees with
    this process: model fits must never mix hosts."""
    if bench is None:
        return DEFAULT_CALIBRATION
    if isinstance(bench, str):
        try:
            with open(bench) as f:
                bench = json.load(f)
        except (OSError, ValueError):
            return DEFAULT_CALIBRATION
    meta = bench.get("meta", {})
    if not hosts_comparable(meta):
        return dataclasses.replace(
            DEFAULT_CALIBRATION,
            source=f"default(host-mismatch:{meta.get('host')})")
    records = {r["name"]: r for r in bench.get("benchmarks", [])
               if r.get("us_per_call", 0) > 0}
    kw = {}
    jnp_fit = _fit_jnp_terms(records)
    if jnp_fit is not None:
        kw["flops_per_us"], kw["iter_overhead_us"] = jnp_fit
    grid = _fit_grid_step(records)
    if grid is not None:
        kw["grid_step_us"] = grid
    if not kw:
        return DEFAULT_CALIBRATION
    verified = all(meta.get(k) is not None
                   for k in ("cpu_count", "device_kind"))
    src = "bench-fit" if verified else "bench-fit(unverified-host)"
    return dataclasses.replace(DEFAULT_CALIBRATION, source=src, **kw)
