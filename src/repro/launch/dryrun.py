import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
the production meshes and extract roofline terms (deliverables (e)+(g)).

MUST be run as its own process (the two lines above fake 512 CPU devices
before jax initializes — never set globally). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out reports/dryrun.json

Each cell is cached into --out as it finishes, so reruns resume. A cell
"passes" when .lower().compile() succeeds; memory_analysis() and
cost_analysis() are recorded for EXPERIMENTS.md.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_arch, list_archs  # noqa: E402
from repro.launch import sharding  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (make_prefill_step, make_serve_step,  # noqa: E402
                                make_train_step)
from repro.models import zoo  # noqa: E402
from repro.roofline import analysis as ra  # noqa: E402


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             verbose: bool = True):
    from repro.launch.mesh import data_axes
    from repro.models.policy import activation_policy
    cfg = get_arch(arch_name)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    with activation_policy(mesh, data_axes(mesh), "model"):
        return _run_cell_inner(cfg, arch_name, shape_name, multi_pod, mesh,
                               verbose)


def _run_cell_inner(cfg, arch_name, shape_name, multi_pod, mesh, verbose):
    cell = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()

    params_shape = zoo.abstract_params(cfg)
    pspecs_p = sharding.param_pspecs(cfg, params_shape, mesh)
    pspecs = sharding.to_named(pspecs_p, mesh)
    named = lambda tree: sharding.to_named(tree, mesh)

    if cell.kind == "train":
        train_step, opt_init = make_train_step(cfg)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        ospecs = named(sharding.opt_pspecs(cfg, opt_shape, mesh, pspecs_p))
        batch = zoo.input_specs(cfg, shape_name)
        bspecs = named(sharding.batch_pspecs(cfg, shape_name, mesh))
        lowered = jax.jit(
            train_step,
            in_shardings=(pspecs, ospecs, bspecs),
            out_shardings=(pspecs, ospecs, None),
        ).lower(params_shape, opt_shape, batch)
        kind, tokens = "train", cell.seq_len * cell.global_batch
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg)
        batch = zoo.input_specs(cfg, shape_name)
        bspecs = named(sharding.batch_pspecs(cfg, shape_name, mesh))
        lowered = jax.jit(
            step, in_shardings=(pspecs, bspecs), out_shardings=None,
        ).lower(params_shape, batch)
        kind, tokens = "prefill", cell.seq_len * cell.global_batch
    else:  # decode
        step = make_serve_step(cfg)
        cache_shape = zoo.abstract_cache(cfg, shape_name)
        cspecs = named(
            sharding.cache_pspecs(cfg, cache_shape, shape_name, mesh))
        ins = zoo.input_specs(cfg, shape_name)
        bspec = named(sharding.batch_pspecs(cfg, shape_name, mesh))
        lowered = jax.jit(
            step,
            in_shardings=(pspecs, cspecs, bspec["cache_len"],
                          bspec["token"]),
            out_shardings=(None, cspecs),
        ).lower(params_shape, cache_shape, ins["cache_len"],
                ins["token"])
        kind, tokens = "decode", cell.global_batch
    t_lower = time.time() - t0

    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    roof = ra.analyze(arch_name, shape_name, mesh_name, chips, compiled,
                      cfg, params_shape, kind, tokens, hlo_text=hlo)
    # Whole-graph cost_analysis under-counts scan bodies (×1, not ×L):
    # keep it as *_scanned, and use the piecewise totals for the roofline.
    scanned = {"flops_scanned": roof.flops_total,
               "bytes_scanned": roof.bytes_total,
               "coll_scanned": roof.coll_bytes_per_chip}
    result_pieces = None
    if not multi_pod:
        # §Roofline is single-pod only; the multi-pod pass proves the
        # "pod" axis shards (compile + memory analysis).
        from repro.roofline.piecewise import analyze_cell_piecewise
        pw = analyze_cell_piecewise(cfg, shape_name, mesh)
        roof.flops_total = pw["flops_dev"] * chips
        roof.bytes_total = pw["bytes_dev"] * chips
        roof.coll_bytes_per_chip = pw["coll_bytes_dev"]
        roof.coll_count = pw["coll_count"]
        result_pieces = pw["pieces"]
    mem = compiled.memory_analysis()
    result = roof.to_dict()
    if result_pieces is not None:
        result["pieces"] = result_pieces
    result.update(scanned)
    result.update(
        status="ok", t_lower_s=round(t_lower, 1),
        t_compile_s=round(t_compile, 1),
        mem_argument_gb=getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        mem_temp_gb=getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        mem_output_gb=getattr(mem, "output_size_in_bytes", 0) / 1e9,
        hlo_lines=hlo.count("\n"),
        params_total=ra.count_params(params_shape),
        params_active=ra.count_active_params(cfg, params_shape),
    )
    if verbose:
        print(f"  memory_analysis: arg={result['mem_argument_gb']:.2f}GB "
              f"temp={result['mem_temp_gb']:.2f}GB "
              f"out={result['mem_output_gb']:.2f}GB (per device)")
        print(f"  cost_analysis: flops/dev={roof.flops_total/chips:.3e} "
              f"bytes/dev={roof.bytes_total/chips:.3e}")
        print(f"  collectives: {roof.coll_count} ops, "
              f"{roof.coll_bytes_per_chip/1e9:.3f} GB/chip")
    return result


def run_pso_cell(dim: int, particles: int, multi_pod: bool):
    """Bonus rows: the paper's own workload lowered on the production mesh."""
    from repro.core import PSOConfig
    from repro.core.distributed import (init_sharded_swarm,
                                        make_distributed_run, swarm_pspec)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    cfg = PSOConfig(dim=dim, particle_cnt=particles, fitness="cubic")
    axes = ("pod", "data") if multi_pod else ("data",)
    runner = make_distributed_run(cfg, mesh, iters=100, variant="queue",
                                  exchange_interval=10, particle_axes=axes)
    state_shape = jax.eval_shape(
        lambda: init_sharded_swarm(cfg, 0, mesh, particle_axes=axes))
    lowered = runner.lower(state_shape)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    cost = ra.cost_analysis_dict(compiled)
    coll = ra.collective_bytes(hlo)
    mem = compiled.memory_analysis()
    # model flops: 100 iters × N × (~10 flops/dim update + fitness ~5/dim)
    mf = 100.0 * particles * dim * 15.0
    return {
        "arch": f"pso-cubic-{dim}d", "shape": f"n{particles}",
        "mesh": mesh_name, "chips": chips, "status": "ok",
        "flops_total": float(cost.get("flops", 0.0)) * chips,
        "bytes_total": float(cost.get("bytes accessed", 0.0)) * chips,
        "coll_bytes_per_chip": coll["total"], "coll_count": coll["count"],
        "model_flops": mf,
        "mem_temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "t_compute": float(cost.get("flops", 0.0)) / ra.PEAK_FLOPS,
        "t_memory": float(cost.get("bytes accessed", 0.0)) / ra.HBM_BW,
        "t_collective": coll["total"] / ra.ICI_BW,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun.json")
    ap.add_argument("--pso", action="store_true",
                    help="also run the PSO bonus rows")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    def save():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)

    for arch in archs:
        cfg = get_arch(arch)
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                if key in results and results[key].get("status") in ("ok", "skip"):
                    continue
                if not cfg.supports(shape):
                    results[key] = {
                        "status": "skip",
                        "reason": "full-attention arch; long_500k is "
                                  "defined for sub-quadratic archs only "
                                  "(DESIGN.md §5)"}
                    save()
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    results[key] = run_cell(arch, shape, mp)
                    print(f"[dryrun] {key} OK "
                          f"(lower {results[key]['t_lower_s']}s, "
                          f"compile {results[key]['t_compile_s']}s)",
                          flush=True)
                except Exception as e:
                    results[key] = {"status": "fail", "error": str(e)[:2000],
                                    "traceback":
                                        traceback.format_exc()[-4000:]}
                    print(f"[dryrun] {key} FAIL: {e}", flush=True)
                save()

    if args.pso:
        for dim, n in ((1, 1 << 20), (120, 1 << 20)):
            for mp in meshes:
                key = f"pso-cubic-{dim}d|n{n}|{'2x16x16' if mp else '16x16'}"
                if key in results and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    results[key] = run_pso_cell(dim, n, mp)
                    print(f"[dryrun] {key} OK", flush=True)
                except Exception as e:
                    results[key] = {"status": "fail", "error": str(e)[:2000]}
                    print(f"[dryrun] {key} FAIL: {e}", flush=True)
                save()

    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    skip = sum(1 for v in results.values() if v.get("status") == "skip")
    fail = sum(1 for v in results.values() if v.get("status") == "fail")
    print(f"[dryrun] done: {ok} ok, {skip} skip, {fail} fail")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
