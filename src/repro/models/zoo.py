"""Uniform model API over all families + ShapeDtypeStruct input specs for
the dry-run.

  init_params(cfg, key)                 -> params pytree
  loss_fn(cfg, params, batch)           -> scalar loss
  decode_fn(cfg, params, cache, n, tok) -> (logits, new_cache)
  init_cache(cfg, batch, max_len)       -> cache pytree
  input_specs(cfg, shape_name)          -> dict of ShapeDtypeStructs
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from . import encdec, transformer

Params = Dict[str, Any]


def init_params(cfg: ArchConfig, key) -> Params:
    if cfg.encdec:
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def abstract_params(cfg: ArchConfig):
    """Shape-only params for dry-run lowering (no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def loss_fn(cfg: ArchConfig, params: Params, batch):
    if cfg.encdec:
        return encdec.loss_fn(cfg, params, batch)
    return transformer.loss_fn(cfg, params, batch)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.encdec:
        # Cross-cache sized by the encoder context (stub: 1500 frames).
        return encdec.init_cache(cfg, batch, max_len, enc_len=1500)
    return transformer.init_cache(cfg, batch, max_len)


def decode_fn(cfg: ArchConfig, params: Params, cache, cache_len, token):
    if cfg.encdec:
        return encdec.decode_step(cfg, params, cache, cache_len, token)
    return transformer.decode_step(cfg, params, cache, cache_len, token)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str,
                override_batch: int = 0) -> Dict[str, Any]:
    """Model inputs for one shape cell (weak-type-correct stand-ins)."""
    cell = SHAPES[shape_name]
    b = override_batch or cell.global_batch
    s = cell.seq_len
    i32 = jnp.int32
    f32 = jnp.dtype(cfg.param_dtype)
    if cell.kind in ("train", "prefill"):
        if cfg.encdec:
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.vision_prefix:
            st = s - cfg.vision_prefix
            return {
                "vision_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.vision_prefix, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "labels": jax.ShapeDtypeStruct((b, st), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a cache of length s
    return {"token": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_len": jax.ShapeDtypeStruct((), i32)}


def abstract_cache(cfg: ArchConfig, shape_name: str):
    cell = SHAPES[shape_name]
    return jax.eval_shape(
        lambda: init_cache(cfg, cell.global_batch, cell.seq_len))


def make_batch(cfg: ArchConfig, shape_name: str, batch: int, seq: int,
               key) -> Dict[str, Any]:
    """Concrete random batch for smoke tests (reduced sizes)."""
    cell = SHAPES[shape_name]
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    if cell.kind in ("train", "prefill"):
        if cfg.encdec:
            return {
                "frames": jax.random.normal(k2, (batch, seq, cfg.d_model), dt),
                "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
                "labels": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
            }
        if cfg.vision_prefix:
            st = max(seq - cfg.vision_prefix, 8)
            return {
                "vision_embeds": jax.random.normal(
                    k2, (batch, cfg.vision_prefix, cfg.d_model), dt),
                "tokens": jax.random.randint(k1, (batch, st), 0, cfg.vocab),
                "labels": jax.random.randint(k1, (batch, st), 0, cfg.vocab),
            }
        return {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab),
                "labels": jax.random.randint(k1, (batch, seq), 0, cfg.vocab)}
    return {"token": jax.random.randint(k1, (batch, 1), 0, cfg.vocab),
            "cache_len": jnp.asarray(seq - 1, jnp.int32)}
