"""Benchmark fitness functions for PSO.

The paper (§6.1, Eq. 3) uses the Cubic function and a *maximization*
convention ("if fit_i > pbest_fit_i then update"): larger fitness is better.
All functions here follow that convention; classical minimization benchmarks
(sphere, rosenbrock, ...) are negated so that every landscape is maximized.

Every function maps ``pos[..., D] -> fit[...]`` and is pure jnp so it can be
used inside jit, grad (not needed for PSO, but free), shard_map and the
Pallas reference oracle. ``FITNESS_FNS`` is the registry used by configs and
the benchmark harness; ``FITNESS_IDS`` gives each function a stable integer
id so the Pallas kernel can select it at trace time.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

Array = jnp.ndarray


def cubic(pos: Array) -> Array:
    """Paper Eq. 3: f = sum_i x_i^3 - 0.8 x_i^2 - 1000 x_i + 8000 (maximize)."""
    x = pos
    return jnp.sum(x * x * x - 0.8 * (x * x) - 1000.0 * x + 8000.0, axis=-1)


def sphere(pos: Array) -> Array:
    """Negated sphere: max at origin, f(0) = 0."""
    return -jnp.sum(pos * pos, axis=-1)


def rosenbrock(pos: Array) -> Array:
    """Negated Rosenbrock (D >= 2; for D == 1 degenerates to -(1-x)^2)."""
    x = pos
    if x.shape[-1] == 1:
        return -jnp.squeeze((1.0 - x) ** 2, axis=-1)
    a, b = x[..., :-1], x[..., 1:]
    return -jnp.sum(100.0 * (b - a * a) ** 2 + (1.0 - a) ** 2, axis=-1)


def griewank(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    idx = jnp.arange(1, d + 1, dtype=x.dtype)
    s = jnp.sum(x * x, axis=-1) / 4000.0
    p = jnp.prod(jnp.cos(x / jnp.sqrt(idx)), axis=-1)
    return -(s - p + 1.0)


def rastrigin(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    return -(10.0 * d + jnp.sum(x * x - 10.0 * jnp.cos(2.0 * jnp.pi * x), axis=-1))


def ackley(pos: Array) -> Array:
    x = pos
    d = x.shape[-1]
    s1 = jnp.sqrt(jnp.sum(x * x, axis=-1) / d)
    s2 = jnp.sum(jnp.cos(2.0 * jnp.pi * x), axis=-1) / d
    return -(-20.0 * jnp.exp(-0.2 * s1) - jnp.exp(s2) + 20.0 + jnp.e)


FITNESS_FNS: Dict[str, Callable[[Array], Array]] = {
    "cubic": cubic,
    "sphere": sphere,
    "rosenbrock": rosenbrock,
    "griewank": griewank,
    "rastrigin": rastrigin,
    "ackley": ackley,
}

# Stable integer ids for kernel-side selection (compile-time static).
FITNESS_IDS: Dict[str, int] = {name: i for i, name in enumerate(FITNESS_FNS)}

# Search-domain defaults per function (paper: cubic on [-100, 100]).
DEFAULT_BOUNDS: Dict[str, tuple] = {
    "cubic": (-100.0, 100.0),
    "sphere": (-100.0, 100.0),
    "rosenbrock": (-30.0, 30.0),
    "griewank": (-600.0, 600.0),
    "rastrigin": (-5.12, 5.12),
    "ackley": (-32.0, 32.0),
}
