"""Telemetry: in-kernel contention counters vs the eager oracle, the
universal convergence traces, and the Perfetto/Prometheus exporters.

The counter pins here are exact event counts, not tolerances: the
kernels and the ref.py oracles count at the same program points, so any
drift in either is a semantic change. ``block_improvements`` counts
per BLOCK-INVOCATION (one event per (iteration, block) where any lane
improved its pbest), so it scales with ``block_n`` — the pinned shape
uses ``block_n=64`` (two blocks of 128 particles) throughout.
"""
import json

import numpy as np
import pytest

import repro
from repro.api import History, Method, solve, solve_many
from repro.core import PSOConfig, batch_row, init_batch, init_swarm
from repro.kernels import ops, ref
from repro.serving.metrics import LatencyStat, ServingMetrics
from repro.telemetry import (COUNTER_NAMES, SLOTS_PER_SWARM, KernelCounters,
                             TraceWriter, prometheus_text, zero_counts)

# the pinned validation shape: dim=2 cubic, 128 particles, two blocks
DIM, N, BN, ITERS, SEED = 2, 128, 64, 12, 5
PINNED = {"queue_updates": 1, "publications": 1, "block_improvements": 11}


def _cfg(fitness="cubic", dim=DIM):
    return PSOConfig(dim=dim, particle_cnt=N, fitness=fitness).resolved()


def _oracle_kwargs(cfg, dim):
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = dim
    return kw


def _oracle_counts(cfg, s, iters, *, sync_every=None):
    """Eager-oracle event counts for the same run."""
    dim = s.pos.shape[1]
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s, dim)
    kw = _oracle_kwargs(cfg, dim)
    fitness = kw.pop("fitness")
    cnt = {}
    if sync_every is None:
        ref.run_fused_oracle(int(s.seed), int(s.iteration), pos, vel, pbp,
                             pbf, gp, gf, iters, BN, fitness=fitness,
                             counters=cnt, **kw)
    else:
        ref.run_fused_async_oracle(int(s.seed), int(s.iteration), pos, vel,
                                   pbp, pbf, gp, float(gf[0]), iters, BN,
                                   sync_every, fitness=fitness,
                                   counters=cnt, **kw)
    return {k: cnt.get(k, 0) for k in COUNTER_NAMES}


# ---------------------------------------------------------------- counters

def test_sync_kernel_counters_match_oracle():
    cfg = _cfg()
    s = init_swarm(cfg, SEED)
    _, cnt = ops.run_queue_lock_fused(cfg, s, iters=ITERS, block_n=BN,
                                      telemetry=True)
    got = KernelCounters.from_array(cnt).as_dict()
    assert got == _oracle_counts(cfg, s, ITERS) == PINNED


def test_async_kernel_counters_match_oracle():
    cfg = _cfg()
    s = init_swarm(cfg, SEED)
    _, cnt = ops.run_queue_lock_fused_async(cfg, s, iters=ITERS,
                                            sync_every=4, block_n=BN,
                                            telemetry=True)
    got = KernelCounters.from_array(cnt).as_dict()
    assert got == _oracle_counts(cfg, s, ITERS, sync_every=4)


def test_batched_counters_match_standalone():
    """Row s of the batched counter buffer == the standalone run's."""
    cfg = _cfg()
    b = init_batch(cfg, (5, 6, 7))
    _, cnt = ops.run_queue_lock_fused_batch(cfg, b, iters=ITERS, block_n=BN,
                                            telemetry=True)
    rows = KernelCounters.rows(cnt)
    assert len(rows) == 3 and cnt.size == 3 * SLOTS_PER_SWARM
    for i in (0, 1, 2):
        _, c1 = ops.run_queue_lock_fused(cfg, batch_row(b, i), iters=ITERS,
                                         block_n=BN, telemetry=True)
        assert rows[i] == KernelCounters.from_array(c1)


def test_counters_disabled_by_default():
    cfg = _cfg()
    s = init_swarm(cfg, SEED)
    out = ops.run_queue_lock_fused(cfg, s, iters=2, block_n=BN)
    assert hasattr(out, "gbest_fit")        # the state itself, not a pair


def test_counters_additive_across_chunks():
    """Chunked launches sum to the uninterrupted run's counts."""
    cfg = _cfg()
    s = init_swarm(cfg, SEED)
    tot = None
    for k in (5, 4, 3):
        s, cnt = ops.run_queue_lock_fused(cfg, s, iters=k, block_n=BN,
                                          telemetry=True)
        c = KernelCounters.from_array(cnt)
        tot = c if tot is None else tot + c
    assert tot.as_dict() == PINNED


def test_kernel_counters_helpers():
    z = zero_counts(2)
    assert z.shape == (2 * SLOTS_PER_SWARM,) and int(z.sum()) == 0
    c = KernelCounters(queue_updates=1, publications=2,
                       block_improvements=3)
    assert (c + c).as_dict() == {"queue_updates": 2, "publications": 4,
                                 "block_improvements": 6}
    with pytest.raises(ValueError):
        KernelCounters.from_array(np.zeros(4, np.int32))


# ------------------------------------------------------------- api surface

def test_result_telemetry():
    r = solve("cubic", dim=DIM, particles=N, iters=ITERS, seed=SEED,
              variant="queue_lock", backend="kernel", block_n=BN,
              telemetry=True)
    assert isinstance(r.telemetry, KernelCounters)
    assert r.telemetry.as_dict() == PINNED
    # off by default: no counter plumbing in the result
    r0 = solve("cubic", dim=DIM, particles=N, iters=ITERS, seed=SEED,
               variant="queue_lock", backend="kernel", block_n=BN)
    assert r0.telemetry is None
    assert float(r0.state.gbest_fit) == float(r.state.gbest_fit)


def test_telemetry_method_validation():
    with pytest.raises(ValueError, match="telemetry"):
        Method(variant="queue_lock", backend="jnp", telemetry=True)
    with pytest.raises(ValueError, match="telemetry"):
        Method(variant="queue", telemetry=True)   # no queue kernel
    with pytest.raises(ValueError, match="telemetry"):
        Method(variant="queue_lock", islands=2, telemetry=True)
    # telemetry alone resolves to the kernel backend
    m = Method(variant="queue_lock", telemetry=True)
    assert m.resolve_backend() == "kernel"


def test_record_history_on_kernel_backend():
    """The former ValueError combo: history via chunk-boundary readback."""
    r = solve("cubic", dim=DIM, particles=N, iters=ITERS, seed=SEED,
              variant="queue_lock", backend="kernel", block_n=BN,
              record_history=True, telemetry=True)
    h = r.history
    assert isinstance(h, History) and len(h) == ITERS
    assert h.iteration[-1] == ITERS
    assert float(h.gbest_fit[-1]) == float(r.state.gbest_fit)
    assert np.all(np.diff(h.gbest_fit) >= 0)      # gbest is monotone
    assert r.telemetry.as_dict() == PINNED        # counters ride along
    # async kernel: sampled at sync_every publication boundaries
    ra = solve("cubic", dim=DIM, particles=N, iters=ITERS, seed=SEED,
               variant="async", backend="kernel", block_n=BN, sync_every=4,
               record_history=True)
    assert list(ra.history.iteration) == [4, 8, 12]
    assert float(ra.history.gbest_fit[-1]) == float(ra.state.gbest_fit)


def test_record_history_islands_still_precise_error():
    with pytest.raises(ValueError, match="single-device"):
        Method(variant="queue", islands=2, record_history=True)


def test_solve_many_row_histories():
    seeds = (5, 6, 7)
    res = solve_many("cubic", seeds, dim=DIM, particles=N, iters=ITERS,
                     variant="queue_lock", backend="kernel", block_n=BN,
                     record_history=True, telemetry=True)
    assert len(res) == 3
    for i, r in enumerate(res):
        single = solve("cubic", dim=DIM, particles=N, iters=ITERS,
                       seed=seeds[i], variant="queue_lock",
                       backend="kernel", block_n=BN, record_history=True,
                       telemetry=True)
        assert float(r.history.gbest_fit[-1]) == float(r.state.gbest_fit)
        assert r.history.iteration[-1] == ITERS
        assert r.telemetry == single.telemetry
        np.testing.assert_array_equal(r.history.gbest_fit,
                                      single.history.gbest_fit)


def test_solve_many_hetero_histories():
    res = solve_many(problems=["cubic", "sphere", "rastrigin"],
                     seeds=(5, 6, 7), dim=DIM, particles=N, iters=ITERS,
                     variant="queue_lock", backend="kernel", block_n=BN,
                     record_history=True, telemetry=True)
    assert len(res) == 3
    pins = [PINNED,
            {"queue_updates": 3, "publications": 3,
             "block_improvements": 24},
            {"queue_updates": 4, "publications": 4,
             "block_improvements": 24}]
    for r, pin in zip(res, pins):
        assert r.telemetry.as_dict() == pin
        assert float(r.history.gbest_fit[-1]) == float(r.state.gbest_fit)


# ---------------------------------------------------------------- exporters

def test_trace_writer_schema(tmp_path):
    tw = TraceWriter()
    tw.complete("chunk", 100.0, 50.0, process="solver", thread="chunks",
                cat="solve", args={"iters": 4})
    tw.instant("admit t0", 120.0, process="serving", thread="lane 0")
    tw.counter("lane 0 fill", 130.0, {"active": 3, "idle": 1})
    p = tmp_path / "trace.json"
    tw.write(str(p))
    doc = json.load(open(p))
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    phs = {e["ph"] for e in evs}
    assert {"M", "X", "i", "C"} <= phs
    for e in evs:
        assert {"name", "ph", "pid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] == 50.0 and "ts" in e
    # ts rebased: earliest non-meta event sits at 0
    tss = [e["ts"] for e in evs if "ts" in e]
    assert min(tss) == 0.0


def test_prometheus_exposition():
    m = ServingMetrics()
    m.inc("completed", 3)
    m.observe("e2e_us", 100.0)
    m.observe("e2e_us", 300.0)
    text = m.prometheus(kernel_counters=PINNED)
    lines = text.splitlines()
    assert any(l.startswith("repro_completed_total 3") for l in lines)
    assert "# TYPE repro_completed_total counter" in lines
    assert "# TYPE repro_uptime_seconds gauge" in lines
    assert any('repro_span_latency_microseconds{span="e2e_us",quantile='
               in l for l in lines)
    assert 'repro_span_latency_microseconds_count{span="e2e_us"} 2' in lines
    assert "repro_kernel_publications_total 1" in lines
    assert "repro_kernel_block_improvements_total 11" in lines
    # bare-function path with a custom prefix
    t2 = prometheus_text(m.snapshot(), prefix="pso")
    assert any(l.startswith("pso_completed_total") for l in t2.splitlines())


def test_solve_stream_trace_and_history(tmp_path):
    from repro.api import solve_stream
    from repro.launch.serve import SolveRequest
    reqs = [SolveRequest(fitness="cubic", dim=DIM, particle_cnt=N,
                         iters=12, seed=5, variant="async", sync_every=4),
            SolveRequest(fitness="sphere", dim=3, particle_cnt=N,
                         iters=16, seed=6, variant="async", sync_every=4),
            SolveRequest(fitness="cubic", dim=DIM, particle_cnt=N,
                         iters=12, seed=9, variant="queue")]
    p = tmp_path / "trace.json"
    res = solve_stream(reqs, lane_width=4, record_history=True,
                       trace_path=str(p))
    for r in res[:2]:        # lane-riding async rows get histories
        h = r.history
        assert h is not None and h.iteration[-1] == r.request.iters
        assert float(h.gbest_fit[-1]) == pytest.approx(r.gbest_fit)
    assert res[2].history is None           # standalone fallback: no lane
    doc = json.load(open(p))
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert any(n.startswith("admit t") for n in names)
    assert any(n.startswith("chunk ") for n in names)
    assert any(n.startswith("request t") for n in names)
    assert any(n.startswith("standalone t") for n in names)
    for e in evs:
        assert {"name", "ph", "pid"} <= set(e)


# ------------------------------------------------------------ LatencyStat

def test_latency_stat_percentile_edges():
    st = LatencyStat()
    assert st.percentile(0) == 0.0 and st.percentile(100) == 0.0  # empty
    st.add(42.0)
    for q in (0, 50, 100):
        assert st.percentile(q) == 42.0                      # single sample
    st.add(10.0)
    assert st.percentile(0) == 10.0 and st.percentile(100) == 42.0


def test_latency_stat_merge_from_overflow():
    """Both reservoirs past cap: exact count/total, sane percentiles."""
    cap = 8
    a, b = ServingMetrics(span_cap=cap), ServingMetrics(span_cap=cap)
    for i in range(20):
        a.observe("x_us", 100.0)
    for i in range(30):
        b.observe("x_us", 200.0)
    a.merge_from(b)
    st = a.span("x_us")
    assert st.count == 50                                    # exact
    assert st.total_us == pytest.approx(20 * 100.0 + 30 * 200.0)
    assert st.mean_us == pytest.approx(160.0)
    assert len(st._samples) <= 2 * cap
    assert 100.0 <= st.p50_us <= 200.0 and 100.0 <= st.p99_us <= 200.0
    a.merge_from(None)                                       # no-op
    assert a.span("x_us").count == 50
