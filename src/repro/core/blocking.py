"""Particle-block sizing: the heuristic DEFAULT schedule, shared by the
Pallas kernels and the jnp async fallback.

This module is the fixed-rule floor under the roofline autotuner
(``repro.core.autotune``): when nothing tunes the schedule,
``pick_block_n`` supplies the block size, and the autotuner uses the same
pick as its fallback candidate and as the anchor of its block-size search
space (``_block_choices``). Tuned solves override it with an explicit
``block_n`` threaded through ``kernels/ops.py`` / ``core.pso.run_async``.

``LANE`` is the TPU vector lane width: kernel block sizes want to be a
multiple of it so a block fills whole [8, 128] tiles. The jnp fallback has
no tile constraint and calls with ``lane=1`` (largest divisor wins,
alignment ignored) — which preserves its pre-unification block choices
bit-for-bit.
"""
from __future__ import annotations

import warnings

LANE = 128

#: Grid-degeneracy guard: a block layout with more than this many blocks
#: (e.g. a prime ``n > target`` whose only small divisor is 1 -> ``n``
#: single-particle blocks) costs more in per-block aggregation and grid
#: steps than any block-size target can save. ``pick_block_n`` then
#: ignores the target and picks the smallest divisor keeping the count
#: under the cap — for a prime ``n`` that is ``n`` itself (one block).
MAX_BLOCK_COUNT = 256


def pick_block_n(n: int, target: int = 512, lane: int = LANE) -> int:
    """Largest divisor of ``n`` that is <= ``target``, preferring
    ``lane``-aligned ones.

    One descending pass: the first ``lane``-aligned (multiple-of-``lane``)
    divisor wins outright; otherwise the first (i.e. largest) divisor of
    any kind is the fallback.  With ``lane=1`` every divisor is "aligned",
    so the largest divisor <= target wins unconditionally.

    Degenerate grids are refused: if the best divisor <= ``target`` would
    shatter ``n`` into more than ``MAX_BLOCK_COUNT`` blocks (a prime
    ``n > target`` is the extreme — its only such divisor is 1), the
    target is overridden by the smallest divisor of ``n`` that keeps the
    block count capped, with a warning. The returned value is therefore
    always a divisor of ``n`` but NOT always <= ``target``.
    """
    best = 1
    for bn in range(min(n, target), 0, -1):
        if n % bn == 0:
            if bn % lane == 0:
                best = bn
                break
            if best == 1:
                best = bn
    if n // best <= MAX_BLOCK_COUNT:
        return best
    # Degenerate: cap the block count. Smallest divisor >= n / cap wins
    # (largest block count still under the cap, i.e. closest to the
    # original target's intent).
    floor = -(-n // MAX_BLOCK_COUNT)                 # ceil(n / cap)
    capped = next(b for b in range(floor, n + 1) if n % b == 0)
    warnings.warn(
        f"pick_block_n({n}, target={target}): best dividing block size "
        f"{best} would give {n // best} single-file blocks (> "
        f"{MAX_BLOCK_COUNT}); overriding the target with block_n={capped} "
        f"({n // capped} block(s)). Pad or resize the swarm to a "
        f"composite particle count to keep blocks near the target.",
        stacklevel=2)
    return capped


def default_block_count(n: int, target: int = 512) -> int:
    """Block COUNT for the jnp async fallback: the largest block size <=
    ``target`` that divides ``n``, alignment-free (``lane=1``), with the
    same ``MAX_BLOCK_COUNT`` degeneracy guard."""
    return n // pick_block_n(n, target, lane=1)
