"""Checkpointing: atomic, sharded, elastic.

Layout: <dir>/step_<k>/shard_<p>.npz + manifest.json, written to a tmp dir
and os.rename()d (atomic on POSIX) so a crash mid-write can never corrupt
the latest checkpoint; `latest_step` scans for complete manifests only.

Elasticity: arrays are saved as *global* logical arrays with their
PartitionSpec recorded. On restore, each array is rebuilt with
``jax.make_array_from_callback`` against the *current* mesh — so a run
checkpointed on 256 chips restores on 64 or 1024 unchanged (the PSO swarm
additionally re-sorts by global particle index, which is layout-free by
construction — DESIGN.md §3).

For multi-host: each process saves only the addressable shards it owns
(process_index-tagged files); restore reads every shard file present. In
this single-process container that degenerates to one file, exercised by
tests/test_checkpoint.py including a simulated-crash restart.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, *,
         extra_meta: Optional[Dict] = None) -> str:
    """Atomic checkpoint write. Returns the final directory path."""
    flat, treedef = _flatten_with_paths(tree)
    pidx = jax.process_index()
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    try:
        arrays = {}
        meta = {"step": step, "dtypes": {}, "treedef": None,
                "extra": extra_meta or {}}
        for name, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            # npz keys may not contain '/', keystr gives dict-ish paths
            key = name.replace("/", "_")
            meta["dtypes"][key] = str(arr.dtype)
            if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
                arr = arr.view(np.uint16)     # npz can't encode bf16
            arrays[key] = arr
        np.savez(os.path.join(tmp, f"shard_{pidx}.npz"), **arrays)
        meta["paths"] = [name for name, _ in flat]
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure (and shardings) of ``template``.

    template: pytree of arrays or ShapeDtypeStructs. shardings: matching
    pytree of NamedShardings (optional; host arrays otherwise).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat, treedef = _flatten_with_paths(template)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (name, tmpl), shd in zip(flat, shard_flat):
        key = name.replace("/", "_")
        arr = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"checkpoint/template shape mismatch at {name}: "
                f"{arr.shape} vs {tmpl.shape}")
        if shd is not None:
            leaf = jax.make_array_from_callback(
                arr.shape, shd, lambda idx, a=arr: a[idx])
        else:
            leaf = jnp.asarray(arr, dtype=tmpl.dtype)
        leaves.append(leaf)
    return jax.tree.unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str, template: Any, shardings: Any = None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, template, shardings)


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
