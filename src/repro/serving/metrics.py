"""Serving observability: latency spans, percentile histograms, counters.

The serving subsystem (``repro.serving.scheduler``, the flush server in
``repro.launch.serve``, and the AOT compile cache) reports everything it
does through one ``ServingMetrics`` object:

* **Spans** — latency samples in microseconds, named by what they cover:
  ``queue_us`` (submit -> admission), ``compile_us`` (building a lane /
  flush program the AOT cache did not have), ``dispatch_us`` (one batched
  device step), ``solve_us`` (admission -> completion) and ``e2e_us``
  (submit -> completion). Each span keeps a bounded reservoir of samples
  and reports count/mean/p50/p99.
* **Counters** — monotonic event counts: ``submitted`` / ``admitted`` /
  ``completed`` / ``failed`` requests, ``dispatches``, ``row_swaps``
  (a freed lane slot re-admitted a fresh request without restarting the
  program — the continuous-batching event), ``tail_ejections`` (a row
  left its lane to finish a sub-chunk remainder standalone),
  ``aot_hits`` / ``aot_misses`` / ``trace_events`` from the compile
  cache, and the batch-fill pair ``lane_slots`` / ``lane_active_slots``.

``batch_fill`` is derived (active / stepped slots — 1.0 means every
dispatched row was real work), and ``snapshot()`` renders the whole
thing as a JSON-able dict so a replica can export its serving state to
disk or over the wire (``dump()``).

Everything here is host-side bookkeeping — no jax imports, no effect on
compiled programs.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional


class LatencyStat:
    """One named latency span: bounded sample reservoir + percentiles.

    Samples beyond ``cap`` overwrite the reservoir round-robin (cheap,
    deterministic, keeps the percentile window recent-ish without a
    wall-clock dependency); ``count``/``total_us`` stay exact.
    """

    def __init__(self, cap: int = 4096):
        self.cap = cap
        self.count = 0
        self.total_us = 0.0
        self._samples: List[float] = []

    def add(self, us: float) -> None:
        us = float(us)
        if len(self._samples) < self.cap:
            self._samples.append(us)
        else:
            self._samples[self.count % self.cap] = us
        self.count += 1
        self.total_us += us

    @property
    def mean_us(self) -> float:
        return self.total_us / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the reservoir (0 when empty)."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        k = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[k]

    @property
    def p50_us(self) -> float:
        return self.percentile(50.0)

    @property
    def p99_us(self) -> float:
        return self.percentile(99.0)

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "mean_us": self.mean_us,
                "p50_us": self.p50_us, "p99_us": self.p99_us}


class ServingMetrics:
    """The serving layer's observability sink: spans + counters.

    One instance is shared by everything serving one replica (scheduler
    lanes, the flush server's ``ServeStats``, the compile cache), so a
    single ``snapshot()`` is the replica's whole serving state.
    """

    def __init__(self, span_cap: int = 4096):
        self._span_cap = span_cap
        self.spans: Dict[str, LatencyStat] = {}
        self.counters: Dict[str, float] = {}
        self.started_at = time.time()

    # -- spans -------------------------------------------------------------
    def span(self, name: str) -> LatencyStat:
        st = self.spans.get(name)
        if st is None:
            st = self.spans[name] = LatencyStat(self._span_cap)
        return st

    def observe(self, name: str, us: float) -> None:
        self.span(name).add(us)

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, k: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + k

    def get(self, name: str) -> float:
        return self.counters.get(name, 0)

    # -- derived -----------------------------------------------------------
    @property
    def batch_fill(self) -> float:
        """Real (request-occupied) rows per dispatched lane slot. 1.0 is a
        perfectly packed scheduler; the flush server reports its own fill
        via ``ServeStats.batch_fill`` (real rows per dispatch)."""
        slots = self.get("lane_slots")
        return self.get("lane_active_slots") / slots if slots else 0.0

    def snapshot(self) -> dict:
        """The whole serving state as a JSON-able dict."""
        return {
            "uptime_s": time.time() - self.started_at,
            "counters": dict(sorted(self.counters.items())),
            "batch_fill": self.batch_fill,
            "spans": {k: v.snapshot()
                      for k, v in sorted(self.spans.items())},
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")

    def prometheus(self, *, prefix: str = "repro",
                   kernel_counters=None) -> str:
        """This sink rendered as a Prometheus text exposition (0.0.4) —
        see ``repro.telemetry.prometheus_text``. ``kernel_counters``
        optionally appends the in-kernel contention counts."""
        from repro.telemetry import prometheus_text
        return prometheus_text(self.snapshot(), prefix=prefix,
                               kernel_counters=kernel_counters)

    def merge_from(self, other: Optional["ServingMetrics"]) -> None:
        """Fold another sink's counts in (e.g. a drained worker's)."""
        if other is None:
            return
        for k, v in other.counters.items():
            self.inc(k, v)
        for k, st in other.spans.items():
            mine = self.span(k)
            for s in st._samples:
                mine.add(s)
            # replayed reservoir may undercount; keep exact totals
            mine.count += st.count - len(st._samples)
            mine.total_us += st.total_us - sum(st._samples)
