"""Gradient-free PSO as an optimizer over model parameters — the paper's
algorithm exposed with the same ergonomics as Adam/SGD (DESIGN.md §3).

Each particle is a full parameter vector; fitness = −loss on the current
batch. Viable for small parameter counts (probes, heads, adapters,
neuroevolution demos) — population × params memory makes it intentionally
NOT a replacement for gradient training of the big assigned archs (see
DESIGN.md §Arch-applicability). Used by examples/quickstart.py and
tests/test_pso_optimizer.py.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.pso import PSOConfig, SwarmState, STEP_FNS, init_swarm


class PSOOptimizer:
    """Flattens a param pytree into the swarm's position space and runs the
    queue-variant PSO steps against a user loss."""

    def __init__(self, params_template: Any, particles: int = 32,
                 span: float = 1.0, w: float = 0.72, c1: float = 1.49,
                 c2: float = 1.49, variant: str = "queue", seed: int = 0):
        leaves, self.treedef = jax.tree.flatten(params_template)
        self.shapes = [l.shape for l in leaves]
        self.sizes = [int(jnp.size(l)) for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        dim = sum(self.sizes)
        self.cfg = PSOConfig(dim=dim, particle_cnt=particles, w=w, c1=c1,
                             c2=c2, fitness="sphere", min_pos=-span,
                             max_pos=span, max_v=0.25 * span).resolved()
        self.step_fn = STEP_FNS[variant]
        self.state = init_swarm(self.cfg, seed)
        # center the swarm on the provided template
        center = self._flatten(params_template)
        self.state = self.state._replace(
            pos=self.state.pos * 0.1 + center[None, :],
            pbest_pos=self.state.pbest_pos * 0.1 + center[None, :],
            gbest_pos=center)

    def _flatten(self, params) -> jnp.ndarray:
        leaves = jax.tree.leaves(params)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves])

    def unflatten(self, vec: jnp.ndarray) -> Any:
        leaves = []
        off = 0
        for shape, size, dt in zip(self.shapes, self.sizes, self.dtypes):
            leaves.append(vec[off:off + size].reshape(shape).astype(dt))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    def step(self, loss_fn: Callable[[Any], jnp.ndarray]) -> float:
        """Evaluate the population, update the swarm. Returns best loss.

        Unlike the built-in step variants (which own their fitness
        function), the external-loss mode evaluates the user loss, applies
        the pbest/gbest updates with the queue predicate, and then advances
        positions WITHOUT re-evaluating any internal fitness.
        """
        from repro.core import rng as crng
        from repro.core.pso import STREAM_R1, STREAM_R2
        fits = -jax.vmap(lambda v: loss_fn(self.unflatten(v)))(self.state.pos)
        s = self.state._replace(fit=fits)
        improved = fits > s.pbest_fit
        pbest_fit = jnp.where(improved, fits, s.pbest_fit)
        pbest_pos = jnp.where(improved[:, None], s.pos, s.pbest_pos)
        if bool(jnp.any(fits > s.gbest_fit)):       # queue predicate (§4.1)
            best = jnp.argmax(pbest_fit)
            s = s._replace(gbest_fit=pbest_fit[best],
                           gbest_pos=pbest_pos[best])
        s = s._replace(pbest_fit=pbest_fit, pbest_pos=pbest_pos)
        # advance (Alg. 1 steps 2 only — no internal fitness)
        cfg = self.cfg
        n, d = s.pos.shape
        it = s.iteration + 1
        idx = jnp.arange(n * d, dtype=jnp.uint32).reshape(n, d)
        r1 = crng.uniform(s.seed, it, STREAM_R1, idx, dtype=s.pos.dtype)
        r2 = crng.uniform(s.seed, it, STREAM_R2, idx, dtype=s.pos.dtype)
        vel = (cfg.w * s.vel + cfg.c1 * r1 * (s.pbest_pos - s.pos)
               + cfg.c2 * r2 * (s.gbest_pos[None] - s.pos))
        vel = jnp.clip(vel, -cfg.max_v, cfg.max_v)
        pos = jnp.clip(s.pos + vel, cfg.min_pos, cfg.max_pos)
        self.state = s._replace(pos=pos, vel=vel, iteration=it)
        return float(-self.state.gbest_fit)

    @property
    def best_params(self):
        return self.unflatten(self.state.gbest_pos)
