"""Multi-chip / multi-pod PSO: the paper's "future work: multi-GPU" built out
to datacenter scale with shard_map.

Design (DESIGN.md §3):
  * Particles are sharded over the ("pod", "data") mesh axes. Each shard runs
    the full per-particle pipeline (advance + fitness + pbest) locally using
    the single-chip step variants — including the Pallas fused kernel when
    enabled.
  * The swarm-global best is the only cross-chip state. Synchronous mode
    (``exchange_interval=1``) all-reduces a scalar ``(fit, argmax-owner)``
    pair every iteration — the collective analogue of the paper's reduction
    kernel, but already minimized to O(1) bytes (8 B) per chip per iteration.
  * Island mode (``exchange_interval=K>1``) is the datacenter analogue of the
    queue-lock idea: shards iterate *asynchronously* against a stale global
    best and publish occasionally. One barrier per K iterations instead of
    per iteration; stragglers only delay the rare exchange, not every step.
  * gbest_pos (O(D) bytes) is broadcast from the winning shard only — via a
    pmax-weighted select, so no gather of positions ever crosses the network
    unless an improvement actually happened (the paper's §5.3 index trick at
    cluster scale).

Elasticity: ``init_sharded_swarm`` builds shard-local particles from global
indices, so a checkpoint taken on 256 chips restores bit-identically on 64 or
1024 (tests/test_distributed.py::test_elastic_reshard_equivalence).

Problems: ``cfg.fitness`` may be a registered name or a first-class
``repro.core.problem.Problem`` — the shard-local step functions evaluate
``cfg.fitness_fn`` (canonical-max form, per-dimension bounds included)
inside shard_map unchanged, so user objectives distribute for free
(tests/test_problem.py::test_distributed_custom_problem).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .pso import PSOConfig, STEP_FNS, SwarmState, init_swarm

Array = jnp.ndarray

# jax moved shard_map to the top level and renamed check_rep -> check_vma in
# newer releases — and not necessarily in the same release, so resolve the
# function and the kwarg spelling independently.
if hasattr(jax, "shard_map"):
    _shard_map_fn = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_fn

import inspect as _inspect

_SM_CHECK_KW = ("check_vma" if "check_vma"
                in _inspect.signature(_shard_map_fn).parameters
                else "check_rep")


def _shard_map(f, mesh, in_specs, out_specs):
    return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **{_SM_CHECK_KW: False})


def swarm_pspec(particle_axes) -> SwarmState:
    """PartitionSpecs for a SwarmState sharded over ``particle_axes``."""
    pa = particle_axes
    return SwarmState(
        pos=P(pa, None), vel=P(pa, None), fit=P(pa),
        pbest_pos=P(pa, None), pbest_fit=P(pa),
        gbest_pos=P(None), gbest_fit=P(), iteration=P(), seed=P(),
    )


def init_sharded_swarm(cfg: PSOConfig, seed: int, mesh: Mesh,
                       particle_axes=("data",)) -> SwarmState:
    """Initialize a swarm laid out over ``mesh`` without materializing it
    densely on one host: each shard constructs only its own slice via the
    counter RNG (index_offset), then the arrays are device_put with the
    swarm sharding."""
    cfg = cfg.resolved()
    axes = (particle_axes,) if isinstance(particle_axes, str) else tuple(particle_axes)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    if cfg.particle_cnt % n_shards:
        raise ValueError(
            f"particle_cnt={cfg.particle_cnt} not divisible by {n_shards} shards")

    def per_shard():
        # Runs under shard_map: build the local slice from global indices.
        shard_id = jax.lax.axis_index(axes)
        local_n = cfg.particle_cnt // n_shards
        local = init_swarm(cfg, seed, n=local_n,
                           index_offset=shard_id * local_n)
        # Reconcile the global best across shards.
        gfit, gpos = _pmax_best(local.gbest_fit, local.gbest_pos, axes)
        return local._replace(gbest_fit=gfit, gbest_pos=gpos)

    specs = swarm_pspec(axes if len(axes) > 1 else axes[0])
    fn = _shard_map(per_shard, mesh, (), specs)
    return jax.jit(fn)()


def _pmax_best(fit: Array, pos: Array, axes) -> Tuple[Array, Array]:
    """All-reduce a (scalar fit, D-dim pos) pair to the global argmax.

    Communicates the scalar twice (max + masked-sum for tie-broken ownership)
    and the position once, only from the winner — O(D) total, not O(N·D).
    """
    gfit = jax.lax.pmax(fit, axes)
    me = jax.lax.axis_index(axes)
    # Tie-break: lowest shard index that achieves the max owns the broadcast.
    winner = jax.lax.pmin(jnp.where(fit >= gfit, me, jnp.iinfo(jnp.int32).max),
                          axes)
    contrib = jnp.where(me == winner, pos, jnp.zeros_like(pos))
    gpos = jax.lax.psum(contrib, axes)
    return gfit, gpos


def make_distributed_run(cfg: PSOConfig, mesh: Mesh, iters: int,
                         variant: str = "queue",
                         exchange_interval: int = 1,
                         particle_axes=("data",),
                         local_step_fn=None):
    """Build a jitted ``run(state) -> state`` over the mesh.

    exchange_interval=1  → synchronous PPSO (reduction-equivalent semantics).
    exchange_interval=K  → island mode: K local iterations per global
                           exchange (queue-lock analogue at scale).
    ``local_step_fn(cfg, state) -> state`` overrides the shard-local step
    (e.g. the Pallas fused kernel from repro.kernels.ops).
    """
    cfg = cfg.resolved()
    axes = (particle_axes,) if isinstance(particle_axes, str) else tuple(particle_axes)
    step = local_step_fn if local_step_fn is not None else STEP_FNS[variant]
    if iters % exchange_interval:
        raise ValueError("iters must be a multiple of exchange_interval")
    rounds = iters // exchange_interval

    def shard_body(state: SwarmState) -> SwarmState:
        def one_round(_, s):
            # K purely-local iterations against the (possibly stale) gbest.
            s = jax.lax.fori_loop(0, exchange_interval,
                                  lambda _, t: step(cfg, t), s)
            # Occasional serialized publication — the "lock" collective.
            gfit, gpos = _pmax_best(s.gbest_fit, s.gbest_pos, axes)
            return s._replace(gbest_fit=gfit, gbest_pos=gpos)

        return jax.lax.fori_loop(0, rounds, one_round, state)

    specs = swarm_pspec(axes if len(axes) > 1 else axes[0])
    fn = _shard_map(shard_body, mesh, (specs,), specs)
    return jax.jit(fn)


def gather_swarm(state: SwarmState) -> SwarmState:
    """Fetch a fully-replicated host copy (for checkpointing / inspection)."""
    return jax.tree.map(lambda x: jax.device_get(x), state)
