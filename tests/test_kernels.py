"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles
(ref.py) and vs the independent library implementation (core/pso.py),
swept over shapes, dims, block sizes and fitness functions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PSOConfig, init_swarm
from repro.core.pso import step_queue
from repro.kernels import ops, ref
from repro.kernels.pso_step import KERNEL_FITNESS, pad_dim


def _oracle_kwargs(cfg, dim):
    kw = ops._cfg_kwargs(cfg)
    kw["d_real"] = dim
    return kw


SHAPE_SWEEP = [
    # (dim, n, block_n) — includes the paper's two regimes (1D, 120D);
    # the two largest interpret-mode shapes ride behind --runslow.
    (1, 128, 128),
    (2, 256, 128),
    (120, 256, 128),
    pytest.param(33, 384, 128,           # non-aligned dim, odd block count
                 marks=pytest.mark.slow),
    pytest.param(1, 1024, 256, marks=pytest.mark.slow),
    pytest.param(120, 512, 512, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("n,expect", [
    (128, 128),        # lane-aligned divisor wins
    (1024, 512),       # largest lane-aligned divisor <= target
    (131072, 512),     # 256 blocks: exactly at the degeneracy cap, kept
    (384, 384),        # 384 = 3*128: lane-aligned
    (100, 100),        # no lane-aligned divisor: largest divisor <= target
    (96, 96),
    (7, 7),            # prime <= target: itself
    (33, 33),          # odd composite <= target: itself
    (1, 1),
])
def test_pick_block_n(n, expect):
    bn = ops.pick_block_n(n)
    assert bn == expect
    assert n % bn == 0 and bn <= max(512, 1)


@pytest.mark.parametrize("n,expect,count", [
    (1009, 1009, 1),     # prime > target: only small divisor is 1 -> one
    #                      whole-swarm block, not 1009 single-file blocks
    (2 * 521, 521, 2),   # 1042: best divisor <= 512 is 2 (521 blocks);
    #                      the cap overrides the target with 521
    (3 * 521, 521, 3),   # 1563: best divisor <= 512 is 3 (521 blocks);
    #                      both prime factors exceed the target
])
def test_pick_block_n_degenerate_grid_capped(n, expect, count):
    from repro.core.blocking import MAX_BLOCK_COUNT
    with pytest.warns(UserWarning, match="single-file blocks"):
        bn = ops.pick_block_n(n)
    assert bn == expect
    assert n % bn == 0 and n // bn == count <= MAX_BLOCK_COUNT
    # the jnp fallback's block COUNT inherits the same guard
    from repro.core.blocking import default_block_count
    with pytest.warns(UserWarning, match="single-file blocks"):
        assert default_block_count(n) == count


def test_pick_block_n_prefers_lane_alignment_over_size():
    # 640 = 5*128: both 320 (bigger, unaligned) and 128 (aligned) divide;
    # the lane-aligned one must win even though it is smaller... except 640
    # itself is unaligned; largest aligned divisor <= 512 is 128.
    assert ops.pick_block_n(640) == 128


def test_explicit_block_n_must_divide():
    cfg = PSOConfig(dim=2, particle_cnt=128, fitness="cubic").resolved()
    s = init_swarm(cfg, 0)
    with pytest.raises(ValueError, match="divisor"):
        ops.run_queue_lock_fused(cfg, s, iters=1, block_n=100)
    with pytest.raises(ValueError, match="divisor"):
        ops.queue_step(cfg, s, block_n=3)


@pytest.mark.parametrize("dim,n,bn", SHAPE_SWEEP)
@pytest.mark.parametrize("fitness", ["cubic", "rastrigin", "rosenbrock"])
def test_queue_kernel_vs_oracle(dim, n, bn, fitness):
    cfg = PSOConfig(dim=dim, particle_cnt=n, fitness=fitness).resolved()
    s = init_swarm(cfg, 42)
    out = ops.queue_step(cfg, s, block_n=bn)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s, dim)
    kw = _oracle_kwargs(cfg, dim)
    fitness_name = kw.pop("fitness")
    o_pos, o_vel, o_pbp, o_pbf, o_gp, o_gf, aux_f, aux_i = ref.queue_step_oracle(
        int(s.seed), int(s.iteration), pos, vel, pbp, pbf, gp, float(gf[0]),
        bn, fitness=fitness_name, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, dim)),
                               np.asarray(o_pos), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.vel, dim)),
                               np.asarray(o_vel), rtol=1e-5, atol=1e-5)
    # atol: |∂f/∂x| ~ 3·max_pos² for cubic ⇒ 1 ulp of pos ≈ 0.25 in fit
    np.testing.assert_allclose(np.asarray(out.pbest_fit),
                               np.asarray(o_pbf)[0], rtol=1e-5, atol=0.5)
    # atol: rosenbrock's optimum is 0, so a 1-ulp compiled-vs-eager fitness
    # difference is unbounded in relative terms near convergence
    np.testing.assert_allclose(float(out.gbest_fit), float(o_gf),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dim,n,bn", SHAPE_SWEEP)
def test_fused_kernel_vs_oracle(dim, n, bn):
    iters = 5
    cfg = PSOConfig(dim=dim, particle_cnt=n, fitness="cubic").resolved()
    s = init_swarm(cfg, 7)
    out = ops.run_queue_lock_fused(cfg, s, iters=iters, block_n=bn)
    scal, pos, vel, pbp, pbf, gp, gf = ops.state_to_kernel(s, dim)
    kw = _oracle_kwargs(cfg, dim)
    fitness_name = kw.pop("fitness")
    o = ref.run_fused_oracle(int(s.seed), int(s.iteration), pos, vel, pbp,
                             pbf, gp, float(gf[0]), iters, bn,
                             fitness=fitness_name, **kw)
    np.testing.assert_allclose(np.asarray(ops.pack_dmajor(out.pos, dim)),
                               np.asarray(o[0]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(out.gbest_fit), float(o[5]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out.gbest_pos),
                               np.asarray(o[4])[:dim, 0],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fitness", list(KERNEL_FITNESS))
def test_kernel_fitness_matches_library(fitness):
    """_fitness_dmajor must agree with repro.core.fitness row-for-row."""
    from repro.core.fitness import FITNESS_FNS
    from repro.kernels.pso_step import _fitness_dmajor
    rng = np.random.default_rng(1)
    for d in (1, 2, 17, 120):
        n = 128
        pos = rng.uniform(-5, 5, size=(n, d)).astype(np.float32)
        want = np.asarray(FITNESS_FNS[fitness](jnp.asarray(pos)))
        packed = ops.pack_dmajor(jnp.asarray(pos), d)
        dmask = (np.arange(pad_dim(d)) < d)[:, None] & np.ones((1, n), bool)
        got = np.asarray(_fitness_dmajor(fitness, packed,
                                         jnp.asarray(dmask), d))[0]
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_queue_kernel_matches_library_step():
    """Kernel (interpret) vs the independent [N,D]-layout library step."""
    cfg = PSOConfig(dim=120, particle_cnt=256, fitness="cubic").resolved()
    s = init_swarm(cfg, 0)
    k = ops.queue_step(cfg, s, block_n=128)
    j = step_queue(cfg, s)
    np.testing.assert_allclose(np.asarray(k.pos), np.asarray(j.pos),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(k.pbest_fit),
                               np.asarray(j.pbest_fit), rtol=1e-4, atol=1.0)
    # gbest: kernel uses (queue ∘ blocks) argmax — same value as global argmax
    np.testing.assert_allclose(float(k.gbest_fit), float(j.gbest_fit),
                               rtol=1e-5)


def test_fused_kernel_converges_120d():
    cfg = PSOConfig(dim=120, particle_cnt=512, fitness="cubic", w=0.9).resolved()
    s = init_swarm(cfg, 0)
    f0 = float(s.gbest_fit)
    out = ops.run_queue_lock_fused(cfg, s, iters=150, block_n=128)
    assert float(out.gbest_fit) > f0
    # 120D cubic optimum = 120 * 900000
    assert float(out.gbest_fit) > 0.55 * 120 * 900000.0
    assert not np.any(np.isnan(np.asarray(out.pos)))


def test_fused_iteration_counter_chains():
    """Two fused calls of k iters == one call of 2k iters (RNG continuity)."""
    cfg = PSOConfig(dim=9, particle_cnt=128, fitness="sphere").resolved()
    s = init_swarm(cfg, 13)
    a = ops.run_queue_lock_fused(cfg, s, iters=4, block_n=128)
    a = ops.run_queue_lock_fused(cfg, a, iters=4, block_n=128)
    b = ops.run_queue_lock_fused(cfg, s, iters=8, block_n=128)
    np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos),
                               rtol=1e-5, atol=1e-5)
    assert int(a.iteration) == int(b.iteration) == 8
