"""Pluggable per-particle update rules — the algorithm half of the
kernel/engine split.

The paper's contribution is the queue-lock *aggregation* scaffold
(intra-block candidate queue, block-local bests, sparse publication);
the per-particle *update rule* is orthogonal. This module is the seam:
an :class:`UpdateRule` is a small frozen spec whose ``advance`` is a
pure elementwise function of the two per-(particle, dim) uniform draws
and the swarm tensors. Because it is elementwise and broadcast-clean it
serves **both** layouts unchanged:

- the Pallas kernels' D-major blocks (``[Dpad, block_n]`` tiles with a
  ``[Dpad, 1]`` gbest column — ``kernels/pso_step.py``), and
- the jnp engine's particle-major arrays (``[N, D]`` with a ``[1, D]``
  or ``[N, D]`` attractor — ``core/pso.py``).

Rules are registered by name in :data:`UPDATE_RULES` and selected via
``PSOConfig(update_rule=...)`` / ``Method(rule=...)``. All shipped rules
draw exactly two uniforms per (particle, dim) from the counter-based RNG
streams ``STREAM_R1``/``STREAM_R2``, so swapping the rule changes *no*
RNG bookkeeping anywhere in the stack; a custom rule that needs fewer
draws simply ignores an operand (the draw cost is priced per rule in
``roofline/pso_cost.py`` via :attr:`UpdateRule.rng_draws`).

Shipped rules:

``pso``
    The canonical inertia-weight velocity update (the pre-refactor
    ``_advance_block`` chain, bit-identical):
    ``v' = w v + c1 r1 (pbest - x) + c2 r2 (gbest - x)`` clipped to
    ``±max_v``; ``x' = clip(x + v', lo, hi)``.

``sso``
    Simplified Swarm Optimization (arXiv 2110.01470): velocity-free
    three-way probabilistic component copy. Per component, draw ``r1``
    and copy from gbest (``r1 < cg``), pbest (``< cg+cp``), keep the
    current value (``< cg+cp+cw``), or resample uniformly in the box
    using ``r2``. ``w``/``c1``/``c2`` are ignored; velocity passes
    through untouched.

``lowcost``
    Low-complexity PSO (arXiv 1401.0546): multiply-free update for
    time-critical serving lanes. The stochastic scaling multiplies are
    replaced by Bernoulli(1/2) *selection* of the difference terms:
    ``v' = v + [r1 < 1/2](pbest - x) + [r2 < 1/2](gbest - x)`` with the
    usual velocity/position clips.

Registering a custom rule (see docs/variants.md): subclass
:class:`UpdateRule` as a frozen dataclass, implement ``advance`` with
broadcast-clean elementwise ops only (no reductions, no layout
assumptions beyond "``gp`` broadcasts against ``pos``"), and add an
instance to :data:`UPDATE_RULES`. Every variant — jnp and kernel, sync
and async, uniform and heterogeneous — picks it up through the shared
scaffolds; only such elementwise rules are kernel-eligible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UpdateRule:
    """Frozen spec for one per-particle update rule.

    ``advance`` receives the two uniform draws (``r1``/``r2``, already
    shaped like ``pos``), the swarm tensors, and the resolved static
    coefficients/bounds, and returns the new ``(pos, vel)`` BEFORE any
    projection hook or sublane masking — those belong to the scaffold,
    not the rule. ``mv``/``lo``/``hi`` are scalars or per-dim columns
    that broadcast against ``pos`` (both layouts arrange this).

    Frozen + hashable so a rule can ride jit-static config objects;
    ``rng_draws`` feeds the roofline cost model's per-rule op mix.
    """

    name: str = "pso"
    #: uniform draws consumed per (particle, dim) per iteration
    rng_draws: int = 2
    #: elementwise rules lower into the Pallas scaffolds unmodified
    kernel_eligible: bool = True

    def advance(self, r1, r2, pos, vel, pbp, gp, *, w, c1, c2, mv, lo, hi
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PSORule(UpdateRule):
    """Canonical inertia-weight PSO — the default rule.

    The op chain below is the pre-refactor ``_advance_block`` body
    verbatim; the committed trajectory digests
    (tests/test_problem.py) pin it bit-identical.
    """

    def advance(self, r1, r2, pos, vel, pbp, gp, *, w, c1, c2, mv, lo, hi):
        vel = (w * vel + c1 * r1 * (pbp - pos) + c2 * r2 * (gp - pos))
        vel = jnp.clip(vel, -mv, mv)
        pos = jnp.clip(pos + vel, lo, hi)
        return pos, vel


@dataclasses.dataclass(frozen=True)
class SSORule(UpdateRule):
    """Simplified Swarm Optimization: three-way probabilistic copy.

    ``cg``/``cp``/``cw`` are the cumulative copy thresholds (gbest,
    pbest, keep); the residual ``1 - cg - cp - cw`` probability
    resamples the component uniformly in ``[lo, hi)`` from ``r2``.
    Velocity is not part of the algorithm and passes through.
    """

    cg: float = 0.4
    cp: float = 0.3
    cw: float = 0.2

    def advance(self, r1, r2, pos, vel, pbp, gp, *, w, c1, c2, mv, lo, hi):
        fresh = lo + (hi - lo) * r2
        pos = jnp.where(
            r1 < self.cg, gp,
            jnp.where(r1 < self.cg + self.cp, pbp,
                      jnp.where(r1 < self.cg + self.cp + self.cw, pos,
                                fresh)))
        pos = jnp.clip(pos, lo, hi)
        return pos, vel


@dataclasses.dataclass(frozen=True)
class LowCostRule(UpdateRule):
    """Low-complexity PSO: Bernoulli-selected difference terms, no
    stochastic multiplies on the hot path."""

    def advance(self, r1, r2, pos, vel, pbp, gp, *, w, c1, c2, mv, lo, hi):
        zero = jnp.zeros_like(pos)
        vel = (vel + jnp.where(r1 < 0.5, pbp - pos, zero)
               + jnp.where(r2 < 0.5, gp - pos, zero))
        vel = jnp.clip(vel, -mv, mv)
        pos = jnp.clip(pos + vel, lo, hi)
        return pos, vel


UPDATE_RULES: Dict[str, UpdateRule] = {
    "pso": PSORule("pso"),
    "sso": SSORule("sso"),
    "lowcost": LowCostRule("lowcost"),
}

#: block-neighborhood topologies for the async variant's local-best pull
TOPOLOGIES: Tuple[str, ...] = ("gbest", "ring", "vonneumann")


def rule_names() -> Tuple[str, ...]:
    return tuple(sorted(UPDATE_RULES))


def resolve_rule(rule) -> UpdateRule:
    """Name or instance -> :class:`UpdateRule` (raises with the full
    valid-name enumeration otherwise)."""
    if isinstance(rule, UpdateRule):
        return rule
    got = UPDATE_RULES.get(rule)
    if got is None:
        raise ValueError(
            f"unknown update rule {rule!r}; one of {rule_names()} "
            f"(register custom rules in repro.core.update_rules."
            f"UPDATE_RULES)")
    return got
